from repro.optim.optimizers import (adamw_init, adamw_update, clip_grads,
                                    init_opt, opt_update, sgd_init, sgd_update)

__all__ = ["adamw_init", "adamw_update", "clip_grads", "init_opt",
           "opt_update", "sgd_init", "sgd_update"]
