"""Optimizers. SGD(momentum) matches the paper's §IV hyperparameters
(lr=0.01, momentum=0.5, dampening=0, weight_decay=0, nesterov=False) with
PyTorch SGD semantics (buf = μ·buf + (1−damp)·g ; p −= lr·buf). AdamW is
the LLM-config default. States mirror params (same sharding specs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


# -- SGD (paper) -------------------------------------------------------------

def sgd_init(params, dtype=jnp.float32):
    return {"momentum": jax.tree.map(lambda p: jnp.zeros_like(p, dtype),
                                     params)}


def sgd_update(params, grads, state, tc: TrainConfig):
    def upd(p, g, buf):
        g = g.astype(jnp.float32)
        if tc.weight_decay:
            g = g + tc.weight_decay * p.astype(jnp.float32)
        bdt = buf.dtype
        buf = (tc.momentum * buf.astype(jnp.float32) + (1.0 - tc.dampening) * g)
        step = (g + tc.momentum * buf) if tc.nesterov else buf
        return ((p.astype(jnp.float32) - tc.lr * step).astype(p.dtype),
                buf.astype(bdt))

    flat = jax.tree.map(upd, params, grads, state["momentum"])
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_buf = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"momentum": new_buf}


# -- AdamW -------------------------------------------------------------------

def adamw_init(params, dtype=jnp.float32):
    z = lambda p: jnp.zeros_like(p, dtype)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, tc: TrainConfig):
    count = state["count"] + 1
    b1, b2 = tc.adam_b1, tc.adam_b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def bc(c, x):
        """count may carry a leading worker dim — broadcast to x's rank."""
        return c.reshape(c.shape + (1,) * (x.ndim - c.ndim)) if c.ndim else c

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        mdt, vdt = m.dtype, v.dtype
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        step = (m / bc(c1, m)) / (jnp.sqrt(v / bc(c2, v)) + tc.adam_eps)
        if tc.weight_decay:
            step = step + tc.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - tc.lr * step).astype(p.dtype),
                m.astype(mdt), v.astype(vdt))

    flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
    pick = lambda i: jax.tree.map(lambda t: t[i], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), {"m": pick(1), "v": pick(2), "count": count}


# -- dispatch ------------------------------------------------------------------

def init_opt(params, tc: TrainConfig):
    dt = jnp.dtype(tc.opt_dtype)
    return (sgd_init(params, dt) if tc.optimizer == "sgd"
            else adamw_init(params, dt))


def opt_update(params, grads, state, tc: TrainConfig):
    if tc.optimizer == "sgd":
        return sgd_update(params, grads, state, tc)
    return adamw_update(params, grads, state, tc)


def clip_grads(grads, max_norm: float):
    if not max_norm:
        return grads
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(jnp.sqrt(sq), 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)
