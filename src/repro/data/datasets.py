"""Federated data pipeline.

No MNIST on disk in this container, so the paper-repro path uses a
deterministic synthetic MNIST surrogate: 10 class-conditional 28×28
stroke-like prototypes + per-sample elastic noise/shift. The paper's claims
are about *consistency across worker counts / blockchain on-off*, which is
preserved under the surrogate (absolute accuracy differs; noted in
DESIGN.md §9).

Partitioners: IID shards and Dirichlet(α) non-IID label skew — the
geographic-cluster data-similarity of the paper's §III.B maps to assigning
adjacent Dirichlet components to workers in the same cluster.

LM path: deterministic synthetic token streams (mixture of n-gram-ish
pattern generators) for the assigned-architecture smoke/e2e runs.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


# -- synthetic MNIST surrogate -------------------------------------------------

def _digit_prototypes(image_size: int = 28) -> np.ndarray:
    """(10, H, W) smooth class-conditional patterns (fixed, deterministic)."""
    rng = np.random.default_rng(1234)
    protos = []
    yy, xx = np.mgrid[0:image_size, 0:image_size] / (image_size - 1)
    for c in range(10):
        freq_x, freq_y = 1 + c % 4, 1 + (c // 3) % 4
        phase = c * 0.7
        base = (np.sin(2 * np.pi * freq_x * xx + phase)
                * np.cos(2 * np.pi * freq_y * yy - phase))
        blob = np.exp(-(((xx - 0.3 - 0.05 * c) ** 2 + (yy - 0.5) ** 2) / 0.05))
        protos.append(0.6 * base + 0.8 * blob + 0.05 * rng.standard_normal(base.shape))
    return np.stack(protos).astype(np.float32)


_PROTOS = None


def synthetic_mnist(n: int, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns images (n, 28, 28, 1) float32 in [0,1]-ish, labels (n,)."""
    global _PROTOS
    if _PROTOS is None:
        _PROTOS = _digit_prototypes()
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n)
    base = _PROTOS[labels]
    shift = rng.integers(-2, 3, size=(n, 2))
    imgs = np.empty_like(base)
    for i in range(n):                                     # small n; fine on host
        imgs[i] = np.roll(base[i], tuple(shift[i]), axis=(0, 1))
    imgs = imgs + 0.35 * rng.standard_normal(imgs.shape).astype(np.float32)
    return imgs[..., None].astype(np.float32), labels.astype(np.int32)


# -- federated partitioners ----------------------------------------------------

def partition_iid(n: int, num_workers: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return np.array_split(perm, num_workers)


def partition_dirichlet(labels: np.ndarray, num_workers: int, alpha: float,
                        seed: int = 0) -> List[np.ndarray]:
    """Label-skewed non-IID split (Dirichlet over workers per class)."""
    rng = np.random.default_rng(seed)
    out: List[List[int]] = [[] for _ in range(num_workers)]
    for c in np.unique(labels):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * num_workers)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for w, part in enumerate(np.split(idx, cuts)):
            out[w].extend(part.tolist())
    return [np.array(sorted(x), dtype=np.int64) for x in out]


class FederatedDataset:
    """Per-worker shards with equal-size round batches (pad by resampling)."""

    def __init__(self, images: np.ndarray, labels: np.ndarray,
                 assignments: List[np.ndarray], seed: int = 0) -> None:
        self.images, self.labels = images, labels
        self.assignments = assignments
        self.rng = np.random.default_rng(seed)

    @property
    def num_workers(self) -> int:
        return len(self.assignments)

    def worker_batch(self, w: int, batch_size: int) -> Dict[str, np.ndarray]:
        idx = self.assignments[w]
        take = self.rng.choice(idx, size=batch_size, replace=len(idx) < batch_size)
        return {"images": self.images[take], "labels": self.labels[take]}

    def round_batches(self, batch_size: int) -> Dict[str, np.ndarray]:
        """Stacked (W, B, ...) batch for the vmapped FL step."""
        batches = [self.worker_batch(w, batch_size) for w in range(self.num_workers)]
        return {k: np.stack([b[k] for b in batches]) for k in batches[0]}

    def eval_batch(self, n: int = 512) -> Dict[str, np.ndarray]:
        take = self.rng.choice(len(self.labels), size=min(n, len(self.labels)),
                               replace=False)
        return {"images": self.images[take], "labels": self.labels[take]}


def make_federated_mnist(num_workers: int, *, samples: int = 4096,
                         non_iid_alpha: float = 0.0, seed: int = 0) -> FederatedDataset:
    imgs, labels = synthetic_mnist(samples, seed=seed)
    if non_iid_alpha > 0:
        parts = partition_dirichlet(labels, num_workers, non_iid_alpha, seed)
    else:
        parts = partition_iid(samples, num_workers, seed)
    return FederatedDataset(imgs, labels, parts, seed=seed + 1)


# -- synthetic LM token streams --------------------------------------------------

def synthetic_tokens(num_workers: int, batch: int, seq: int, vocab: int,
                     seed: int = 0) -> Dict[str, np.ndarray]:
    """(W, B, S) learnable-but-nontrivial token streams: each worker has its
    own Markov-ish generator (cluster data similarity analogue)."""
    rng = np.random.default_rng(seed)
    toks = np.empty((num_workers, batch, seq), np.int32)
    for w in range(num_workers):
        period = 3 + (w % 5)
        base = rng.integers(0, vocab, size=(batch, period))
        reps = -(-seq // period)
        stream = np.tile(base, (1, reps))[:, :seq]
        noise = rng.random((batch, seq)) < 0.1
        stream = np.where(noise, rng.integers(0, vocab, size=(batch, seq)), stream)
        toks[w] = stream
    return {"tokens": toks, "labels": toks.copy()}
