"""zamba2-7b — Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, conv_width=4, expand=2, chunk_size=128),
    shared_attn_every=6,      # one *shared* attention+MLP block, applied every 6th layer
    source="arXiv:2411.15242",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512,
        vocab_size=512, ssm=SSMConfig(state_dim=16, conv_width=4, expand=2, chunk_size=64),
        shared_attn_every=2,
    )
