"""yi-6b — llama-arch GQA dense. [arXiv:2403.04652]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
                          d_ff=512, vocab_size=512)
