"""--arch <id> registry: maps arch ids to config modules."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, INPUT_SHAPES, ShapeConfig

_ARCH_MODULES = {
    "zamba2-7b":        "repro.configs.zamba2_7b",
    "smollm-135m":      "repro.configs.smollm_135m",
    "chameleon-34b":    "repro.configs.chameleon_34b",
    "whisper-base":     "repro.configs.whisper_base",
    "xlstm-1.3b":       "repro.configs.xlstm_1_3b",
    "qwen2-moe-a2.7b":  "repro.configs.qwen2_moe_a2_7b",
    "olmoe-1b-7b":      "repro.configs.olmoe_1b_7b",
    "yi-6b":            "repro.configs.yi_6b",
    "minicpm3-4b":      "repro.configs.minicpm3_4b",
    "h2o-danube-1.8b":  "repro.configs.h2o_danube_1_8b",
    "paper-net":        "repro.configs.paper_net",
}

ARCH_IDS = [a for a in _ARCH_MODULES if a != "paper-net"]


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).smoke_config()


def get_shape(shape: str) -> ShapeConfig:
    return INPUT_SHAPES[shape]


def applicable(arch: str, shape: str) -> tuple[bool, str]:
    """Whether (arch, shape) is exercised; reason when skipped (DESIGN.md §5)."""
    cfg = get_config(arch)
    sh = get_shape(shape)
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch: 524k decode requires sub-quadratic attention (skip per spec)"
    if sh.kind == "decode" and cfg.family == "cnn":
        return False, "cnn classifier has no decode step"
    return True, ""
