"""Config dataclasses for the SDFL-B framework.

Every assigned architecture gets a module in this package exporting
``CONFIG: ModelConfig`` (full-size, dry-run only) and ``smoke_config()``
(reduced variant instantiable on CPU). ``repro.configs.registry`` maps
``--arch <id>`` to these.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""
    num_experts: int = 0            # routed experts
    top_k: int = 0
    d_ff_expert: int = 0            # per-expert hidden dim
    num_shared_experts: int = 0     # always-on shared experts
    d_ff_shared: int = 0            # per-shared-expert hidden dim
    router_aux_loss: float = 0.01   # load-balance loss coefficient
    router_z_loss: float = 0.001
    capacity_factor: float = 1.25   # GShard-style capacity (tokens dropped
                                    # beyond C = ceil(k·T/E·cf))

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """State-space (Mamba2 / xLSTM) block configuration."""
    state_dim: int = 0              # N: per-channel state size (Mamba2) / head state (mLSTM)
    conv_width: int = 4
    expand: int = 2                 # inner dim = expand * d_model
    num_ssm_heads: int = 0          # Mamba2 SSD heads (0 => derived)
    chunk_size: int = 256           # SSD chunked-scan block length

    @property
    def enabled(self) -> bool:
        return self.state_dim > 0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek/MiniCPM3-style) configuration."""
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    @property
    def enabled(self) -> bool:
        return self.kv_lora_rank > 0


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. ``family`` selects the block builder:

    dense  : pre-norm decoder-only transformer (llama-style)
    moe    : dense attention + MoE MLP
    ssm    : xLSTM (mLSTM/sLSTM mix) or pure-Mamba2 stacks
    hybrid : Mamba2 backbone + shared attention block (zamba2)
    vlm    : dense decoder consuming early-fused token+patch embeddings
    audio  : encoder-decoder consuming stub mel-frame embeddings (whisper)
    cnn    : the paper's own MNIST Net (conv1/conv2/dropout/fc1/fc2)
    """
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                       # 0 => d_model // num_heads
    # --- attention flavor ---
    attn_type: str = "gqa"                  # gqa | mla | swa
    window: int = 0                         # SWA window (attn_type == "swa")
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- sub-configs ---
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    # --- hybrid (zamba2): shared attention block every k-th layer ---
    shared_attn_every: int = 0              # 0 => no shared block
    # --- xLSTM: put an sLSTM block every k-th layer (rest mLSTM) ---
    slstm_every: int = 0
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500                 # mel-frame count (stub frontend output)
    # --- vlm (chameleon): stub patch-embedding frontend ---
    num_patch_tokens: int = 0               # patches prepended per sample
    # --- paper CNN ---
    image_size: int = 28
    num_classes: int = 10
    cnn_channels: Tuple[int, int] = (10, 20)
    # --- numerics / citation ---
    dtype: str = "bfloat16"
    source: str = ""                        # citation bracket from the assignment

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode path exists (SSM state or sliding window)."""
        return self.family in ("ssm", "hybrid") or self.attn_type == "swa"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned input shape. ``kind`` picks train_step vs serve_step."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                               # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


@dataclass(frozen=True)
class FederationConfig:
    """SDFL-B protocol configuration (the paper's technique)."""
    task_id: str = "task-0"                 # name of this task on a (possibly
                                            # multi-tenant) chain node — keys
                                            # its contract's commits in
                                            # multi-task blocks
    num_clusters: int = 4
    workers_per_cluster: int = 4            # data axis = clusters * workers
    # Algorithm 1 economics
    requester_deposit: float = 1000.0       # D
    worker_stake: float = 10.0              # F
    penalty_pct: float = 50.0               # P (percent of F)
    trust_threshold: float = 0.5            # T on the normalized score
    top_k_rewarded: int = 4                 # k
    # trust score blend (EvaluatePerformance): cosine, norm-dev, loss terms
    w_cosine: float = 0.5
    w_norm: float = 0.3
    w_loss: float = 0.2
    # trust weighting of aggregation (0 => paper-faithful hard filter only)
    soft_trust_weighting: bool = True
    # async functionality
    async_mode: bool = False
    staleness_alpha: float = 0.5            # weight = 1 / (1 + staleness)**alpha
    buffer_size: int = 8                    # FedBuff-style buffer capacity; on
                                            # the event-driven node this is the
                                            # per-task arrival-buffer size an
                                            # aggregation event waits for
    max_wait: float = float("inf")          # event-driven node: max simulated
                                            # seconds an aggregation event
                                            # waits for the buffer to fill
                                            # before sealing whatever cohort
                                            # arrived (inf = fill the buffer)
    # aggregation topology
    mode: str = "allreduce"                 # "allreduce" | "head_gather" (paper-faithful)
    head_rotation_seed: int = 0
    fused_trust_path: str = "auto"          # flat-pack + fused Pallas trust
                                            # round (kernels.fused_round):
                                            # the cohort's updates pack into
                                            # ONE (W, D) matrix and trust
                                            # stats + weighted aggregation
                                            # run in two streamed HBM passes
                                            # instead of ~5 per-leaf sweeps.
                                            # "auto" engages for unsharded
                                            # flat/CNN param trees (uniform
                                            # leaf dtype, no mesh
                                            # constraints); "on" forces it
                                            # (errors on unpackable trees);
                                            # "off" keeps the per-leaf
                                            # reference path everywhere.
                                            # Value-equivalent to every
                                            # aggregation ``mode`` (the
                                            # hierarchy telescopes)
    # chain-layer scaling knobs
    merkle_chunk_size: int = 64             # settlement records per Merkle
                                            # leaf (commit hashes ~2W/k nodes;
                                            # proofs O(log(W/k)) + k)
    pipeline_depth: int = 2                 # pending rounds the background
                                            # settler may hold (0 = settle
                                            # inline on the training thread)
    settlement_shards: int = 1              # contract shards per round: slices
                                            # settle + hash their own Merkle
                                            # subtree in parallel under one
                                            # cross-shard super-root (subtree-
                                            # aligned, so block hashes are
                                            # shard-count independent)
    settler_pool_size: int = 0              # shard-worker threads draining the
                                            # per-shard queues (0 = auto:
                                            # min(settlement_shards, cpus),
                                            # spawned only when the leaf-size
                                            # gate could feed them; an explicit
                                            # size forces the spawn; effective
                                            # only with pipeline_depth > 0 and
                                            # shards > 1). On a multi-tenant
                                            # ChainNode the pool is shared:
                                            # node-level sizing takes the max
                                            # shard count across tasks
    sparse_settlement: bool = False         # settle rounds as incremental
                                            # DeltaCommits over the full
                                            # population: only the round's
                                            # changed records (the workers
                                            # that participated, per the
                                            # participation mask) re-hash —
                                            # O(C·log(W/k)) per round instead
                                            # of O(W/k) — while every block
                                            # still commits (and proves) all
                                            # W workers' latest records. The
                                            # million-worker mode; block
                                            # hashes differ from the dense
                                            # path (full-population root)
    sparse_rebase_every: int = 0            # re-anchor the delta chain with a
                                            # dense full-population commit
                                            # every N sparse rounds (0 = only
                                            # when forced: first round, after
                                            # enrollment growth, or full
                                            # participation). Bounds deep-
                                            # verify replay depth and the
                                            # overlay-chain walk of audits


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 0.01                        # paper: SGD lr=0.01
    momentum: float = 0.5                   # paper: momentum=0.5
    dampening: float = 0.0
    weight_decay: float = 0.0
    nesterov: bool = False
    optimizer: str = "sgd"                  # "sgd" (paper) | "adamw" (LLM configs)
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    grad_clip: float = 0.0
    local_steps: int = 1                    # local SGD steps per FL round
    remat: bool = True
    seed: int = 0
    opt_dtype: str = "float32"              # optimizer-state dtype ("bfloat16"
                                            # for the biggest archs: memory fit)
    kv_chunk: int = 512                     # flash-attention KV chunk (train)
