"""chameleon-34b — early-fusion VLM, VQ image tokens. [arXiv:2405.09818]

The vision side is the spec'd stub: ``input_specs`` provides precomputed
VQ patch-token *embeddings* which are early-fused (concatenated) into the
text token stream; the language decoder below is the real implementation.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    num_patch_tokens=256,     # stub VQ frontend: 256 patch embeddings per sample
    source="arXiv:2405.09818",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
                          d_ff=512, vocab_size=512, num_patch_tokens=16)
