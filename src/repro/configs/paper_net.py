"""The paper's own model: MNIST 'Net' — conv1, conv2, conv2_drop, fc1, fc2.

Matches §IV of the paper (the architecture printed as a TorchScript module)
and its hyperparameters: SGD(lr=0.01, momentum=0.5, dampening=0, wd=0,
nesterov=False). Used by the paper-faithful reproduction path.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-net",
    family="cnn",
    num_layers=2,             # conv layers
    d_model=50,               # fc1 hidden width (LeNet-style Net uses 50)
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=0,
    image_size=28,
    num_classes=10,
    cnn_channels=(10, 20),
    dtype="float32",
    source="DOI 10.1109/UEMCON59035.2023.10316006 §IV",
)


def smoke_config() -> ModelConfig:
    return CONFIG
