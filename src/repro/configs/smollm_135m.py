"""smollm-135m — llama-arch small dense. [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=288, num_heads=6, num_kv_heads=2,
                          d_ff=512, vocab_size=512)
