"""olmoe-1b-7b — 64 experts top-8 MoE. [arXiv:2409.02060]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,                # per-expert hidden dim
    vocab_size=50304,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
    source="arXiv:2409.02060",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
    )
