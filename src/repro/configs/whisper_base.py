"""whisper-base — enc-dec audio, conv frontend stubbed. [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is the spec'd stub:
``input_specs`` provides precomputed frame embeddings (encoder_seq, d_model).
Encoder + decoder transformers are real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,             # decoder layers
    encoder_layers=6,
    encoder_seq=1500,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    source="arXiv:2212.04356",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, encoder_layers=2, encoder_seq=64,
                          d_model=256, num_heads=4, num_kv_heads=4,
                          d_ff=512, vocab_size=512)
