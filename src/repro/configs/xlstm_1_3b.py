"""xlstm-1.3b — sLSTM + mLSTM blocks. [arXiv:2405.04517]

d_ff=0 per the assignment: xLSTM blocks carry their own up/down projections
(pre-up-projection mLSTM, post-up-projection sLSTM) instead of a separate MLP.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm=SSMConfig(state_dim=512, conv_width=4, expand=2, num_ssm_heads=4,
                  chunk_size=256),   # Q=1024 (=sqrt(dk*dv)) tried in §Perf
                                     # H9: no peak-memory win — refuted
    slstm_every=8,            # every 8th block is sLSTM, rest mLSTM (≈7:1 mix)
    source="arXiv:2405.04517",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, vocab_size=512,
        ssm=SSMConfig(state_dim=64, conv_width=4, expand=2, num_ssm_heads=4,
                      chunk_size=64),
        slstm_every=2,
    )
