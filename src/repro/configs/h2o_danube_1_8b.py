"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention. [arXiv:2401.16818]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    attn_type="swa",
    window=4096,              # mistral-style sliding window
    source="arXiv:2401.16818",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
                          d_ff=512, vocab_size=512, window=64)
