"""Multi-node semi-decentralized settlement (the `repro.net` tentpole).

Each ``SettlementNode`` owns one cluster of workers plus a full local
replica of the chain: its own ``Ledger``, ``TrustContract`` (enrolling
the *whole* federation so every replica prices penalties identically),
``IPFSStore``/``ClusterExchange``, and a ``BlockTree`` for fork
tracking. Nodes exchange four gossip messages over ``repro.net.sim``:

- ``ScoreGossip`` — a cluster head's trust scores for its own workers,
- ``AggregateGossip`` — the cluster aggregate's cid *plus the raw
  blob*, ingested content-verified into the receiver's store,
- ``BlockGossip`` — a sealed block with its record commit, flooded
  with per-hash dedup so every replica eventually sees every seal,
- ``ChainRequest``/``ChainResponse`` — post-partition catch-up (a node
  that receives an orphan block asks the sender for its chain).

Round protocol (driven by ``NetworkHarness``): at the round start every
node broadcasts its scores + aggregate; then proposer slots open in
candidate-rank order — rank 0 is the proposer drawn from the head-hash
randomness beacon (``Ledger.randomness_from``), rank j is the j-th
backup. A node proposes in its slot only if the round is still
unsettled on its chain, so under normal latency exactly one block per
partition side is sealed; lost proposals are healed by backups and the
resulting short forks by fork choice (``repro.net.fork_choice``).

Byzantine behavior and its on-chain consequences:

- An **equivocating head** (``EquivocatingNode``) seals two different
  blocks for one (round, proposer) slot and ships one variant to half
  its peers. Replicas relay blocks, so some honest node sees both,
  records ``equivocation`` evidence (invalidating both variants and
  every descendant), relays the conflict, and blanket-rejects the
  offender's future seals. The evidence transaction lands in a later
  honest block; *applying* that block slashes the offender's head
  worker — trust penalization of head misbehavior, on-chain.
- A **tampering head** (``TamperingNode``) seals an honest block but
  gossips it with forged settlement records (an inflated stake). The
  receiver validates records semantically against its own replica state
  *before* applying (exact-float penalty/stake recomputation — the
  LightClient-style check on receipt), rejects the block, and records
  ``tampered_block`` evidence. The proposer's ``sync_head``-visible
  fork becomes a real reorg once the honest fork outgrows it.

Determinism: scores come from a seeded per-round generator shared by
all honest nodes, blocks are sealed at logical timestamps
(``float(round+1)``), and non-proposers apply settlement records with
the *same vectorized numpy ops in the same id order* as the proposer's
``finish_round_batch`` — so replica contract state is bit-equal to the
proposer's, and to a from-scratch replay of the winning chain
(``replay_chain``), which the property tests assert byte-for-byte.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.chain.contract import (_RECORD_DTYPE, TrustContract,
                                  encode_settlement_records)
from repro.chain.ipfs import IPFSStore
from repro.chain.ledger import (Block, DeltaCommit, Ledger, MultiTaskCommit,
                                RecordBatch, ShardedCommit)
from repro.core.gossip import ClusterExchange
from repro.net.fork_choice import BlockTree, seal_info
from repro.net.sim import LinkSpec, Partition, SimNet

__all__ = ["ScoreGossip", "AggregateGossip", "BlockGossip", "HeadAnnounce",
           "ChainRequest",
           "ChainResponse", "SettlementNode", "EquivocatingNode",
           "TamperingNode", "NetworkHarness", "replay_chain",
           "settlement_records", "apply_block_state", "contract_fingerprint",
           "make_score_fn", "head_worker"]


# -- wire messages ----------------------------------------------------------

@dataclass(frozen=True)
class ScoreGossip:
    """A cluster head's trust scores for its own workers this round."""

    round_index: int
    cluster: int
    worker_ids: Tuple[int, ...]
    scores: Tuple[float, ...]


@dataclass(frozen=True)
class AggregateGossip:
    """A cluster aggregate: content address + the raw blob bytes (the
    receiver verifies blob-hash == cid before storing — §III.A's
    fetch-by-hash, pushed)."""

    round_index: int
    cluster: int
    cid: str
    blob: bytes


@dataclass(frozen=True)
class BlockGossip:
    """A sealed block plus its off-chain record commit."""

    block: Block
    commit: Optional[MultiTaskCommit]


@dataclass(frozen=True)
class HeadAnnounce:
    """Periodic head advertisement (sent at every round start and by
    ``NetworkHarness.sync``): a receiver that does not know the
    announced head chain-syncs from the sender — the retransmission
    path that heals blocks lost to message drops."""

    height: int
    head: str


@dataclass(frozen=True)
class ChainRequest:
    """Ask a peer for its canonical chain from ``from_index`` up."""

    from_index: int


@dataclass(frozen=True)
class ChainResponse:
    blocks: Tuple[Block, ...]
    commits: Tuple[Optional[MultiTaskCommit], ...]


# -- deterministic scoring ---------------------------------------------------

def make_score_fn(score_seed: int, population: int):
    """Every honest node draws the *same* per-round population scores
    (seeded by (score_seed, round)) and slices out its own cluster —
    the stand-in for "evaluate local updates against the shared task"
    that keeps replicas byte-reproducible."""

    def score_fn(round_index: int, ids: np.ndarray) -> np.ndarray:
        rng = np.random.default_rng([int(score_seed), int(round_index)])
        s = 0.3 + 0.7 * rng.random(population)
        return s[np.asarray(ids, np.int64)]

    return score_fn


def head_worker(round_index: int, proposer: int, workers_per_node: int) -> int:
    """The worker account slashed for a proposer's misbehavior evidence:
    head duty rotates over the proposer's own cluster by round."""
    return proposer * workers_per_node + round_index % workers_per_node


# -- record application (shared by replicas and replay) ----------------------

def settlement_records(commit: MultiTaskCommit,
                       round_index: int) -> np.ndarray:
    """The round's settlement record rows out of a block commit. Dense
    (``ShardedCommit``) commits must be entirely this round's rows;
    sparse (``DeltaCommit``) commits are the full population overlay, so
    the round's changed rows are filtered out by their round stamp."""
    c = commit.commit_for(None)
    if isinstance(c, DeltaCommit):
        batch = c.materialize()
        rows = np.frombuffer(batch.buf, _RECORD_DTYPE)
        return rows[rows["round"] == round_index]
    rows = np.concatenate([np.frombuffer(s.buf, _RECORD_DTYPE)
                           for s in c.shards])
    if not (rows["round"] == round_index).all():
        raise ValueError("commit contains rows from a foreign round")
    return rows


def apply_block_state(contract: TrustContract, block: Block,
                      commit: Optional[MultiTaskCommit],
                      onchain_evidence: Set[Tuple[int, int]],
                      workers_per_node: int) -> None:
    """Apply one adopted block's settlement records + evidence to a
    replica contract — the same vectorized transitions, in the same id
    order, as the proposer's ``finish_round_batch``, so replica state is
    bit-equal to the sealing node's."""
    info = seal_info(block)
    if info is None:
        return
    round_index, _proposer = info
    if commit is not None:
        rec = settlement_records(commit, round_index)
        ids = rec["worker"].astype(np.int64)
        s = rec["score"].astype(np.float64)
        bad = s < contract.T
        contract.stake[ids] = rec["stake_after"]
        contract.penalized_rounds[ids] += bad
        contract.requester_balance += float(rec["penalty"].sum())
        contract.score_sum[ids] += s
        contract.score_count[ids] += 1
        contract._score_log.append((ids, s))
        contract.note_block(round_index, ids, block.index)
    for tx in block.transactions:
        if not isinstance(tx, dict):
            continue
        if tx.get("type") in ("equivocation", "tampered_block"):
            key = (int(tx["round"]), int(tx["proposer"]))
            if key in onchain_evidence:
                continue
            w = int(tx["worker"])
            pen = min(contract.F * contract.P / 100.0,
                      float(contract.stake[w]))
            contract.stake[w] -= pen
            contract.requester_balance += pen
            contract.penalized_rounds[w] += 1
            onchain_evidence.add(key)


def contract_fingerprint(contract: TrustContract) -> Dict[str, bytes]:
    """Byte-exact digest of consensus-visible contract state, for
    bit-equality assertions across replicas and replays."""
    return {
        "stake": contract.stake.tobytes(),
        "balance": contract.balance.tobytes(),
        "penalized_rounds": contract.penalized_rounds.tobytes(),
        "score_sum": contract.score_sum.tobytes(),
        "score_count": contract.score_count.tobytes(),
        "requester_balance": np.float64(
            contract.requester_balance).tobytes(),
        "reward_pool": np.float64(contract.reward_pool).tobytes(),
    }


def replay_chain(blocks: Sequence[Block],
                 commits: Dict[int, Optional[MultiTaskCommit]],
                 workers_per_node: int,
                 merkle_chunk_size: int = 4
                 ) -> Tuple[Ledger, TrustContract]:
    """Single-node replay oracle: rebuild a fresh ledger + contract from
    a chain's own deployment block and apply every settlement record and
    evidence transaction. The property tests assert a live replica's
    state is bit-equal to this replay of its canonical chain."""
    ledger = Ledger()
    if not blocks or blocks[0].hash != ledger.head.hash:
        raise ValueError("chain does not start at the shared genesis")
    deploy_blk = blocks[1]
    deploy = next(tx for tx in deploy_blk.transactions
                  if tx.get("type") == "deploy")
    join = next(tx for tx in deploy_blk.transactions
                if tx.get("type") == "join_batch")
    contract = TrustContract(
        ledger, requester_deposit=deploy["deposit"],
        worker_stake=deploy["F"], penalty_pct=deploy["P"],
        trust_threshold=deploy["T"], top_k=deploy["k"],
        merkle_chunk_size=merkle_chunk_size)
    contract.join_batch(join["count"])
    contract.pending = []
    ledger.adopt_block(deploy_blk)
    onchain_evidence: Set[Tuple[int, int]] = set()
    for blk in blocks[2:]:
        commit = commits.get(blk.index)
        ledger.adopt_block(blk, commit)
        apply_block_state(contract, blk, commit, onchain_evidence,
                          workers_per_node)
    return ledger, contract


# -- the settlement node -----------------------------------------------------

class SettlementNode:
    """One cluster head + full chain replica on the simulated network."""

    def __init__(self, node_id: int, net: SimNet, *, num_nodes: int,
                 workers_per_node: int = 2, score_seed: int = 7,
                 requester_deposit: float = 1000.0,
                 worker_stake: float = 10.0, penalty_pct: float = 50.0,
                 trust_threshold: float = 0.5, top_k: int = 4,
                 merkle_chunk_size: int = 4, score_fn=None) -> None:
        self.node_id = int(node_id)
        self.net = net
        self.num_nodes = int(num_nodes)
        self.workers_per_node = int(workers_per_node)
        population = self.num_nodes * self.workers_per_node
        self.ledger = Ledger()
        self.contract = TrustContract(
            self.ledger, requester_deposit=requester_deposit,
            worker_stake=worker_stake, penalty_pct=penalty_pct,
            trust_threshold=trust_threshold,
            top_k=min(top_k, population),
            merkle_chunk_size=merkle_chunk_size)
        self.contract.join_batch(population)
        # identical deterministic deployment block on every node: the
        # shared 2-block base chain every fork descends from
        deploy_txs = list(self.contract.pending)
        self.contract.pending = []
        self.ledger.append_block(deploy_txs, timestamp=0.0)
        self.tree = BlockTree(list(self.ledger.blocks))
        self.exchange = ClusterExchange(IPFSStore(), self.ledger,
                                        num_clusters=self.num_nodes)
        self.score_fn = score_fn if score_fn is not None \
            else make_score_fn(score_seed, population)
        # per-height contract snapshots anchor reorg rollbacks
        self._onchain_evidence: Set[Tuple[int, int]] = set()
        self._snapshots: Dict[int, Tuple[dict, Set[Tuple[int, int]]]] = {}
        self._snapshot()
        # round state + misbehavior tracking
        self._scores: Dict[int, Dict[int, Tuple[np.ndarray, np.ndarray]]] = {}
        self._own_aggregate: Dict[int, object] = {}
        self._proposed_rounds: Set[int] = set()
        self._blocks_by_slot: Dict[Tuple[int, int], str] = {}
        self._equivocators: Set[int] = set()
        self._evidence_pool: List[dict] = []
        self._rejected_hashes: Set[str] = set()
        self._orphans: Dict[str, Tuple[Block, Optional[MultiTaskCommit]]] = {}
        self._relayed: Set[str] = set()
        self._sync_requested: Set[Tuple[int, int]] = set()
        self._mute_relay = False
        # observability counters (benchmarks + tests)
        self.reorgs = 0
        self.rejected_blocks = 0
        self.rejected_aggregates = 0
        self.stale_messages = 0
        self.malformed_messages = 0
        self.evidence_found = 0
        net.register(self.node_id, self.on_message)

    # -- identity ------------------------------------------------------------

    @property
    def cluster_ids(self) -> np.ndarray:
        base = self.node_id * self.workers_per_node
        return np.arange(base, base + self.workers_per_node)

    def candidate_rank(self, round_index: int) -> int:
        """This node's position in the round's proposer rotation, drawn
        from the randomness beacon over *this replica's* head — rank 0
        is the primary proposer, rank j the j-th backup."""
        primary = Ledger.randomness_from(
            self.ledger.head.hash, round_index) % self.num_nodes
        return (self.node_id - primary) % self.num_nodes

    def verify(self) -> bool:
        return self.ledger.verify_chain(deep=True)

    # -- round protocol ------------------------------------------------------

    def announce_head(self) -> None:
        """Advertise the canonical head; peers missing it will sync.
        Opens a fresh sync epoch (prior request dedup is cleared, so a
        lost ChainResponse is retried on the next announcement wave)."""
        self._sync_requested.clear()
        self.net.broadcast(self.node_id, HeadAnnounce(
            self.ledger.head.index, self.ledger.head.hash))

    def begin_round(self, round_index: int) -> None:
        """Score own cluster, publish the cluster aggregate, gossip both."""
        self.announce_head()
        ids = self.cluster_ids
        scores = np.asarray(self.score_fn(round_index, ids), np.float64)
        self._scores.setdefault(round_index, {})[self.node_id] = (ids, scores)
        aggregate = {"cluster_mean": np.asarray(
            [float(round_index), float(scores.mean())], np.float32)}
        self._own_aggregate[round_index] = aggregate
        cid = self.exchange.publish(round_index, self.node_id, aggregate)
        _, blob = self.exchange.blob(round_index, self.node_id)
        self.net.broadcast(self.node_id, ScoreGossip(
            round_index, self.node_id,
            tuple(int(i) for i in ids), tuple(float(x) for x in scores)))
        self.net.broadcast(self.node_id, AggregateGossip(
            round_index, self.node_id, cid, blob))

    def maybe_propose(self, round_index: int,
                      rank_slot: int) -> Optional[Block]:
        """Seal the round iff this node holds the slot's rank on its own
        chain and the round is still unsettled there. One proposal per
        round per node, ever — a mid-round reorg shifting ranks must not
        make an honest node equivocate."""
        if round_index in self._proposed_rounds:
            return None
        if round_index in self.contract._round_blocks:
            return None
        if self.candidate_rank(round_index) != rank_slot:
            return None
        return self._propose(round_index)

    def _propose(self, round_index: int) -> Block:
        clusters = sorted(self._scores.get(round_index, {}))
        ids = np.concatenate(
            [self._scores[round_index][c][0] for c in clusters])
        scores = np.concatenate(
            [self._scores[round_index][c][1] for c in clusters])
        evidence = [tx for tx in self._evidence_pool
                    if (tx["round"], tx["proposer"])
                    not in self._onchain_evidence]
        pend: List[dict] = list(evidence)
        pend.extend(self.exchange.round_transactions(round_index))
        pend.append({"type": "seal", "round": int(round_index),
                     "proposer": self.node_id,
                     "trust": float(scores.sum())})
        saved = list(self.contract.pending)
        self.contract.pending = saved + pend
        try:
            self.contract.settle_round_batch(
                round_index, scores, worker_ids=ids,
                timestamp=float(round_index + 1))
        except BaseException:
            self.contract.pending = saved
            raise
        self._proposed_rounds.add(round_index)
        blk = self.ledger.head
        commit = self.ledger.commit(blk.index)
        # settle applied the records; evidence is the remaining state delta
        for tx in evidence:
            key = (tx["round"], tx["proposer"])
            if key in self._onchain_evidence:
                continue
            w = int(tx["worker"])
            pen = min(self.contract.F * self.contract.P / 100.0,
                      float(self.contract.stake[w]))
            self.contract.stake[w] -= pen
            self.contract.requester_balance += pen
            self.contract.penalized_rounds[w] += 1
            self._onchain_evidence.add(key)
        self.tree.add(blk, commit)
        self._blocks_by_slot[(round_index, self.node_id)] = blk.hash
        self._snapshot()
        self._relay(BlockGossip(blk, commit))
        return blk

    # -- gossip ingest -------------------------------------------------------

    def on_message(self, src: int, msg) -> None:
        if isinstance(msg, ScoreGossip):
            self._on_scores(src, msg)
        elif isinstance(msg, AggregateGossip):
            self._on_aggregate(src, msg)
        elif isinstance(msg, BlockGossip):
            self._on_block(src, msg)
        elif isinstance(msg, HeadAnnounce):
            self._on_head_announce(src, msg)
        elif isinstance(msg, ChainRequest):
            self._on_chain_request(src, msg)
        elif isinstance(msg, ChainResponse):
            self._on_chain_response(src, msg)
        else:
            self.malformed_messages += 1

    def _on_scores(self, src: int, m: ScoreGossip) -> None:
        try:
            r = int(m.round_index)
            cluster = int(m.cluster)
            ids = np.asarray(m.worker_ids, np.int64)
            scores = np.asarray(m.scores, np.float64)
        except (TypeError, ValueError):
            self.malformed_messages += 1
            return
        lo = cluster * self.workers_per_node
        hi = lo + self.workers_per_node
        if (r < 0 or cluster != src or len(ids) != len(scores)
                or len(ids) == 0 or len(np.unique(ids)) != len(ids)
                or ids.min() < lo or ids.max() >= hi
                or not np.isfinite(scores).all()
                or scores.min() < 0.0 or scores.max() > 1.0):
            self.malformed_messages += 1
            return
        if r in self.contract._round_blocks:
            self.stale_messages += 1
            return
        order = np.argsort(ids, kind="stable")
        self._scores.setdefault(r, {})[cluster] = (ids[order], scores[order])

    def _on_aggregate(self, src: int, m: AggregateGossip) -> None:
        try:
            self.exchange.ingest(int(m.round_index), int(m.cluster),
                                 m.cid, m.blob)
        except (TypeError, ValueError):
            self.rejected_aggregates += 1

    def merged_aggregate(self, round_index: int):
        """Trust-weighted fold of peers' gossiped aggregates into this
        node's own (§III.A cross-cluster exchange over the network)."""
        like = self._own_aggregate[round_index]
        counts = np.maximum(self.contract.score_count, 1)
        mean = self.contract.score_sum / counts
        per_cluster = mean.reshape(self.num_nodes,
                                   self.workers_per_node).mean(axis=1)
        return self.exchange.merge(round_index, self.node_id, like,
                                   peer_trust=per_cluster)

    def _on_block(self, src: int, m: BlockGossip) -> None:
        blk, commit = m.block, m.commit
        if not isinstance(blk, Block):
            self.malformed_messages += 1
            return
        h = blk.hash
        if h in self.tree or h in self._rejected_hashes:
            return
        if blk.compute_hash() != h:
            self.rejected_blocks += 1
            self._rejected_hashes.add(h)
            return
        info = seal_info(blk)
        if info is None:
            self.rejected_blocks += 1
            self._rejected_hashes.add(h)
            return
        r, proposer = info
        if not (0 <= proposer < self.num_nodes) or r < 0:
            self.rejected_blocks += 1
            self._rejected_hashes.add(h)
            return
        if proposer in self._equivocators:
            self.rejected_blocks += 1
            self._rejected_hashes.add(h)
            return
        prev = self._blocks_by_slot.get((r, proposer))
        if prev is not None and prev != h:
            self._record_equivocation(r, proposer, prev, h, m)
            return
        if blk.prev_hash not in self.tree:
            self._orphans[h] = (blk, commit)
            self._request_sync(src)
            return
        self._admit(blk, commit, r, proposer)
        self._try_orphans()
        self._maybe_reorg()

    def _admit(self, blk: Block, commit, r: int, proposer: int) -> None:
        self.tree.add(blk, commit)
        self._blocks_by_slot[(r, proposer)] = blk.hash
        self._relay(BlockGossip(blk, commit))

    def _try_orphans(self) -> None:
        progress = True
        while progress:
            progress = False
            for h in list(self._orphans):
                blk, commit = self._orphans[h]
                info = seal_info(blk)
                if info is None or info[1] in self._equivocators \
                        or h in self._rejected_hashes:
                    del self._orphans[h]
                    continue
                if blk.prev_hash in self.tree:
                    del self._orphans[h]
                    self._admit(blk, commit, *info)
                    progress = True

    def _record_equivocation(self, r: int, proposer: int, prev_hash: str,
                             new_hash: str, m: BlockGossip) -> None:
        """Two distinct seals for one (round, proposer) slot: both become
        invalid, the offender is blanket-rejected from now on, and a
        slash-on-inclusion evidence transaction joins the pool."""
        self._equivocators.add(proposer)
        self.evidence_found += 1
        self.tree.invalidate(prev_hash)
        self._rejected_hashes.add(new_hash)
        self._add_evidence({
            "type": "equivocation", "round": int(r),
            "proposer": int(proposer),
            "worker": head_worker(r, proposer, self.workers_per_node),
            "blocks": sorted([prev_hash, new_hash])})
        self._relay(m)                 # let peers see the conflict too
        self._maybe_reorg()

    def _add_evidence(self, tx: dict) -> None:
        key = (tx["round"], tx["proposer"])
        for existing in self._evidence_pool:
            if (existing["round"], existing["proposer"]) == key:
                return
        self._evidence_pool.append(tx)

    def _on_head_announce(self, src: int, m: HeadAnnounce) -> None:
        try:
            head = str(m.head)
            height = int(m.height)
        except (TypeError, ValueError):
            self.malformed_messages += 1
            return
        if height < 0 or len(head) != 64:
            self.malformed_messages += 1
            return
        if head not in self.tree and head not in self._rejected_hashes:
            self._request_sync(src)

    def _request_sync(self, src: int) -> None:
        key = (src, self.ledger.head.index)
        if key in self._sync_requested:
            return
        self._sync_requested.add(key)
        self.net.send(self.node_id, src, ChainRequest(2))

    def _on_chain_request(self, src: int, m: ChainRequest) -> None:
        try:
            start = int(m.from_index)
        except (TypeError, ValueError):
            self.malformed_messages += 1
            return
        if start < 0:
            self.malformed_messages += 1
            return
        blocks = tuple(self.ledger.blocks[start:])
        commits = tuple(self.ledger._commits.get(b.index) for b in blocks)
        self.net.send(self.node_id, src, ChainResponse(blocks, commits))

    def _on_chain_response(self, src: int, m: ChainResponse) -> None:
        if len(m.blocks) != len(m.commits):
            self.malformed_messages += 1
            return
        for blk, commit in zip(m.blocks, m.commits):
            self._on_block(src, BlockGossip(blk, commit))

    def _relay(self, msg: BlockGossip) -> None:
        if self._mute_relay or msg.block.hash in self._relayed:
            return
        self._relayed.add(msg.block.hash)
        self.net.broadcast(self.node_id, msg)

    # -- fork choice + state transitions --------------------------------------

    def _snapshot(self) -> None:
        self._snapshots[self.ledger.head.index] = (
            self.contract.snapshot(), set(self._onchain_evidence))

    def _maybe_reorg(self) -> None:
        """Re-run fork choice; when the winner moves, roll contract +
        ledger back to the common ancestor's snapshot and replay the
        winning branch with full semantic validation per block. A branch
        whose block fails validation is invalidated (with evidence) and
        fork choice re-runs without it."""
        while True:
            best = self.tree.best_head()
            cur = self.ledger.head.hash
            if best == cur:
                return
            anc = self.tree.ancestor(cur, best)
            anc_index = self.tree.height(anc)
            root_index = self.tree.height(self.tree.root)
            path = self.tree.chain_to(best)[anc_index - root_index + 1:]
            snap, evidence = self._snapshots[anc_index]
            self.ledger.rollback_to(anc_index)
            self.contract.restore(snap)
            self._onchain_evidence = set(evidence)
            for i in list(self._snapshots):
                if i > anc_index:
                    del self._snapshots[i]
            if anc != cur:
                self.reorgs += 1
            clean = True
            for blk in path:
                commit = self.tree.commit(blk.hash)
                err = self._validate_block(blk, commit)
                if err is None:
                    try:
                        self.ledger.adopt_block(blk, commit)
                    except ValueError as exc:
                        err = str(exc)
                if err is not None:
                    self._flag_invalid(blk, err)
                    clean = False
                    break
                apply_block_state(self.contract, blk, commit,
                                  self._onchain_evidence,
                                  self.workers_per_node)
                self._register_block_cids(blk)
                self._snapshot()
            if clean:
                return

    def _validate_block(self, blk: Block, commit) -> Optional[str]:
        """Semantic validation against the replica's own state at the
        block's parent — the tampered-records check. Exact float
        equality is correct here: honest penalties/stakes are computed
        by the identical numpy expressions from identical inputs."""
        info = seal_info(blk)
        if info is None:
            return "missing seal"
        r, _proposer = info
        if r in self.contract._round_blocks:
            return f"round {r} already settled on this fork"
        has_settlement = any(
            isinstance(tx, dict) and tx.get("type") == "settlement_batch"
            for tx in blk.transactions)
        if not blk.records_root:
            return "settlement without records" if has_settlement else None
        if commit is None:
            return "records_root without a shipped commit"
        try:
            rec = settlement_records(commit, r)
        except (ValueError, KeyError) as exc:
            return f"bad commit: {exc}"
        ids = rec["worker"].astype(np.int64)
        s = rec["score"].astype(np.float64)
        if len(ids) == 0 or len(np.unique(ids)) != len(ids) \
                or (np.diff(ids) < 0).any():
            return "records not in canonical id order"
        if ids.min() < 0 or ids.max() >= self.contract.num_workers:
            return "records for unknown workers"
        if not np.isfinite(s).all():
            return "non-finite scores"
        stake_before = self.contract.stake[ids]
        full_pen = self.contract.F * self.contract.P / 100.0
        expect_pen = np.where(s < self.contract.T,
                              np.minimum(full_pen, stake_before), 0.0)
        if not np.array_equal(rec["penalty"], expect_pen):
            return "penalty mismatch (tampered records)"
        if not np.array_equal(rec["stake_after"], stake_before - expect_pen):
            return "stake mismatch (tampered records)"
        batch_tx = next(
            (tx for tx in blk.transactions if isinstance(tx, dict)
             and tx.get("type") == "settlement_batch"), None)
        if batch_tx is None:
            return "records without a settlement_batch tx"
        if (batch_tx.get("round") != r
                or batch_tx.get("workers") != len(ids)
                or batch_tx.get("bad_count")
                != int((s < self.contract.T).sum())
                or batch_tx.get("total_penalty")
                != float(expect_pen.sum())):
            return "settlement_batch tx mismatch"
        for tx in blk.transactions:
            if isinstance(tx, dict) \
                    and tx.get("type") in ("equivocation", "tampered_block"):
                try:
                    key = (int(tx["round"]), int(tx["proposer"]))
                    w = int(tx["worker"])
                except (KeyError, TypeError, ValueError):
                    return "malformed evidence tx"
                if key in self._onchain_evidence:
                    return "duplicate evidence"
                if not 0 <= w < self.contract.num_workers:
                    return "evidence against unknown worker"
        return None

    def _flag_invalid(self, blk: Block, err: str) -> None:
        self.rejected_blocks += 1
        self.tree.invalidate(blk.hash)
        info = seal_info(blk)
        if info is not None:
            r, proposer = info
            self._add_evidence({
                "type": "tampered_block", "round": int(r),
                "proposer": int(proposer),
                "worker": head_worker(r, proposer, self.workers_per_node),
                "block": blk.hash, "error": err})

    def _register_block_cids(self, blk: Block) -> None:
        for tx in blk.transactions:
            if isinstance(tx, dict) and tx.get("type") == "cluster_model":
                self.exchange.register(int(tx["round"]), int(tx["cluster"]),
                                       tx["cid"])


# -- byzantine heads ---------------------------------------------------------

class EquivocatingNode(SettlementNode):
    """A byzantine cluster head that seals *two* different blocks for
    every round it proposes and ships variant A to half its peers and
    variant B to the rest — the equivocation scenario the evidence path
    must catch for every seed."""

    def maybe_propose(self, round_index: int,
                      rank_slot: int) -> Optional[Block]:
        # always jump the rotation at slot 0 (a byzantine head does not
        # wait its turn), but still only once per round
        if rank_slot != 0 or round_index in self._proposed_rounds \
                or round_index in self.contract._round_blocks:
            return None
        self._mute_relay = True
        try:
            blk = self._propose(round_index)
        finally:
            self._mute_relay = False
        commit_a = self.tree.commit(blk.hash)
        blk_b, commit_b = self._forge_variant(blk, round_index)
        peers = [d for d in self.net.node_ids if d != self.node_id]
        for i, dst in enumerate(peers):
            variant = BlockGossip(blk, commit_a) if i % 2 == 0 \
                else BlockGossip(blk_b, commit_b)
            self.net.send(self.node_id, dst, variant)
        return blk

    def _forge_variant(self, blk: Block,
                       round_index: int) -> Tuple[Block, MultiTaskCommit]:
        """A second, *semantically valid* block for the same slot: same
        parent, same cohort, different scores for the offender's own
        cluster — so only equivocation detection (not record validation)
        can catch it."""
        parent_snap, _ = self._snapshots[blk.index - 1]
        rec = settlement_records(self.tree.commit(blk.hash), round_index)
        ids = rec["worker"].astype(np.int64)
        s = rec["score"].astype(np.float64).copy()
        own = (ids // self.workers_per_node) == self.node_id
        s[own] = np.clip(s[own] * 0.5, 0.0, 1.0)   # always != honest score
        stake_before = parent_snap["stake"][ids]
        full_pen = self.contract.F * self.contract.P / 100.0
        pen = np.where(s < self.contract.T,
                       np.minimum(full_pen, stake_before), 0.0)
        stake_after = stake_before - pen
        records = encode_settlement_records(round_index, ids, s, pen,
                                            stake_after)
        commit = MultiTaskCommit({None: ShardedCommit(
            [records], self.contract.merkle_chunk_size)})
        txs = []
        for tx in blk.transactions:
            if isinstance(tx, dict) and tx.get("type") == "seal":
                tx = {**tx, "trust": float(s.sum())}
            elif isinstance(tx, dict) \
                    and tx.get("type") == "settlement_batch":
                tx = {**tx,
                      "bad_count": int((s < self.contract.T).sum()),
                      "total_penalty": float(pen.sum())}
            txs.append(tx)
        forged = Block(blk.index, blk.prev_hash, txs, blk.timestamp,
                       records_root=commit.root)
        forged.hash = forged.compute_hash()
        return forged, commit


class TamperingNode(SettlementNode):
    """A byzantine head that seals an honest block but gossips it with a
    *tampered commit* — settlement records inflating its own head
    worker's post-round stake. Receivers catch the mismatch in semantic
    validation (the super-root check on receipt) and slash it."""

    def maybe_propose(self, round_index: int,
                      rank_slot: int) -> Optional[Block]:
        if rank_slot != 0 or round_index in self._proposed_rounds \
                or round_index in self.contract._round_blocks:
            return None
        self._mute_relay = True
        try:
            blk = self._propose(round_index)
        finally:
            self._mute_relay = False
        rec = settlement_records(
            self.tree.commit(blk.hash), round_index).copy()
        me = head_worker(round_index, self.node_id, self.workers_per_node)
        mask = rec["worker"] == me
        rec["stake_after"] = np.where(mask, rec["stake_after"] + 5.0,
                                      rec["stake_after"])
        forged = MultiTaskCommit({None: ShardedCommit(
            [RecordBatch(memoryview(rec).cast("B"), _RECORD_DTYPE.itemsize)],
            self.contract.merkle_chunk_size)})
        self.net.broadcast(self.node_id, BlockGossip(blk, forged))
        return blk


# -- the multi-node harness --------------------------------------------------

class NetworkHarness:
    """Deterministic N-node scenario driver. One round =

    1. every node scores + publishes + gossips (``begin_round``),
    2. a gossip window for scores/aggregates to spread,
    3. N staggered proposer slots in candidate-rank order (each slot
       ends with the network draining its deliveries),
    4. a tail window for the sealed block to flood every replica.

    ``byzantine`` maps node id → ``"equivocate" | "tamper"``.
    ``partition_rounds`` are ``(start_round, stop_round, groups)``
    triples, converted to simulated-second ``Partition`` windows."""

    def __init__(self, num_nodes: int, workers_per_node: int = 2, *,
                 seed: int = 0, score_seed: int = 7,
                 link: Optional[LinkSpec] = None,
                 partition_rounds: Sequence[Tuple[int, int, tuple]] = (),
                 byzantine: Optional[Dict[int, str]] = None,
                 gossip_window: float = 0.25, slot_stagger: float = 0.25,
                 round_tail: float = 0.5, **node_kwargs) -> None:
        self.num_nodes = int(num_nodes)
        self.workers_per_node = int(workers_per_node)
        self.gossip_window = gossip_window
        self.slot_stagger = slot_stagger
        self.round_period = (gossip_window
                             + num_nodes * slot_stagger + round_tail)
        partitions = tuple(
            Partition(start * self.round_period, stop * self.round_period,
                      tuple(tuple(g) for g in groups))
            for start, stop, groups in partition_rounds)
        self.net = SimNet(
            seed=seed,
            default_link=link if link is not None
            else LinkSpec(latency=0.02, jitter=0.02),
            partitions=partitions)
        kinds = {"equivocate": EquivocatingNode, "tamper": TamperingNode}
        byzantine = byzantine or {}
        self.byzantine = dict(byzantine)
        self.nodes: List[SettlementNode] = [
            kinds.get(byzantine.get(i), SettlementNode)(
                i, self.net, num_nodes=num_nodes,
                workers_per_node=workers_per_node, score_seed=score_seed,
                **node_kwargs)
            for i in range(num_nodes)]
        self.rounds_run = 0

    def run_round(self) -> None:
        r = self.rounds_run
        t0 = r * self.round_period
        self.net.run(until=t0)
        for node in self.nodes:
            node.begin_round(r)
        self.net.run(until=t0 + self.gossip_window)
        for k in range(self.num_nodes):
            for node in self.nodes:
                node.maybe_propose(r, k)
            self.net.run(until=t0 + self.gossip_window
                         + (k + 1) * self.slot_stagger)
        self.net.run(until=(r + 1) * self.round_period)
        self.rounds_run += 1

    def run(self, rounds: int) -> None:
        for _ in range(rounds):
            self.run_round()

    def sync(self, max_waves: int = 5) -> int:
        """Post-run anti-entropy: head-announcement waves until every
        honest replica converges (or ``max_waves``). Heals blocks whose
        gossip was lost in the *final* round — mid-run losses already
        heal at the next round's announcements. Returns waves used."""
        for wave in range(max_waves):
            if self.converged():
                return wave
            for node in self.nodes:
                node.announce_head()
            self.net.run(until=self.net.now + self.round_period)
        return max_waves

    def honest_nodes(self) -> List[SettlementNode]:
        return [n for n in self.nodes if n.node_id not in self.byzantine]

    def heads(self) -> List[str]:
        return [n.ledger.head.hash for n in self.nodes]

    def chain_hashes(self, node: SettlementNode) -> List[str]:
        return [b.hash for b in node.ledger.blocks]

    def converged(self, honest_only: bool = True) -> bool:
        """All (honest) replicas hold byte-identical chains."""
        nodes = self.honest_nodes() if honest_only else self.nodes
        chains = [self.chain_hashes(n) for n in nodes]
        return all(c == chains[0] for c in chains[1:])
