"""Deterministic simulated transport for the multi-node settlement net.

``SimNet`` is the fault-injection harness every ``repro.net`` scenario
runs on: nodes register a message handler, and all traffic flows through
a single event heap ordered by simulated delivery time. The clock is the
same *simulated seconds* timeline as ``core.async_sim.AsyncScheduler``
(monotone floats starting at 0.0, advanced only by ``run``), so one
scenario can interleave worker-arrival events and network deliveries on
one deterministic timeline.

Determinism contract (what makes runs byte-reproducible):

- Every directed link ``(src, dst)`` owns a private ``numpy`` RNG seeded
  from ``(seed, src, dst)``. Latency/jitter/loss draws consume *that
  link's* stream in that link's send order — so one link's schedule is
  independent of global send interleaving, and a scenario replays
  identically for a given seed regardless of how callers order their
  broadcasts.
- The event heap breaks delivery-time ties by a global send sequence
  number; handlers run one at a time.
- ``Date``/wall-clock never enters the sim: ``now`` only moves via
  ``run(until=...)`` and delivered-event timestamps.

Fault-injection knobs:

- ``LinkSpec(latency, jitter, loss)`` — per-link base delay, uniform
  extra jitter, and iid drop probability. Set per directed link with
  ``set_link`` or network-wide via ``default_link``.
- ``Partition(start, stop, groups)`` — during ``[start, stop)`` in
  simulated seconds, messages *sent* between nodes in different groups
  are dropped (nodes absent from every group form one implicit extra
  group). Overlapping windows compose: a send is dropped if any active
  window separates the endpoints.

Counters (``sent``, ``delivered``, ``dropped_loss``,
``dropped_partition``) make reliability benchmarks cheap to assert.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.chain.ledger import sha256

__all__ = ["LinkSpec", "Partition", "SimNet"]


@dataclass(frozen=True)
class LinkSpec:
    """One directed link's fault model: ``latency`` (base simulated
    seconds), ``jitter`` (uniform extra delay in ``[0, jitter)``), and
    ``loss`` (iid drop probability per message)."""

    latency: float = 0.01
    jitter: float = 0.0
    loss: float = 0.0

    def __post_init__(self) -> None:
        if self.latency < 0 or self.jitter < 0:
            raise ValueError("latency/jitter must be >= 0")
        if not 0.0 <= self.loss < 1.0:
            raise ValueError("loss must be in [0, 1)")


@dataclass(frozen=True)
class Partition:
    """A network split active over ``[start, stop)`` simulated seconds:
    ``groups`` are the mutually-unreachable node sets. Nodes listed in no
    group form one implicit extra group (still reachable to each other,
    cut off from every listed group)."""

    start: float
    stop: float
    groups: Tuple[Tuple[int, ...], ...]

    def side(self, node: int) -> int:
        for gi, g in enumerate(self.groups):
            if node in g:
                return gi
        return -1                      # the implicit "everyone else" group

    def separates(self, a: int, b: int, t: float) -> bool:
        return self.start <= t < self.stop and self.side(a) != self.side(b)


class SimNet:
    """Seeded, clocked, in-process message fabric (see module docstring)."""

    def __init__(self, seed: int = 0,
                 default_link: LinkSpec = LinkSpec(),
                 partitions: Tuple[Partition, ...] = ()) -> None:
        self.seed = int(seed)
        self.default_link = default_link
        self.partitions: List[Partition] = list(partitions)
        self.now = 0.0
        self._seq = 0
        # (deliver_time, seq, src, dst, msg)
        self._heap: List[Tuple[float, int, int, int, Any]] = []
        self._handlers: Dict[int, Callable[[int, Any], None]] = {}
        self._links: Dict[Tuple[int, int], LinkSpec] = {}
        self._rngs: Dict[Tuple[int, int], np.random.Generator] = {}
        self.sent = 0
        self.delivered = 0
        self.dropped_loss = 0
        self.dropped_partition = 0

    # -- topology --------------------------------------------------------------

    def register(self, node_id: int,
                 handler: Callable[[int, Any], None]) -> None:
        """Attach ``handler(src, msg)`` as ``node_id``'s inbox."""
        if node_id in self._handlers:
            raise ValueError(f"node {node_id} already registered")
        self._handlers[int(node_id)] = handler

    @property
    def node_ids(self) -> List[int]:
        return sorted(self._handlers)

    def set_link(self, src: int, dst: int, spec: LinkSpec) -> None:
        """Override one directed link's fault model."""
        self._links[(src, dst)] = spec

    def link(self, src: int, dst: int) -> LinkSpec:
        return self._links.get((src, dst), self.default_link)

    def _rng(self, src: int, dst: int) -> np.random.Generator:
        key = (src, dst)
        rng = self._rngs.get(key)
        if rng is None:
            # per-link stream: independent of global send interleaving
            digest = sha256(f"simnet:{self.seed}:{src}->{dst}".encode())
            rng = self._rngs[key] = np.random.default_rng(
                int(digest[:16], 16))
        return rng

    def partitioned(self, a: int, b: int, t: Optional[float] = None) -> bool:
        """Whether any active partition window separates ``a`` and ``b``
        at simulated time ``t`` (default: now)."""
        t = self.now if t is None else t
        return any(p.separates(a, b, t) for p in self.partitions)

    # -- sending ---------------------------------------------------------------

    def send(self, src: int, dst: int, msg: Any) -> bool:
        """Queue one message at the current simulated time. Returns
        whether it was scheduled (partition/loss drops return False).
        Partition semantics are send-time: a message sent inside a
        partition window is lost even if it would have been delivered
        after the heal."""
        if dst not in self._handlers:
            raise KeyError(f"unknown destination node {dst}")
        self.sent += 1
        if self.partitioned(src, dst):
            self.dropped_partition += 1
            return False
        spec = self.link(src, dst)
        rng = self._rng(src, dst)
        # fixed draw order per message keeps the link stream aligned
        # whatever the spec: loss first, then jitter
        u_loss = rng.random()
        delay = spec.latency + (spec.jitter * rng.random()
                                if spec.jitter else 0.0)
        if spec.loss and u_loss < spec.loss:
            self.dropped_loss += 1
            return False
        self._seq += 1
        heapq.heappush(self._heap,
                       (self.now + delay, self._seq, src, dst, msg))
        return True

    def broadcast(self, src: int, msg: Any) -> int:
        """Send to every other registered node (id order). Returns how
        many copies were scheduled."""
        return sum(self.send(src, dst, msg)
                   for dst in self.node_ids if dst != src)

    # -- the clock -------------------------------------------------------------

    def run(self, until: Optional[float] = None,
            max_events: int = 1_000_000) -> int:
        """Deliver queued messages in ``(time, seq)`` order until the
        heap is empty (or past ``until``). Handlers may send more
        messages; those are delivered too if due. Advances ``now`` to
        ``until`` (or the last delivery). Returns deliveries made."""
        n = 0
        while self._heap and n < max_events:
            t = self._heap[0][0]
            if until is not None and t > until:
                break
            t, _, src, dst, msg = heapq.heappop(self._heap)
            self.now = max(self.now, t)
            self._handlers[dst](src, msg)
            self.delivered += 1
            n += 1
        if until is not None:
            self.now = max(self.now, until)
        return n
