"""Fork tracking and fork choice for the multi-node settlement chain.

``BlockTree`` indexes every valid block a node has seen (its own seals
plus gossiped peers' blocks) by hash, keyed off the node's trusted base
chain (genesis + deployment block). Fork choice is **longest valid
chain with a cumulative-trust tiebreak**:

1. greater height wins (most settled rounds),
2. at equal height, greater cumulative trust wins — each block
   contributes its ``seal`` transaction's ``trust`` field (the sum of
   the cohort's trust scores it settled), so after a partition the
   majority side's fork — the one that kept settling more of the
   federation — beats the minority fork of the same length (the
   reliability tiebreak of the paper's trust-penalization pillar),
3. at equal trust, the lexicographically smaller block hash wins
   (arbitrary but deterministic: every node picks the same head).

``apply_reorg`` turns a fork-choice decision into ledger state: roll
the ledger back to the common ancestor (``Ledger.rollback_to``) and
adopt the winning branch block-by-block (``Ledger.adopt_block``, which
re-verifies linkage, hashes, and each shipped commit against the
block's ``records_root`` — including sparse ``DeltaCommit`` overlay
chains, whose ancestor commits survive the rollback so idle-worker
proofs from the surviving prefix stay valid). Contract state is the
caller's half: ``repro.net.node.SettlementNode`` restores its snapshot
at the ancestor and replays the adopted blocks' settlement records.

Blocks marked invalid (equivocation evidence, failed semantic
validation) are excluded from fork choice together with all their
descendants.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.chain.ledger import Block, Ledger, MultiTaskCommit

__all__ = ["block_trust", "seal_info", "BlockTree", "apply_reorg"]


def seal_info(block: Block) -> Optional[Tuple[int, int]]:
    """``(round, proposer)`` from a network block's ``seal`` transaction,
    or None for non-network blocks (genesis, deployment)."""
    for tx in block.transactions:
        if isinstance(tx, dict) and tx.get("type") == "seal":
            try:
                return int(tx["round"]), int(tx["proposer"])
            except (KeyError, TypeError, ValueError):
                return None
    return None


def block_trust(block: Block) -> float:
    """One block's fork-choice weight: the trust mass its seal settled
    (0.0 for blocks without a ``seal`` tx, so base-chain blocks are
    weightless)."""
    total = 0.0
    for tx in block.transactions:
        if isinstance(tx, dict) and tx.get("type") == "seal":
            try:
                total += float(tx["trust"])
            except (KeyError, TypeError, ValueError):
                pass
    return total


class BlockTree:
    """Hash-indexed fork tree over one node's view of the network chain."""

    def __init__(self, base_blocks: Sequence[Block],
                 base_commits: Optional[Dict[int, MultiTaskCommit]] = None
                 ) -> None:
        """Seed the tree with the node's trusted base chain (typically
        ``ledger.blocks`` right after local genesis + deployment —
        adopted without re-verification)."""
        if not base_blocks:
            raise ValueError("base chain must contain at least genesis")
        self._blocks: Dict[str, Block] = {}
        self._commits: Dict[str, Optional[MultiTaskCommit]] = {}
        self._children: Dict[str, List[str]] = {}
        self._height: Dict[str, int] = {}
        self._weight: Dict[str, float] = {}
        self._invalid: Set[str] = set()
        prev: Optional[str] = None
        for blk in base_blocks:
            h = blk.hash
            self._blocks[h] = blk
            self._commits[h] = None if base_commits is None \
                else base_commits.get(blk.index)
            self._height[h] = blk.index
            self._weight[h] = (0.0 if prev is None
                               else self._weight[prev]) + block_trust(blk)
            self._children.setdefault(h, [])
            if prev is not None:
                self._children[prev].append(h)
            prev = h
        self.root = base_blocks[0].hash

    def __contains__(self, block_hash: str) -> bool:
        return block_hash in self._blocks

    def block(self, block_hash: str) -> Block:
        return self._blocks[block_hash]

    def commit(self, block_hash: str) -> Optional[MultiTaskCommit]:
        return self._commits[block_hash]

    def height(self, block_hash: str) -> int:
        return self._height[block_hash]

    def is_valid(self, block_hash: str) -> bool:
        return block_hash in self._blocks \
            and block_hash not in self._invalid

    def add(self, block: Block,
            commit: Optional[MultiTaskCommit] = None) -> bool:
        """Index a block under its parent. Returns False when the parent
        is unknown (orphan — the caller should chain-sync from the
        sender); duplicate adds are no-ops returning True. Descendants of
        invalidated blocks inherit the invalidation."""
        h = block.hash
        if h in self._blocks:
            return True
        parent = block.prev_hash
        if parent not in self._blocks:
            return False
        self._blocks[h] = block
        self._commits[h] = commit
        self._height[h] = self._height[parent] + 1
        self._weight[h] = self._weight[parent] + block_trust(block)
        self._children.setdefault(h, [])
        self._children[parent].append(h)
        if parent in self._invalid:
            self._invalid.add(h)
        return True

    def invalidate(self, block_hash: str) -> int:
        """Mark a block and every descendant ineligible for fork choice
        (equivocation / tampered records / failed validation). Returns
        how many blocks were newly invalidated."""
        if block_hash not in self._blocks:
            return 0
        stack, n = [block_hash], 0
        while stack:
            h = stack.pop()
            if h not in self._invalid:
                self._invalid.add(h)
                n += 1
            stack.extend(self._children.get(h, ()))
        return n

    def best_head(self) -> str:
        """The fork-choice winner over all valid blocks: max
        ``(height, cumulative trust)``, ties broken by the smaller hash
        (deterministic across nodes)."""
        best: Optional[str] = None
        for h in self._blocks:
            if h in self._invalid:
                continue
            if best is None:
                best = h
                continue
            key = (self._height[h], self._weight[h])
            bkey = (self._height[best], self._weight[best])
            if key > bkey or (key == bkey and h < best):
                best = h
        assert best is not None            # the base chain is never invalid
        return best

    def chain_to(self, block_hash: str) -> List[Block]:
        """Root→``block_hash`` path (inclusive)."""
        out = []
        h: Optional[str] = block_hash
        while h is not None:
            blk = self._blocks[h]
            out.append(blk)
            h = blk.prev_hash if blk.index > self._blocks[self.root].index \
                else None
        out.reverse()
        if out[0].hash != self.root:
            raise KeyError(f"{block_hash[:12]}… does not descend from root")
        return out

    def ancestor(self, a: str, b: str) -> str:
        """Hash of the deepest common ancestor of two blocks."""
        ha, hb = self._height[a], self._height[b]
        while ha > hb:
            a = self._blocks[a].prev_hash
            ha -= 1
        while hb > ha:
            b = self._blocks[b].prev_hash
            hb -= 1
        while a != b:
            a = self._blocks[a].prev_hash
            b = self._blocks[b].prev_hash
        return a


def apply_reorg(ledger: Ledger, tree: BlockTree, new_head: str,
                verify_commit: bool = True) -> Tuple[int, List[Block]]:
    """Move ``ledger`` from its current head to ``new_head``: roll back
    to the common ancestor, then adopt the winning branch (each block's
    shipped commit re-verified against its ``records_root`` unless
    ``verify_commit=False``). Returns ``(ancestor_index, adopted)`` —
    the caller restores contract state at ``ancestor_index`` and replays
    the adopted blocks' settlement records. On an adoption failure
    (tampered block mid-branch) the ledger is left at the consistent
    prefix ending in the last good block and the error propagates."""
    cur = ledger.head.hash
    if cur == new_head:
        return ledger.head.index, []
    anc = tree.ancestor(cur, new_head)
    anc_index = tree.height(anc)
    path = tree.chain_to(new_head)[anc_index - tree.height(tree.root) + 1:]
    ledger.rollback_to(anc_index)
    adopted: List[Block] = []
    for blk in path:
        ledger.adopt_block(blk, tree.commit(blk.hash),
                           verify_commit=verify_commit)
        adopted.append(blk)
    return anc_index, adopted
