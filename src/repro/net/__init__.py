"""`repro.net` — multi-node semi-decentralized settlement.

The paper's semi-decentralized layer, made multi-*node*: several chain
replicas (one per cluster head) gossip scores, cluster aggregates, and
sealed blocks over a deterministic simulated transport, agree via
longest-valid-chain fork choice with a cumulative-trust tiebreak, and
punish head misbehavior (equivocation, tampered super-roots) with
on-chain evidence and stake slashes.

Layers:

- ``repro.net.sim`` — ``SimNet``: seeded per-link latency/jitter/loss
  and timed partition windows on the shared simulated clock;
  byte-reproducible runs (the fault-injection harness).
- ``repro.net.fork_choice`` — ``BlockTree`` + ``apply_reorg``: fork
  tracking, (height, trust, hash) fork choice, rollback/replay through
  ``Ledger.rollback_to``/``adopt_block``.
- ``repro.net.node`` — ``SettlementNode`` (honest replica),
  ``EquivocatingNode``/``TamperingNode`` (byzantine heads),
  ``NetworkHarness`` (round driver), ``replay_chain`` (the
  single-node replay oracle the property tests compare against).
"""
from repro.net.fork_choice import (BlockTree, apply_reorg, block_trust,
                                   seal_info)
from repro.net.node import (AggregateGossip, BlockGossip, ChainRequest,
                            HeadAnnounce,
                            ChainResponse, EquivocatingNode, NetworkHarness,
                            ScoreGossip, SettlementNode, TamperingNode,
                            apply_block_state, contract_fingerprint,
                            head_worker, make_score_fn, replay_chain,
                            settlement_records)
from repro.net.sim import LinkSpec, Partition, SimNet

__all__ = [
    "LinkSpec", "Partition", "SimNet",
    "BlockTree", "apply_reorg", "block_trust", "seal_info",
    "ScoreGossip", "AggregateGossip", "BlockGossip", "ChainRequest",
    "ChainResponse", "HeadAnnounce", "SettlementNode", "EquivocatingNode",
    "TamperingNode",
    "NetworkHarness", "replay_chain", "settlement_records",
    "apply_block_state", "contract_fingerprint", "make_score_fn",
    "head_worker",
]
