"""Decoder-only transformer stack (dense / moe / vlm families).

Layout: params are nested dicts; per-layer params are *stacked* on a leading
layer dim and the stack is applied with ``lax.scan`` (keeps HLO size and
compile time flat in depth); ``jax.checkpoint`` on the scanned body gives the
activation-remat policy for training shapes.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models.layers import maybe, shard_dim
from repro.models.sharding import barrier, shard_residual


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_decoder_layer(key, cfg: ModelConfig, tp: int):
    dt = _dtype(cfg)
    k_attn, k_mlp = jax.random.split(key)
    if cfg.attn_type == "mla":
        attn, attn_s = L.init_mla(k_attn, cfg.d_model, cfg.num_heads, cfg.mla, tp, dt)
    else:
        attn, attn_s = L.init_gqa(k_attn, cfg.d_model, cfg.num_heads,
                                  cfg.num_kv_heads, cfg.resolved_head_dim, tp, dt)
    params = {"attn": attn,
              "norm1": jnp.ones((cfg.d_model,), dt),
              "norm2": jnp.ones((cfg.d_model,), dt)}
    specs = {"attn": attn_s, "norm1": P(None), "norm2": P(None)}
    if cfg.moe.enabled:
        params["moe"], specs["moe"] = MOE.init_moe(k_mlp, cfg.d_model, cfg.moe, tp, dt)
    else:
        params["mlp"], specs["mlp"] = L.init_swiglu(k_mlp, cfg.d_model, cfg.d_ff, tp, dt)
    return params, specs


def init_decoder(key, cfg: ModelConfig, tp: int):
    dt = _dtype(cfg)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    v = maybe(shard_dim(cfg.vocab_size, tp))
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    stacked = jax.vmap(lambda k: init_decoder_layer(k, cfg, tp)[0])(layer_keys)
    _, layer_specs = init_decoder_layer(layer_keys[0], cfg, tp)
    layer_specs = jax.tree.map(lambda s: P(None, *s), layer_specs,
                               is_leaf=lambda x: isinstance(x, P))
    params = {"embed": L.embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dt),
              "layers": stacked,
              "final_norm": jnp.ones((cfg.d_model,), dt)}
    specs = {"embed": P(v, None), "layers": layer_specs, "final_norm": P(None)}
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                         cfg.d_model, dt)
        specs["lm_head"] = P(None, v)
    return params, specs


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens, patch_embeds=None):
    """tokens: (B, S_text) int32. VLM: ``patch_embeds`` (B, P, d) prepended
    (early fusion — the stub VQ frontend's output)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    return x


def decoder_forward(params, cfg: ModelConfig, tokens, *, patch_embeds=None,
                    remat: bool = False, kv_chunk: int = 1024,
                    prefill_cache_len: int = 0, return_hidden: bool = False):
    """Returns (logits (B, S, V), aux_loss); in prefill mode
    (``prefill_cache_len > 0``) returns (last_logits (B, 1, V), cache) — the
    per-layer K/V emitted from the scan, zero-padded to the cache length."""
    x = embed_tokens(params, cfg, tokens, patch_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)
    prefill = prefill_cache_len > 0

    def body(carry, lp):
        x, aux = carry
        # barrier: stops XLA hoisting convert(whole checkpoint stack) out of
        # the backward loop (an f32 copy of all saved residuals)
        x = barrier(x)
        h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
        kv = None
        if cfg.attn_type == "mla":
            a = L.apply_mla(lp["attn"], h, num_heads=cfg.num_heads, mla=cfg.mla,
                            positions=positions, rope_theta=cfg.rope_theta,
                            kv_chunk=kv_chunk, return_kv=prefill)
        else:
            a = L.apply_gqa(lp["attn"], h, num_heads=cfg.num_heads,
                            num_kv_heads=cfg.num_kv_heads,
                            head_dim=cfg.resolved_head_dim, positions=positions,
                            rope_theta=cfg.rope_theta,
                            window=cfg.window if cfg.attn_type == "swa" else 0,
                            kv_chunk=kv_chunk, return_kv=prefill)
        if prefill:
            a, kv = a
            pad = prefill_cache_len - S
            kv = jax.tree.map(
                lambda t: jnp.pad(t.astype(jnp.dtype(cfg.dtype)),
                                  ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2)),
                kv)
        x = x + a
        h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
        if cfg.moe.enabled:
            m, aux_l = MOE.apply_moe(lp["moe"], h, cfg.moe)
        else:
            m, aux_l = L.apply_swiglu(lp["mlp"], h), 0.0
        return (shard_residual(x + m), aux + aux_l), kv

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), cache = jax.lax.scan(body, (x, 0.0), params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if prefill:
        return x[:, -1:, :] @ head, cache
    if return_hidden:
        return x, aux
    return x @ head, aux


# ---------------------------------------------------------------------------
# decode (single-token serve step with stacked per-layer KV cache)
# ---------------------------------------------------------------------------

def decoder_cache_shape(cfg: ModelConfig, batch: int, seq: int):
    if cfg.attn_type == "mla":
        per = L.mla_cache_shape(batch, seq, cfg.mla)
    else:
        per = L.gqa_cache_shape(batch, seq, cfg.num_kv_heads, cfg.resolved_head_dim)
    return {k: (cfg.num_layers,) + v for k, v in per.items()}


def decoder_cache_spec(cfg: ModelConfig, tp: int, data_axes):
    if cfg.attn_type == "mla":
        per = L.mla_cache_spec(data_axes, tp)
    else:
        per = L.gqa_cache_spec(cfg.num_kv_heads, tp, data_axes)
    return {k: P(None, *v) for k, v in per.items()}


def decoder_decode_step(params, cfg: ModelConfig, cache, tokens, cur_index):
    """tokens: (B, 1) — one new token per sequence. Returns (logits, cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)           # (B,1,d)
    positions = jnp.full((1,), cur_index)

    def body(x, inp):
        lp, layer_cache = inp
        # barrier: keep per-layer cache converts inside the loop (XLA would
        # otherwise hoist an f32 copy of the whole stacked cache out)
        layer_cache = barrier(layer_cache)
        h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
        if cfg.attn_type == "mla":
            a, new_cache = L.apply_mla(
                lp["attn"], h, num_heads=cfg.num_heads, mla=cfg.mla,
                positions=positions, rope_theta=cfg.rope_theta,
                cache=layer_cache, cur_index=cur_index)
        else:
            a, new_cache = L.apply_gqa(
                lp["attn"], h, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                positions=positions, rope_theta=cfg.rope_theta,
                window=cfg.window if cfg.attn_type == "swa" else 0,
                cache=layer_cache, cur_index=cur_index)
        x = x + a
        h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
        if cfg.moe.enabled:
            m, _ = MOE.apply_moe(lp["moe"], h, cfg.moe,
                                 capacity_factor=2 * cfg.moe.capacity_factor)
        else:
            m = L.apply_swiglu(lp["mlp"], h)
        return x + m, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_cache
