"""Mixture-of-Experts layer (qwen2-moe, olmoe).

Two mathematically-identical implementations:

* ``apply_moe(..., impl="gather")`` — production path. Per-expert top-C
  token selection (capacity-based, GShard-style dropping) + batched gather,
  expert matmuls batched over the expert dim (sharded over the ``model``
  mesh axis => expert parallelism), scatter-add combine. All ops are plain
  jnp => vmap-safe (needed by the FL worker dim) and GSPMD-shardable.
* ``apply_moe(..., impl="dense")`` — oracle: every token through every
  expert, mask-weighted. Used in tests to validate the gather path
  (identical outputs when capacity is not exceeded).

Experts are padded to a multiple of the TP axis so the expert dim shards
evenly (padded experts get -inf router logits => never selected).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init, maybe, shard_dim


def padded_experts(num_experts: int, tp: int) -> int:
    return int(math.ceil(num_experts / max(tp, 1)) * max(tp, 1))


def init_moe(key, d_model: int, moe_cfg, tp: int, dtype):
    """Router + routed experts (+ optional always-on shared experts)."""
    E = padded_experts(moe_cfg.num_experts, tp)
    f = moe_cfg.d_ff_expert
    ks = jax.random.split(key, 6)
    params = {
        "router": dense_init(ks[0], (d_model, E), d_model, jnp.float32),
        "w_gate": dense_init(ks[1], (E, d_model, f), d_model, dtype),
        "w_up": dense_init(ks[2], (E, d_model, f), d_model, dtype),
        "w_down": dense_init(ks[3], (E, f, d_model), f, dtype),
    }
    e = maybe(shard_dim(E, tp))
    fs = maybe(shard_dim(f, tp)) if e is None else None
    specs = {
        "router": P(None, None),
        "w_gate": P(e, None, fs), "w_up": P(e, None, fs), "w_down": P(e, fs, None),
    }
    if moe_cfg.num_shared_experts > 0:
        fsh = moe_cfg.num_shared_experts * moe_cfg.d_ff_shared
        sh = maybe(shard_dim(fsh, tp))
        params["shared"] = {
            "w_gate": dense_init(ks[4], (d_model, fsh), d_model, dtype),
            "w_up": dense_init(ks[5], (d_model, fsh), d_model, dtype),
            "w_down": dense_init(jax.random.fold_in(ks[5], 1), (fsh, d_model), fsh, dtype),
            "gate": dense_init(jax.random.fold_in(ks[4], 1), (d_model, 1), d_model, jnp.float32),
        }
        specs["shared"] = {"w_gate": P(None, sh), "w_up": P(None, sh),
                           "w_down": P(sh, None), "gate": P(None, None)}
    return params, specs


def _router_probs(params, x_flat, moe_cfg):
    """x_flat: (T, d) -> (probs (T, E) f32 with pads masked, logits)."""
    E_pad = params["router"].shape[1]
    logits = x_flat.astype(jnp.float32) @ params["router"]
    pad_mask = jnp.arange(E_pad) < moe_cfg.num_experts
    logits = jnp.where(pad_mask[None, :], logits, -1e30)
    return jax.nn.softmax(logits, axis=-1), logits


def _topk_weights(probs, top_k: int):
    """(T, E) -> sparse weight matrix (T, E): renormalized top-k probs."""
    T, E = probs.shape
    vals, idx = jax.lax.top_k(probs, top_k)                 # (T, k)
    vals = vals / jnp.maximum(jnp.sum(vals, axis=-1, keepdims=True), 1e-9)
    w = jnp.zeros((T, E), jnp.float32)
    w = w.at[jnp.arange(T)[:, None], idx].set(vals)
    return w


def _aux_losses(probs, w, logits, moe_cfg):
    """GShard load-balance loss + router z-loss."""
    E = moe_cfg.num_experts
    frac_routed = jnp.mean((w > 0).astype(jnp.float32), axis=0) * E   # (E_pad,)
    mean_prob = jnp.mean(probs, axis=0) * E
    lb = jnp.sum(frac_routed * mean_prob) / E
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return moe_cfg.router_aux_loss * lb + moe_cfg.router_z_loss * z


def _expert_ffn(params, xe):
    """xe: (E, C, d) -> (E, C, d); batched-over-experts SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["w_down"])


def apply_moe(params, x, moe_cfg, *, capacity_factor: float = 0.0,
              impl: str = "gather") -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d). Returns (out (B, S, d), aux_loss scalar).
    capacity_factor 0 => take moe_cfg.capacity_factor."""
    capacity_factor = capacity_factor or moe_cfg.capacity_factor
    B, S, d = x.shape
    T = B * S
    x_flat = x.reshape(T, d)
    probs, logits = _router_probs(params, x_flat, moe_cfg)
    w = _topk_weights(probs, moe_cfg.top_k)                 # (T, E_pad)
    aux = _aux_losses(probs, w, logits, moe_cfg)
    E_pad = w.shape[1]

    if impl == "dense":
        # oracle: all tokens through all experts, weighted combine
        g = jnp.einsum("td,edf->tef", x_flat, params["w_gate"])
        u = jnp.einsum("td,edf->tef", x_flat, params["w_up"])
        y = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, params["w_down"])
        out = jnp.einsum("ted,te->td", y.astype(jnp.float32), w)
    else:
        # capacity-based: per-expert top-C tokens by routing weight
        C = max(1, int(math.ceil(moe_cfg.top_k * T / moe_cfg.num_experts
                                 * capacity_factor)))
        C = min(C, T)
        w_e = w.T                                           # (E_pad, T)
        top_w, top_idx = jax.lax.top_k(w_e, C)              # (E_pad, C)
        xe = jnp.take(x_flat, top_idx.reshape(-1), axis=0)
        xe = xe.reshape(E_pad, C, d)                        # expert-batched gather
        ye = _expert_ffn(params, xe).astype(jnp.float32)
        ye = ye * top_w[..., None]                          # dropped tokens have w=0
        out = jnp.zeros((T, d), jnp.float32)
        out = out.at[top_idx.reshape(-1)].add(ye.reshape(E_pad * C, d))

    if "shared" in params:
        sp = params["shared"]
        shared = (jax.nn.silu(x_flat @ sp["w_gate"]) * (x_flat @ sp["w_up"])) @ sp["w_down"]
        gate = jax.nn.sigmoid(x_flat.astype(jnp.float32) @ sp["gate"])
        out = out + gate * shared.astype(jnp.float32)

    return out.reshape(B, S, d).astype(x.dtype), aux
