"""Shared model-zoo building blocks.

Pure-functional: params are nested dicts of jnp arrays; every ``init_*``
returns ``(params, specs)`` where ``specs`` mirrors ``params`` with
``jax.sharding.PartitionSpec`` leaves (TP over the ``model`` mesh axis,
replicated where a dim doesn't divide the axis size).

Attention never materializes the (Sq, Skv) score matrix for long sequences:
``blocked_attention`` runs an online-softmax scan over KV chunks
(flash-attention structure, pure JAX — the Pallas ``swa_decode`` kernel in
``repro.kernels`` is the TPU-tiled decode variant).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# The TP mesh axis name used by every spec in the zoo.
TP_AXIS = "model"


def shard_dim(size: int, tp: int) -> bool:
    """Whether a dim of ``size`` can be TP-sharded over ``tp`` devices."""
    return tp > 1 and size % tp == 0


def maybe(axis_ok: bool):
    return TP_AXIS if axis_ok else None


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_dim: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x, weight, eps: float = 1e-5):
    """RMSNorm with a hand-written VJP whose cotangents live in the PRIMAL
    dtype. With the autodiff VJP, XLA fuses the f32 upcast of dx into the
    producing TP matmul and then all-reduces the residual cotangent in f32 —
    2x the collective bytes of the bf16 boundary (measured: the dominant
    collective of the 34B train step)."""
    return _rms_fwd(x, weight, eps)[0]


def _rms_fwd(x, weight, eps):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    y = (xf * r * weight).astype(dt)
    return y, (x, weight, r)


def _rms_bwd(eps, res, g):
    x, weight, r = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    gw = gf * weight.astype(jnp.float32)
    xhat = xf * r
    dx = r * (gw - xhat * jnp.mean(gw * xhat, axis=-1, keepdims=True))
    dw = jnp.sum(gf * xhat, axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dw.astype(weight.dtype)


rms_norm.defvjp(_rms_fwd, _rms_bwd)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                           # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q: (B, Sq, KV, G, hd), k: (B, Skv, KV, hd) -> (B, KV, G, Sq, Skv) f32.
    Inputs stay in their storage dtype (bf16) with f32 MXU accumulation —
    casting the operands would let XLA hoist a full-precision copy of the
    whole KV cache out of the layer scan."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                      preferred_element_type=jnp.float32)


def blocked_attention(q, k, v, *, q_positions, kv_positions, causal: bool = True,
                      window: int = 0, kv_chunk: int = 1024):
    """Online-softmax attention over KV chunks — O(Sq·chunk) live memory.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with H % KV == 0.
    window > 0 => sliding-window mask (q_pos - kv_pos < window).
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qs = q.reshape(B, Sq, KV, G, hd) * scale

    if Skv <= kv_chunk or Skv % kv_chunk != 0:
        # direct path (small or non-chunk-aligned KV, e.g. whisper's 1500
        # encoder frames)
        s = _gqa_scores(qs, k)                              # (B,KV,G,Sq,Skv)
        mask = _attn_mask(q_positions, kv_positions, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, Sq, H, hd).astype(q.dtype)
    n_chunks = Skv // kv_chunk
    kc = k.reshape(B, n_chunks, kv_chunk, KV, hd)
    vc = v.reshape(B, n_chunks, kv_chunk, KV, hd)
    kvpos = kv_positions.reshape(n_chunks, kv_chunk)

    # remat the chunk body: backward recomputes per-chunk scores instead of
    # stacking (n_chunks, B, KV, G, Sq, chunk) f32 residuals (flash-style)
    @jax.checkpoint
    def body(carry, inp):
        m, l, acc = carry
        k_i, v_i, pos_i = inp
        s = _gqa_scores(qs, k_i)                            # (B,KV,G,Sq,chunk)
        mask = _attn_mask(q_positions, pos_i, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v_i.dtype), v_i,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), kvpos))
    o = acc / jnp.maximum(l, 1e-30)[..., None]              # (B,KV,G,Sq,hd)
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd).astype(q.dtype)


def _attn_mask(q_pos, kv_pos, causal: bool, window: int):
    """(Sq, Skv) boolean mask: True = attend."""
    dq = q_pos[:, None]
    dk = kv_pos[None, :]
    mask = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        mask &= dk <= dq
    if window > 0:
        mask &= (dq - dk) < window
    return mask


def decode_attention(q, k_cache, v_cache, *, cur_index, window: int = 0):
    """Single-token decode: q (B, 1, H, hd) vs cache (B, S, KV, hd).

    ``cur_index``: scalar position of the new token; cache slots >= cur_index
    are masked (and slots outside the sliding window when ``window > 0``).
    Linear in cache length — the sub-quadratic decode path.
    """
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qs = (q.reshape(B, KV, G, hd) * scale).astype(k_cache.dtype)
    s = jnp.einsum("bkgh,bskh->bkgs", qs, k_cache,
                   preferred_element_type=jnp.float32)      # (B,KV,G,S)
    pos = jnp.arange(S)
    valid = pos <= cur_index
    if window > 0:
        valid &= (cur_index - pos) < window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (llama/yi/smollm/danube/whisper-self/zamba-shared)
# ---------------------------------------------------------------------------

def init_gqa(key, d_model: int, num_heads: int, num_kv_heads: int,
             head_dim: int, tp: int, dtype):
    ks = jax.random.split(key, 4)
    hq, hkv = num_heads * head_dim, num_kv_heads * head_dim
    params = {
        "wq": dense_init(ks[0], (d_model, hq), d_model, dtype),
        "wk": dense_init(ks[1], (d_model, hkv), d_model, dtype),
        "wv": dense_init(ks[2], (d_model, hkv), d_model, dtype),
        "wo": dense_init(ks[3], (hq, d_model), hq, dtype),
    }
    specs = {
        "wq": P(None, maybe(shard_dim(num_heads, tp))),
        "wk": P(None, maybe(shard_dim(num_kv_heads, tp))),
        "wv": P(None, maybe(shard_dim(num_kv_heads, tp))),
        "wo": P(maybe(shard_dim(num_heads, tp)), None),
    }
    return params, specs


def apply_gqa(params, x, *, num_heads: int, num_kv_heads: int, head_dim: int,
              positions, rope_theta: float, causal: bool = True,
              window: int = 0, kv_chunk: int = 1024,
              cache=None, cur_index=None, cross_kv=None,
              return_kv: bool = False):
    """x: (B, S, d). If ``cache`` is given (decode): S == 1, returns
    (out, new_cache). ``cross_kv=(k, v)`` bypasses self-attn KV projections'
    inputs (whisper cross-attention: kv from encoder states).
    ``return_kv``: prefill mode — also return the projected (k, v) so the
    caller can populate a decode cache."""
    from repro.models.sharding import gather_weight as gw
    B, S, _ = x.shape
    q = (x @ gw(params["wq"])).reshape(B, S, num_heads, head_dim)
    if cross_kv is None:
        k = (x @ gw(params["wk"])).reshape(B, S, num_kv_heads, head_dim)
        v = (x @ gw(params["wv"])).reshape(B, S, num_kv_heads, head_dim)
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    else:
        enc = cross_kv
        Se = enc.shape[1]
        k = (enc @ params["wk"]).reshape(B, Se, num_kv_heads, head_dim)
        v = (enc @ params["wv"]).reshape(B, Se, num_kv_heads, head_dim)

    if cache is not None and cross_kv is None:
        # decode: write this token's k/v at cur_index, attend over cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cur_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cur_index, axis=1)
        o = decode_attention(q, k_cache, v_cache, cur_index=cur_index, window=window)
        new_cache = {"k": k_cache, "v": v_cache}
        return (o.reshape(B, S, -1) @ params["wo"]), new_cache

    if cross_kv is not None:
        kv_pos = jnp.arange(k.shape[1])
        o = blocked_attention(q, k, v, q_positions=positions, kv_positions=kv_pos,
                              causal=False, window=0, kv_chunk=kv_chunk)
    else:
        from repro.models.sharding import replicate_kv
        k2, v2 = replicate_kv(k, v)
        o = blocked_attention(q, k2, v2, q_positions=positions,
                              kv_positions=positions, causal=causal,
                              window=window, kv_chunk=kv_chunk)
    out = o.reshape(B, S, -1) @ gw(params["wo"])
    if return_kv:
        return out, {"k": k, "v": v}
    return out


def gqa_cache_shape(batch: int, seq: int, num_kv_heads: int, head_dim: int):
    return {"k": (batch, seq, num_kv_heads, head_dim),
            "v": (batch, seq, num_kv_heads, head_dim)}


def gqa_cache_spec(num_kv_heads: int, tp: int, data_axes):
    h = maybe(shard_dim(num_kv_heads, tp))
    if h is None and tp > 1:
        # few KV heads (GQA): shard the cache SEQUENCE over the TP axis
        # instead — decode attention becomes a partial softmax + tiny psum
        # (flash-decode) rather than a replicated-cache reshuffle.
        return {"k": P(data_axes, TP_AXIS, None, None),
                "v": P(data_axes, TP_AXIS, None, None)}
    return {"k": P(data_axes, None, h, None), "v": P(data_axes, None, h, None)}


# ---------------------------------------------------------------------------
# MLA attention (minicpm3 / deepseek-style latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, d_model: int, num_heads: int, mla, tp: int, dtype):
    ks = jax.random.split(key, 8)
    qk_hd = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    params = {
        "wq_a": dense_init(ks[0], (d_model, mla.q_lora_rank), d_model, dtype),
        "q_a_norm": jnp.ones((mla.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], (mla.q_lora_rank, num_heads * qk_hd), mla.q_lora_rank, dtype),
        "wkv_a": dense_init(ks[2], (d_model, mla.kv_lora_rank + mla.qk_rope_head_dim), d_model, dtype),
        "kv_a_norm": jnp.ones((mla.kv_lora_rank,), dtype),
        "wkv_b": dense_init(ks[3], (mla.kv_lora_rank, num_heads * (mla.qk_nope_head_dim + mla.v_head_dim)), mla.kv_lora_rank, dtype),
        "wo": dense_init(ks[4], (num_heads * mla.v_head_dim, d_model), num_heads * mla.v_head_dim, dtype),
    }
    h = maybe(shard_dim(num_heads, tp))
    r = maybe(shard_dim(mla.q_lora_rank, tp))
    specs = {
        "wq_a": P(None, r), "q_a_norm": P(r),
        "wq_b": P(r, h),
        "wkv_a": P(None, None), "kv_a_norm": P(None),
        "wkv_b": P(None, h),
        "wo": P(h, None),
    }
    return params, specs


def apply_mla(params, x, *, num_heads: int, mla, positions, rope_theta: float,
              kv_chunk: int = 1024, cache=None, cur_index=None,
              return_kv: bool = False):
    """MLA: queries through a low-rank bottleneck; K/V through a compressed
    latent (kv_lora_rank) + a decoupled RoPE key shared across heads.
    The decode cache stores the *latent* (B, S, kv_lora_rank + rope_dim) —
    the MLA memory win."""
    B, S, _ = x.shape
    nope, rd, vd = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    qk_hd = nope + rd

    q = rms_norm(x @ params["wq_a"], params["q_a_norm"])
    q = (q @ params["wq_b"]).reshape(B, S, num_heads, qk_hd)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    kv_a = x @ params["wkv_a"]                              # (B,S,rank+rd)
    latent, k_rope = kv_a[..., :mla.kv_lora_rank], kv_a[..., mla.kv_lora_rank:]
    latent = rms_norm(latent, params["kv_a_norm"])
    k_rope = apply_rope(k_rope[..., None, :], positions, rope_theta)  # (B,S,1,rd)

    if cache is not None:
        # ABSORBED decode (DeepSeek-V2-style serving form): attention runs in
        # the compressed latent space — the cache is never expanded to
        # per-head K/V. q̃_h = W_kvb_k(h)ᵀ q_nope_h ∈ R^rank;
        # score_i = q̃·latent_i + q_rope·k_rope_i; out_h = W_kvb_v(h) (p·latent).
        lat_entry = jnp.concatenate([latent, k_rope[..., 0, :]], axis=-1)
        lat_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["latent"], lat_entry.astype(cache["latent"].dtype), cur_index, axis=1)
        rank = mla.kv_lora_rank
        lat_dt = lat_cache.dtype
        latent_all = lat_cache[..., :rank]                       # (B,Sc,r)
        k_rope_all = lat_cache[..., rank:]                       # (B,Sc,rd)
        wkv = params["wkv_b"].reshape(rank, num_heads, nope + vd)
        w_k, w_v = wkv[..., :nope], wkv[..., nope:]
        scale = 1.0 / math.sqrt(qk_hd)
        qh = (q[:, 0] * scale).astype(lat_dt)                    # (B,H,qk_hd)
        q_til = jnp.einsum("bhn,rhn->bhr", qh[..., :nope],
                           w_k.astype(lat_dt),
                           preferred_element_type=jnp.float32).astype(lat_dt)
        s = (jnp.einsum("bhr,bsr->bhs", q_til, latent_all,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bhd,bsd->bhs", qh[..., nope:], k_rope_all,
                          preferred_element_type=jnp.float32))
        Sc = lat_cache.shape[1]
        pos = jnp.arange(Sc)
        s = jnp.where((pos <= cur_index)[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhs,bsr->bhr", p.astype(lat_dt), latent_all,
                         preferred_element_type=jnp.float32)     # (B,H,r)
        o = jnp.einsum("bhr,rhv->bhv", ctx.astype(lat_dt),
                       w_v.astype(lat_dt),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        return (o.reshape(B, S, -1) @ params["wo"]), {"latent": lat_cache}

    kv = (latent @ params["wkv_b"]).reshape(B, S, num_heads, nope + vd)
    k = jnp.concatenate([kv[..., :nope],
                         jnp.broadcast_to(k_rope, (B, S, num_heads, rd))], axis=-1)
    v = kv[..., nope:]
    # pad v to qk head dim for the shared blocked core, then slice back
    o = blocked_attention(q, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_hd - vd))),
                          q_positions=positions, kv_positions=positions,
                          causal=True, kv_chunk=kv_chunk)[..., :vd]
    out = o.reshape(B, S, -1) @ params["wo"]
    if return_kv:
        # MLA prefill cache: the compressed latent + decoupled rope key
        return out, {"latent": jnp.concatenate([latent, k_rope[..., 0, :]], axis=-1)}
    return out


def mla_cache_shape(batch: int, seq: int, mla):
    return {"latent": (batch, seq, mla.kv_lora_rank + mla.qk_rope_head_dim)}


def mla_cache_spec(data_axes, tp: int = 1):
    # the compressed latent has no head dim: shard its sequence over TP
    return {"latent": P(data_axes, TP_AXIS if tp > 1 else None, None)}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(key, d_model: int, d_ff: int, tp: int, dtype):
    ks = jax.random.split(key, 3)
    f = maybe(shard_dim(d_ff, tp))
    params = {
        "w_gate": dense_init(ks[0], (d_model, d_ff), d_model, dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), d_model, dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), d_ff, dtype),
    }
    specs = {"w_gate": P(None, f), "w_up": P(None, f), "w_down": P(f, None)}
    return params, specs


def apply_swiglu(params, x):
    from repro.models.sharding import gather_weight as gw
    return (jax.nn.silu(x @ gw(params["w_gate"]))
            * (x @ gw(params["w_up"]))) @ gw(params["w_down"])


def init_gelu_mlp(key, d_model: int, d_ff: int, tp: int, dtype):
    ks = jax.random.split(key, 2)
    f = maybe(shard_dim(d_ff, tp))
    params = {
        "w_in": dense_init(ks[0], (d_model, d_ff), d_model, dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(ks[1], (d_ff, d_model), d_ff, dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }
    specs = {"w_in": P(None, f), "b_in": P(f), "w_out": P(f, None), "b_out": P(None)}
    return params, specs


def apply_gelu_mlp(params, x):
    return jax.nn.gelu(x @ params["w_in"] + params["b_in"]) @ params["w_out"] + params["b_out"]
