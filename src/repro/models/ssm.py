"""State-space blocks: Mamba2 (SSD), xLSTM (mLSTM + sLSTM).

The shared compute core is ``chunked_decay_attention`` — chunkwise
linear-attention-with-scalar-decay:

    y_t = q_t · ( Σ_{j<=t}  exp(Σ_{l=j+1..t} a_l) · i_j · (k_j ⊗ v_j) )

which covers Mamba2's SSD (q=C, k=B, v=x, a=Δ·A, i=Δ) and mLSTM
(q, k, v projections; a=log f gate; i=exp input gate, stabilized).
Intra-chunk work is quadratic in the chunk (Q²·MXU-friendly), inter-chunk
state is carried by a scan — O(S·Q) total, never O(S²): the sub-quadratic
long-context path for SSM/hybrid architectures.

Everything is plain jnp (vmap-safe for the FL worker dim, GSPMD-shardable).
Recurrences run in float32 for stability; block edges cast back.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init, maybe, rms_norm, shard_dim

MAMBA_HEAD_DIM = 64


# ---------------------------------------------------------------------------
# chunked decay attention (SSD core)
# ---------------------------------------------------------------------------

def _segsum(a):
    """a: (..., Q) log-decays -> (..., Q, Q) with out[i,j] = sum(a[j+1..i]),
    -inf above the diagonal."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]              # sum(a[j+1..i])
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def chunked_decay_attention(q, k, v, a, i, *, chunk: int,
                            initial_state=None, return_state: bool = False):
    """q: (B,S,H,dk), k: (B,S,H,dk), v: (B,S,H,dv), a: (B,S,H) log-decay,
    i: (B,S,H) input scale. Returns (y (B,S,H,dv)[, final_state (B,H,dk,dv)]).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    f32 = jnp.float32
    qc = q.astype(f32).reshape(B, nc, chunk, H, dk)
    kc = k.astype(f32).reshape(B, nc, chunk, H, dk)
    vc = v.astype(f32).reshape(B, nc, chunk, H, dv)
    ac = a.astype(f32).reshape(B, nc, chunk, H)
    ic = i.astype(f32).reshape(B, nc, chunk, H)

    # --- intra-chunk (quadratic in chunk) ---
    L = jnp.exp(_segsum(jnp.moveaxis(ac, 3, 2)))            # (B,nc,H,Q,Q)
    scores = jnp.einsum("bnqhd,bnshd->bnhqs", qc, kc)       # (B,nc,H,Q,Q)
    gated = scores * L * jnp.moveaxis(ic, 3, 2)[..., None, :]
    y_intra = jnp.einsum("bnhqs,bnshv->bnqhv", gated, vc)

    # --- chunk summary states: S_n = Σ_j exp(Σ_{l>j} a) i_j k_j ⊗ v_j ---
    cum = jnp.cumsum(ac, axis=2)                            # (B,nc,Q,H)
    total = cum[:, :, -1:, :]                               # (B,nc,1,H)
    decay_to_end = jnp.exp(total - cum)                     # exp(sum a[j+1..Q])
    state_n = jnp.einsum("bnqh,bnqhd,bnqhv->bnhdv",
                         decay_to_end * ic, kc, vc)         # (B,nc,H,dk,dv)

    # --- inter-chunk recurrence over chunk index ---
    chunk_decay = jnp.exp(total[:, :, 0, :])                # (B,nc,H)

    def scan_body(h_prev, inp):
        s_n, dec = inp                                      # (B,H,dk,dv),(B,H)
        h_new = h_prev * dec[..., None, None] + s_n
        return h_new, h_prev                                # emit state *before* chunk

    h0 = (jnp.zeros((B, H, dk, dv), f32) if initial_state is None
          else initial_state.astype(f32))
    h_final, h_before = jax.lax.scan(
        scan_body, h0,
        (jnp.moveaxis(state_n, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_before = jnp.moveaxis(h_before, 0, 1)                 # (B,nc,H,dk,dv)

    # --- inter-chunk contribution: q_t · (decay-to-t · h_before) ---
    decay_from_start = jnp.exp(cum)                         # exp(sum a[1..t])
    y_inter = jnp.einsum("bnqhd,bnhdv->bnqhv", qc, h_before)
    y_inter = y_inter * jnp.moveaxis(decay_from_start, 2, 2)[..., None]

    y = (y_intra + y_inter).reshape(B, S, H, dv)
    if return_state:
        return y.astype(v.dtype), h_final
    return y.astype(v.dtype)


def decay_attention_step(q, k, v, a, i, state):
    """Single decode step. q,k: (B,H,dk); v: (B,H,dv); a,i: (B,H);
    state: (B,H,dk,dv). Returns (y (B,H,dv), new_state)."""
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    new_state = (state * jnp.exp(a)[..., None, None].astype(f32)
                 + i[..., None, None].astype(f32) * k[..., :, None] * v[..., None, :])
    y = jnp.einsum("bhd,bhdv->bhv", q, new_state)
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba2_dims(d_model: int, ssm_cfg):
    d_inner = ssm_cfg.expand * d_model
    nheads = d_inner // MAMBA_HEAD_DIM
    return d_inner, nheads


def init_mamba2(key, d_model: int, ssm_cfg, tp: int, dtype):
    d_inner, nheads = mamba2_dims(d_model, ssm_cfg)
    N, cw = ssm_cfg.state_dim, ssm_cfg.conv_width
    ks = jax.random.split(key, 8)
    params = {
        "w_z": dense_init(ks[0], (d_model, d_inner), d_model, dtype),
        "w_x": dense_init(ks[1], (d_model, d_inner), d_model, dtype),
        "w_bc": dense_init(ks[2], (d_model, 2 * N), d_model, dtype),
        "w_dt": dense_init(ks[3], (d_model, nheads), d_model, dtype),
        "conv_x": (jax.random.normal(ks[4], (cw, d_inner)) * 0.1).astype(dtype),
        "conv_bc": (jax.random.normal(ks[5], (cw, 2 * N)) * 0.1).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "norm": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[6], (d_inner, d_model), d_inner, dtype),
    }
    c = maybe(shard_dim(d_inner, tp))
    h = maybe(shard_dim(nheads, tp))
    specs = {
        "w_z": P(None, c), "w_x": P(None, c), "w_bc": P(None, None),
        "w_dt": P(None, h), "conv_x": P(None, c), "conv_bc": P(None, None),
        "A_log": P(h), "dt_bias": P(h), "D": P(h),
        "norm": P(c), "w_out": P(c, None),
    }
    return params, specs


def _causal_conv(x, w, conv_state=None):
    """Depthwise causal conv. x: (B,S,C), w: (cw,C).
    With conv_state (B,cw-1,C): single/streaming step, returns new state."""
    cw = w.shape[0]
    if conv_state is None:
        pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    windows = jnp.stack([pad[:, i:i + x.shape[1]] for i in range(cw)], axis=-1)
    out = jnp.einsum("bscw,wc->bsc", windows.astype(jnp.float32),
                     w.astype(jnp.float32)).astype(x.dtype)
    out = jax.nn.silu(out)
    if conv_state is None:
        return out, None
    return out, pad[:, -(cw - 1):]


def apply_mamba2(params, x, ssm_cfg, *, state=None, conv_state=None,
                 return_state: bool = False):
    """x: (B,S,d). Prefill/train when state is None; else decode (S==1).
    Decode returns (out, (ssm_state, conv_states)); prefill with
    ``return_state`` returns the same tuple (cache hand-off to decode)."""
    B, S, d = x.shape
    d_inner, nheads = params["w_x"].shape[1], params["A_log"].shape[0]
    N = ssm_cfg.state_dim
    cw = params["conv_x"].shape[0]
    z = x @ params["w_z"]
    xi = x @ params["w_x"]
    bc = x @ params["w_bc"]
    dt_raw = x @ params["w_dt"]

    decode = state is not None
    cs_x = cs_bc = None
    if decode:
        cs_x, cs_bc = conv_state
    elif return_state:
        # raw pre-conv tails become the streaming conv state
        cs_x = xi[:, -(cw - 1):]
        cs_bc = bc[:, -(cw - 1):]
    xi, cs_x_dec = _causal_conv(xi, params["conv_x"], cs_x if decode else None)
    bc, cs_bc_dec = _causal_conv(bc, params["conv_bc"], cs_bc if decode else None)
    if decode:
        cs_x, cs_bc = cs_x_dec, cs_bc_dec
    B_, C_ = bc[..., :N], bc[..., N:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                           # (H,) negative
    a = dt * A                                              # (B,S,H) log decay
    xh = xi.reshape(B, S, nheads, MAMBA_HEAD_DIM)
    # B_, C_ shared across heads (n_groups=1)
    k = jnp.broadcast_to(B_[:, :, None, :], (B, S, nheads, N))
    q = jnp.broadcast_to(C_[:, :, None, :], (B, S, nheads, N))

    if decode:
        y, new_state = decay_attention_step(
            q[:, 0], k[:, 0], xh[:, 0], a[:, 0], dt[:, 0], state)
        y = y[:, None]                                      # (B,1,H,P)
    elif return_state:
        y, new_state = chunked_decay_attention(
            q, k, xh, a, dt, chunk=min(ssm_cfg.chunk_size, S),
            return_state=True)
    else:
        y = chunked_decay_attention(q, k, xh, a, dt, chunk=min(ssm_cfg.chunk_size, S))
        new_state = None

    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), params["norm"])
    out = y @ params["w_out"]
    if decode or return_state:
        return out, (new_state, (cs_x, cs_bc))
    return out


def mamba2_state_shape(batch: int, d_model: int, ssm_cfg):
    d_inner, nheads = mamba2_dims(d_model, ssm_cfg)
    cw = ssm_cfg.conv_width
    return {"ssm": (batch, nheads, ssm_cfg.state_dim, MAMBA_HEAD_DIM),
            "conv_x": (batch, cw - 1, d_inner),
            "conv_bc": (batch, cw - 1, 2 * ssm_cfg.state_dim)}


def mamba2_state_spec(d_model: int, ssm_cfg, tp: int, data_axes):
    _, nheads = mamba2_dims(d_model, ssm_cfg)
    h = maybe(shard_dim(nheads, tp))
    d_inner, _ = mamba2_dims(d_model, ssm_cfg)
    c = maybe(shard_dim(d_inner, tp))
    return {"ssm": P(data_axes, h, None, None),
            "conv_x": P(data_axes, None, c),
            "conv_bc": P(data_axes, None, None)}


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM) — matrix memory, exp gating, chunked via SSD core
# ---------------------------------------------------------------------------

def init_mlstm(key, d_model: int, ssm_cfg, tp: int, dtype):
    d_inner = ssm_cfg.expand * d_model
    H = max(ssm_cfg.num_ssm_heads, 1)
    dh = d_inner // H
    ks = jax.random.split(key, 8)
    c = maybe(shard_dim(d_inner, tp))
    params = {
        "w_up": dense_init(ks[0], (d_model, 2 * d_inner), d_model, dtype),
        "conv": (jax.random.normal(ks[1], (ssm_cfg.conv_width, d_inner)) * 0.1).astype(dtype),
        # headwise (block-diagonal) q/k/v, as in the released xLSTM
        "w_q": dense_init(ks[2], (H, dh, dh), dh, dtype),
        "w_k": dense_init(ks[3], (H, dh, dh), dh, dtype),
        "w_v": dense_init(ks[4], (H, dh, dh), dh, dtype),
        "w_i": dense_init(ks[5], (d_inner, H), d_inner, jnp.float32),
        "w_f": dense_init(ks[6], (d_inner, H), d_inner, jnp.float32),
        "f_bias": jnp.full((H,), 3.0, jnp.float32),   # open forget gates at init
        "norm": jnp.ones((d_inner,), dtype),
        "w_down": dense_init(ks[7], (d_inner, d_model), d_inner, dtype),
    }
    h = maybe(shard_dim(H, tp))
    k = maybe(shard_dim(dh, tp)) if h is None else None
    specs = {
        "w_up": P(None, None), "conv": P(None, c),
        "w_q": P(h, None, k), "w_k": P(h, None, k), "w_v": P(h, None, k),
        "w_i": P(None, None), "w_f": P(None, None), "f_bias": P(None),
        "norm": P(c), "w_down": P(c, None),
    }
    return params, specs


def apply_mlstm(params, x, ssm_cfg, *, state=None, conv_state=None,
                chunk: int = 256, return_state: bool = False):
    """x: (B,S,d). mLSTM via the decay-attention core with a = logsigmoid(f̃)
    and i = exp-gate folded into the input scale (stabilized by clamping —
    the chunked log-space max-stabilizer is applied inside per-chunk)."""
    B, S, d = x.shape
    d_inner = params["w_down"].shape[0]
    H = params["f_bias"].shape[0]
    dh = d_inner // H
    up = x @ params["w_up"]
    xp, z = up[..., :d_inner], up[..., d_inner:]

    decode = state is not None
    cw = params["conv"].shape[0]
    cs = conv_state if decode else None
    if not decode and return_state:
        tail = xp[:, -(cw - 1):]
    xc, cs = _causal_conv(xp, params["conv"], cs)
    if not decode and return_state:
        cs = tail

    xh = xc.reshape(B, S, H, dh)
    q = jnp.einsum("bshd,hde->bshe", xh, params["w_q"]) * (dh ** -0.5)
    k = jnp.einsum("bshd,hde->bshe", xh, params["w_k"]) * (dh ** -0.5)
    v = jnp.einsum("bshd,hde->bshe", xh, params["w_v"])
    f_t = xc.astype(jnp.float32) @ params["w_f"] + params["f_bias"]
    i_t = xc.astype(jnp.float32) @ params["w_i"]
    a = jax.nn.log_sigmoid(f_t)                             # (B,S,H) log decay
    i = jnp.exp(jnp.clip(i_t, -10.0, 10.0))                 # clamped exp gate

    # augmented value channel tracks the normalizer n_t = Σ decay·i·k-weight
    v_aug = jnp.concatenate([v.astype(jnp.float32),
                             jnp.ones((B, S, H, 1), jnp.float32)], axis=-1)
    if decode:
        y, new_state = decay_attention_step(
            q[:, 0], k[:, 0], v_aug[:, 0], a[:, 0], i[:, 0], state)
        y = y[:, None]
    elif return_state:
        y, new_state = chunked_decay_attention(q, k, v_aug, a, i,
                                               chunk=min(chunk, S),
                                               return_state=True)
    else:
        y = chunked_decay_attention(q, k, v_aug, a, i, chunk=min(chunk, S))
        new_state = None
    y, n = y[..., :dh], y[..., dh:]
    y = y / jnp.maximum(jnp.abs(n), 1.0)                    # xLSTM normalizer

    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = y @ params["w_down"]
    if decode or return_state:
        return out, (new_state, cs)
    return out


def mlstm_state_shape(batch: int, d_model: int, ssm_cfg):
    d_inner = ssm_cfg.expand * d_model
    H = max(ssm_cfg.num_ssm_heads, 1)
    dh = d_inner // H
    return {"ssm": (batch, H, dh, dh + 1),
            "conv": (batch, ssm_cfg.conv_width - 1, d_inner)}


def mlstm_state_spec(d_model: int, ssm_cfg, tp: int, data_axes):
    d_inner = ssm_cfg.expand * d_model
    c = maybe(shard_dim(d_inner, tp))
    H = max(ssm_cfg.num_ssm_heads, 1)
    dh = d_inner // H
    k = maybe(shard_dim(dh, tp))
    return {"ssm": P(data_axes, None, k, None),
            "conv": P(data_axes, None, c)}


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — scalar memory, strictly sequential scan
# ---------------------------------------------------------------------------

def init_slstm(key, d_model: int, num_heads: int, tp: int, dtype):
    dh = d_model // num_heads
    ks = jax.random.split(key, 4)
    ffn = int(d_model * 4 / 3)
    ffn = (ffn + 127) // 128 * 128                          # lane-align
    f = maybe(shard_dim(ffn, tp))
    params = {
        # 4 gates (i, f, z, o) from input and block-diag recurrent R per head
        "w_gates": dense_init(ks[0], (d_model, 4 * d_model), d_model, dtype),
        "r_gates": (jax.random.normal(ks[1], (num_heads, dh, 4 * dh)) /
                    math.sqrt(dh)).astype(dtype),
        "b_gates": jnp.zeros((4 * d_model,), jnp.float32),
        "norm": jnp.ones((d_model,), dtype),
        "ffn_up": dense_init(ks[2], (d_model, 2 * ffn), d_model, dtype),
        "ffn_down": dense_init(ks[3], (ffn, d_model), ffn, dtype),
    }
    specs = {
        "w_gates": P(None, None), "r_gates": P(None, None, None),
        "b_gates": P(None), "norm": P(None),
        "ffn_up": P(None, f), "ffn_down": P(f, None),
    }
    return params, specs


def _slstm_gates(g, c, n, m, num_heads):
    """Gate math given pre-activations g: (B, 4d). The stabilizer m is a
    pure numerical device (h is exactly invariant to it), so it carries
    stop_gradient — gradients stay exact and the hand-written VJP below
    never differentiates through the max."""
    B = g.shape[0]
    d = g.shape[1] // 4
    dh = d // num_heads
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    gi_h = gi.reshape(B, num_heads, dh)
    gf_h = gf.reshape(B, num_heads, dh)
    fi = jnp.max(gf_h, axis=-1) + m                         # (B,H)
    ii = jnp.max(gi_h, axis=-1)
    m_new = jax.lax.stop_gradient(jnp.maximum(fi, ii))
    i_p = jnp.exp(gi_h - m_new[..., None]).reshape(B, d)
    f_p = jnp.exp(gf_h + m[:, :, None] - m_new[:, :, None]).reshape(B, d)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return c_new, n_new, h_new, m_new


def _slstm_cell(params, num_heads, x_t, carry):
    """One sLSTM step. x_t: (B, 4d) pre-activations from the input path;
    carry: (c, n, h, m) each (B, d) except m (B, H)."""
    c, n, h, m = carry
    B, d = h.shape
    dh = d // num_heads
    hh = h.reshape(B, num_heads, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh.astype(jnp.float32),
                     params["r_gates"].astype(jnp.float32)).reshape(B, 4 * d)
    g = x_t + rec + params["b_gates"]
    c_new, n_new, h_new, m_new = _slstm_gates(g, c, n, m, num_heads)
    return (c_new, n_new, h_new, m_new), h_new


# --- temporal scan with a hand-written VJP -----------------------------------
#
# Autodiff of the scan accumulates the recurrent-matrix cotangent
# dR = Σ_t h_tᵀ dg_t INSIDE the backward loop; with batch-sharded h that
# contraction psums 17 MiB per TIME STEP (4096× per layer — §Perf H12,
# 1.6 TB/step for xlstm-1.3b). Here the backward loop accumulates the
# BATCH-EXPANDED outer product (B, H, dh, 4dh) locally and the batch
# reduction happens ONCE after the loop.

@partial(jax.custom_vjp, nondiff_argnums=(4,))
def _slstm_scan(r_gates, b_gates, pre, carry0, num_heads):
    def body(cr, x_t):
        return _slstm_cell({"r_gates": r_gates, "b_gates": b_gates},
                           num_heads, x_t, cr)
    carry, hs = jax.lax.scan(body, carry0, pre)
    return carry, hs


def _slstm_scan_fwd(r_gates, b_gates, pre, carry0, num_heads):
    def body(cr, x_t):
        new_cr, h = _slstm_cell({"r_gates": r_gates, "b_gates": b_gates},
                                num_heads, x_t, cr)
        return new_cr, (h, cr)                     # save carry per step
    carry, (hs, carries) = jax.lax.scan(body, carry0, pre)
    return (carry, hs), (r_gates, b_gates, pre, carries)


def _slstm_scan_bwd(num_heads, res, ct):
    r_gates, b_gates, pre, carries = res
    (d_carry_final, d_hs) = ct
    B, d = pre.shape[1], pre.shape[2] // 4
    H = num_heads
    dh = d // H
    r32 = r_gates.astype(jnp.float32)

    def step(acc, inp):
        (dc, dn, dhh, dm), dr_acc, db_acc = acc
        x_t, cr_t, dh_out_t = inp
        c_p, n_p, h_p, m_p = cr_t

        def f(g, c_, n_):
            c2, n2, h2, _ = _slstm_gates(g, c_, n_, m_p, num_heads)
            return c2, n2, h2
        hh_p = h_p.reshape(B, H, dh)
        rec = jnp.einsum("bhd,hde->bhe", hh_p.astype(jnp.float32),
                         r32).reshape(B, 4 * d)
        g = x_t + rec + b_gates
        _, vjp = jax.vjp(f, g, c_p, n_p)
        dg, dc_p, dn_p = vjp((dc, dn, dhh + dh_out_t))
        dg_h = dg.reshape(B, H, 4 * dh)
        # recurrent path: local, batch-expanded dR (reduced over B *after*
        # the loop — keeps the per-step loop collective-free)
        dh_p = jnp.einsum("bhe,hde->bhd", dg_h, r32).reshape(B, d)
        dr_step = jnp.einsum("bhd,bhe->bhde", hh_p.astype(jnp.float32), dg_h)
        new_acc = ((dc_p, dn_p, dh_p, jnp.zeros_like(dm)),
                   dr_acc + dr_step, db_acc + dg)
        return new_acc, dg

    zeros_m = jnp.zeros_like(d_carry_final[3])
    acc0 = ((d_carry_final[0], d_carry_final[1], d_carry_final[2], zeros_m),
            jnp.zeros((B, H, dh, 4 * dh), jnp.float32),
            jnp.zeros((B, 4 * d), jnp.float32))
    (d_carry0, dr_b, db_b), d_pre = jax.lax.scan(
        step, acc0, (pre, carries, d_hs), reverse=True)
    d_r = jnp.sum(dr_b, axis=0).astype(r_gates.dtype)   # ONE batch reduction
    d_b = jnp.sum(db_b, axis=0).astype(b_gates.dtype)
    return d_r, d_b, d_pre, d_carry0


_slstm_scan.defvjp(_slstm_scan_fwd, _slstm_scan_bwd)


def apply_slstm(params, x, num_heads: int, *, carry=None,
                return_state: bool = False):
    """x: (B,S,d). Sequential over S (lax.scan). Returns out (+ carry when
    streaming or ``return_state``)."""
    B, S, d = x.shape
    decode = carry is not None or return_state
    pre = (x @ params["w_gates"]).astype(jnp.float32)       # (B,S,4d)
    if carry is None:
        z32 = jnp.zeros((B, d), jnp.float32)
        carry = (z32, z32, z32, jnp.zeros((B, num_heads), jnp.float32))
    else:
        carry = jax.tree.map(lambda a: a.astype(jnp.float32), carry)

    carry, hs = _slstm_scan(params["r_gates"], params["b_gates"],
                            jnp.moveaxis(pre, 1, 0), carry, num_heads)
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)              # (B,S,d)
    y = rms_norm(y, params["norm"])
    u = y @ params["ffn_up"]
    ffn = params["ffn_down"].shape[0]
    y = (jax.nn.gelu(u[..., :ffn]) * u[..., ffn:]) @ params["ffn_down"]
    if decode:
        return y, carry
    return y


def slstm_state_shape(batch: int, d_model: int, num_heads: int):
    return {"c": (batch, d_model), "n": (batch, d_model),
            "h": (batch, d_model), "m": (batch, num_heads)}


def slstm_state_spec(data_axes):
    return {"c": P(data_axes, None), "n": P(data_axes, None),
            "h": P(data_axes, None), "m": P(data_axes, None)}
