"""whisper-base encoder-decoder (audio family).

The mel-spectrogram + conv feature extractor is the spec'd stub: the model
consumes precomputed frame embeddings ``frames: (B, encoder_seq, d_model)``
(what the conv frontend would emit). Encoder and decoder transformers are
real (pre-LN, GELU MLPs, learned-sinusoidal positions approximated with
RoPE=0 + learned pos embeddings, per whisper's layout).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.sharding import barrier, shard_residual


def _init_block(key, cfg: ModelConfig, tp, dt, cross: bool):
    ks = jax.random.split(key, 3)
    attn, attn_s = L.init_gqa(ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                              cfg.resolved_head_dim, tp, dt)
    mlp, mlp_s = L.init_gelu_mlp(ks[1], cfg.d_model, cfg.d_ff, tp, dt)
    p = {"attn": attn, "mlp": mlp,
         "ln1": {"w": jnp.ones((cfg.d_model,), dt), "b": jnp.zeros((cfg.d_model,), dt)},
         "ln2": {"w": jnp.ones((cfg.d_model,), dt), "b": jnp.zeros((cfg.d_model,), dt)}}
    s = {"attn": attn_s, "mlp": mlp_s,
         "ln1": {"w": P(None), "b": P(None)}, "ln2": {"w": P(None), "b": P(None)}}
    if cross:
        xattn, xattn_s = L.init_gqa(ks[2], cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.resolved_head_dim, tp, dt)
        p["xattn"] = xattn
        p["ln_x"] = {"w": jnp.ones((cfg.d_model,), dt), "b": jnp.zeros((cfg.d_model,), dt)}
        s["xattn"] = xattn_s
        s["ln_x"] = {"w": P(None), "b": P(None)}
    return p, s


def init_encdec(key, cfg: ModelConfig, tp: int):
    dt = jnp.dtype(cfg.dtype)
    k_emb, k_pos, k_enc, k_dec, k_head = jax.random.split(key, 5)
    v = L.maybe(L.shard_dim(cfg.vocab_size, tp))

    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    enc = jax.vmap(lambda k: _init_block(k, cfg, tp, dt, cross=False)[0])(enc_keys)
    dec = jax.vmap(lambda k: _init_block(k, cfg, tp, dt, cross=True)[0])(dec_keys)
    _, enc_s = _init_block(enc_keys[0], cfg, tp, dt, cross=False)
    _, dec_s = _init_block(dec_keys[0], cfg, tp, dt, cross=True)
    lift = lambda t: jax.tree.map(lambda s: P(None, *s), t,
                                  is_leaf=lambda x: isinstance(x, P))
    params = {
        "embed": L.embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dt),
        "enc_pos": L.embed_init(k_pos, (cfg.encoder_seq, cfg.d_model), dt),
        "enc": enc, "dec": dec,
        "enc_norm": {"w": jnp.ones((cfg.d_model,), dt), "b": jnp.zeros((cfg.d_model,), dt)},
        "dec_norm": {"w": jnp.ones((cfg.d_model,), dt), "b": jnp.zeros((cfg.d_model,), dt)},
    }
    specs = {
        "embed": P(v, None), "enc_pos": P(None, None),
        "enc": lift(enc_s), "dec": lift(dec_s),
        "enc_norm": {"w": P(None), "b": P(None)},
        "dec_norm": {"w": P(None), "b": P(None)},
    }
    return params, specs


def _ln(x, p, eps):
    return L.layer_norm(x, p["w"], p["b"], eps)


def encode(params, cfg: ModelConfig, frames, *, remat: bool = False):
    """frames: (B, encoder_seq, d) stub-frontend embeddings -> encoder states."""
    x = frames.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"][None]
    positions = jnp.arange(x.shape[1])

    def body(x, lp):
        x = barrier(x)
        h = _ln(x, lp["ln1"], cfg.norm_eps)
        x = x + L.apply_gqa(lp["attn"], h, num_heads=cfg.num_heads,
                            num_kv_heads=cfg.num_kv_heads,
                            head_dim=cfg.resolved_head_dim, positions=positions,
                            rope_theta=cfg.rope_theta, causal=False)
        h = _ln(x, lp["ln2"], cfg.norm_eps)
        return shard_residual(x + L.apply_gelu_mlp(lp["mlp"], h)), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return _ln(x, params["enc_norm"], cfg.norm_eps)


def decode_train(params, cfg: ModelConfig, tokens, enc_states, *,
                 remat: bool = False, kv_chunk: int = 1024,
                 prefill_cache_len: int = 0, return_hidden: bool = False):
    """Teacher-forced decoder over full target sequence; in prefill mode
    also emits per-layer self-attn K/V (padded) and cross-attn K/V."""
    x = jnp.take(params["embed"], tokens, axis=0)
    Sq = x.shape[1]
    positions = jnp.arange(Sq)
    prefill = prefill_cache_len > 0
    dt = jnp.dtype(cfg.dtype)

    def body(x, lp):
        x = barrier(x)
        h = _ln(x, lp["ln1"], cfg.norm_eps)
        a = L.apply_gqa(lp["attn"], h, num_heads=cfg.num_heads,
                        num_kv_heads=cfg.num_kv_heads,
                        head_dim=cfg.resolved_head_dim, positions=positions,
                        rope_theta=cfg.rope_theta, kv_chunk=kv_chunk,
                        return_kv=prefill)
        self_kv = None
        if prefill:
            a, self_kv = a
            pad = prefill_cache_len - Sq
            self_kv = jax.tree.map(lambda t: jnp.pad(
                t.astype(dt), ((0, 0), (0, pad), (0, 0), (0, 0))), self_kv)
        x = x + a
        h = _ln(x, lp["ln_x"], cfg.norm_eps)
        a = L.apply_gqa(lp["xattn"], h, num_heads=cfg.num_heads,
                        num_kv_heads=cfg.num_kv_heads,
                        head_dim=cfg.resolved_head_dim, positions=positions,
                        rope_theta=cfg.rope_theta, cross_kv=enc_states,
                        return_kv=prefill)
        cross_kv = None
        if prefill:
            a, cross_kv = a
            cross_kv = jax.tree.map(lambda t: t.astype(dt), cross_kv)
        x = x + a
        h = _ln(x, lp["ln2"], cfg.norm_eps)
        x = shard_residual(x + L.apply_gelu_mlp(lp["mlp"], h))
        return x, ((self_kv, cross_kv) if prefill else None)

    if remat and not prefill:
        body = jax.checkpoint(body, prevent_cse=False)
    x, ys = jax.lax.scan(body, x, params["dec"])
    x = _ln(x, params["dec_norm"], cfg.norm_eps)
    if prefill:
        return x[:, -1:, :] @ params["embed"].T, {"self": ys[0],
                                                  "cross_kv": ys[1]}
    if return_hidden:
        return x, 0.0
    return x @ params["embed"].T, 0.0     # whisper ties output head


def encdec_forward(params, cfg: ModelConfig, tokens, *, frames,
                   remat: bool = False, kv_chunk: int = 1024,
                   prefill_cache_len: int = 0, return_hidden: bool = False):
    enc_states = encode(params, cfg, frames, remat=remat)
    return decode_train(params, cfg, tokens, enc_states, remat=remat,
                        kv_chunk=kv_chunk, prefill_cache_len=prefill_cache_len,
                        return_hidden=return_hidden)


def encdec_cache_shape(cfg: ModelConfig, batch: int, seq: int):
    per = L.gqa_cache_shape(batch, seq, cfg.num_kv_heads, cfg.resolved_head_dim)
    cross = L.gqa_cache_shape(batch, cfg.encoder_seq, cfg.num_kv_heads,
                              cfg.resolved_head_dim)
    return {"self": {k: (cfg.num_layers,) + v for k, v in per.items()},
            "cross_kv": {k: (cfg.num_layers,) + v for k, v in cross.items()}}


def encdec_cache_spec(cfg: ModelConfig, tp: int, data_axes):
    per = L.gqa_cache_spec(cfg.num_kv_heads, tp, data_axes)
    # cross K/V spans encoder_seq (1500) — not TP-divisible: batch-shard only
    h = L.maybe(L.shard_dim(cfg.num_kv_heads, tp))
    cross = {k: P(data_axes, None, h, None) for k in ("k", "v")}
    return {"self": {k: P(None, *v) for k, v in per.items()},
            "cross_kv": {k: P(None, *v) for k, v in cross.items()}}


def encdec_decode_step(params, cfg: ModelConfig, cache, tokens, cur_index):
    """Single-token decode: self-attn against cache + cross-attn against the
    prefill-computed per-layer cross K/V."""
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.full((1,), cur_index)

    def body(x, inp):
        lp, self_c, cross_c = inp
        self_c, cross_c = barrier((self_c, cross_c))
        h = _ln(x, lp["ln1"], cfg.norm_eps)
        a, new_self = L.apply_gqa(lp["attn"], h, num_heads=cfg.num_heads,
                                  num_kv_heads=cfg.num_kv_heads,
                                  head_dim=cfg.resolved_head_dim,
                                  positions=positions, rope_theta=cfg.rope_theta,
                                  cache=self_c, cur_index=cur_index)
        x = x + a
        h = _ln(x, lp["ln_x"], cfg.norm_eps)
        # cross-attn reads the (static) cached encoder K/V directly
        q = (h @ lp["xattn"]["wq"]).reshape(
            x.shape[0], 1, cfg.num_heads, cfg.resolved_head_dim)
        o = L.decode_attention(q, cross_c["k"], cross_c["v"],
                               cur_index=cross_c["k"].shape[1] - 1)
        x = x + o.reshape(x.shape[0], 1, -1) @ lp["xattn"]["wo"]
        h = _ln(x, lp["ln2"], cfg.norm_eps)
        x = x + L.apply_gelu_mlp(lp["mlp"], h)
        return x, new_self

    x, new_self = jax.lax.scan(body, x, (params["dec"], cache["self"],
                                         cache["cross_kv"]))
    x = _ln(x, params["dec_norm"], cfg.norm_eps)
    return x @ params["embed"].T, {"self": new_self, "cross_kv": cache["cross_kv"]}
