"""Uniform model API over the zoo — what the FL core, launcher, and dry-run
consume. Dispatches on ``cfg.family``.

    init(cfg, key, tp)                  -> (params, param_specs)
    loss_fn(cfg)(params, batch, rng)    -> (loss, metrics)      # train step unit
    forward(params, cfg, batch)         -> (logits, aux)        # prefill/full fwd
    cache_shape(cfg, batch, seq)        -> pytree of shapes
    cache_spec(cfg, tp, data_axes)      -> pytree of PartitionSpec
    decode_step(params, cfg, cache, tokens, cur_index) -> (logits, cache)

Batches are dicts:
    dense/moe/ssm/hybrid : {tokens (B,S), labels (B,S)}
    vlm                  : + {patch_embeds (B,P,d)}      (stub VQ frontend)
    audio                : + {frames (B,enc_seq,d)}      (stub conv frontend)
    cnn                  : {images (B,28,28,1), labels (B,)}
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import cnn as CNN
from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import transformer as TF
from repro.models import xlstm as XL


def init(cfg: ModelConfig, key, tp: int = 1):
    if cfg.family in ("dense", "moe", "vlm"):
        return TF.init_decoder(key, cfg, tp)
    if cfg.family == "hybrid":
        return HY.init_hybrid(key, cfg, tp)
    if cfg.family == "ssm":
        return XL.init_xlstm(key, cfg, tp)
    if cfg.family == "audio":
        return ED.init_encdec(key, cfg, tp)
    if cfg.family == "cnn":
        return CNN.init_cnn(key, cfg, tp)
    raise ValueError(cfg.family)


def forward(params, cfg: ModelConfig, batch, *, remat: bool = False,
            kv_chunk: int = 1024):
    """Full forward producing logits (the prefill path for LM families)."""
    if cfg.family in ("dense", "moe"):
        return TF.decoder_forward(params, cfg, batch["tokens"], remat=remat,
                                  kv_chunk=kv_chunk)
    if cfg.family == "vlm":
        return TF.decoder_forward(params, cfg, batch["tokens"],
                                  patch_embeds=batch["patch_embeds"],
                                  remat=remat, kv_chunk=kv_chunk)
    if cfg.family == "hybrid":
        return HY.hybrid_forward(params, cfg, batch["tokens"], remat=remat,
                                 kv_chunk=kv_chunk)
    if cfg.family == "ssm":
        return XL.xlstm_forward(params, cfg, batch["tokens"], remat=remat)
    if cfg.family == "audio":
        return ED.encdec_forward(params, cfg, batch["tokens"],
                                 frames=batch["frames"], remat=remat,
                                 kv_chunk=kv_chunk)
    raise ValueError(cfg.family)


def prefill(params, cfg: ModelConfig, batch, cache_len: int, *,
            kv_chunk: int = 1024):
    """Process the prompt, returning (last_logits (B,1,V), decode cache).
    The cache is allocated at ``cache_len`` slots; decode continues at
    cur_index = prompt_len."""
    kw = dict(prefill_cache_len=cache_len, kv_chunk=kv_chunk)
    if cfg.family in ("dense", "moe"):
        return TF.decoder_forward(params, cfg, batch["tokens"], **kw)
    if cfg.family == "vlm":
        return TF.decoder_forward(params, cfg, batch["tokens"],
                                  patch_embeds=batch["patch_embeds"], **kw)
    if cfg.family == "hybrid":
        return HY.hybrid_forward(params, cfg, batch["tokens"], **kw)
    if cfg.family == "ssm":
        return XL.xlstm_forward(params, cfg, batch["tokens"], **kw)
    if cfg.family == "audio":
        return ED.encdec_forward(params, cfg, batch["tokens"],
                                 frames=batch["frames"], **kw)
    raise ValueError(cfg.family)


def _label_logit(logits, safe_labels):
    """logits[..., labels] via a one-hot contraction — unlike
    take_along_axis this keeps a vocab-sharded logits tensor sharded (the
    contraction lowers to a tiny psum instead of an all-gather of the full
    (B, S, V) f32 logits)."""
    one_hot = jax.nn.one_hot(safe_labels, logits.shape[-1],
                             dtype=logits.dtype)
    return jnp.einsum("...v,...v->...", logits, one_hot)


def _xent(logits, labels):
    """Causal LM loss; labels == -100 are masked."""
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = _label_logit(logits, safe)
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def _chunked_xent(x, head, targets, *, seq_chunk: int = 512):
    """Cross-entropy without materializing (B, S, V) logits: scan over
    sequence chunks; the chunk body is rematerialized so backward never
    holds more than one chunk's f32 logits/cotangents.

    x: (B, S, d) final hidden; head: (d, V); targets: (B, S) with -100 pads.
    """
    B, S, d = x.shape
    if S % seq_chunk or S <= seq_chunk:
        return _xent(x @ head, targets)
    nc = S // seq_chunk
    xc = jnp.moveaxis(x.reshape(B, nc, seq_chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(B, nc, seq_chunk), 1, 0)

    @jax.checkpoint
    def body(carry, inp):
        nll_sum, cnt = carry
        xb, tb = inp
        logits = (xb @ head).astype(jnp.float32)
        mask = tb >= 0
        safe = jnp.where(mask, tb, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = _label_logit(logits, safe)
        nll = jnp.sum((lse - ll) * mask)
        return (nll_sum + nll, cnt + jnp.sum(mask)), None

    (nll_sum, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xc, tc))
    return nll_sum / jnp.maximum(cnt, 1)


def _shifted_targets(labels, total_len: int, offset: int):
    """targets[pos] = next-token label aligned to the fused sequence:
    positions < offset (patch prompt) and the final position get -100."""
    B, S_text = labels.shape
    tgt = jnp.full((B, total_len), -100, jnp.int32)
    tgt = jax.lax.dynamic_update_slice(
        tgt, labels[:, 1:].astype(jnp.int32), (0, offset))
    return tgt


def loss_fn(cfg: ModelConfig, *, remat: bool = False, kv_chunk: int = 1024):
    """Returns f(params, batch, rng) -> (loss, metrics)."""
    if cfg.family == "cnn":
        def f_cnn(params, batch, rng=None):
            logits = CNN.cnn_forward(params, cfg, batch["images"], rng=rng,
                                     train=rng is not None)
            labels = batch["labels"]
            loss = _xent(logits[:, None, :], labels[:, None])
            acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
            return loss, {"loss": loss, "accuracy": acc}
        return f_cnn

    def f(params, batch, rng=None):
        kw = dict(remat=remat, kv_chunk=kv_chunk, return_hidden=True)
        if cfg.family in ("dense", "moe"):
            x, aux = TF.decoder_forward(params, cfg, batch["tokens"], **kw)
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["lm_head"])
            offset = 0
        elif cfg.family == "vlm":
            x, aux = TF.decoder_forward(params, cfg, batch["tokens"],
                                        patch_embeds=batch["patch_embeds"],
                                        **kw)
            head = params["lm_head"]
            offset = batch["patch_embeds"].shape[1]
        elif cfg.family == "hybrid":
            x, aux = HY.hybrid_forward(params, cfg, batch["tokens"], **kw)
            head, offset = params["lm_head"], 0
        elif cfg.family == "ssm":
            x, aux = XL.xlstm_forward(params, cfg, batch["tokens"], **kw)
            head, offset = params["lm_head"], 0
        elif cfg.family == "audio":
            x, aux = ED.encdec_forward(params, cfg, batch["tokens"],
                                       frames=batch["frames"], **kw)
            head, offset = params["embed"].T, 0
        else:
            raise ValueError(cfg.family)
        targets = _shifted_targets(batch["labels"], x.shape[1], offset)
        loss = _chunked_xent(x, head, targets) + aux
        return loss, {"loss": loss, "aux": jnp.asarray(aux, jnp.float32)}
    return f


def cache_shape(cfg: ModelConfig, batch: int, seq: int):
    if cfg.family in ("dense", "moe", "vlm"):
        return TF.decoder_cache_shape(cfg, batch, seq)
    if cfg.family == "hybrid":
        return HY.hybrid_cache_shape(cfg, batch, seq)
    if cfg.family == "ssm":
        return XL.xlstm_cache_shape(cfg, batch, seq)
    if cfg.family == "audio":
        return ED.encdec_cache_shape(cfg, batch, seq)
    raise ValueError(cfg.family)


def cache_spec(cfg: ModelConfig, tp: int, data_axes):
    if cfg.family in ("dense", "moe", "vlm"):
        return TF.decoder_cache_spec(cfg, tp, data_axes)
    if cfg.family == "hybrid":
        return HY.hybrid_cache_spec(cfg, tp, data_axes)
    if cfg.family == "ssm":
        return XL.xlstm_cache_spec(cfg, tp, data_axes)
    if cfg.family == "audio":
        return ED.encdec_cache_spec(cfg, tp, data_axes)
    raise ValueError(cfg.family)


# recurrent-state leaves live in f32; KV-style caches in the model dtype
_F32_LEAVES = ("ssm", "c", "n", "h", "m")


def _cache_leaf_dtype(cfg: ModelConfig, name: str):
    return jnp.float32 if name in _F32_LEAVES else jnp.dtype(cfg.dtype)


def cache_struct(cfg: ModelConfig, batch: int, seq: int):
    """Pytree of jax.ShapeDtypeStruct for the decode cache (dry-run input)."""
    shapes = cache_shape(cfg, batch, seq)

    def mk(path, shape):
        name = path[-1].key
        return jax.ShapeDtypeStruct(shape, _cache_leaf_dtype(cfg, name))
    return jax.tree_util.tree_map_with_path(
        mk, shapes, is_leaf=lambda x: isinstance(x, tuple))


def make_cache(cfg: ModelConfig, batch: int, seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_struct(cfg, batch, seq))


def decode_step(params, cfg: ModelConfig, cache, tokens, cur_index):
    if cfg.family in ("dense", "moe", "vlm"):
        return TF.decoder_decode_step(params, cfg, cache, tokens, cur_index)
    if cfg.family == "hybrid":
        return HY.hybrid_decode_step(params, cfg, cache, tokens, cur_index)
    if cfg.family == "ssm":
        return XL.xlstm_decode_step(params, cfg, cache, tokens, cur_index)
    if cfg.family == "audio":
        return ED.encdec_decode_step(params, cfg, cache, tokens, cur_index)
    raise ValueError(cfg.family)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# --- flat-param view (the fused trust round's packed layout) -----------------
# Thin delegations to ``kernels.pack`` so protocol/launch code can reason
# about a model's flat (D,) coordinate space (slice offsets per leaf, total
# length, pack dtype) without importing the kernel package directly.

def flat_param_spec(params):
    """Static pack metadata for ``params``: leaf order, (offset, size, shape)
    slices into the flat axis, pack dtype, and total length D. Raises if the
    tree mixes leaf dtypes (see ``flat_packable``)."""
    from repro.kernels import pack
    return pack.pack_spec(params)


def flat_packable(params) -> bool:
    """Whether ``params`` admits the flat view (uniform floating leaf dtype —
    the eligibility signal behind ``FederationConfig.fused_trust_path``)."""
    from repro.kernels import pack
    return pack.packable(params)


def flatten_params(params):
    """params pytree -> ((D,) vector, spec). Inverse: ``unflatten_params``."""
    from repro.kernels import pack
    spec = pack.pack_spec(params)
    flat = jnp.concatenate(
        [x.reshape(-1) for x in jax.tree.leaves(params)])
    return flat, spec


def unflatten_params(flat, spec):
    """(D,) vector + spec -> params pytree (exact inverse of flatten)."""
    from repro.kernels import pack
    return pack.unpack_vector(flat, spec)
