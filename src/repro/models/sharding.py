"""Activation-sharding hook + the differentiable optimization barrier.

The launch layer installs a NamedSharding for the residual stream
(B, S, d) — e.g. P(None, "model", None): Megatron-style sequence sharding
across the TP group between blocks. Model scan bodies call
``shard_residual`` on the carry; under the FL worker vmap the leading W dim
is batched out (unconstrained), so the same model code works on CPU (hook
unset => no-op) and on the production mesh.

Why: without this, GSPMD may keep the remat checkpoint stack
(L, B, S, d) fully replicated across the model axis — 48-96 GiB/device for
the 34B config. Sequence-sharding the carry makes the saved activations
1/TP of that, at the cost of an all-gather per layer on recompute.
"""
from __future__ import annotations

import contextlib

import jax

_RESIDUAL_SHARDING = None


@jax.custom_jvp
def barrier(x):
    """``jax.lax.optimization_barrier`` with a differentiation rule.

    The raw primitive has no JVP on this JAX version, so any barriered scan
    body fails under ``jax.grad`` with NotImplementedError. The barrier only
    exists to pin XLA's scheduling of the *values* (e.g. stop hoisting an
    f32 convert of the whole remat checkpoint stack out of the backward
    loop), so differentiation is identity: barrier the primal, pass the
    tangent straight through (keeping the tangent map a plain identity also
    keeps it trivially transposable for reverse mode). Accepts any pytree,
    like the primitive.
    """
    return jax.lax.optimization_barrier(x)


@barrier.defjvp
def _barrier_jvp(primals, tangents):
    (x,), (dx,) = primals, tangents
    return barrier(x), dx


def _register_barrier_batching() -> None:
    """This JAX version is also missing the primitive's *batching* rule, so
    the FL worker ``vmap`` dies the same way ``grad`` did. The barrier is
    shape-polymorphic — batching is the trivial vectorized rule (bind the
    batched operands, keep the batch dims) that later JAX versions ship.
    Registered only when absent; silently skipped if the internals move."""
    try:
        from jax._src.interpreters import batching
        from jax._src.lax import lax as lax_internal
        prim = lax_internal.optimization_barrier_p
    except (ImportError, AttributeError):      # pragma: no cover
        return
    if prim in batching.primitive_batchers:    # pragma: no cover
        return

    def _batch_rule(args, dims):
        return prim.bind(*args), dims

    batching.primitive_batchers[prim] = _batch_rule


_register_barrier_batching()


@contextlib.contextmanager
def activation_sharding(sharding):
    """sharding: NamedSharding for per-worker (B, S, d) activations."""
    global _RESIDUAL_SHARDING
    prev = _RESIDUAL_SHARDING
    _RESIDUAL_SHARDING = sharding
    try:
        yield
    finally:
        _RESIDUAL_SHARDING = prev


def shard_residual(x):
    if _RESIDUAL_SHARDING is None:
        return x
    return jax.lax.with_sharding_constraint(x, _RESIDUAL_SHARDING)


def gather_weight(w):
    """Under sequence-sharded activations the partitioner must all-gather
    model-sharded weights at each use; constraining the weight itself to
    replicated makes that gather happen on the bf16 parameter (344 MiB for
    the 34B MLP) instead of on an f32-converted copy (688 MiB) fused into
    the matmul."""
    if _RESIDUAL_SHARDING is None:
        return w
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(_RESIDUAL_SHARDING.mesh, P(*([None] * w.ndim)))
    return jax.lax.with_sharding_constraint(w, rep)


def replicate_kv(k, v):
    """When sequence-sharded activations are active, pin projected K/V to
    replicated — one bf16 all-gather per layer instead of per-KV-chunk
    f32 gathers inside the flash scan."""
    if _RESIDUAL_SHARDING is None:
        return k, v
    mesh = _RESIDUAL_SHARDING.mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P(*([None] * k.ndim)))
    return (jax.lax.with_sharding_constraint(k, rep),
            jax.lax.with_sharding_constraint(v, rep))
