"""zamba2-style hybrid: Mamba2 backbone + a single *shared* attention block.

The shared attention+MLP block (one parameter copy) is applied after every
``shared_attn_every``-th Mamba2 layer. Layers are grouped into scanned
"super-layers" of ``shared_attn_every`` Mamba2 layers + one shared-block
application; a remainder tail is applied unscanned.

Deviation from the released Zamba2 (noted in DESIGN.md): the shared block
consumes the hidden stream directly rather than concat(hidden, embedding),
and per-invocation LoRA deltas are omitted — compute/communication character
is preserved; parameter sharing (the paper point of the architecture) is
exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.sharding import barrier, shard_residual


def _split_layers(cfg: ModelConfig):
    k = cfg.shared_attn_every
    n_super = cfg.num_layers // k
    n_tail = cfg.num_layers - n_super * k
    return k, n_super, n_tail


def init_hybrid(key, cfg: ModelConfig, tp: int):
    dt = jnp.dtype(cfg.dtype)
    k, n_super, n_tail = _split_layers(cfg)
    k_emb, k_m, k_t, k_sh, k_head = jax.random.split(key, 5)

    def init_m(kk):
        p, _ = S.init_mamba2(kk, cfg.d_model, cfg.ssm, tp, dt)
        return {"mamba": p, "norm": jnp.ones((cfg.d_model,), dt)}

    _, m_specs = S.init_mamba2(k_m, cfg.d_model, cfg.ssm, tp, dt)
    m_specs = {"mamba": m_specs, "norm": P(None)}

    super_keys = jax.random.split(k_m, n_super * k)
    super_keys = super_keys.reshape(n_super, k, *super_keys.shape[1:])
    super_params = jax.vmap(jax.vmap(init_m))(super_keys)
    super_specs = jax.tree.map(lambda s: P(None, None, *s), m_specs,
                               is_leaf=lambda x: isinstance(x, P))
    tail_params = [init_m(kk) for kk in jax.random.split(k_t, n_tail)] if n_tail else []

    # shared attention + MLP block (single copy)
    ka, km = jax.random.split(k_sh)
    attn, attn_s = L.init_gqa(ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                              cfg.resolved_head_dim, tp, dt)
    mlp, mlp_s = L.init_swiglu(km, cfg.d_model, cfg.d_ff, tp, dt)
    shared = {"attn": attn, "mlp": mlp,
              "norm1": jnp.ones((cfg.d_model,), dt),
              "norm2": jnp.ones((cfg.d_model,), dt)}
    shared_s = {"attn": attn_s, "mlp": mlp_s, "norm1": P(None), "norm2": P(None)}

    v = L.maybe(L.shard_dim(cfg.vocab_size, tp))
    params = {"embed": L.embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dt),
              "super": super_params, "tail": tail_params, "shared": shared,
              "final_norm": jnp.ones((cfg.d_model,), dt),
              "lm_head": L.dense_init(k_head, (cfg.d_model, cfg.vocab_size), cfg.d_model, dt)}
    specs = {"embed": P(v, None), "super": super_specs,
             "tail": [m_specs for _ in range(n_tail)], "shared": shared_s,
             "final_norm": P(None), "lm_head": P(None, v)}
    return params, specs


def _shared_fwd(cfg, sp, x, positions, kv_chunk, cache=None, cur_index=None,
                return_kv=False):
    h = L.rms_norm(x, sp["norm1"], cfg.norm_eps)
    if cache is not None:
        a, new_cache = L.apply_gqa(sp["attn"], h, num_heads=cfg.num_heads,
                                   num_kv_heads=cfg.num_kv_heads,
                                   head_dim=cfg.resolved_head_dim,
                                   positions=positions, rope_theta=cfg.rope_theta,
                                   cache=cache, cur_index=cur_index)
    else:
        a = L.apply_gqa(sp["attn"], h, num_heads=cfg.num_heads,
                        num_kv_heads=cfg.num_kv_heads,
                        head_dim=cfg.resolved_head_dim, positions=positions,
                        rope_theta=cfg.rope_theta, kv_chunk=kv_chunk,
                        return_kv=return_kv)
        new_cache = None
        if return_kv:
            a, new_cache = a
    x = x + a
    h = L.rms_norm(x, sp["norm2"], cfg.norm_eps)
    x = x + L.apply_swiglu(sp["mlp"], h)
    return (x, new_cache) if (cache is not None or return_kv) else x


def hybrid_forward(params, cfg: ModelConfig, tokens, *, remat: bool = False,
                   kv_chunk: int = 1024, prefill_cache_len: int = 0,
                   return_hidden: bool = False):
    k, n_super, n_tail = _split_layers(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    Sq = x.shape[1]
    positions = jnp.arange(Sq)
    prefill = prefill_cache_len > 0
    dt = jnp.dtype(cfg.dtype)

    def mamba_step(x, lp):
        h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
        if prefill:
            out, (ssm_new, (cx, cbc)) = S.apply_mamba2(lp["mamba"], h, cfg.ssm,
                                                       return_state=True)
            return x + out, {"ssm": ssm_new, "conv_x": cx, "conv_bc": cbc}
        return x + S.apply_mamba2(lp["mamba"], h, cfg.ssm), None

    def super_body(x, sl):
        x = barrier(x)
        states = []
        for j in range(k):
            lp = jax.tree.map(lambda a: a[j], sl)
            x, st = mamba_step(x, lp)
            states.append(st)
        x = shard_residual(x)
        if prefill:
            x, kv = _shared_fwd(cfg, params["shared"], x, positions, kv_chunk,
                                return_kv=True)
            pad = prefill_cache_len - Sq
            kv = jax.tree.map(lambda t: jnp.pad(
                t.astype(dt), ((0, 0), (0, pad), (0, 0), (0, 0))), kv)
            states = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
            return x, (states, kv)
        x = _shared_fwd(cfg, params["shared"], x, positions, kv_chunk)
        return x, None

    if remat and not prefill:
        super_body = jax.checkpoint(super_body, prevent_cse=False)
    x, ys = jax.lax.scan(super_body, x, params["super"])
    tail_states = []
    for lp in params["tail"]:
        x, st = mamba_step(x, lp)
        tail_states.append(st)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if prefill:
        super_ssm, shared_kv = ys
        tail = (jax.tree.map(lambda *xs: jnp.stack(xs), *tail_states)
                if tail_states else
                jax.tree.map(lambda t: jnp.zeros((1,) + t.shape[1:], t.dtype),
                             jax.tree.map(lambda a: a[:, 0], super_ssm)))
        cache = {"super_ssm": super_ssm, "tail_ssm": tail,
                 "shared_attn": shared_kv}
        return x[:, -1:, :] @ params["lm_head"], cache
    if return_hidden:
        return x, 0.0
    return x @ params["lm_head"], 0.0


def hybrid_cache_shape(cfg: ModelConfig, batch: int, seq: int):
    k, n_super, n_tail = _split_layers(cfg)
    m = S.mamba2_state_shape(batch, cfg.d_model, cfg.ssm)
    attn = L.gqa_cache_shape(batch, seq, cfg.num_kv_heads, cfg.resolved_head_dim)
    return {
        "super_ssm": {kk: (n_super, k) + v for kk, v in m.items()},
        "tail_ssm": {kk: (max(n_tail, 1),) + v for kk, v in m.items()},
        "shared_attn": {kk: (n_super,) + v for kk, v in attn.items()},
    }


def hybrid_cache_spec(cfg: ModelConfig, tp: int, data_axes):
    m = S.mamba2_state_spec(cfg.d_model, cfg.ssm, tp, data_axes)
    a = L.gqa_cache_spec(cfg.num_kv_heads, tp, data_axes)
    return {
        "super_ssm": {kk: P(None, None, *v) for kk, v in m.items()},
        "tail_ssm": {kk: P(None, *v) for kk, v in m.items()},
        "shared_attn": {kk: P(None, *v) for kk, v in a.items()},
    }


def hybrid_decode_step(params, cfg: ModelConfig, cache, tokens, cur_index):
    k, n_super, n_tail = _split_layers(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.full((1,), cur_index)

    def super_body(x, inp):
        sl, ssm_states, attn_cache = inp
        ssm_states, attn_cache = barrier(
            (ssm_states, attn_cache))
        new_states = []
        for j in range(k):
            lp = jax.tree.map(lambda a: a[j], sl)
            st = jax.tree.map(lambda a: a[j], ssm_states)
            h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
            out, (ssm_new, (cx, cbc)) = S.apply_mamba2(
                lp["mamba"], h, cfg.ssm,
                state=st["ssm"], conv_state=(st["conv_x"], st["conv_bc"]))
            x = x + out
            new_states.append({"ssm": ssm_new, "conv_x": cx, "conv_bc": cbc})
        new_states = jax.tree.map(lambda *xs: jnp.stack(xs), *new_states)
        x, new_attn = _shared_fwd(cfg, params["shared"], x, positions, 1024,
                                  cache=attn_cache, cur_index=cur_index)
        return x, (new_states, new_attn)

    x, (new_super_ssm, new_shared) = jax.lax.scan(
        super_body, x, (params["super"], cache["super_ssm"], cache["shared_attn"]))

    new_tail = cache["tail_ssm"]
    if n_tail:
        tails = []
        for i, lp in enumerate(params["tail"]):
            st = jax.tree.map(lambda a: a[i], cache["tail_ssm"])
            h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
            out, (ssm_new, (cx, cbc)) = S.apply_mamba2(
                lp["mamba"], h, cfg.ssm,
                state=st["ssm"], conv_state=(st["conv_x"], st["conv_bc"]))
            x = x + out
            tails.append({"ssm": ssm_new, "conv_x": cx, "conv_bc": cbc})
        new_tail = jax.tree.map(lambda *xs: jnp.stack(xs), *tails)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    return logits, {"super_ssm": new_super_ssm, "tail_ssm": new_tail,
                    "shared_attn": new_shared}
