"""The paper's MNIST 'Net' (§IV): conv1 -> pool -> conv2 -> dropout -> pool
-> fc1 -> fc2. Matches the classic PyTorch MNIST example the paper's
TorchScript dump corresponds to (10/20 channels, 5x5 kernels, fc1 320->50).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


def init_cnn(key, cfg: ModelConfig, tp: int = 1):
    c1, c2 = cfg.cnn_channels
    ks = jax.random.split(key, 4)
    # 28x28 -> conv5 -> 24 -> pool -> 12 -> conv5 -> 8 -> pool -> 4 ; 4*4*c2
    flat = (((cfg.image_size - 4) // 2 - 4) // 2) ** 2 * c2
    params = {
        "conv1": {"w": dense_init(ks[0], (5, 5, 1, c1), 25, jnp.float32),
                  "b": jnp.zeros((c1,), jnp.float32)},
        "conv2": {"w": dense_init(ks[1], (5, 5, c1, c2), 25 * c1, jnp.float32),
                  "b": jnp.zeros((c2,), jnp.float32)},
        "fc1": {"w": dense_init(ks[2], (flat, cfg.d_model), flat, jnp.float32),
                "b": jnp.zeros((cfg.d_model,), jnp.float32)},
        "fc2": {"w": dense_init(ks[3], (cfg.d_model, cfg.num_classes), cfg.d_model,
                                jnp.float32),
                "b": jnp.zeros((cfg.num_classes,), jnp.float32)},
    }
    specs = jax.tree.map(lambda _: P(), params)
    return params, specs


def _conv(x, w, b):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_forward(params, cfg: ModelConfig, images, *, rng=None, train=False):
    """images: (B, 28, 28, 1) -> logits (B, 10). Dropout (p=0.5 feature-map
    dropout, like the paper's conv2_drop) only when ``train`` and rng given."""
    x = jax.nn.relu(_maxpool2(_conv(images, params["conv1"]["w"], params["conv1"]["b"])))
    x = _conv(x, params["conv2"]["w"], params["conv2"]["b"])
    if train and rng is not None:
        keep = jax.random.bernoulli(rng, 0.5, x.shape[:1] + (1, 1, x.shape[-1]))
        x = jnp.where(keep, x / 0.5, 0.0)
    x = jax.nn.relu(_maxpool2(x))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]
