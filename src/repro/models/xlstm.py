"""xLSTM stack (mLSTM + sLSTM mix) — the ``ssm`` family.

Layers are grouped into scanned super-layers of ``slstm_every - 1`` mLSTM
blocks followed by one sLSTM block (the ≈7:1 mix of xLSTM-1.3b when
``slstm_every == 8``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.sharding import barrier, shard_residual


def _split_layers(cfg: ModelConfig):
    k = cfg.slstm_every
    assert cfg.num_layers % k == 0, "xlstm stack expects num_layers % slstm_every == 0"
    return k - 1, cfg.num_layers // k      # (mlstm per super-layer, n_super)


def init_xlstm(key, cfg: ModelConfig, tp: int):
    dt = jnp.dtype(cfg.dtype)
    n_m, n_super = _split_layers(cfg)
    k_emb, k_m, k_s, k_head = jax.random.split(key, 4)

    def init_mblock(kk):
        p, _ = S.init_mlstm(kk, cfg.d_model, cfg.ssm, tp, dt)
        return {"mlstm": p, "norm": jnp.ones((cfg.d_model,), dt)}

    def init_sblock(kk):
        p, _ = S.init_slstm(kk, cfg.d_model, cfg.num_heads, tp, dt)
        return {"slstm": p, "norm": jnp.ones((cfg.d_model,), dt)}

    _, m_specs = S.init_mlstm(k_m, cfg.d_model, cfg.ssm, tp, dt)
    _, s_specs = S.init_slstm(k_s, cfg.d_model, cfg.num_heads, tp, dt)
    m_specs = {"mlstm": m_specs, "norm": P(None)}
    s_specs = {"slstm": s_specs, "norm": P(None)}

    mkeys = jax.random.split(k_m, n_super * n_m)
    mkeys = mkeys.reshape(n_super, n_m, *mkeys.shape[1:])
    skeys = jax.random.split(k_s, n_super)
    super_params = {
        "m": jax.vmap(jax.vmap(init_mblock))(mkeys),
        "s": jax.vmap(init_sblock)(skeys),
    }
    super_specs = {
        "m": jax.tree.map(lambda s: P(None, None, *s), m_specs,
                          is_leaf=lambda x: isinstance(x, P)),
        "s": jax.tree.map(lambda s: P(None, *s), s_specs,
                          is_leaf=lambda x: isinstance(x, P)),
    }
    v = L.maybe(L.shard_dim(cfg.vocab_size, tp))
    params = {"embed": L.embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dt),
              "super": super_params,
              "final_norm": jnp.ones((cfg.d_model,), dt),
              "lm_head": L.dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                      cfg.d_model, dt)}
    specs = {"embed": P(v, None), "super": super_specs, "final_norm": P(None),
             "lm_head": P(None, v)}
    return params, specs


def xlstm_forward(params, cfg: ModelConfig, tokens, *, remat: bool = False,
                  prefill_cache_len: int = 0, return_hidden: bool = False,
                  **_):
    n_m, n_super = _split_layers(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    prefill = prefill_cache_len > 0

    def super_body(x, sl):
        x = barrier(x)
        mstates = []
        for j in range(n_m):
            lp = jax.tree.map(lambda a: a[j], sl["m"])
            h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
            if prefill:
                out, (ssm_new, conv_new) = S.apply_mlstm(
                    lp["mlstm"], h, cfg.ssm, chunk=cfg.ssm.chunk_size,
                    return_state=True)
                mstates.append({"ssm": ssm_new, "conv": conv_new})
            else:
                out = S.apply_mlstm(lp["mlstm"], h, cfg.ssm,
                                    chunk=cfg.ssm.chunk_size)
            x = x + out
        x = shard_residual(x)
        h = L.rms_norm(x, sl["s"]["norm"], cfg.norm_eps)
        if prefill:
            out, (c, n, hh, m) = S.apply_slstm(sl["s"]["slstm"], h,
                                               cfg.num_heads, return_state=True)
            x = x + out
            mstates = jax.tree.map(lambda *xs: jnp.stack(xs), *mstates)
            return x, (mstates, {"c": c, "n": n, "h": hh, "m": m})
        x = x + S.apply_slstm(sl["s"]["slstm"], h, cfg.num_heads)
        return x, None

    if remat and not prefill:
        super_body = jax.checkpoint(super_body, prevent_cse=False)
    x, ys = jax.lax.scan(super_body, x, params["super"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if prefill:
        return x[:, -1:, :] @ params["lm_head"], {"m": ys[0], "s": ys[1]}
    if return_hidden:
        return x, 0.0
    return x @ params["lm_head"], 0.0


def xlstm_cache_shape(cfg: ModelConfig, batch: int, seq: int):
    n_m, n_super = _split_layers(cfg)
    m = S.mlstm_state_shape(batch, cfg.d_model, cfg.ssm)
    s = S.slstm_state_shape(batch, cfg.d_model, cfg.num_heads)
    return {"m": {k: (n_super, n_m) + v for k, v in m.items()},
            "s": {k: (n_super,) + v for k, v in s.items()}}


def xlstm_cache_spec(cfg: ModelConfig, tp: int, data_axes):
    m = S.mlstm_state_spec(cfg.d_model, cfg.ssm, tp, data_axes)
    s = S.slstm_state_spec(data_axes)
    return {"m": {k: P(None, None, *v) for k, v in m.items()},
            "s": {k: P(None, *v) for k, v in s.items()}}


def xlstm_decode_step(params, cfg: ModelConfig, cache, tokens, cur_index):
    n_m, n_super = _split_layers(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)          # (B,1,d)

    def super_body(x, inp):
        sl, mstate, sstate = inp
        mstate, sstate = barrier((mstate, sstate))
        new_m = []
        for j in range(n_m):
            lp = jax.tree.map(lambda a: a[j], sl["m"])
            st = jax.tree.map(lambda a: a[j], mstate)
            h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
            out, (ssm_new, conv_new) = S.apply_mlstm(
                lp["mlstm"], h, cfg.ssm, state=st["ssm"], conv_state=st["conv"])
            x = x + out
            new_m.append({"ssm": ssm_new, "conv": conv_new})
        new_m = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
        h = L.rms_norm(x, sl["s"]["norm"], cfg.norm_eps)
        carry = (sstate["c"], sstate["n"], sstate["h"], sstate["m"])
        out, (c, n, hh, m) = S.apply_slstm(sl["s"]["slstm"], h, cfg.num_heads,
                                           carry=carry)
        x = x + out
        return x, (new_m, {"c": c, "n": n, "h": hh, "m": m})

    x, (new_m, new_s) = jax.lax.scan(super_body, x,
                                     (params["super"], cache["m"], cache["s"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["lm_head"], {"m": new_m, "s": new_s}
