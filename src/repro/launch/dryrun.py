import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, print memory/cost analysis, and extract the roofline
terms. MUST be the process entrypoint (device count locks on first jax init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out]
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.compat.xla import normalize_cost_analysis
from repro.configs.base import FederationConfig
from repro.configs.registry import ARCH_IDS, INPUT_SHAPES, applicable
from repro.launch import mesh as meshlib
from repro.launch import specs as speclib

# collective cost convention (ring algorithms, bytes moved per device per op,
# expressed as a multiple of the per-device HLO operand/result bytes)
COLLECTIVE_FACTORS = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)\[([0-9,]*)\]")
_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "f64": 8}


def _first_shape_bytes(line: str) -> int:
    m = _SHAPE_RE.search(line)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES[dt]


def _split_computations(hlo_text: str):
    """{computation_name: [lines]} from an HLO text dump."""
    comps, cur, name = {}, None, None
    for line in hlo_text.splitlines():
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)(?:\.clone)? \(.*\) -> .* \{",
                     line.strip())
        if m:
            name = m.group(1)
            cur = comps.setdefault(name, [])
            continue
        if line.strip() == "}":
            name, cur = None, None
            continue
        if cur is not None:
            cur.append(line.strip())
    return comps


def _trip_count(cond_lines):
    """Best-effort loop bound from a while condition computation: the
    largest s32 constant compared against the induction variable."""
    best = 1
    for s in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", s):
            best = max(best, int(m.group(1)))
    return best


def collective_bytes(hlo_text: str):
    """Per-device collective bytes summed over the partitioned HLO, with
    collectives inside while bodies multiplied by the loop trip count
    (lax.scan lowers to while; XLA cost tools count bodies once — we don't).
    Returns (total weighted bytes, per-op-kind breakdown)."""
    comps = _split_computations(hlo_text)
    # map body -> trip count via while instructions anywhere in the module
    body_trip = {}
    for lines in comps.values():
        for s in lines:
            m = re.search(r"while\(.*condition=%?([\w.\-]+).*body=%?([\w.\-]+)",
                          s)
            if m:
                cond, body = m.group(1), m.group(2)
                body_trip[body] = _trip_count(comps.get(cond, []))

    # nested loops: effective multiplier = product along the call chain;
    # compute by propagating (bodies referencing inner whiles already carry
    # their inner multiplication when we walk each computation separately)
    def comp_multiplier(name, seen=()):
        mult = body_trip.get(name, 1) if name in body_trip else 1
        return mult

    breakdown = {k: {"count": 0, "bytes": 0.0} for k in COLLECTIVE_FACTORS}

    def scan_comp(name, multiplier, seen):
        if name in seen:
            return
        seen = seen | {name}
        for s in comps.get(name, []):
            m = re.search(r"=\s+[^=]*?\b"
                          r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                          r"collective-permute)\b", s)
            if m and "-done" not in s.split("=")[0]:
                kind = m.group(1)
                b = _first_shape_bytes(s)
                breakdown[kind]["count"] += multiplier
                breakdown[kind]["bytes"] += b * COLLECTIVE_FACTORS[kind] * multiplier
            w = re.search(r"while\(.*body=%?([\w.\-]+)", s)
            if w:
                body = w.group(1)
                scan_comp(body, multiplier * body_trip.get(body, 1), seen)
            # descend into fusions/calls that might wrap collectives
            c = re.search(r"(?:fusion|call)\(.*(?:calls|to_apply)=%?([\w.\-]+)", s)
            if c:
                scan_comp(c.group(1), multiplier, seen)

    entry = next((n for n in comps if n.startswith("main")), None)
    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n]), default=None)
    if entry is not None:
        scan_comp(entry, 1, frozenset())
    total = sum(v["bytes"] for v in breakdown.values())
    return total, breakdown


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for train;
    2·N·D for prefill; 2·N per token for decode."""
    from repro.configs.registry import get_config, get_shape
    cfg = get_config(arch)
    sh = get_shape(shape_name)
    sds, _ = speclib.init_specs(cfg, 16)
    n_total = sum(x.size for x in jax.tree.leaves(sds))
    if cfg.moe.enabled:
        e = cfg.moe
        per_layer_routed = 3 * cfg.d_model * e.d_ff_expert
        n_active = (n_total
                    - cfg.num_layers * e.num_experts * per_layer_routed
                    + cfg.num_layers * e.top_k * per_layer_routed)
    else:
        n_active = n_total
    tokens = sh.global_batch * (sh.seq_len if sh.kind != "decode" else 1)
    factor = 6 if sh.kind == "train" else 2
    return factor * n_active * tokens, n_active


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            head_gather: bool = False, local_steps: int = 1,
            setup_override=None):
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    fed = FederationConfig()
    kw = {}
    if INPUT_SHAPES[shape_name].kind == "train":
        kw = {"head_gather": head_gather, "local_steps": local_steps}
    setup = setup_override or speclib.setup_for
    fn, args, in_sh, out_sh, donate = setup(arch, shape_name, mesh, fed, **kw)

    t0 = time.monotonic()
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    class _NoMem:
        temp_size_in_bytes = argument_size_in_bytes = 0
        output_size_in_bytes = alias_size_in_bytes = 0

    mem = compiled.memory_analysis() or _NoMem()
    # list-of-dicts on this jaxlib; normalized so .get works everywhere
    cost = normalize_cost_analysis(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll_total, coll_breakdown = collective_bytes(hlo)

    n_dev = mesh.devices.size
    flops_total = float(cost.get("flops", 0.0))
    bytes_total = float(cost.get("bytes accessed", 0.0))
    # cost_analysis of an SPMD module reports per-partition numbers
    compute_s = flops_total / meshlib.PEAK_FLOPS_BF16
    memory_s = bytes_total / meshlib.HBM_BW
    collective_s = coll_total / meshlib.ICI_BW

    mf, n_active = model_flops(arch, shape_name)
    useful = mf / (flops_total * n_dev) if flops_total else 0.0

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "flops_per_device": flops_total,
        "bytes_per_device": bytes_total,
        "collective_bytes_per_device": coll_total,
        "collective_breakdown": {k: v for k, v in coll_breakdown.items()
                                 if v["count"]},
        **terms,
        "dominant": dominant,
        "model_flops": mf,
        "params_active": n_active,
        "useful_flops_ratio": useful,
        "peak_memory_per_device_gb":
            float(getattr(mem, "temp_size_in_bytes", 0)
                  + getattr(mem, "argument_size_in_bytes", 0)
                  + getattr(mem, "output_size_in_bytes", 0)
                  - getattr(mem, "alias_size_in_bytes", 0)) / 2**30,
        "temp_gb": float(getattr(mem, "temp_size_in_bytes", 0)) / 2**30,
        "args_gb": float(getattr(mem, "argument_size_in_bytes", 0)) / 2**30,
        "lower_s": t_lower, "compile_s": t_compile,
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--head-gather", action="store_true",
                    help="paper-faithful cluster-head gather aggregation")
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--json", default="")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    results, failures = [], []
    for a, s in combos:
        ok, reason = applicable(a, s)
        if not ok:
            print(f"SKIP  {a:18s} {s:12s} {reason}")
            results.append({"arch": a, "shape": s, "skipped": reason})
            continue
        try:
            r = run_one(a, s, multi_pod=args.multi_pod,
                        head_gather=args.head_gather,
                        local_steps=args.local_steps)
            results.append(r)
            print(f"OK    {a:18s} {s:12s} mesh={r['mesh']} "
                  f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                  f"coll={r['collective_s']:.4f}s dom={r['dominant']:10s} "
                  f"mem/dev={r['peak_memory_per_device_gb']:.2f}GiB "
                  f"compile={r['compile_s']:.0f}s")
            sys.stdout.flush()
        except Exception as e:
            failures.append((a, s, repr(e)))
            print(f"FAIL  {a:18s} {s:12s} {e!r}")
            traceback.print_exc()
            sys.stdout.flush()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES"); sys.exit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
