"""Input ShapeDtypeStructs + shardings for every (arch × shape × mesh) —
what the dry-run lowers. No device allocation anywhere (eval_shape only).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (FederationConfig, ModelConfig,
                                TrainConfig)
from repro.configs.registry import get_config, get_shape
from repro.core import fl_step
from repro.launch import mesh as meshlib
from repro.models import api

SDS = jax.ShapeDtypeStruct


def federation_for(mesh, fed: FederationConfig) -> FederationConfig:
    """Scale the cluster topology to the mesh: W must equal the data-axis
    extent (each worker = one data slot); each pod hosts ``num_clusters``
    clusters."""
    dp = meshlib.dp_size(mesh)
    per_pod = mesh.shape["data"]
    wpc = per_pod // fed.num_clusters
    clusters_total = dp // wpc
    return dataclasses.replace(fed, num_clusters=clusters_total,
                               workers_per_cluster=wpc)


def train_config_for(cfg: ModelConfig) -> TrainConfig:
    """LLM FL rounds: paper's SGD(momentum) economics, bf16 opt state for
    the biggest archs (HBM fit), remat on."""
    big = cfg.num_layers * cfg.d_model * cfg.d_model > 2e9   # ≳ 20B params
    return TrainConfig(optimizer="sgd", lr=0.01, momentum=0.5,
                       remat=True, opt_dtype="bfloat16" if big else "float32")


def _named(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def init_specs(cfg: ModelConfig, tp: int):
    """(param ShapeDtypeStructs, PartitionSpec tree) without allocating:
    init runs abstractly under eval_shape; the spec tree (plain python) is
    captured by side effect."""
    captured = {}

    def f(k):
        p, s = api.init(cfg, k, tp)
        captured["specs"] = s
        return p

    sds = jax.eval_shape(f, jax.random.PRNGKey(0))
    return sds, captured["specs"]


# ---------------------------------------------------------------------------
# per-shape step functions + arg structs + shardings
# ---------------------------------------------------------------------------

def _batch_struct(cfg: ModelConfig, W: int, steps: int, per_worker: int,
                  seq: int):
    b = {"tokens": SDS((W, steps, per_worker, seq), jnp.int32),
         "labels": SDS((W, steps, per_worker, seq), jnp.int32)}
    if cfg.family == "vlm":
        text = seq - cfg.num_patch_tokens
        b["tokens"] = SDS((W, steps, per_worker, text), jnp.int32)
        b["labels"] = SDS((W, steps, per_worker, text), jnp.int32)
        b["patch_embeds"] = SDS(
            (W, steps, per_worker, cfg.num_patch_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        b["frames"] = SDS(
            (W, steps, per_worker, cfg.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return b


def _batch_spec(batch, dp):
    return jax.tree.map(lambda s: P(dp, *([None] * (len(s.shape) - 1))), batch)


def train_setup(arch: str, shape_name: str, mesh, fed: FederationConfig,
                *, head_gather: bool = False, local_steps: int = 1):
    """Returns (fn, arg_structs, in_shardings, out_shardings, donate)."""
    cfg = get_config(arch)
    sh = get_shape(shape_name)
    fed = federation_for(mesh, fed)
    if head_gather:
        fed = dataclasses.replace(fed, mode="head_gather")
    tc = dataclasses.replace(train_config_for(cfg), local_steps=local_steps)
    tp = meshlib.tp_size(mesh)
    dp = meshlib.data_axes(mesh)
    W = fl_step.num_workers(fed)
    assert sh.global_batch % W == 0, (sh.global_batch, W)
    per_worker = sh.global_batch // W

    params_sds, param_specs = init_specs(cfg, tp)
    opt_sds = jax.eval_shape(
        lambda p: fl_step.init_worker_opt(p, fed, tc), params_sds)
    wspec = lambda s: P(dp, *s)
    if tc.optimizer == "sgd":
        opt_specs = {"momentum": jax.tree.map(
            lambda s: wspec(s), param_specs, is_leaf=lambda x: isinstance(x, P))}
    else:
        t = jax.tree.map(lambda s: wspec(s), param_specs,
                         is_leaf=lambda x: isinstance(x, P))
        opt_specs = {"m": t, "v": t, "count": P(dp)}

    batch_sds = _batch_struct(cfg, W, tc.local_steps, per_worker, sh.seq_len)
    batch_specs = _batch_spec(batch_sds, dp)

    def worker_constraint(tree):
        """Pin the leading worker dim of params-shaped (W, ...) trees to the
        data axes (leaf-wise: P(dp, *param_spec))."""
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, *s))),
            tree, param_specs)

    def param_constraint(tree):
        """Per-worker param constraint (applied under vmap — the W dim is
        batched out): makes grad cotangents inherit the param sharding."""
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, s)),
            tree, param_specs)

    from repro.models.sharding import activation_sharding
    fl_round = fl_step.make_fl_round(cfg, fed, tc,
                                     worker_constraint=worker_constraint,
                                     param_constraint=param_constraint)
    # Shard the per-worker residual stream (B, S, d) on d over the TP axis
    # between blocks (sequence-parallel-style): shrinks the remat checkpoint
    # stack 1/TP at the cost of per-layer (re)gathers. Only worth it when
    # the replicated stack would be large — for small d_model the extra
    # collectives dwarf the memory win (measured: smollm 0.03s compute vs
    # 2.9s collective with it always-on).
    n_ckpt = {"hybrid": cfg.num_layers // max(cfg.shared_attn_every, 1),
              "ssm": cfg.num_layers // max(cfg.slstm_every, 1)}.get(
                  cfg.family, cfg.num_layers + cfg.encoder_layers)
    stack_bytes = n_ckpt * per_worker * sh.seq_len * cfg.d_model * 2
    # SEQUENCE sharding (not d): per-position ops (norms, MLP) stay local,
    # attention gathers only the small GQA K/V, and the checkpoint stack
    # still shrinks 1/TP. d-sharding measured 33 collectives/layer (§Perf).
    # Activation sharding policy (per-worker (B, S, d) residual):
    #   batch-sharding over the model axis (FSDP-style) when B divides TP —
    #   every layer is embarrassingly parallel over batch rows (SSM scans
    #   included); collectives become per-layer bf16 weight gathers instead
    #   of per-layer f32 residual psums (measured: zamba2 1.2 TB -> ~50 GB).
    #   Falls back to seq-sharding (dense attention families only — SSD's
    #   (B, nc, Q) reshapes fight seq sharding), else replicated.
    act = None
    if per_worker % tp == 0:
        # always profitable here: per-layer activations (B·S·d) far exceed
        # per-layer params for every assigned arch at train_4k
        act = NamedSharding(mesh, P("model", None, None))
    elif stack_bytes > 4 * 2**30 and sh.seq_len % tp == 0 \
            and cfg.family in ("dense", "moe", "vlm"):
        act = NamedSharding(mesh, P(None, "model", None))

    def fn(*a, **kw):
        with activation_sharding(act):
            return fl_round(*a, **kw)
    in_shardings = (_named(mesh, param_specs), _named(mesh, opt_specs),
                    _named(mesh, batch_specs))
    rep = NamedSharding(mesh, P())
    dpn = NamedSharding(mesh, P(dp))
    out_shardings = fl_step.RoundOutput(
        global_params=_named(mesh, param_specs),
        opt_state=_named(mesh, opt_specs),
        scores=dpn, weights=dpn, losses=dpn,
        metrics={"mean_loss": rep})
    return (fn, (params_sds, opt_sds, batch_sds), in_shardings, out_shardings,
            (0, 1))


def _prefill_batch_struct(cfg: ModelConfig, B: int, seq: int):
    b = {"tokens": SDS((B, seq), jnp.int32)}
    if cfg.family == "vlm":
        b["tokens"] = SDS((B, seq - cfg.num_patch_tokens), jnp.int32)
        b["patch_embeds"] = SDS((B, cfg.num_patch_tokens, cfg.d_model),
                                jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        b["frames"] = SDS((B, cfg.encoder_seq, cfg.d_model),
                          jnp.dtype(cfg.dtype))
    return b


def prefill_setup(arch: str, shape_name: str, mesh):
    cfg = get_config(arch)
    sh = get_shape(shape_name)
    tp = meshlib.tp_size(mesh)
    dp = meshlib.data_axes(mesh)
    B = sh.global_batch

    params_sds, param_specs = init_specs(cfg, tp)
    batch_sds = _prefill_batch_struct(cfg, B, sh.seq_len)
    batch_specs = jax.tree.map(
        lambda s: P(dp, *([None] * (len(s.shape) - 1))), batch_sds)
    cache_specs = api.cache_spec(cfg, tp, dp)

    # Prefill activation policy (§Perf H11): sequence-sharding helps ONLY
    # MLA (minicpm3 12.5→6.4 s — its low-rank latent projections gain
    # nothing from head-TP); for GQA/MoE prefill the head-TP layout measured
    # strictly better (yi 1.2→5.0 s, qwen2 8.4→20 s when seq-sharded).
    from repro.models.sharding import activation_sharding
    act = (NamedSharding(mesh, P(None, "model", None))
           if cfg.attn_type == "mla" and sh.seq_len % tp == 0 else None)

    def fn(params, batch):
        with activation_sharding(act):
            return api.prefill(params, cfg, batch, sh.seq_len)

    logits_spec = P(dp, None, None)   # logits replicated over model
                                      # unless vocab sharded
    in_shardings = (_named(mesh, param_specs), _named(mesh, batch_specs))
    out_shardings = (NamedSharding(mesh, logits_spec),
                     _named(mesh, cache_specs))
    return fn, (params_sds, batch_sds), in_shardings, out_shardings, ()


def decode_setup(arch: str, shape_name: str, mesh, *,
                 long_context: bool = False):
    cfg = get_config(arch)
    sh = get_shape(shape_name)
    tp = meshlib.tp_size(mesh)
    dp = meshlib.data_axes(mesh)
    B = sh.global_batch
    long_context = long_context or shape_name == "long_500k"

    params_sds, param_specs = init_specs(cfg, tp)
    cache_sds = api.cache_struct(cfg, B, sh.seq_len)
    if long_context:
        # batch=1: KV caches shard their *sequence* dim over the data axes;
        # recurrent states shard over model only.
        base = api.cache_spec(cfg, tp, None)

        seq_axes = tuple(dp) + ("model",)   # 524288 % (dp·tp) == 0

        def fix(path, spec):
            name = path[-1].key
            if name in ("k", "v"):
                # (L, B, S, KV, hd) — seq at index 2
                return P(spec[0], None, seq_axes, None, None)
            if name == "latent":
                return P(spec[0], None, seq_axes, None)
            return spec
        cache_specs = jax.tree_util.tree_map_with_path(
            fix, base, is_leaf=lambda x: isinstance(x, P))
    else:
        cache_specs = api.cache_spec(cfg, tp, dp)
    tokens_sds = SDS((B, 1), jnp.int32)
    idx_sds = SDS((), jnp.int32)

    def fn(params, cache, tokens, cur_index):
        return api.decode_step(params, cfg, cache, tokens, cur_index)

    logits_spec = P(None if long_context else dp, None, None)
    in_shardings = (_named(mesh, param_specs), _named(mesh, cache_specs),
                    NamedSharding(mesh, P(None if long_context else dp, None)),
                    NamedSharding(mesh, P()))
    out_shardings = (NamedSharding(mesh, logits_spec),
                     _named(mesh, cache_specs))
    return (fn, (params_sds, cache_sds, tokens_sds, idx_sds), in_shardings,
            out_shardings, (1,))


def setup_for(arch: str, shape_name: str, mesh, fed: FederationConfig,
              **kw):
    kind = get_shape(shape_name).kind
    if kind == "train":
        return train_setup(arch, shape_name, mesh, fed, **kw)
    if kind == "prefill":
        return prefill_setup(arch, shape_name, mesh)
    return decode_setup(arch, shape_name, mesh)
