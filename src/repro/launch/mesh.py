"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.

Target hardware: TPU v5e pods — 256 chips/pod (16×16), 2 pods = 512 chips.
Axes: ``data`` carries the SDFL-B worker dim W (clusters are contiguous
groups along it), ``model`` is tensor/expert parallel, ``pod`` is the
cross-pod (DCN) axis for the multi-pod dry-run.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh():
    """Single-device mesh with the production axis names — lets the same
    sharded code paths run on the CPU container (every axis size 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The axes the worker/batch dim shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def tp_size(mesh) -> int:
    return mesh.shape["model"]


def dp_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


# v5e hardware constants for the roofline terms (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
