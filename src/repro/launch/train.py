"""End-to-end SDFL-B training driver.

Two modes:
  * ``--arch paper-net`` — the paper's own experiment: MNIST-surrogate CNN,
    SGD(lr=0.01, momentum=0.5), N workers in clusters, blockchain on/off.
  * any assigned LLM arch — federated LM training on synthetic token
    streams using the *smoke-size* variant by default (CPU container), or
    the full config with ``--full`` (expects a real TPU mesh).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch paper-net \
      --workers 8 --clusters 2 --rounds 50 [--no-blockchain] [--async]
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --rounds 5
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs.base import FederationConfig, TrainConfig
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core import async_sim
from repro.core.protocol import SDFLBProtocol
from repro.data.datasets import make_federated_mnist, synthetic_tokens


def build_protocol(args):
    fed = FederationConfig(
        num_clusters=args.clusters,
        workers_per_cluster=args.workers // args.clusters,
        async_mode=args.async_mode,
        trust_threshold=args.trust_threshold,
        mode="head_gather" if args.head_gather else "allreduce")
    if args.arch == "paper-net":
        cfg = get_config("paper-net")
        tc = TrainConfig(optimizer="sgd", lr=0.01, momentum=0.5, remat=False)
    else:
        cfg = (get_config(args.arch) if args.full
               else get_smoke_config(args.arch))
        tc = TrainConfig(optimizer="adamw", lr=3e-4, remat=args.full,
                         grad_clip=1.0)
    proto = SDFLBProtocol(cfg, fed, tc, use_blockchain=not args.no_blockchain,
                          seed=args.seed)
    return proto, cfg, fed, tc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-net",
                    choices=ARCH_IDS + ["paper-net"])
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--samples", type=int, default=4096)
    ap.add_argument("--no-blockchain", action="store_true")
    ap.add_argument("--async", dest="async_mode", action="store_true")
    ap.add_argument("--head-gather", action="store_true")
    ap.add_argument("--trust-threshold", type=float, default=0.3)
    ap.add_argument("--non-iid", type=float, default=0.0)
    ap.add_argument("--full", action="store_true",
                    help="full-size arch config (TPU mesh expected)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="")
    args = ap.parse_args()
    assert args.workers % args.clusters == 0

    proto, cfg, fed, tc = build_protocol(args)
    W = args.workers

    scheduler = None
    if args.async_mode:
        scheduler = async_sim.AsyncScheduler(
            async_sim.heterogeneous_profiles(W, seed=args.seed),
            seed=args.seed, buffer_size=max(2, W // 2))

    if args.arch == "paper-net":
        ds = make_federated_mnist(W, samples=args.samples,
                                  non_iid_alpha=args.non_iid, seed=args.seed)
        eval_batch = ds.eval_batch(512)
        get_batch = lambda: ds.round_batches(args.batch)
    else:
        data = synthetic_tokens(W, args.batch, args.seq, cfg.vocab_size,
                                seed=args.seed)
        eval_batch = {k: v[0] for k, v in data.items()}
        get_batch = lambda: synthetic_tokens(W, args.batch, args.seq,
                                             cfg.vocab_size,
                                             seed=args.seed + len(proto.history))

    log = []
    t_start = time.monotonic()
    for r in range(args.rounds):
        part = None
        if scheduler is not None:
            _, mask, _ = scheduler.next_aggregation()
            part = mask
        rec = proto.run_round(get_batch(), participation=part)
        if (r + 1) % max(1, args.rounds // 10) == 0 or r == args.rounds - 1:
            ev = proto.evaluate(eval_batch)
            entry = {"round": r + 1, **ev,
                     "mean_score": float(np.mean(rec.scores)),
                     "chain_time": rec.chain_time,
                     "wall": time.monotonic() - t_start}
            log.append(entry)
            print(json.dumps(entry))
    payouts = proto.finalize()
    if proto.ledger is not None:
        print(f"ledger: {len(proto.ledger.blocks)} blocks, "
              f"verified={proto.ledger.verify_chain()}, "
              f"ipfs objects={proto.ipfs.puts}")
        print(f"value conservation: {proto.contract.total_value():.2f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"log": log, "payouts": payouts}, f, indent=1)


if __name__ == "__main__":
    main()
