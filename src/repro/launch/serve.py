"""Serving driver: batched prefill + decode with per-layer caches.

CPU container: runs the smoke-size variant of any arch end-to-end
(prefill a batch of prompts, decode N tokens, report tok/s). On a real
mesh the same step functions are what ``dryrun.py`` lowers at full size.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --batch 4 \
      --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import api


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params, _ = api.init(cfg, key, tp=1)

    B = args.batch
    off = cfg.num_patch_tokens if cfg.family == "vlm" else 0
    cache_len = off + args.prompt_len + args.gen
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (B, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.num_patch_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 3), (B, cfg.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.dtype))

    prefill = jax.jit(lambda p, b: api.prefill(p, cfg, b, cache_len))
    decode = jax.jit(lambda p, c, t, i: api.decode_step(p, cfg, c, t, i))

    t0 = time.monotonic()
    logits, cache = jax.block_until_ready(prefill(params, batch))
    t_prefill = time.monotonic() - t0

    def sample(lg, k):
        if args.temperature <= 0:
            return jnp.argmax(lg[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(k, lg[:, -1] / args.temperature)[:, None]

    tok = sample(logits, key)
    out_tokens = [np.asarray(tok)]
    t0 = time.monotonic()
    for t in range(args.gen - 1):
        logits, cache = decode(params, cache, tok,
                               off + args.prompt_len + t)
        tok = sample(logits, jax.random.fold_in(key, 10 + t))
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.monotonic() - t0

    gen = np.concatenate(out_tokens, axis=1)
    print(f"arch={args.arch} B={B} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:8.1f} ms "
          f"({B*args.prompt_len/t_prefill:9.0f} tok/s)")
    print(f"decode : {t_decode*1e3:8.1f} ms "
          f"({B*(args.gen-1)/max(t_decode,1e-9):9.0f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
