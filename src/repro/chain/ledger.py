"""Hash-chained ledger — the simulated permissioned blockchain.

Not a stub: blocks are really SHA-256 hash-chained over canonically-encoded
transaction payloads, and ``verify_chain`` actually detects tampering. What
is simulated away (consensus latency, gossip) is accounted for by
``work_units`` so the with/without-blockchain wall-time comparison (paper
Fig. 2) has a mechanism-faithful cost model.

Batched settlement (the array-native chain path): instead of embedding one
score/penalty transaction dict per worker — O(W) Python dicts hashed into
every round block — a block *commits* to the round's per-worker settlement
records through a Merkle root over their canonical encodings
(``Block.records_root``, part of the block hash). The records themselves
live in the ledger's off-chain availability layer (``record_batch`` per
block); any single worker's settlement stays auditable via an O(log W)
``merkle_proof`` / ``verify_proof`` without rehashing the whole round.
``verify_chain(deep=True)`` additionally recomputes every stored batch's
root, so tampering with an individual record is detected exactly like
tampering with an embedded transaction used to be. ``work_units`` counts
the batched cost model: 1 + |txs| per block plus the 2n−1 Merkle hashes of
an n-record commit.
"""
from __future__ import annotations

import hashlib
import json
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple


def canonical(obj: Any) -> bytes:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=str).encode()


def sha256(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


# -- Merkle commitment over per-worker settlement records ---------------------

_LEAF_PREFIX = b"\x00"   # domain separation: leaf vs interior node hashing
_NODE_PREFIX = b"\x01"   # (prevents second-preimage/extension confusions)


class MerkleTree:
    """Binary Merkle tree over raw leaf byte-strings.

    Odd nodes are promoted unpaired (Bitcoin-style duplication would allow
    mutation by appending a copy of the last leaf; promotion does not).
    Proofs are lists of ``(side, sibling_digest_hex)`` with side ``"L"`` if
    the sibling sits left of the running hash.
    """

    def __init__(self, leaves: Sequence[bytes]) -> None:
        if not leaves:
            raise ValueError("MerkleTree needs at least one leaf")
        level = [hashlib.sha256(_LEAF_PREFIX + l).digest() for l in leaves]
        self.levels: List[List[bytes]] = [level]
        while len(level) > 1:
            nxt = []
            for i in range(0, len(level) - 1, 2):
                nxt.append(hashlib.sha256(
                    _NODE_PREFIX + level[i] + level[i + 1]).digest())
            if len(level) % 2:
                nxt.append(level[-1])            # promote unpaired node
            self.levels.append(nxt)
            level = nxt
        # cost model: one hash per leaf + one per interior node (≈ 2n−1)
        self.hash_ops = sum(len(lv) for lv in self.levels[:-1]) + 1 \
            if len(self.levels) > 1 else 1

    @property
    def num_leaves(self) -> int:
        return len(self.levels[0])

    @property
    def root(self) -> str:
        return self.levels[-1][0].hex()

    def proof(self, index: int) -> List[Tuple[str, str]]:
        if not 0 <= index < self.num_leaves:
            raise IndexError(f"leaf index {index} out of range")
        path: List[Tuple[str, str]] = []
        for level in self.levels[:-1]:
            sib = index ^ 1
            if sib < len(level):
                path.append(("L" if sib < index else "R", level[sib].hex()))
            index //= 2
        return path

    @staticmethod
    def verify(leaf: bytes, proof: Sequence[Tuple[str, str]],
               root: str) -> bool:
        h = hashlib.sha256(_LEAF_PREFIX + leaf).digest()
        for side, sib_hex in proof:
            sib = bytes.fromhex(sib_hex)
            pair = sib + h if side == "L" else h + sib
            h = hashlib.sha256(_NODE_PREFIX + pair).digest()
        return h.hex() == root


@dataclass
class Block:
    index: int
    prev_hash: str
    transactions: List[dict]
    timestamp: float
    records_root: str = ""    # Merkle root of the batch commit ("" if none)
    hash: str = ""

    def compute_hash(self) -> str:
        body = {"index": self.index, "prev": self.prev_hash,
                "txs": self.transactions, "ts": self.timestamp}
        if self.records_root:       # keep genesis/legacy block hashes stable
            body["records_root"] = self.records_root
        return sha256(canonical(body))


class Ledger:
    """Append-only block chain with one block per FL round (plus genesis)."""

    GENESIS_HASH = "0" * 64

    def __init__(self) -> None:
        genesis = Block(0, self.GENESIS_HASH, [{"type": "genesis"}], 0.0)
        genesis.hash = genesis.compute_hash()
        self.blocks: List[Block] = [genesis]
        self.work_units: int = 0          # hashing/verification operations done
        # off-chain data availability: per-block batch records + their tree
        self._record_batches: Dict[int, List[bytes]] = {}
        self._record_trees: Dict[int, MerkleTree] = {}

    @property
    def head(self) -> Block:
        return self.blocks[-1]

    def append_block(self, transactions: List[dict],
                     timestamp: Optional[float] = None,
                     record_batch: Optional[Sequence[bytes]] = None) -> Block:
        """Seal a block. ``record_batch`` (canonically-encoded per-worker
        settlement records) is Merkle-committed into the block hash via
        ``records_root``; the records themselves stay off-chain but
        per-record auditable (``merkle_proof``)."""
        root = ""
        tree = None
        if record_batch:
            tree = MerkleTree(record_batch)
            root = tree.root
        blk = Block(len(self.blocks), self.head.hash, list(transactions),
                    time.monotonic() if timestamp is None else timestamp,
                    records_root=root)
        blk.hash = blk.compute_hash()
        # verification pass every append (each node re-hashes the new block);
        # batched commits add their 2n−1 Merkle hashes
        self.work_units += 1 + len(transactions)
        if tree is not None:
            self.work_units += tree.hash_ops
            self._record_batches[blk.index] = list(record_batch)
            self._record_trees[blk.index] = tree
        self.blocks.append(blk)
        return blk

    def verify_chain(self, deep: bool = False) -> bool:
        """Hash-chain integrity; ``deep=True`` additionally recomputes every
        stored record batch's Merkle root against its block commitment."""
        prev = self.GENESIS_HASH
        for blk in self.blocks:
            if blk.prev_hash != prev or blk.hash != blk.compute_hash():
                return False
            if deep and blk.index in self._record_batches:
                if (MerkleTree(self._record_batches[blk.index]).root
                        != blk.records_root):
                    return False
            prev = blk.hash
        return True

    # -- per-record audit -----------------------------------------------------

    def record_batch(self, block_index: int) -> List[bytes]:
        return self._record_batches[block_index]

    def merkle_proof(self, block_index: int,
                     leaf_index: int) -> List[Tuple[str, str]]:
        """O(log n) inclusion proof for one settlement record of a batched
        block — auditing worker w never rehashes the whole round."""
        return self._record_trees[block_index].proof(leaf_index)

    def verify_record(self, block_index: int, leaf_index: int,
                      leaf: Optional[bytes] = None,
                      proof: Optional[Sequence[Tuple[str, str]]] = None
                      ) -> bool:
        """Check one record against the on-chain root (leaf/proof default to
        the ledger's own stored copies; pass externally-held values to audit
        a third party's claim)."""
        blk = self.blocks[block_index]
        if not blk.records_root:
            return False
        if leaf is None:
            leaf = self._record_batches[block_index][leaf_index]
        if proof is None:
            proof = self.merkle_proof(block_index, leaf_index)
        return MerkleTree.verify(leaf, proof, blk.records_root)

    def tamper_record(self, block_index: int, leaf_index: int,
                      leaf: bytes) -> None:
        """Test hook: corrupt an off-chain settlement record in place."""
        self._record_batches[block_index][leaf_index] = leaf

    def randomness(self, round_index: int) -> int:
        """Deterministic on-chain randomness (leader rotation seed) derived
        from the head block hash — every node derives the same leader."""
        return int(sha256(f"{self.head.hash}:{round_index}".encode())[:16], 16)

    def transactions_of_type(self, tx_type: str) -> List[dict]:
        return [tx for blk in self.blocks for tx in blk.transactions
                if tx.get("type") == tx_type]
