"""Hash-chained ledger — the simulated permissioned blockchain.

Not a stub: blocks are really SHA-256 hash-chained over canonically-encoded
transaction payloads, and ``verify_chain`` actually detects tampering. What
is simulated away (consensus latency, gossip) is accounted for by
``work_units`` so the with/without-blockchain wall-time comparison (paper
Fig. 2) has a mechanism-faithful cost model.
"""
from __future__ import annotations

import hashlib
import json
import time
from dataclasses import dataclass, field
from typing import Any, List, Optional


def canonical(obj: Any) -> bytes:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=str).encode()


def sha256(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


@dataclass
class Block:
    index: int
    prev_hash: str
    transactions: List[dict]
    timestamp: float
    hash: str = ""

    def compute_hash(self) -> str:
        body = canonical({"index": self.index, "prev": self.prev_hash,
                          "txs": self.transactions, "ts": self.timestamp})
        return sha256(body)


class Ledger:
    """Append-only block chain with one block per FL round (plus genesis)."""

    GENESIS_HASH = "0" * 64

    def __init__(self) -> None:
        genesis = Block(0, self.GENESIS_HASH, [{"type": "genesis"}], 0.0)
        genesis.hash = genesis.compute_hash()
        self.blocks: List[Block] = [genesis]
        self.work_units: int = 0          # hashing/verification operations done

    @property
    def head(self) -> Block:
        return self.blocks[-1]

    def append_block(self, transactions: List[dict],
                     timestamp: Optional[float] = None) -> Block:
        blk = Block(len(self.blocks), self.head.hash, list(transactions),
                    time.monotonic() if timestamp is None else timestamp)
        blk.hash = blk.compute_hash()
        # verification pass every append (each node re-hashes the new block)
        self.work_units += 1 + len(transactions)
        self.blocks.append(blk)
        return blk

    def verify_chain(self) -> bool:
        prev = self.GENESIS_HASH
        for blk in self.blocks:
            if blk.prev_hash != prev or blk.hash != blk.compute_hash():
                return False
            prev = blk.hash
        return True

    def randomness(self, round_index: int) -> int:
        """Deterministic on-chain randomness (leader rotation seed) derived
        from the head block hash — every node derives the same leader."""
        return int(sha256(f"{self.head.hash}:{round_index}".encode())[:16], 16)

    def transactions_of_type(self, tx_type: str) -> List[dict]:
        return [tx for blk in self.blocks for tx in blk.transactions
                if tx.get("type") == tx_type]
