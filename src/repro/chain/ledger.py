"""Hash-chained ledger — the simulated permissioned blockchain.

Not a stub: blocks are really SHA-256 hash-chained over canonically-encoded
transaction payloads, and ``verify_chain`` actually detects tampering. What
is simulated away (consensus latency, gossip) is accounted for by
``work_units`` so the with/without-blockchain wall-time comparison (paper
Fig. 2) has a mechanism-faithful cost model.

Batched settlement (the array-native chain path): instead of embedding one
score/penalty transaction dict per worker — O(W) Python dicts hashed into
every round block — a block *commits* to the round's per-worker settlement
records through a Merkle root over their canonical encodings
(``Block.records_root``, part of the block hash). The records themselves
live in the ledger's off-chain availability layer (``record_batch`` per
block); any single worker's settlement stays auditable via an
O(log(W/k) + k) ``merkle_proof`` / ``verify_record`` without rehashing the
whole round. ``verify_chain(deep=True)`` additionally recomputes every
stored batch's root, so tampering with an individual record is detected
exactly like tampering with an embedded transaction used to be.

Chunked leaves: a commit may pack ``chunk_size`` consecutive records into
each Merkle leaf (leaf bytes = the records' concatenation), so a W-record
commit hashes ~2·W/k nodes instead of ~2·W — the per-leaf SHA-256 was the
last O(W) host cost on the settlement path. Auditing one record then needs
its chunk (k records, fixed-width so the offset is unambiguous) plus the
O(log(W/k)) node path; ``chunk_size=1`` reproduces the per-record tree
bit-for-bit. ``work_units`` counts the batched cost model: 1 + |txs| per
block plus the ~2·ceil(n/k)−1 Merkle hashes of an n-record commit.

Sharded commits: a block may commit S per-shard record batches at once
(``ShardedCommit``). Shard boundaries produced by ``plan_shard_bounds``
are *subtree-aligned* — every shard but the last covers exactly 2^m chunk
leaves — so the cross-shard super-root (shard subtree roots combined
pairwise bottom-up with the same interior-node rule) is bit-identical to
the flat tree over the concatenated records, for every shard count.
Sharding is therefore a node-local execution detail (subtrees build in
parallel on a settler pool) rather than a consensus-visible change: S=1,
S=4 and the unsharded commit all seal byte-identical blocks, and a
record's ``merkle_proof`` — its chunk path inside the shard followed by
the shard path to the super-root — is the same ``(side, digest)`` list
the flat tree emits, verified by the unchanged ``MerkleTree.verify``.
``verify_chain(deep=True)`` recurses through shards, rebuilding every
subtree and the super-root from the stored batches.

Multi-task commits (the multi-tenant chain layout): one chain node may
serve N concurrent federated tasks, and a block may commit several tasks'
rounds at once. ``MultiTaskCommit`` layers a third Merkle level over the
per-task commit roots — task roots combine pairwise in canonical (sorted
``task_id``) order with the same interior-node rule into the block root,
and multi-task blocks additionally carry the canonical
``task_id → super-root`` map (``Block.task_roots``, part of the block
hash). A settlement proof is then three-level — chunk path in shard,
shard path in task, task path in block — still one ``(side, digest)``
list consumed by the unchanged ``MerkleTree.verify``. With a single task
the task level is a lone root: the block root equals the task's
super-root, the task path is empty, and ``task_roots`` is omitted from
the hashed body, so single-task blocks are bit-identical to the
pre-multi-tenant layout. ``verify_chain(deep=True)`` recurses through
every task's shards and the task level, and corrupting one task's stored
records never invalidates another task's proofs (its sibling digests are
the stored task roots, not the corrupted bytes).

Two commit paths — dense and delta. Everything above describes the
*dense* path: a block commits a fresh tree over every record the round
produced, and its cost is O(W/k) hashes per round. ``DeltaCommit`` is the
*sparse* path for huge, mostly-idle populations (the million-worker
regime): the commit always covers the **full population's** latest
settlement records, but only the records that changed this round are
re-hashed. A base (anchor) commit snapshots the whole population once;
each subsequent delta commit references its predecessor, stores only the
changed rows, clones the predecessor's tree level lists (pointer copies,
O(W/k) references not hashes), re-digests the dirty chunk leaves, and
bubbles the O(C·log(W/k)) dirty interior paths up via
``MerkleTree.update_leaves`` — the resulting root is bit-identical to a
full rebuild over the same records (property-tested). Proof semantics are
unchanged and population-wide: an *idle* worker's record is committed by
every delta block, so its proof verifies (and tampering with it is
detected) without the worker having been active for rounds.
``verify_chain(deep=True)`` treats a delta block like any other: the
overlay chain is materialized back to its base and the root recomputed
from scratch. ``work_units`` charges a delta block its actual hashing
(dirty leaves + dirty interior nodes), so the cost model scales with
activity, not population.

Batched leaf hashing: leaf digests for contiguous record buffers are
computed by framing each chunk into one packed buffer (a ``\\x00``
domain-separation prefix byte before each chunk's records, laid out
contiguously) and issuing one ``hashlib.sha256`` call per leaf over the
framed row — byte-identical digests to the incremental two-``update``
path, but a single C call per leaf that releases the GIL once instead of
twice. This both speeds up serial hashing (~1.15x at small chunk sizes)
and lowers the chunk-size floor at which pooled shard fan-out wins (see
``MIN_PARALLEL_LEAF_BYTES`` in ``chain.contract``).
"""
from __future__ import annotations

import hashlib
import json
import time
from bisect import bisect_right
from dataclasses import dataclass
from typing import (Any, Dict, List, Mapping, Optional, Sequence, Tuple,
                    Union)

import numpy as np


def canonical(obj: Any) -> bytes:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=str).encode()


def sha256(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


# -- Merkle commitment over per-worker settlement records ---------------------

_LEAF_PREFIX = b"\x00"   # domain separation: leaf vs interior node hashing
_NODE_PREFIX = b"\x01"   # (prevents second-preimage/extension confusions)


class RecordBatch(Sequence):
    """Fixed-width records backed by one contiguous buffer.

    The batch settlement path encodes a whole round as a single structured
    numpy buffer; wrapping it (instead of slicing W small ``bytes`` objects
    up front) keeps the commit zero-copy — chunk leaves are direct buffer
    slices and per-record access materializes only the record asked for.
    ``buf`` may be any bytes-like object (a ``memoryview`` straight onto
    the numpy array's memory avoids even the one up-front copy).
    """

    __slots__ = ("buf", "itemsize")

    def __init__(self, buf, itemsize: int) -> None:
        if itemsize <= 0 or len(buf) % itemsize:
            raise ValueError("buffer is not a whole number of records")
        self.buf = buf
        self.itemsize = itemsize

    def __len__(self) -> int:
        return len(self.buf) // self.itemsize

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        if not -len(self) <= i < len(self):
            raise IndexError(i)
        i %= len(self)
        return self.buf[i * self.itemsize:(i + 1) * self.itemsize]

    def chunk_bytes(self, start: int, stop: int) -> bytes:
        return self.buf[start * self.itemsize:stop * self.itemsize]


Records = Union[RecordBatch, Sequence[bytes]]


def _chunk_bytes(records: Records, start: int, stop: int) -> bytes:
    if stop - start == 1:                     # per-record leaf (chunk_size=1)
        return records[start]
    if isinstance(records, RecordBatch):
        return records.chunk_bytes(start, stop)
    return b"".join(records[start:stop])


def _leaf_digest(chunk) -> bytes:
    """Domain-separated leaf hash. Two ``update`` calls instead of one
    ``_LEAF_PREFIX + chunk`` concatenation: the chunk may be a zero-copy
    ``memoryview`` onto the record buffer (bytes + memoryview would
    TypeError, and the concat would copy the leaf)."""
    h = hashlib.sha256(_LEAF_PREFIX)
    h.update(chunk)
    return h.digest()


def _framed_digests(framed: np.ndarray) -> List[bytes]:
    """One ``sha256`` call per framed row (prefix byte + chunk bytes laid
    out contiguously). A single C call per leaf releases the GIL once —
    the batched replacement for per-chunk ``_leaf_digest`` calls, with
    byte-identical output (same ``prefix || chunk`` preimage)."""
    rows, row_len = framed.shape
    flat = memoryview(framed).cast("B")
    sha = hashlib.sha256
    return [sha(flat[i * row_len:(i + 1) * row_len]).digest()
            for i in range(rows)]


def batch_leaf_digests(batch: RecordBatch, chunk_size: int) -> List[bytes]:
    """All leaf digests of a chunked tree over ``batch``, via one framed
    contiguous buffer and one hash call per leaf. The partial tail chunk
    (when ``len(batch)`` is not a multiple of ``chunk_size``) is hashed
    separately."""
    n, itemsize = len(batch), batch.itemsize
    leaf_bytes = chunk_size * itemsize
    full = n // chunk_size
    digests: List[bytes] = []
    if full:
        flat = np.frombuffer(batch.buf, dtype=np.uint8,
                             count=full * leaf_bytes)
        framed = np.empty((full, 1 + leaf_bytes), np.uint8)
        framed[:, 0] = _LEAF_PREFIX[0]
        framed[:, 1:] = flat.reshape(full, leaf_bytes)
        digests = _framed_digests(framed)
    if full * chunk_size < n:
        digests.append(_leaf_digest(batch.chunk_bytes(full * chunk_size, n)))
    return digests


def gathered_leaf_digests(batch: RecordBatch, chunk_size: int,
                          leaf_indices) -> Dict[int, bytes]:
    """Leaf digests for a *subset* of a chunked tree's leaves over
    ``batch`` — the dirty-chunk pass of a delta commit. The selected full
    chunks are gathered into one framed buffer (one vectorized copy) and
    hashed with one C call each; a selected partial tail chunk is hashed
    separately. Returns ``{leaf_index: digest}``."""
    n, itemsize = len(batch), batch.itemsize
    leaf_bytes = chunk_size * itemsize
    sel = np.asarray(leaf_indices, np.int64).reshape(-1)
    if len(sel) and (sel.min() < 0 or
                     sel.max() * chunk_size >= max(n, 1)):
        raise IndexError("leaf index out of range")
    out: Dict[int, bytes] = {}
    full_mask = (sel + 1) * chunk_size <= n
    fsel = sel[full_mask]
    if len(fsel):
        flat = np.frombuffer(batch.buf, dtype=np.uint8,
                             count=(n // chunk_size) * leaf_bytes)
        mat = flat.reshape(n // chunk_size, leaf_bytes)
        framed = np.empty((len(fsel), 1 + leaf_bytes), np.uint8)
        framed[:, 0] = _LEAF_PREFIX[0]
        framed[:, 1:] = mat[fsel]
        for li, d in zip(fsel.tolist(), _framed_digests(framed)):
            out[li] = d
    for li in sel[~full_mask].tolist():
        out[li] = _leaf_digest(batch.chunk_bytes(li * chunk_size, n))
    return out


def _combine(level: List[bytes]) -> Tuple[List[bytes], int]:
    """One level of pairwise interior hashing; the odd node is promoted
    unpaired. Returns (next level, interior hashes performed). Shared by
    the in-shard tree and the cross-shard super-root so there is exactly
    one hashing rule."""
    nxt = [hashlib.sha256(_NODE_PREFIX + level[i] + level[i + 1]).digest()
           for i in range(0, len(level) - 1, 2)]
    ops = len(nxt)
    if len(level) % 2:
        nxt.append(level[-1])
    return nxt, ops


def _path_through(levels: Sequence[List[bytes]],
                  index: int) -> List[Tuple[str, str]]:
    """Sibling path for ``index`` through pairwise-combined ``levels``
    (all levels below the root)."""
    path: List[Tuple[str, str]] = []
    for level in levels:
        sib = index ^ 1
        if sib < len(level):
            path.append(("L" if sib < index else "R", level[sib].hex()))
        index //= 2
    return path


class MerkleTree:
    """Binary Merkle tree over records, ``chunk_size`` records per leaf.

    A leaf's bytes are the concatenation of its chunk's records (with the
    default ``chunk_size=1`` this is exactly a per-record tree — same roots
    and proofs as always). Odd nodes are promoted unpaired (Bitcoin-style
    duplication would allow mutation by appending a copy of the last leaf;
    promotion does not). Proofs are lists of ``(side, sibling_digest_hex)``
    with side ``"L"`` if the sibling sits left of the running hash.
    """

    def __init__(self, records: Records, chunk_size: int = 1) -> None:
        if not len(records):
            raise ValueError("MerkleTree needs at least one record")
        if chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        n = len(records)
        self.num_records = n
        self.chunk_size = chunk_size
        if isinstance(records, RecordBatch):
            # contiguous buffer: framed batched hashing, one C call per leaf
            level = batch_leaf_digests(records, chunk_size)
        else:
            level = [_leaf_digest(
                _chunk_bytes(records, i, min(i + chunk_size, n)))
                for i in range(0, n, chunk_size)]
        self.levels: List[List[bytes]] = [level]
        while len(level) > 1:
            level, _ = _combine(level)
            self.levels.append(level)
        # cost model: one hash per leaf + one per interior node
        self.hash_ops = sum(len(lv) for lv in self.levels[:-1]) + 1 \
            if len(self.levels) > 1 else 1

    @property
    def num_leaves(self) -> int:
        return len(self.levels[0])

    @property
    def root(self) -> str:
        return self.levels[-1][0].hex()

    def proof(self, index: int) -> List[Tuple[str, str]]:
        """Node path for leaf (= chunk) ``index``."""
        if not 0 <= index < self.num_leaves:
            raise IndexError(f"leaf index {index} out of range")
        return _path_through(self.levels[:-1], index)

    def record_proof(self, record_index: int) -> List[Tuple[str, str]]:
        """Node path for the chunk containing record ``record_index``."""
        if not 0 <= record_index < self.num_records:
            raise IndexError(f"record index {record_index} out of range")
        return self.proof(record_index // self.chunk_size)

    def clone(self) -> "MerkleTree":
        """Copy-on-write clone for incremental updates: the per-level digest
        lists are fresh (so ``update_leaves`` never mutates the original)
        but the digests themselves are shared — O(L) pointer copies, zero
        hashing."""
        t = object.__new__(MerkleTree)
        t.num_records = self.num_records
        t.chunk_size = self.chunk_size
        t.levels = [list(lv) for lv in self.levels]
        t.hash_ops = self.hash_ops
        return t

    def update_leaf_digests(self, digests: Mapping[int, bytes]) -> int:
        """Incremental in-place update from precomputed leaf digests:
        replace the given leaves and recompute only the dirty interior
        paths — O(|dirty|·log L) hashes instead of a full rebuild, with a
        root bit-identical to rebuilding from the updated records
        (property-tested). Returns the interior hashes performed."""
        leaves = self.levels[0]
        for i, d in digests.items():
            if not 0 <= i < len(leaves):
                raise IndexError(f"leaf index {i} out of range")
            leaves[i] = d
        dirty = {i // 2 for i in digests}
        ops = 0
        for li in range(1, len(self.levels)):
            below, cur = self.levels[li - 1], self.levels[li]
            for p in dirty:
                lo = 2 * p
                if lo + 1 < len(below):
                    cur[p] = hashlib.sha256(
                        _NODE_PREFIX + below[lo] + below[lo + 1]).digest()
                    ops += 1
                else:                         # odd node promoted unpaired
                    cur[p] = below[lo]
            dirty = {p // 2 for p in dirty}
        self.hash_ops += len(digests) + ops
        return ops

    def update_leaves(self, leaves: Mapping[int, bytes]) -> int:
        """Incremental update from whole leaf byte-strings (for a chunked
        tree, each value is the updated chunk's concatenated records). See
        ``update_leaf_digests``."""
        return self.update_leaf_digests(
            {i: _leaf_digest(b) for i, b in leaves.items()})

    @staticmethod
    def verify(leaf: bytes, proof: Sequence[Tuple[str, str]],
               root: str) -> bool:
        """``leaf`` is the full leaf byte-string (any bytes-like object) —
        for a chunked tree, the concatenation of the chunk's records.

        This is the low-level hashing primitive behind the unified
        ``repro.chain.proofs.SettlementProof.verify`` — application code
        should verify whole ``SettlementProof`` claims, not bare paths."""
        h = _leaf_digest(leaf)
        for side, sib_hex in proof:
            sib = bytes.fromhex(sib_hex)
            pair = sib + h if side == "L" else h + sib
            h = hashlib.sha256(_NODE_PREFIX + pair).digest()
        return h.hex() == root


# -- sharded (two-level) commits ----------------------------------------------


def plan_shard_bounds(num_records: int, chunk_size: int,
                      shards: int) -> List[int]:
    """Record-index boundaries splitting ``num_records`` into at most
    ``shards`` contiguous ranges whose edges land on whole subtrees: every
    shard but the last covers exactly 2^m chunk leaves (the last takes the
    remainder), with m the smallest exponent giving ≤ ``shards`` ranges.
    This alignment is what makes the per-shard subtree roots combine to
    exactly the flat tree's root (see ``ShardedCommit``)."""
    if num_records < 0 or chunk_size < 1 or shards < 1:
        raise ValueError("need num_records >= 0, chunk_size/shards >= 1")
    if num_records == 0:
        return [0]
    leaves = -(-num_records // chunk_size)
    shards = min(shards, leaves)
    m = 0
    while (1 << m) * shards < leaves:      # smallest m: ceil(L/2^m) <= shards
        m += 1
    step = (1 << m) * chunk_size
    return list(range(0, num_records, step)) + [num_records]


class ShardedCommit(Sequence):
    """Two-level Merkle commitment over per-shard record batches.

    Level one: each shard's records get their own chunked subtree (built
    independently — in parallel on a settler pool when one is supplied).
    Level two: the shard subtree roots combine pairwise bottom-up with the
    same interior-node rule into the cross-shard *super-root*, which is
    what the block commits to. With subtree-aligned shard boundaries
    (``plan_shard_bounds``) the super-root and every record's proof are
    bit-identical to the flat single-tree commit, so shard count never
    changes block hashes — only who hashes which records.

    Indexing is over the concatenated record sequence, so the ledger's
    per-record audit surface is shard-agnostic.
    """

    __slots__ = ("shards", "trees", "chunk_size", "bounds", "super_levels",
                 "hash_ops")

    def __init__(self, shards: Sequence[Records], chunk_size: int = 1,
                 trees: Optional[Sequence[MerkleTree]] = None) -> None:
        if not shards or any(not len(s) for s in shards):
            raise ValueError("ShardedCommit needs non-empty shards")
        self.shards: List[Records] = list(shards)
        self.chunk_size = chunk_size
        if trees is None:
            trees = [MerkleTree(s, chunk_size) for s in self.shards]
        self.trees: List[MerkleTree] = list(trees)
        if len(self.trees) != len(self.shards):
            raise ValueError("one precomputed tree per shard required")
        bounds = [0]
        for s in self.shards:
            bounds.append(bounds[-1] + len(s))
        self.bounds = bounds
        level = [t.levels[-1][0] for t in self.trees]   # shard root digests
        self.super_levels: List[List[bytes]] = [level]
        super_ops = 0
        while len(level) > 1:
            level, ops = _combine(level)
            super_ops += ops
            self.super_levels.append(level)
        self.hash_ops = sum(t.hash_ops for t in self.trees) + super_ops

    # -- concatenated-record view --------------------------------------------

    def __len__(self) -> int:
        return self.bounds[-1]

    def _locate(self, record_index: int) -> Tuple[int, int]:
        if not 0 <= record_index < len(self):
            raise IndexError(f"record index {record_index} out of range")
        s = bisect_right(self.bounds, record_index) - 1
        return s, record_index - self.bounds[s]

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        if i < 0:
            i += len(self)
        s, local = self._locate(i)
        return self.shards[s][local]

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def root(self) -> str:
        return self.super_levels[-1][0].hex()

    @property
    def root_digest(self) -> bytes:
        """Raw super-root digest — the task-level leaf of a multi-task
        commit (shared accessor across commit kinds)."""
        return self.super_levels[-1][0]

    def shard_roots(self) -> List[str]:
        return [t.root for t in self.trees]

    # -- two-level proofs -----------------------------------------------------

    def shard_path(self, shard_index: int) -> List[Tuple[str, str]]:
        """Sibling path from shard ``shard_index``'s subtree root to the
        super-root — the cross-shard half of a settlement proof."""
        if not 0 <= shard_index < self.num_shards:
            raise IndexError(f"shard index {shard_index} out of range")
        return _path_through(self.super_levels[:-1], shard_index)

    def record_proof(self, record_index: int) -> List[Tuple[str, str]]:
        """Chunk path inside the record's shard + the shard path to the
        super-root. ``MerkleTree.verify`` consumes it unchanged (both
        halves are the same ``(side, digest)`` encoding), and with aligned
        shards the concatenation is byte-equal to the flat tree's proof."""
        s, local = self._locate(record_index)
        return self.trees[s].record_proof(local) + self.shard_path(s)

    def record_chunk(self, record_index: int) -> Tuple[List[bytes], int]:
        """The record's leaf chunk (within its shard) and its offset."""
        s, local = self._locate(record_index)
        k = self.chunk_size
        start = (local // k) * k
        stop = min(start + k, len(self.shards[s]))
        return [bytes(self.shards[s][i]) for i in range(start, stop)], \
            local - start

    def tamper(self, record_index: int, leaf: bytes) -> None:
        """Test hook: corrupt one stored record in place."""
        s, local = self._locate(record_index)
        if isinstance(self.shards[s], RecordBatch):
            self.shards[s] = list(self.shards[s])
        self.shards[s][local] = leaf

    def rebuild(self) -> "ShardedCommit":
        """Fresh commit rebuilt from the stored batches."""
        return ShardedCommit(self.shards, self.chunk_size)

    def recompute_root(self) -> str:
        """Root rebuilt from the stored batches (deep verification —
        recurses through every shard subtree and the super levels)."""
        return self.rebuild().root


# -- delta (incremental) commits ----------------------------------------------


class DeltaCommit(Sequence):
    """Incremental full-population Merkle commitment.

    A *base* commit (``DeltaCommit.full``) snapshots and hashes the whole
    population's latest settlement records — one dense anchor. Each
    subsequent *delta* commit (``DeltaCommit.delta``) references its
    predecessor, stores only the rows that changed this round (sorted by
    record index), clones the predecessor's tree (pointer copies), and
    re-hashes only the dirty chunk leaves plus their O(C·log(W/k))
    interior paths via ``MerkleTree.update_leaf_digests`` — producing a
    root bit-identical to a dense rebuild over the same records.

    Indexing is population-wide: ``commit[i]`` resolves record ``i``
    through the overlay chain (this commit's changed rows, else the
    predecessor's, down to the base), so proofs and audits cover *idle*
    workers too — every block commits every worker's latest record, and
    ``record_proof``/``record_chunk``/``MerkleTree.verify`` behave exactly
    as on a single-shard dense commit (the tree is flat, so the proof is
    the flat tree's ``(side, digest)`` path).

    ``hash_ops`` counts only the hashing this commit actually performed
    (all leaves + interiors for a base; dirty leaves + dirty interiors for
    a delta), which is what ``Ledger.work_units`` charges — commit cost
    scales with activity, not population. ``recompute_root`` (deep
    verification) materializes the overlay back to the base and rebuilds
    from scratch, so tampering with any stored row — changed or inherited
    — is detected."""

    __slots__ = ("prev", "base_records", "changed", "new_records",
                 "chunk_size", "num_records", "tree", "hash_ops",
                 "_tampered", "depth")

    def __init__(self, *_a, **_k) -> None:
        raise TypeError(
            "use DeltaCommit.full(records, chunk_size) or "
            "DeltaCommit.delta(prev, changed, new_records)")

    @classmethod
    def full(cls, records: Records, chunk_size: int = 1) -> "DeltaCommit":
        """Dense base (anchor) commit over the full population."""
        c = object.__new__(cls)
        c.prev = None
        c.base_records = records
        c.changed = None
        c.new_records = None
        c.chunk_size = chunk_size
        c.num_records = len(records)
        c.tree = MerkleTree(records, chunk_size)
        c.hash_ops = c.tree.hash_ops
        c._tampered = {}
        c.depth = 0
        return c

    @classmethod
    def delta(cls, prev: "DeltaCommit", changed, new_records: Records,
              leaf_digests: Optional[Mapping[int, bytes]] = None
              ) -> "DeltaCommit":
        """Incremental commit: ``changed`` (strictly increasing record
        indices) and ``new_records`` (aligned updated rows) overlay
        ``prev``. ``leaf_digests`` optionally supplies the dirty chunks'
        precomputed digests (the batched fast path — the caller holds the
        up-to-date population buffer); otherwise dirty chunks are
        materialized through the overlay and hashed here."""
        changed = np.asarray(changed, np.int64).reshape(-1)
        if len(changed) != len(new_records):
            raise ValueError("changed/new_records length mismatch")
        if len(changed):
            if len(changed) > 1 and (np.diff(changed) <= 0).any():
                raise ValueError(
                    "changed indices must be strictly increasing")
            if changed[0] < 0 or changed[-1] >= prev.num_records:
                raise IndexError("changed record index out of range")
        c = object.__new__(cls)
        c.prev = prev
        c.base_records = None
        c.changed = changed
        c.new_records = new_records
        c.chunk_size = prev.chunk_size
        c.num_records = prev.num_records
        c._tampered = {}
        c.depth = prev.depth + 1
        c.tree = prev.tree.clone()
        if leaf_digests is None:
            k = c.chunk_size
            leaf_digests = {
                int(li): _leaf_digest(b"".join(c.record_chunk(int(li) * k)[0]))
                for li in np.unique(changed // k).tolist()}
        ops = c.tree.update_leaf_digests(leaf_digests)
        c.hash_ops = len(leaf_digests) + ops
        return c

    # -- population-wide record view -----------------------------------------

    def __len__(self) -> int:
        return self.num_records

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        if not -len(self) <= i < len(self):
            raise IndexError(i)
        i %= len(self)
        c = self
        while c is not None:
            if i in c._tampered:
                return c._tampered[i]
            if c.changed is not None and len(c.changed):
                pos = int(np.searchsorted(c.changed, i))
                if pos < len(c.changed) and c.changed[pos] == i:
                    return c.new_records[pos]
            if c.prev is None:
                return c.base_records[i]
            c = c.prev
        raise IndexError(i)                   # unreachable

    @property
    def num_shards(self) -> int:
        return 1

    @property
    def root(self) -> str:
        return self.tree.root

    @property
    def root_digest(self) -> bytes:
        return self.tree.levels[-1][0]

    def shard_roots(self) -> List[str]:
        return [self.root]

    # -- proofs / audit (flat-tree semantics) --------------------------------

    def record_proof(self, record_index: int) -> List[Tuple[str, str]]:
        """Flat-tree node path for the chunk committing ``record_index`` —
        the same ``(side, digest)`` list a dense single-shard commit
        emits, valid for idle and active records alike."""
        return self.tree.record_proof(record_index)

    def record_chunk(self, record_index: int) -> Tuple[List[bytes], int]:
        """The record's leaf chunk, materialized through the overlay
        chain, and its offset within the chunk."""
        if not 0 <= record_index < self.num_records:
            raise IndexError(f"record index {record_index} out of range")
        k = self.chunk_size
        start = (record_index // k) * k
        stop = min(start + k, self.num_records)
        return [bytes(self[i]) for i in range(start, stop)], \
            record_index - start

    def tamper(self, record_index: int, leaf: bytes) -> None:
        """Test hook: corrupt one record of *this block's* stored view in
        place (works for inherited — idle-worker — records too)."""
        if not 0 <= record_index < self.num_records:
            raise IndexError(f"record index {record_index} out of range")
        self._tampered[record_index] = leaf

    def materialize(self) -> Records:
        """The full population's records with the overlay collapsed. One
        vectorized replay (base buffer copy + per-delta row scatter) when
        every layer is an untampered ``RecordBatch``; a per-record
        materialization otherwise (tampered rows may have any length)."""
        chain = [self]
        c = self
        while c.prev is not None:
            c = c.prev
            chain.append(c)
        base = chain[-1]
        fast = (isinstance(base.base_records, RecordBatch)
                and all(not layer._tampered for layer in chain)
                and all(isinstance(layer.new_records, RecordBatch)
                        for layer in chain[:-1]))
        if fast:
            itemsize = base.base_records.itemsize
            buf = np.frombuffer(base.base_records.buf, np.uint8).reshape(
                self.num_records, itemsize).copy()
            for layer in reversed(chain[:-1]):      # oldest delta first
                rows = np.frombuffer(layer.new_records.buf, np.uint8)
                buf[layer.changed] = rows.reshape(
                    len(layer.new_records), itemsize)
            return RecordBatch(memoryview(buf).cast("B"), itemsize)
        return [bytes(self[i]) for i in range(self.num_records)]

    def rebuild(self) -> "DeltaCommit":
        """Fresh dense commit over the materialized population."""
        return DeltaCommit.full(self.materialize(), self.chunk_size)

    def recompute_root(self) -> str:
        """Root rebuilt from scratch over the materialized population
        (deep verification — detects tampering with changed *and*
        inherited rows)."""
        return MerkleTree(self.materialize(), self.chunk_size).root


AnyCommit = Union[ShardedCommit, DeltaCommit]


# -- multi-task (three-level) commits -----------------------------------------


class MultiTaskCommit:
    """Third Merkle level over per-task commit roots.

    ``commits`` maps ``task_id`` (an arbitrary string; ``None`` names the
    anonymous single-task legacy path) to that task's commit — a dense
    ``ShardedCommit`` or an incremental ``DeltaCommit`` (tenants may mix
    freely; the task level only consumes each commit's ``root_digest``).
    Task roots combine pairwise bottom-up in canonical (sorted task id)
    order with the interior-node rule into the block root. A record proof
    is the task's own proof followed by the task path — with a single
    task the root equals the task's super-root and the task path is empty,
    so single-task commits are bit-identical to a bare commit. Each
    task's chunk size may differ (heterogeneous tenants)."""

    __slots__ = ("task_ids", "commits", "task_levels", "hash_ops")

    def __init__(self, commits: Dict[Optional[str], AnyCommit]) -> None:
        if not commits:
            raise ValueError("MultiTaskCommit needs at least one task commit")
        if len(commits) > 1 and any(t is None for t in commits):
            raise ValueError("anonymous task commit only allowed alone")
        self.task_ids: List[Optional[str]] = (
            sorted(commits) if len(commits) > 1 else list(commits))
        self.commits: Dict[Optional[str], AnyCommit] = {
            t: commits[t] for t in self.task_ids}
        level = [c.root_digest for c in self.commits.values()]
        self.task_levels: List[List[bytes]] = [level]
        task_ops = 0
        while len(level) > 1:
            level, ops = _combine(level)
            task_ops += ops
            self.task_levels.append(level)
        self.hash_ops = sum(c.hash_ops for c in self.commits.values()) \
            + task_ops

    @property
    def num_tasks(self) -> int:
        return len(self.task_ids)

    @property
    def root(self) -> str:
        return self.task_levels[-1][0].hex()

    def task_roots(self) -> Dict[Optional[str], str]:
        """The canonical ``task_id → super-root`` map this commit binds."""
        return {t: c.root for t, c in self.commits.items()}

    def _resolve(self, task_id: Optional[str]) -> Optional[str]:
        if task_id is None:
            if self.num_tasks == 1:
                return self.task_ids[0]
            raise KeyError(
                "block commits multiple tasks; a task_id is required")
        if task_id not in self.commits:
            raise KeyError(f"no commit for task {task_id!r}")
        return task_id

    def commit_for(self, task_id: Optional[str] = None) -> AnyCommit:
        """One task's commit (``task_id`` optional when the block commits
        a single task — the legacy single-tenant accessors)."""
        return self.commits[self._resolve(task_id)]

    def task_path(self, task_id: Optional[str] = None
                  ) -> List[Tuple[str, str]]:
        """Sibling path from a task's super-root to the block root — the
        cross-task (third) level of a settlement proof."""
        tid = self._resolve(task_id)
        return _path_through(self.task_levels[:-1], self.task_ids.index(tid))

    def record_proof(self, record_index: int,
                     task_id: Optional[str] = None) -> List[Tuple[str, str]]:
        """Three-level node path: chunk path inside the record's shard, the
        shard path to the task's super-root, then the task path to the
        block root. ``MerkleTree.verify`` consumes it unchanged."""
        tid = self._resolve(task_id)
        return self.commits[tid].record_proof(record_index) \
            + self.task_path(tid)

    def record_chunk(self, record_index: int,
                     task_id: Optional[str] = None
                     ) -> Tuple[List[bytes], int]:
        return self.commit_for(task_id).record_chunk(record_index)

    def tamper(self, record_index: int, leaf: bytes,
               task_id: Optional[str] = None) -> None:
        """Test hook: corrupt one task's stored record in place."""
        self.commit_for(task_id).tamper(record_index, leaf)

    def recompute_root(self) -> str:
        """Block root rebuilt from every task's stored records (deep
        verification — rebuilds each task's commit from scratch, its
        super levels, and the cross-task task level; delta commits
        materialize their overlay chain back to the base first)."""
        rebuilt = {t: c.rebuild() for t, c in self.commits.items()}
        return MultiTaskCommit(rebuilt).root


@dataclass
class Block:
    index: int
    prev_hash: str
    transactions: List[dict]
    timestamp: float
    records_root: str = ""    # Merkle root of the batch commit ("" if none)
    # canonical task_id → super-root map of a multi-task block; None when
    # the block commits at most one task (single-task hashes stay stable)
    task_roots: Optional[Dict[str, str]] = None
    hash: str = ""

    def compute_hash(self) -> str:
        body = {"index": self.index, "prev": self.prev_hash,
                "txs": self.transactions, "ts": self.timestamp}
        if self.records_root:       # keep genesis/legacy block hashes stable
            body["records_root"] = self.records_root
        if self.task_roots:         # multi-task layout only — a single-task
            body["task_roots"] = self.task_roots   # block hashes like PR-3
        return sha256(canonical(body))


class Ledger:
    """Append-only block chain with one block per FL round (plus genesis)."""

    GENESIS_HASH = "0" * 64

    def __init__(self) -> None:
        genesis = Block(0, self.GENESIS_HASH, [{"type": "genesis"}], 0.0)
        genesis.hash = genesis.compute_hash()
        self.blocks: List[Block] = [genesis]
        self.work_units: int = 0          # hashing/verification operations done
        # off-chain data availability: per-block multi-task commit (per-task
        # batches + shard subtrees + super levels + the task level);
        # single-task single-shard commits additionally mirror their tree
        # into _record_trees (the pre-sharding introspection API)
        self._commits: Dict[int, MultiTaskCommit] = {}
        self._record_trees: Dict[int, MerkleTree] = {}

    @property
    def head(self) -> Block:
        return self.blocks[-1]

    @staticmethod
    def _build_commit(record_batch: Optional[Records],
                      record_shards: Optional[Sequence[Records]],
                      shard_trees: Optional[Sequence[MerkleTree]],
                      chunk_size: int) -> Optional[ShardedCommit]:
        """One task's sharded commit from either a flat batch or per-shard
        batches (with optional prebuilt trees); None when empty."""
        if record_shards is not None:
            if shard_trees is not None and \
                    len(shard_trees) != len(record_shards):
                raise ValueError("one precomputed tree per shard required")
            # drop empty shards and their trees in lockstep so the
            # shard↔tree pairing survives the filter
            keep = [i for i, s in enumerate(record_shards) if len(s)]
            if keep:
                return ShardedCommit(
                    [record_shards[i] for i in keep], chunk_size,
                    trees=None if shard_trees is None
                    else [shard_trees[i] for i in keep])
        elif record_batch is not None and len(record_batch):
            return ShardedCommit([record_batch], chunk_size)
        return None

    def _seal(self, transactions: List[dict], timestamp: Optional[float],
              commit: Optional[MultiTaskCommit]) -> Block:
        blk = Block(len(self.blocks), self.head.hash, list(transactions),
                    time.monotonic() if timestamp is None else timestamp,
                    records_root=commit.root if commit is not None else "",
                    task_roots={t: r for t, r in commit.task_roots().items()}
                    if commit is not None and commit.num_tasks > 1 else None)
        blk.hash = blk.compute_hash()
        # verification pass every append (each node re-hashes the new block);
        # batched commits add their ~2·ceil(n/k)−1 Merkle hashes per task
        self.work_units += 1 + len(transactions)
        if commit is not None:
            self.work_units += commit.hash_ops
            # Publication order is the read path's lock-free contract: the
            # block's commit is registered in `_commits` BEFORE the block
            # becomes visible in `blocks` (list append is atomic under the
            # GIL), and sealed commits are immutable — so a concurrent
            # reader (`repro.serve.ChainReadServer`) that can see block i
            # can always resolve block i's proofs without taking any lock,
            # and never makes the settler thread wait.
            self._commits[blk.index] = commit
            if commit.num_tasks == 1:
                only = commit.commit_for()
                if isinstance(only, ShardedCommit) and only.num_shards == 1:
                    self._record_trees[blk.index] = only.trees[0]
        self.blocks.append(blk)
        return blk

    def append_block(self, transactions: List[dict],
                     timestamp: Optional[float] = None,
                     record_batch: Optional[Records] = None,
                     chunk_size: int = 1,
                     record_shards: Optional[Sequence[Records]] = None,
                     shard_trees: Optional[Sequence[MerkleTree]] = None,
                     record_delta: Optional[DeltaCommit] = None,
                     task_id: Optional[str] = None) -> Block:
        """Seal a single-task block. Canonically-encoded per-worker
        settlement records are Merkle-committed into the block hash via
        ``records_root`` with ``chunk_size`` records per leaf; the records
        themselves stay off-chain but per-record auditable
        (``merkle_proof`` / ``record_chunk``). Pass either ``record_batch``
        (one flat batch), ``record_shards`` (per-shard batches, optionally
        with their ``shard_trees`` prebuilt in parallel by a settler pool —
        with subtree-aligned shards both commit the identical root), or
        ``record_delta`` (a prebuilt incremental ``DeltaCommit`` — the
        sparse path; the block commits the full population's root while
        only the dirty paths were hashed). ``task_id`` names the
        committing task on a multi-tenant node; block hashes are task-id
        independent for single-task blocks."""
        commit: Optional[AnyCommit] = record_delta
        if commit is None:
            commit = self._build_commit(record_batch, record_shards,
                                        shard_trees, chunk_size)
        return self._seal(transactions, timestamp,
                          MultiTaskCommit({task_id: commit})
                          if commit is not None else None)

    def append_multi_block(self, transactions: List[dict],
                           timestamp: Optional[float],
                           task_commits: Dict[str, AnyCommit]) -> Block:
        """Seal a multi-task block committing several tasks' rounds at
        once: the canonical ``task_id → super-root`` map enters the block
        hash (``task_roots``) and the ``records_root`` is the cross-task
        combined root. With exactly one task this is bit-identical to
        ``append_block`` — co-tenancy, like shard count, only becomes
        consensus-visible when a block genuinely carries several tasks."""
        commits = {t: c for t, c in task_commits.items() if c is not None}
        return self._seal(transactions, timestamp,
                          MultiTaskCommit(commits) if commits else None)

    def verify_chain(self, deep: bool = False) -> bool:
        """Hash-chain integrity; ``deep=True`` additionally recurses through
        every stored commit — rebuilding each task's shard subtrees, its
        cross-shard super-root, and the cross-task task level — against the
        block commitment (including the ``task_roots`` map)."""
        prev = self.GENESIS_HASH
        for blk in self.blocks:
            if blk.prev_hash != prev or blk.hash != blk.compute_hash():
                return False
            if deep and blk.index in self._commits:
                commit = self._commits[blk.index]
                if commit.recompute_root() != blk.records_root:
                    return False
                if blk.task_roots is not None and \
                        blk.task_roots != commit.task_roots():
                    return False
            prev = blk.hash
        return True

    # -- fork tracking (repro.net) --------------------------------------------

    def rollback_to(self, block_index: int) -> List[Block]:
        """Fork-choice rollback: drop every block *above* ``block_index``
        (which stays the new head) together with its registered commits.
        Returns the removed blocks oldest-first, so a caller that tracked
        them in a fork tree can re-adopt a competing branch. Contract
        state is *not* touched here — the network node restores its own
        snapshot for the surviving height and replays the winning branch
        through ``adopt_block`` (see ``repro.net.fork_choice``)."""
        if not 0 <= block_index < len(self.blocks):
            raise ValueError(
                f"rollback_to({block_index}) outside chain of height "
                f"{len(self.blocks)}")
        removed = self.blocks[block_index + 1:]
        for blk in removed:
            self._commits.pop(blk.index, None)
            self._record_trees.pop(blk.index, None)
        del self.blocks[block_index + 1:]
        self.work_units += len(removed)
        return removed

    def adopt_block(self, block: Block,
                    commit: Optional[MultiTaskCommit] = None,
                    verify_commit: bool = True) -> Block:
        """Append an *externally sealed* block (gossiped by a peer node)
        after LightClient-style verification on receipt: index
        continuity, ``prev_hash`` linkage, full hash recomputation, and —
        when the block commits records — that the shipped commit really
        re-hashes to the block's ``records_root``/``task_roots`` (the
        tampered-super-root check; ``verify_commit=False`` downgrades it
        to a root-equality check for commits already verified upstream).
        Raises ``ValueError`` on any mismatch with nothing applied."""
        if block.index != len(self.blocks):
            raise ValueError(
                f"adopted block index {block.index} != chain height "
                f"{len(self.blocks)}")
        if block.prev_hash != self.head.hash:
            raise ValueError(
                f"adopted block {block.index} does not link to head "
                f"{self.head.hash[:12]}…")
        if block.compute_hash() != block.hash:
            raise ValueError(
                f"adopted block {block.index} hash does not recompute")
        self.work_units += 1 + len(block.transactions)
        if commit is None:
            if block.records_root:
                raise ValueError(
                    f"adopted block {block.index} commits records but no "
                    f"commit was supplied")
        else:
            root = commit.recompute_root() if verify_commit else commit.root
            if root != block.records_root:
                raise ValueError(
                    f"adopted block {block.index} commit root mismatch "
                    f"(tampered super-root?)")
            if block.task_roots is not None \
                    and block.task_roots != commit.task_roots():
                raise ValueError(
                    f"adopted block {block.index} task_roots mismatch")
            self.work_units += commit.hash_ops
            # same publication order as _seal: commit registered before
            # the block becomes visible (lock-free read-path contract)
            self._commits[block.index] = commit
            if commit.num_tasks == 1:
                only = commit.commit_for()
                if isinstance(only, ShardedCommit) and only.num_shards == 1:
                    self._record_trees[block.index] = only.trees[0]
        self.blocks.append(block)
        return block

    # -- per-record audit -----------------------------------------------------

    def commit(self, block_index: int) -> MultiTaskCommit:
        """The block's stored multi-task commit — the proof server's entry
        into off-chain data availability (read-only; sealed commits are
        immutable, so reader threads may hold one while the settler
        appends)."""
        return self._commits[block_index]

    def settlement_proof(self, block_index: int, record_index: int,
                         task_id: Optional[str] = None):
        """Typed unified proof (``repro.chain.proofs.SettlementProof``)
        for one committed record — the modern replacement for the
        ``merkle_proof`` / ``record_chunk`` / ``verify_record`` triple;
        verify with ``proof.verify(head)`` against any trusted head."""
        from repro.chain.proofs import build_settlement_proof
        return build_settlement_proof(self, block_index, record_index,
                                      task_id)

    def task_ids(self, block_index: int) -> List[Optional[str]]:
        """Tasks committed in a block, canonical order."""
        return list(self._commits[block_index].task_ids)

    def task_roots(self, block_index: int) -> Dict[Optional[str], str]:
        """The block's canonical ``task_id → super-root`` map."""
        return self._commits[block_index].task_roots()

    def record_batch(self, block_index: int,
                     task_id: Optional[str] = None) -> Records:
        """One task's committed records as one concatenated sequence
        (shard-agnostic view; single-shard commits return the batch; delta
        commits return the population-wide overlay view)."""
        commit = self._commits[block_index].commit_for(task_id)
        if isinstance(commit, DeltaCommit):
            return commit
        return commit.shards[0] if commit.num_shards == 1 else commit

    def record_chunk_size(self, block_index: int,
                          task_id: Optional[str] = None) -> int:
        return self._commits[block_index].commit_for(task_id).chunk_size

    def num_shards(self, block_index: int,
                   task_id: Optional[str] = None) -> int:
        return self._commits[block_index].commit_for(task_id).num_shards

    def shard_roots(self, block_index: int,
                    task_id: Optional[str] = None) -> List[str]:
        """Per-shard subtree roots under a task's super-root."""
        return self._commits[block_index].commit_for(task_id).shard_roots()

    def merkle_proof(self, block_index: int, record_index: int,
                     task_id: Optional[str] = None) -> List[Tuple[str, str]]:
        """O(log(n/k)) three-level node path — the chunk path inside the
        record's shard, the shard path to its task's super-root, and the
        task path to the block root (empty for single-task blocks) — for
        one settlement record of a batched block; auditing worker w never
        rehashes the round.

        Deprecated thin wrapper: the bare path is one field of the typed
        ``settlement_proof`` (property-tested identical to
        ``SettlementProof.path``); new code should carry the whole
        ``SettlementProof``."""
        return self._commits[block_index].record_proof(record_index, task_id)

    def record_chunk(self, block_index: int, record_index: int,
                     task_id: Optional[str] = None
                     ) -> Tuple[List[bytes], int]:
        """The chunk of records whose leaf commits ``record_index``, plus
        the record's offset within it — what an auditor ships alongside the
        node path so a verifier can recompute the leaf."""
        return self._commits[block_index].record_chunk(record_index, task_id)

    def verify_record(self, block_index: int, record_index: int,
                      leaf: Optional[bytes] = None,
                      proof: Optional[Sequence[Tuple[str, str]]] = None,
                      task_id: Optional[str] = None) -> bool:
        """Check one record against the on-chain root (record/proof default
        to the ledger's own stored copies; pass externally-held values to
        audit a third party's claim). The leaf is recomputed from the
        record's chunk with ``leaf`` substituted at the record's offset.

        Deprecated thin wrapper over ``SettlementProof.verify`` (the one
        verification rule for every block flavor)."""
        from repro.chain.proofs import SettlementProof
        blk = self.blocks[block_index]
        if not blk.records_root:
            return False
        chunk, offset = self.record_chunk(block_index, record_index, task_id)
        if leaf is not None:
            chunk[offset] = leaf
        if proof is None:
            proof = self.merkle_proof(block_index, record_index, task_id)
        sp = SettlementProof(block_index=block_index,
                             leaf_index=record_index, chunk=tuple(chunk),
                             offset=offset,
                             path=tuple(tuple(p) for p in proof),
                             root=blk.records_root)
        return sp.verify(blk)

    def tamper_record(self, block_index: int, record_index: int,
                      leaf: bytes, task_id: Optional[str] = None) -> None:
        """Test hook: corrupt an off-chain settlement record in place."""
        self._commits[block_index].tamper(record_index, leaf, task_id)

    @staticmethod
    def randomness_from(head_hash: str, round_index: int) -> int:
        """Deterministic on-chain randomness (leader rotation seed) derived
        from a chain-head hash — every node derives the same leader. Static
        so a pipelined driver can consume a head published by the settler
        thread without racing live ledger state."""
        return int(sha256(f"{head_hash}:{round_index}".encode())[:16], 16)

    def randomness(self, round_index: int) -> int:
        return self.randomness_from(self.head.hash, round_index)

    def transactions_of_type(self, tx_type: str) -> List[dict]:
        return [tx for blk in self.blocks for tx in blk.transactions
                if tx.get("type") == tx_type]
