"""Hash-chained ledger — the simulated permissioned blockchain.

Not a stub: blocks are really SHA-256 hash-chained over canonically-encoded
transaction payloads, and ``verify_chain`` actually detects tampering. What
is simulated away (consensus latency, gossip) is accounted for by
``work_units`` so the with/without-blockchain wall-time comparison (paper
Fig. 2) has a mechanism-faithful cost model.

Batched settlement (the array-native chain path): instead of embedding one
score/penalty transaction dict per worker — O(W) Python dicts hashed into
every round block — a block *commits* to the round's per-worker settlement
records through a Merkle root over their canonical encodings
(``Block.records_root``, part of the block hash). The records themselves
live in the ledger's off-chain availability layer (``record_batch`` per
block); any single worker's settlement stays auditable via an
O(log(W/k) + k) ``merkle_proof`` / ``verify_record`` without rehashing the
whole round. ``verify_chain(deep=True)`` additionally recomputes every
stored batch's root, so tampering with an individual record is detected
exactly like tampering with an embedded transaction used to be.

Chunked leaves: a commit may pack ``chunk_size`` consecutive records into
each Merkle leaf (leaf bytes = the records' concatenation), so a W-record
commit hashes ~2·W/k nodes instead of ~2·W — the per-leaf SHA-256 was the
last O(W) host cost on the settlement path. Auditing one record then needs
its chunk (k records, fixed-width so the offset is unambiguous) plus the
O(log(W/k)) node path; ``chunk_size=1`` reproduces the per-record tree
bit-for-bit. ``work_units`` counts the batched cost model: 1 + |txs| per
block plus the ~2·ceil(n/k)−1 Merkle hashes of an n-record commit.
"""
from __future__ import annotations

import hashlib
import json
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union


def canonical(obj: Any) -> bytes:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=str).encode()


def sha256(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


# -- Merkle commitment over per-worker settlement records ---------------------

_LEAF_PREFIX = b"\x00"   # domain separation: leaf vs interior node hashing
_NODE_PREFIX = b"\x01"   # (prevents second-preimage/extension confusions)


class RecordBatch(Sequence):
    """Fixed-width records backed by one contiguous buffer.

    The batch settlement path encodes a whole round as a single structured
    numpy buffer; wrapping it (instead of slicing W small ``bytes`` objects
    up front) keeps the commit zero-copy — chunk leaves are direct buffer
    slices and per-record access materializes only the record asked for.
    """

    __slots__ = ("buf", "itemsize")

    def __init__(self, buf: bytes, itemsize: int) -> None:
        if itemsize <= 0 or len(buf) % itemsize:
            raise ValueError("buffer is not a whole number of records")
        self.buf = buf
        self.itemsize = itemsize

    def __len__(self) -> int:
        return len(self.buf) // self.itemsize

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        if not -len(self) <= i < len(self):
            raise IndexError(i)
        i %= len(self)
        return self.buf[i * self.itemsize:(i + 1) * self.itemsize]

    def chunk_bytes(self, start: int, stop: int) -> bytes:
        return self.buf[start * self.itemsize:stop * self.itemsize]


Records = Union[RecordBatch, Sequence[bytes]]


def _chunk_bytes(records: Records, start: int, stop: int) -> bytes:
    if stop - start == 1:                     # per-record leaf (chunk_size=1)
        return records[start]
    if isinstance(records, RecordBatch):
        return records.chunk_bytes(start, stop)
    return b"".join(records[start:stop])


class MerkleTree:
    """Binary Merkle tree over records, ``chunk_size`` records per leaf.

    A leaf's bytes are the concatenation of its chunk's records (with the
    default ``chunk_size=1`` this is exactly a per-record tree — same roots
    and proofs as always). Odd nodes are promoted unpaired (Bitcoin-style
    duplication would allow mutation by appending a copy of the last leaf;
    promotion does not). Proofs are lists of ``(side, sibling_digest_hex)``
    with side ``"L"`` if the sibling sits left of the running hash.
    """

    def __init__(self, records: Records, chunk_size: int = 1) -> None:
        if not len(records):
            raise ValueError("MerkleTree needs at least one record")
        if chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        n = len(records)
        self.num_records = n
        self.chunk_size = chunk_size
        level = [hashlib.sha256(
            _LEAF_PREFIX + _chunk_bytes(records, i, min(i + chunk_size, n))
        ).digest() for i in range(0, n, chunk_size)]
        self.levels: List[List[bytes]] = [level]
        while len(level) > 1:
            nxt = []
            for i in range(0, len(level) - 1, 2):
                nxt.append(hashlib.sha256(
                    _NODE_PREFIX + level[i] + level[i + 1]).digest())
            if len(level) % 2:
                nxt.append(level[-1])            # promote unpaired node
            self.levels.append(nxt)
            level = nxt
        # cost model: one hash per leaf + one per interior node
        self.hash_ops = sum(len(lv) for lv in self.levels[:-1]) + 1 \
            if len(self.levels) > 1 else 1

    @property
    def num_leaves(self) -> int:
        return len(self.levels[0])

    @property
    def root(self) -> str:
        return self.levels[-1][0].hex()

    def proof(self, index: int) -> List[Tuple[str, str]]:
        """Node path for leaf (= chunk) ``index``."""
        if not 0 <= index < self.num_leaves:
            raise IndexError(f"leaf index {index} out of range")
        path: List[Tuple[str, str]] = []
        for level in self.levels[:-1]:
            sib = index ^ 1
            if sib < len(level):
                path.append(("L" if sib < index else "R", level[sib].hex()))
            index //= 2
        return path

    def record_proof(self, record_index: int) -> List[Tuple[str, str]]:
        """Node path for the chunk containing record ``record_index``."""
        if not 0 <= record_index < self.num_records:
            raise IndexError(f"record index {record_index} out of range")
        return self.proof(record_index // self.chunk_size)

    @staticmethod
    def verify(leaf: bytes, proof: Sequence[Tuple[str, str]],
               root: str) -> bool:
        """``leaf`` is the full leaf byte-string — for a chunked tree, the
        concatenation of the chunk's records."""
        h = hashlib.sha256(_LEAF_PREFIX + leaf).digest()
        for side, sib_hex in proof:
            sib = bytes.fromhex(sib_hex)
            pair = sib + h if side == "L" else h + sib
            h = hashlib.sha256(_NODE_PREFIX + pair).digest()
        return h.hex() == root


@dataclass
class Block:
    index: int
    prev_hash: str
    transactions: List[dict]
    timestamp: float
    records_root: str = ""    # Merkle root of the batch commit ("" if none)
    hash: str = ""

    def compute_hash(self) -> str:
        body = {"index": self.index, "prev": self.prev_hash,
                "txs": self.transactions, "ts": self.timestamp}
        if self.records_root:       # keep genesis/legacy block hashes stable
            body["records_root"] = self.records_root
        return sha256(canonical(body))


class Ledger:
    """Append-only block chain with one block per FL round (plus genesis)."""

    GENESIS_HASH = "0" * 64

    def __init__(self) -> None:
        genesis = Block(0, self.GENESIS_HASH, [{"type": "genesis"}], 0.0)
        genesis.hash = genesis.compute_hash()
        self.blocks: List[Block] = [genesis]
        self.work_units: int = 0          # hashing/verification operations done
        # off-chain data availability: per-block batch records + their tree
        self._record_batches: Dict[int, Records] = {}
        self._record_trees: Dict[int, MerkleTree] = {}
        self._record_chunks: Dict[int, int] = {}

    @property
    def head(self) -> Block:
        return self.blocks[-1]

    def append_block(self, transactions: List[dict],
                     timestamp: Optional[float] = None,
                     record_batch: Optional[Records] = None,
                     chunk_size: int = 1) -> Block:
        """Seal a block. ``record_batch`` (canonically-encoded per-worker
        settlement records) is Merkle-committed into the block hash via
        ``records_root`` with ``chunk_size`` records per leaf; the records
        themselves stay off-chain but per-record auditable
        (``merkle_proof`` / ``record_chunk``)."""
        root = ""
        tree = None
        if record_batch is not None and len(record_batch):
            tree = MerkleTree(record_batch, chunk_size)
            root = tree.root
        blk = Block(len(self.blocks), self.head.hash, list(transactions),
                    time.monotonic() if timestamp is None else timestamp,
                    records_root=root)
        blk.hash = blk.compute_hash()
        # verification pass every append (each node re-hashes the new block);
        # batched commits add their ~2·ceil(n/k)−1 Merkle hashes
        self.work_units += 1 + len(transactions)
        if tree is not None:
            self.work_units += tree.hash_ops
            self._record_batches[blk.index] = (
                record_batch if isinstance(record_batch, RecordBatch)
                else list(record_batch))
            self._record_trees[blk.index] = tree
            self._record_chunks[blk.index] = chunk_size
        self.blocks.append(blk)
        return blk

    def verify_chain(self, deep: bool = False) -> bool:
        """Hash-chain integrity; ``deep=True`` additionally recomputes every
        stored record batch's Merkle root against its block commitment."""
        prev = self.GENESIS_HASH
        for blk in self.blocks:
            if blk.prev_hash != prev or blk.hash != blk.compute_hash():
                return False
            if deep and blk.index in self._record_batches:
                if (MerkleTree(self._record_batches[blk.index],
                               self._record_chunks[blk.index]).root
                        != blk.records_root):
                    return False
            prev = blk.hash
        return True

    # -- per-record audit -----------------------------------------------------

    def record_batch(self, block_index: int) -> Records:
        return self._record_batches[block_index]

    def record_chunk_size(self, block_index: int) -> int:
        return self._record_chunks[block_index]

    def merkle_proof(self, block_index: int,
                     record_index: int) -> List[Tuple[str, str]]:
        """O(log(n/k)) node path for the chunk holding one settlement record
        of a batched block — auditing worker w never rehashes the round."""
        return self._record_trees[block_index].record_proof(record_index)

    def record_chunk(self, block_index: int,
                     record_index: int) -> Tuple[List[bytes], int]:
        """The chunk of records whose leaf commits ``record_index``, plus
        the record's offset within it — what an auditor ships alongside the
        node path so a verifier can recompute the leaf."""
        records = self._record_batches[block_index]
        k = self._record_chunks[block_index]
        start = (record_index // k) * k
        stop = min(start + k, len(records))
        return [bytes(records[i]) for i in range(start, stop)], \
            record_index - start

    def verify_record(self, block_index: int, record_index: int,
                      leaf: Optional[bytes] = None,
                      proof: Optional[Sequence[Tuple[str, str]]] = None
                      ) -> bool:
        """Check one record against the on-chain root (record/proof default
        to the ledger's own stored copies; pass externally-held values to
        audit a third party's claim). The leaf is recomputed from the
        record's chunk with ``leaf`` substituted at the record's offset."""
        blk = self.blocks[block_index]
        if not blk.records_root:
            return False
        chunk, offset = self.record_chunk(block_index, record_index)
        if leaf is not None:
            chunk[offset] = leaf
        if proof is None:
            proof = self.merkle_proof(block_index, record_index)
        return MerkleTree.verify(b"".join(chunk), proof, blk.records_root)

    def tamper_record(self, block_index: int, record_index: int,
                      leaf: bytes) -> None:
        """Test hook: corrupt an off-chain settlement record in place."""
        batch = self._record_batches[block_index]
        if isinstance(batch, RecordBatch):     # materialize to a mutable list
            batch = self._record_batches[block_index] = list(batch)
        batch[record_index] = leaf

    @staticmethod
    def randomness_from(head_hash: str, round_index: int) -> int:
        """Deterministic on-chain randomness (leader rotation seed) derived
        from a chain-head hash — every node derives the same leader. Static
        so a pipelined driver can consume a head published by the settler
        thread without racing live ledger state."""
        return int(sha256(f"{head_hash}:{round_index}".encode())[:16], 16)

    def randomness(self, round_index: int) -> int:
        return self.randomness_from(self.head.hash, round_index)

    def transactions_of_type(self, tx_type: str) -> List[dict]:
        return [tx for blk in self.blocks for tx in blk.transactions
                if tx.get("type") == tx_type]
