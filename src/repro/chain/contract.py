"""Smart-contract state machine executing the paper's Algorithm 1.

Steps (paper §III.E):
  1. Requester deploys, depositing D (task reward pool).
  2. Each worker joins by staking F.
  3. Per round: workers submit evaluation scores S(w).
  4. BadWorkers = {w | S(w) < T}.
     Pen(w) = F · P / 100, deducted from the stake.
  5. D(w) = F − Pen(w).
  6. Refund(w) = D(w) at task end.
  7. Collected penalties transfer to the requester.
  8. TopKWorkers split the reward pool: Reward(w) = R_total / k.

Every state transition emits a transaction; the ledger stores them in the
round's block, so balances are fully auditable/replayable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.chain.ledger import Ledger


class ContractError(RuntimeError):
    pass


@dataclass
class WorkerAccount:
    stake: float                     # remaining deposit D(w)
    balance: float = 0.0             # rewards + refunds received
    penalized_rounds: int = 0
    scores: List[float] = field(default_factory=list)


class TrustContract:
    """One deployed FL task. Mirrors Algorithm 1 exactly."""

    def __init__(self, ledger: Ledger, *, requester_deposit: float,
                 worker_stake: float, penalty_pct: float,
                 trust_threshold: float, top_k: int) -> None:
        if requester_deposit <= 0:
            raise ContractError("deployment requires a positive deposit")
        self.ledger = ledger
        self.F = worker_stake
        self.P = penalty_pct
        self.T = trust_threshold
        self.k = top_k
        self.reward_pool = requester_deposit
        self.requester_balance = 0.0
        self.workers: Dict[str, WorkerAccount] = {}
        self.pending: List[dict] = [{"type": "deploy", "deposit": requester_deposit,
                                     "F": worker_stake, "P": penalty_pct,
                                     "T": trust_threshold, "k": top_k}]
        self.closed = False

    # -- enrollment ---------------------------------------------------------

    def join(self, worker_id: str) -> None:
        if self.closed:
            raise ContractError("task closed")
        if worker_id in self.workers:
            raise ContractError(f"{worker_id} already joined")
        self.workers[worker_id] = WorkerAccount(stake=self.F)
        self.pending.append({"type": "join", "worker": worker_id, "stake": self.F})

    # -- per-round settlement (Alg. 1 steps 3-7) -----------------------------

    def settle_round(self, round_index: int, scores: Dict[str, float],
                     model_cid: str = "") -> Dict[str, float]:
        """Record scores, penalize bad workers, seal the round's block.
        Returns the penalties imposed this round."""
        if self.closed:
            raise ContractError("task closed")
        unknown = set(scores) - set(self.workers)
        if unknown:
            raise ContractError(f"scores from non-participants: {unknown}")
        penalties: Dict[str, float] = {}
        for wid, s in sorted(scores.items()):
            acct = self.workers[wid]
            acct.scores.append(float(s))
            self.pending.append({"type": "score", "round": round_index,
                                 "worker": wid, "score": float(s)})
            if s < self.T:                                   # BadWorkers
                pen = min(self.F * self.P / 100.0, acct.stake)
                acct.stake -= pen
                acct.penalized_rounds += 1
                self.requester_balance += pen                # step 7
                penalties[wid] = pen
                self.pending.append({"type": "penalty", "round": round_index,
                                     "worker": wid, "amount": pen})
        if model_cid:
            self.pending.append({"type": "model", "round": round_index,
                                 "cid": model_cid})
        self.ledger.append_block(self.pending)
        self.pending = []
        return penalties

    # -- task finalization (Alg. 1 steps 6 & 8) ------------------------------

    def finalize(self) -> Dict[str, float]:
        """Refund remaining stakes; pay top-k by mean score. Returns payouts."""
        if self.closed:
            raise ContractError("already finalized")
        self.closed = True
        txs: List[dict] = []
        payouts: Dict[str, float] = {}
        for wid, acct in sorted(self.workers.items()):
            refund = acct.stake                              # Refund(w) = D(w)
            acct.stake = 0.0
            acct.balance += refund
            payouts[wid] = refund
            txs.append({"type": "refund", "worker": wid, "amount": refund})
        ranked = sorted(self.workers,
                        key=lambda w: (sum(self.workers[w].scores) /
                                       max(len(self.workers[w].scores), 1)),
                        reverse=True)
        top = ranked[: self.k]
        if top:
            share = self.reward_pool / len(top)              # R_total / k
            for wid in top:
                self.workers[wid].balance += share
                payouts[wid] = payouts.get(wid, 0.0) + share
                txs.append({"type": "reward", "worker": wid, "amount": share})
            self.reward_pool = 0.0
        self.ledger.append_block(txs)
        return payouts

    # -- conservation invariant (property tests) -----------------------------

    def total_value(self) -> float:
        """Money is conserved: pool + requester + stakes + balances."""
        return (self.reward_pool + self.requester_balance +
                sum(a.stake + a.balance for a in self.workers.values()))
