"""Smart-contract state machine executing the paper's Algorithm 1.

Steps (paper §III.E):
  1. Requester deploys, depositing D (task reward pool).
  2. Each worker joins by staking F.
  3. Per round: workers submit evaluation scores S(w).
  4. BadWorkers = {w | S(w) < T}.
     Pen(w) = F · P / 100, deducted from the stake.
  5. D(w) = F − Pen(w).
  6. Refund(w) = D(w) at task end.
  7. Collected penalties transfer to the requester.
  8. TopKWorkers split the reward pool: Reward(w) = R_total / k.

Array-native state: accounts are a struct-of-arrays (numpy ``stake`` /
``balance`` / ``penalized_rounds`` / ``score_sum`` / ``score_count``
vectors indexed by integer worker id), so a round settles in O(1) Python
ops and O(W) vectorized numpy — ``settle_round_batch`` computes BadWorkers,
penalties, and the requester transfer without a per-worker loop, and
``finalize`` ranks top-k via ``argpartition``. Each settlement block
commits to the round's canonically-encoded per-worker records through a
chunked Merkle root (see ``chain.ledger``): records are encoded as one
contiguous fixed-width buffer (``RecordBatch``) and committed
``merkle_chunk_size`` records per leaf, so the commit hashes ~2·W/k nodes
instead of ~2·W while balances stay fully auditable — per-worker via
O(log(W/k) + k) proofs (``settlement_proof``: the record's chunk plus the
node path) rather than per-worker embedded transactions.

Sharded settlement (``settlement_shards`` > 1): a round is partitioned
into contiguous slices of the struct-of-arrays state — each shard's
``settle_shard`` computes its slice's BadWorkers mask, penalties and
chunked Merkle subtree *without mutating contract state*, so slices run
concurrently on a settler pool. A deterministic merge (shard order ==
worker-id order) then applies the state transition from the concatenated
per-shard results and seals the block over the cross-shard super-root.
Shard boundaries are subtree-aligned (``plan_shard_bounds``), making the
super-root — and hence every block hash, proof, election and penalty —
bit-identical across shard counts and to the unsharded path; and because
no state is touched until every shard succeeded, a failing shard leaves
the contract and chain exactly as before the round (no half-settled
super-root is ever committed).

Multi-tenant settlement (``task_id``): several ``TrustContract`` tasks can
share one ledger on a chain node. The round settlement is split into three
composable phases so a node can co-commit many tasks' rounds into one
multi-task block: ``prepare_round_batch`` (validation + per-shard compute
thunks, pure), ``finish_round_batch`` (the deterministic merge — state
transition + transactions + commit parts), and ``note_block`` (audit
bookkeeping once the block is sealed). ``settle_round_batch`` composes the
three over a single-task block exactly as before, so the single-tenant
path is bit-identical. Proofs are task-scoped: ``settlement_proof`` walks
chunk-in-shard, shard-in-task, and task-in-block levels (the last empty on
single-task blocks) and verifies against the block's combined root.

Sparse settlement (``sparse_settlement=True``): the million-worker path.
The contract keeps a persistent full-population record buffer (every
worker's latest settlement record; genesis rows for the never-settled)
and commits each round as a ``DeltaCommit`` (see ``chain.ledger``): a
dense anchor on the first round / after enrollment growth / at full
participation / every ``sparse_rebase_every`` rounds, and otherwise an
incremental commit that re-hashes only the chunks the round's *changed
set* dirtied — O(C·log(W/k)) instead of O(W/k) per round, so settlement
cost scales with activity, not population. Every block still commits the
full population's root: ``settlement_proof`` covers idle workers (record
index == worker id), and ``verify_chain(deep=True)`` detects tampering
with inherited records exactly like with fresh ones. Algorithm 1
semantics (penalties, stakes, transfers) are unchanged — only the commit
strategy differs.

Staleness-aware settlement (``staleness_alpha`` > 0): the event-driven
node (``core.node.ChainNode.run_events``) settles whatever cohort arrived
at each aggregation event, and each settled record carries the update's
*staleness* (rounds since it was computed) in the canonical record
encoding — committed under the block's Merkle root, so the discount a
worker received is auditable on-chain. Penalties and payout credit scale
by ``(1+staleness)^-alpha`` (the same discount ``trust.staleness_discount``
applies to aggregation weight): a late-but-honest update is discounted,
not punished at full freshness weight. ``alpha=0`` — the default and the
synchronous path — is bit-identical to staleness-unaware settlement.

The documented surface is the batch API (``join_batch`` /
``settle_round_batch``) plus the typed proof surface (``proof`` returning
``repro.chain.proofs.SettlementProof``, verified with
``SettlementProof.verify(head)``). The legacy scalar API (``join`` /
``settle_round`` with a score dict / dict-like ``workers`` access) lives
behind the explicit ``contract.legacy`` namespace — still a thin wrapper
over the batch path, so Algorithm 1 semantics are provably unchanged (see
the batch-vs-scalar equivalence property test in ``tests/test_chain.py``);
calling ``join``/``settle_round`` directly warns ``DeprecationWarning``.
Likewise ``settlement_proof``/``verify_settlement`` remain as deprecated
dict-shaped wrappers emitting bit-identical proofs.
"""
from __future__ import annotations

import os
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Mapping, Optional, Tuple

import numpy as np

from repro.chain.ledger import (DeltaCommit, Ledger, MerkleTree, RecordBatch,
                                gathered_leaf_digests, plan_shard_bounds)
from repro.chain.proofs import SettlementProof, build_settlement_proof


class ContractError(RuntimeError):
    pass


# GIL economics of parallel settlement: a leaf hash releases the GIL only
# for updates of >= 2048 bytes (CPython's HASHLIB_GIL_MINSIZE — below that
# pure-CPython parallel hashing is architecturally impossible), and each
# release/acquire handoff costs more than a small leaf's hash. The framed
# batched hasher (``chain.ledger.batch_leaf_digests``) issues exactly one
# C call per leaf, halving the handoffs of the old two-``update`` path and
# dropping the measured pooled-fanout crossover from ~32 KiB to ~4 KiB per
# leaf on a 2-core host. Below the gate the sharded commit still runs
# (same bytes, same root), just on the calling thread. Env-overridable
# fallback for unusual hosts: SDFLB_MIN_PARALLEL_LEAF_BYTES.
MIN_PARALLEL_LEAF_BYTES = int(
    os.environ.get("SDFLB_MIN_PARALLEL_LEAF_BYTES", 4096))


_RECORD_DTYPE = np.dtype([("round", "<i8"), ("worker", "<i8"),
                          ("score", "<f8"), ("penalty", "<f8"),
                          ("stake_after", "<f8"), ("staleness", "<i8")])


def encode_settlement_records(round_index: int, worker_ids: np.ndarray,
                              scores: np.ndarray, penalties: np.ndarray,
                              stakes_after: np.ndarray,
                              staleness: Optional[np.ndarray] = None
                              ) -> RecordBatch:
    """Canonical fixed-width binary encoding of per-worker settlement
    records — the Merkle-committed data of a settlement block. Built
    vectorized into one contiguous buffer; the returned ``RecordBatch``
    wraps a memoryview straight onto the array's memory (no ``tobytes``
    copy — the commit hashes leaves out of the buffer zero-copy) and
    indexes like a list of per-record bytes. ``staleness`` (rounds since
    the worker's update was computed, 0 = fresh) defaults to zeros — the
    synchronous path."""
    n = len(worker_ids)
    rec = np.empty(n, dtype=_RECORD_DTYPE)
    rec["round"] = round_index
    rec["worker"] = worker_ids
    rec["score"] = scores
    rec["penalty"] = penalties
    rec["stake_after"] = stakes_after
    rec["staleness"] = 0 if staleness is None else staleness
    return RecordBatch(memoryview(rec).cast("B"), _RECORD_DTYPE.itemsize)


def decode_settlement_record(leaf: bytes) -> Dict[str, float]:
    rec = np.frombuffer(leaf, dtype=_RECORD_DTYPE)[0]
    return {"round": int(rec["round"]), "worker": int(rec["worker"]),
            "score": float(rec["score"]), "penalty": float(rec["penalty"]),
            "stake_after": float(rec["stake_after"]),
            "staleness": int(rec["staleness"])}


@dataclass
class ShardSettlement:
    """One shard's slice of a round, computed by ``settle_shard`` without
    mutating contract state: the merge barrier applies mutations only after
    every shard of the round succeeded."""
    start: int                     # slice [start, stop) of the round's ids
    stop: int
    penalties: np.ndarray          # (stop-start,) Pen(w), stake-capped
    stake_after: np.ndarray        # (stop-start,) post-penalty stakes
    records: RecordBatch           # canonical encodings of this slice
    tree: Optional[MerkleTree]     # chunked Merkle subtree over the slice
    #                                (None on the sparse path — the delta
    #                                commit re-hashes dirty chunks instead)


@dataclass
class RoundPrep:
    """Validated inputs + per-shard compute thunks for one round — the
    pure (no state mutation) first phase of a settlement, so a multi-task
    node can fan many tasks' shard thunks out through one shared pool."""
    round_index: int
    ids: np.ndarray                # participating worker ids, id order
    scores: np.ndarray             # aligned scores, float64
    thunks: List[Callable[[], ShardSettlement]] = field(default_factory=list)
    sparse: bool = False           # settle as a delta commit
    # permutation s.t. ids == original_ids[order] when sparse settlement
    # had to sort the caller's ids into canonical record order (None when
    # they already were); penalties are unpermuted back before returning
    order: Optional[np.ndarray] = None
    staleness: Optional[np.ndarray] = None  # aligned with ids (None = fresh)


@dataclass
class RoundSeal:
    """The deterministic merge's output — everything a block needs from
    one task's round: drained transactions, per-shard commit parts (dense
    path) or the prebuilt incremental commit (sparse path), and the
    penalty vector. State has already transitioned when this exists."""
    txs: List[dict]
    shards: List[RecordBatch]
    trees: List[MerkleTree]
    chunk_size: int
    penalties: np.ndarray
    delta: Optional[DeltaCommit] = None


class WorkerAccount:
    """Read/write *view* onto one worker's slice of the struct-of-arrays
    state — preserves the legacy ``contract.workers[wid].stake`` API."""

    __slots__ = ("_c", "_i")

    def __init__(self, contract: "TrustContract", index: int) -> None:
        self._c = contract
        self._i = index

    @property
    def stake(self) -> float:
        return float(self._c.stake[self._i])

    @stake.setter
    def stake(self, v: float) -> None:
        self._c.stake[self._i] = v

    @property
    def balance(self) -> float:
        return float(self._c.balance[self._i])

    @balance.setter
    def balance(self, v: float) -> None:
        self._c.balance[self._i] = v

    @property
    def penalized_rounds(self) -> int:
        return int(self._c.penalized_rounds[self._i])

    @property
    def scores(self) -> List[float]:
        """Score history of this worker across settled rounds (only rounds
        the worker was scored in)."""
        return self._c._worker_scores(self._i)


class _WorkersView(Mapping):
    """Mapping façade over the array state: accepts integer worker ids or
    registered string names (``"worker-3"``), yields account views."""

    def __init__(self, contract: "TrustContract") -> None:
        self._c = contract

    def _index(self, key) -> int:
        if isinstance(key, (int, np.integer)):
            if not 0 <= int(key) < self._c.num_workers:
                raise KeyError(key)
            return int(key)
        try:
            return self._c._index[key]
        except KeyError:
            raise KeyError(key) from None

    def __getitem__(self, key) -> WorkerAccount:
        return WorkerAccount(self._c, self._index(key))

    def __contains__(self, key) -> bool:
        try:
            self._index(key)
            return True
        except KeyError:
            return False

    def __iter__(self) -> Iterator[str]:
        return iter(self._c._names)

    def __len__(self) -> int:
        return self._c.num_workers

    def values(self):
        return (WorkerAccount(self._c, i)
                for i in range(self._c.num_workers))

    def items(self):
        return ((n, WorkerAccount(self._c, i))
                for i, n in enumerate(self._c._names))


class TrustContract:
    """One deployed FL task. Mirrors Algorithm 1 exactly — array-native."""

    def __init__(self, ledger: Ledger, *, requester_deposit: float,
                 worker_stake: float, penalty_pct: float,
                 trust_threshold: float, top_k: int,
                 merkle_chunk_size: int = 64,
                 settlement_shards: int = 1,
                 sparse_settlement: bool = False,
                 sparse_rebase_every: int = 0,
                 staleness_alpha: float = 0.0,
                 task_id: Optional[str] = None) -> None:
        if requester_deposit <= 0:
            raise ContractError("deployment requires a positive deposit")
        if merkle_chunk_size < 1:
            raise ContractError("merkle_chunk_size must be >= 1")
        if settlement_shards < 1:
            raise ContractError("settlement_shards must be >= 1")
        if sparse_rebase_every < 0:
            raise ContractError("sparse_rebase_every must be >= 0")
        if staleness_alpha < 0:
            raise ContractError("staleness_alpha must be >= 0")
        self.ledger = ledger
        self.task_id = task_id         # name on a multi-tenant chain node
        self.F = worker_stake
        self.P = penalty_pct
        self.T = trust_threshold
        self.k = top_k
        # staleness-aware economics (event-driven settlement): a worker
        # settled with staleness s has penalty and payout-credit scaled by
        # (1+s)^-alpha — a late-but-honest update is discounted, not
        # punished at full freshness weight. alpha=0 (the default, and the
        # sync path) is bit-identical to staleness-unaware settlement.
        self.staleness_alpha = float(staleness_alpha)
        self.merkle_chunk_size = merkle_chunk_size
        self.settlement_shards = settlement_shards
        self.sparse_settlement = bool(sparse_settlement)
        self.sparse_rebase_every = int(sparse_rebase_every)
        self.min_parallel_leaf_bytes = MIN_PARALLEL_LEAF_BYTES
        # sparse-path state: the persistent full-population record buffer
        # (every worker's latest settlement record, genesis rows for the
        # never-settled), the chain's latest commit to overlay against,
        # and the delta depth since the last dense anchor
        self._pop_records: Optional[np.ndarray] = None
        self._last_commit: Optional[DeltaCommit] = None
        self._rounds_since_base = 0
        self._round_full_cover: Dict[int, bool] = {}
        self.reward_pool = requester_deposit
        self.requester_balance = 0.0
        # struct-of-arrays account state (amortized-doubling capacity)
        self.stake = np.zeros(0, np.float64)
        self.balance = np.zeros(0, np.float64)
        self.penalized_rounds = np.zeros(0, np.int64)
        self.score_sum = np.zeros(0, np.float64)
        self.score_count = np.zeros(0, np.int64)
        self._names: List[str] = []
        self._index: Dict[str, int] = {}
        # audit trails: append-only settlement log (score history) plus
        # round → (block, settled ids) for O(log W) settlement proofs
        self._score_log: List[Tuple[np.ndarray, np.ndarray]] = []
        self._round_blocks: Dict[int, int] = {}
        self._round_ids: Dict[int, np.ndarray] = {}
        self.pending: List[dict] = [{"type": "deploy",
                                     "deposit": requester_deposit,
                                     "F": worker_stake, "P": penalty_pct,
                                     "T": trust_threshold, "k": top_k}]
        self.closed = False

    # -- enrollment ---------------------------------------------------------

    @property
    def num_workers(self) -> int:
        return len(self._names)

    @property
    def workers(self) -> _WorkersView:
        return _WorkersView(self)

    def _grow(self, n: int) -> None:
        old = len(self.stake)
        for attr in ("stake", "balance", "penalized_rounds",
                     "score_sum", "score_count"):
            arr = getattr(self, attr)
            out = np.zeros(old + n, arr.dtype)
            out[:old] = arr
            setattr(self, attr, out)

    def join_batch(self, count: int, *, name_prefix: str = "worker-",
                   start: Optional[int] = None) -> np.ndarray:
        """Enroll ``count`` workers in one vectorized transition (O(count)
        numpy, O(count) name registration). Returns their integer ids.
        The whole batch is a single on-chain join transaction."""
        if self.closed:
            raise ContractError("task closed")
        if count <= 0:
            raise ContractError("join_batch needs a positive count")
        base = self.num_workers
        start = base if start is None else start
        names = [f"{name_prefix}{start + i}" for i in range(count)]
        dup = [n for n in names if n in self._index]
        if dup:
            raise ContractError(f"already joined: {dup[:3]}")
        self._grow(count)
        self.stake[base:] = self.F
        for i, n in enumerate(names):
            self._index[n] = base + i
        self._names.extend(names)
        self.pending.append({"type": "join_batch", "count": count,
                             "first_id": base, "stake_each": self.F})
        return np.arange(base, base + count)

    def _join_scalar(self, worker_id: str) -> None:
        if self.closed:
            raise ContractError("task closed")
        if worker_id in self._index:
            raise ContractError(f"{worker_id} already joined")
        base = self.num_workers
        self._grow(1)
        self.stake[base] = self.F
        self._index[worker_id] = base
        self._names.append(worker_id)
        self.pending.append({"type": "join", "worker": worker_id,
                             "stake": self.F})

    def join(self, worker_id: str) -> None:
        """Deprecated scalar enrollment — use ``join_batch`` (or, for
        intentionally per-worker demos, ``contract.legacy.join``)."""
        warnings.warn(
            "TrustContract.join is deprecated; use join_batch "
            "(or contract.legacy.join)", DeprecationWarning, stacklevel=2)
        self._join_scalar(worker_id)

    @property
    def legacy(self) -> "LegacyContractAPI":
        """The sanctioned namespace for the scalar per-worker API."""
        return LegacyContractAPI(self)

    def worker_id(self, name: str) -> int:
        return self._index[name]

    def worker_name(self, index: int) -> str:
        return self._names[index]

    # -- per-round settlement (Alg. 1 steps 3-7), batch path ------------------

    def shard_bounds(self, num_records: int,
                     shards: Optional[int] = None) -> List[int]:
        """Subtree-aligned record boundaries splitting a round of
        ``num_records`` settlements into ≤ ``shards`` slices (default:
        this contract's ``settlement_shards``). Because boundaries are
        subtree-aligned, the committed super-root — and every proof and
        block hash — is identical for every shard count: callers (e.g. a
        multi-tenant node balancing N tasks over one pool) may re-plan
        execution granularity freely."""
        return plan_shard_bounds(num_records, self.merkle_chunk_size,
                                 self.settlement_shards
                                 if shards is None else shards)

    def parallel_fanout_possible(self) -> bool:
        """Whether ``settle_round_batch`` could ever hand shards to a pool:
        more than one shard configured AND chunk leaves clear the GIL
        threshold. Lets callers skip spawning worker threads that the gate
        would never feed."""
        return self.settlement_shards > 1 and self.parallel_leaf_ok()

    def settle_shard(self, round_index: int, ids: np.ndarray, s: np.ndarray,
                     start: int, stop: int, build_tree: bool = True,
                     staleness: Optional[np.ndarray] = None
                     ) -> ShardSettlement:
        """Compute one contract shard's slice [start, stop) of a round —
        BadWorkers mask, stake-capped penalties, canonical records, chunked
        Merkle subtree — reading the struct-of-arrays state but mutating
        nothing, so shards of one round run concurrently on a settler pool
        (their id slices are disjoint, and the merge applies all mutations
        afterwards on one thread). The sparse path passes
        ``build_tree=False``: the slice's records become the *changed set*
        of a delta commit, whose incremental update replaces the per-slice
        subtree. ``staleness`` (aligned with ``ids``) makes penalties
        staleness-discounted and is committed in the records, so the
        event-driven node's economics are auditable on-chain."""
        sl_ids = ids[start:stop]
        sl_s = s[start:stop]
        bad = sl_s < self.T                               # BadWorkers
        stake_sel = self.stake[sl_ids]
        full_pen = self.F * self.P / 100.0
        sl_st = None
        if staleness is not None:
            sl_st = staleness[start:stop]
            if self.staleness_alpha:
                # a stale update was honest work against an old global —
                # penalize it at its (discounted) evidentiary weight
                full_pen = full_pen * self._staleness_discount(sl_st)
        pen = np.where(bad, np.minimum(full_pen, stake_sel),
                       0.0)                               # Pen(w), stake-capped
        stake_after = stake_sel - pen
        records = encode_settlement_records(round_index, sl_ids, sl_s, pen,
                                            stake_after, staleness=sl_st)
        return ShardSettlement(start, stop, pen, stake_after, records,
                               MerkleTree(records, self.merkle_chunk_size)
                               if build_tree else None)

    def _staleness_discount(self, staleness: np.ndarray) -> np.ndarray:
        """(1+s)^-alpha — the same discount ``core.trust.staleness_discount``
        applies inside the jitted round, here on the settlement side."""
        return (1.0 + staleness.astype(np.float64)) ** (-self.staleness_alpha)

    def prepare_round_batch(self, round_index: int, scores: np.ndarray,
                            worker_ids: Optional[np.ndarray] = None,
                            shards: Optional[int] = None,
                            staleness: Optional[np.ndarray] = None
                            ) -> RoundPrep:
        """Phase 1 of a settlement: validate inputs and build the per-shard
        compute thunks (pure — no contract state is touched until
        ``finish_round_batch``), so a multi-tenant node can interleave many
        tasks' thunks through one shared worker pool. ``shards`` overrides
        the execution granularity (consensus-invisible: subtree-aligned
        boundaries commit the identical root for every shard count).
        ``staleness`` (aligned with ``scores``) is recorded on-chain and —
        with ``staleness_alpha > 0`` — discounts penalties and payout
        credit. A failure here, or in any thunk, aborts the round with
        nothing applied and nothing committed."""
        if self.closed:
            raise ContractError("task closed")
        s = np.asarray(scores, np.float64).reshape(-1)
        if worker_ids is None:
            if len(s) != self.num_workers:
                raise ContractError(
                    f"expected {self.num_workers} scores, got {len(s)}")
            ids = np.arange(self.num_workers)
        else:
            ids = np.asarray(worker_ids, np.int64).reshape(-1)
            if len(ids) != len(s):
                raise ContractError("worker_ids/scores length mismatch")
            if len(ids) and (ids.min() < 0 or ids.max() >= self.num_workers):
                bad = ids[(ids < 0) | (ids >= self.num_workers)]
                raise ContractError(
                    f"scores from non-participants: {set(bad.tolist())}")
            if len(np.unique(ids)) != len(ids):
                raise ContractError("duplicate worker ids in settlement")
        st = None
        if staleness is not None:
            st = np.asarray(staleness, np.int64).reshape(-1)
            if len(st) != len(s):
                raise ContractError("staleness/scores length mismatch")
            if len(st) and st.min() < 0:
                raise ContractError("staleness must be >= 0")
        if self.sparse_settlement:
            # canonical record order is id order (record index == worker
            # id in the population commit); remember the permutation so
            # penalties return aligned with the caller's score order
            order = None
            if worker_ids is not None and len(ids) > 1 \
                    and (np.diff(ids) < 0).any():
                order = np.argsort(ids, kind="stable")
                ids, s = ids[order], s[order]
                if st is not None:
                    st = st[order]
            # one slice: the delta commit replaces the per-shard subtrees,
            # so there is no per-slice tree to fan out
            bounds = [0, len(ids)] if len(ids) else [0]
            kw = {} if st is None else {"staleness": st}
            thunks = [lambda a=a, b=b: self.settle_shard(
                round_index, ids, s, a, b, build_tree=False, **kw)
                for a, b in zip(bounds, bounds[1:])]
            return RoundPrep(round_index, ids, s, thunks, sparse=True,
                             order=order, staleness=st)
        bounds = self.shard_bounds(len(ids), shards)
        # staleness rides as a kwarg only when present: the sync path keeps
        # the legacy settle_shard call signature
        kw = {} if st is None else {"staleness": st}
        thunks = [lambda a=a, b=b: self.settle_shard(round_index, ids, s,
                                                     a, b, **kw)
                  for a, b in zip(bounds, bounds[1:])]
        return RoundPrep(round_index, ids, s, thunks, staleness=st)

    def parallel_leaf_ok(self) -> bool:
        """The GIL gate for this contract's leaves: fan shard thunks out to
        a pool only when one chunk leaf amortizes the release/acquire
        handoff (see ``MIN_PARALLEL_LEAF_BYTES``)."""
        return (self.merkle_chunk_size * _RECORD_DTYPE.itemsize
                >= self.min_parallel_leaf_bytes)

    def finish_round_batch(self, prep: RoundPrep,
                           results: List[ShardSettlement],
                           model_cid: str = "") -> RoundSeal:
        """Phase 2: the deterministic merge. Applies the state transition
        from the concatenated per-shard results (shard order == id order,
        so every reduction is bit-identical to the unsharded path), drains
        the pending transactions, and returns the block commit parts. Runs
        only after *every* shard of the round succeeded."""
        ids, s = prep.ids, prep.scores
        round_index = prep.round_index
        bad = s < self.T
        if results:
            pen = np.concatenate([r.penalties for r in results])
            stake_after = np.concatenate([r.stake_after for r in results])
        else:
            pen = np.zeros(0, np.float64)
            stake_after = np.zeros(0, np.float64)
        self.stake[ids] = stake_after
        self.penalized_rounds[ids] += bad
        self.requester_balance += float(pen.sum())        # step 7
        if prep.staleness is not None and self.staleness_alpha:
            # stale contributions earn payout credit at the same
            # (1+s)^-alpha discount the aggregation gave their update
            self.score_sum[ids] += s * self._staleness_discount(prep.staleness)
        else:
            self.score_sum[ids] += s
        self.score_count[ids] += 1
        self._score_log.append((ids, s))

        txs = self.pending
        self.pending = []
        txs.append({"type": "settlement_batch", "round": round_index,
                    "workers": int(len(ids)), "bad_count": int(bad.sum()),
                    "total_penalty": float(pen.sum())})
        if model_cid:
            txs.append({"type": "model", "round": round_index,
                        "cid": model_cid})
        if prep.sparse:
            self._round_full_cover[round_index] = True
            delta = self._sparse_commit(round_index, ids, results)
            pen_out = pen
            if prep.order is not None:      # back to the caller's order
                pen_out = np.empty_like(pen)
                pen_out[prep.order] = pen
            return RoundSeal(txs, [], [], self.merkle_chunk_size, pen_out,
                             delta=delta)
        return RoundSeal(txs, [r.records for r in results],
                         [r.tree for r in results],
                         self.merkle_chunk_size, pen)

    def _sparse_commit(self, round_index: int, ids: np.ndarray,
                       results: List[ShardSettlement]
                       ) -> Optional[DeltaCommit]:
        """Fold this round's changed records into the persistent
        full-population buffer and commit: a dense anchor
        (``DeltaCommit.full``) on the first round, after enrollment grew
        the population, at full participation, or every
        ``sparse_rebase_every`` rounds — an incremental
        ``DeltaCommit.delta`` (dirty chunks re-hashed from the population
        buffer in one batched pass, O(C·log(W/k)) interior updates)
        otherwise."""
        W = self.num_workers
        if W == 0:
            return None
        k = self.merkle_chunk_size
        itemsize = _RECORD_DTYPE.itemsize
        rebase = False
        if self._pop_records is None or len(self._pop_records) != W:
            # (re)build the population buffer: genesis rows (round -1,
            # zero score/penalty, current stake) for workers without a
            # settlement record in the buffer's lifetime
            pop = np.empty(W, dtype=_RECORD_DTYPE)
            pop["round"] = -1
            pop["worker"] = np.arange(W)
            pop["score"] = 0.0
            pop["penalty"] = 0.0
            pop["stake_after"] = self.stake
            pop["staleness"] = 0
            self._pop_records = pop
            rebase = True
        pop = self._pop_records
        if results:
            new_rows = np.concatenate(
                [np.frombuffer(r.records.buf, _RECORD_DTYPE)
                 for r in results])
        else:
            new_rows = np.empty(0, dtype=_RECORD_DTYPE)
        pop[ids] = new_rows                 # scatter this round's records
        self._rounds_since_base += 1
        if (self._last_commit is None or rebase or len(ids) == W
                or (self.sparse_rebase_every
                    and self._rounds_since_base >= self.sparse_rebase_every)):
            snap = pop.copy()               # the anchor owns its snapshot
            commit = DeltaCommit.full(
                RecordBatch(memoryview(snap).cast("B"), itemsize), k)
            self._rounds_since_base = 0
        else:
            digests = gathered_leaf_digests(
                RecordBatch(memoryview(pop).cast("B"), itemsize), k,
                np.unique(ids // k))
            commit = DeltaCommit.delta(
                self._last_commit, ids.copy(),
                RecordBatch(memoryview(new_rows).cast("B"), itemsize),
                leaf_digests=digests)
        self._last_commit = commit
        return commit

    def note_block(self, round_index: int, ids: np.ndarray,
                   block_index: int) -> None:
        """Phase 3: audit bookkeeping once the round's block is sealed —
        keys ``settlement_proof`` to the block that committed it."""
        self._round_blocks[round_index] = block_index
        self._round_ids[round_index] = ids

    def settle_round_batch(self, round_index: int, scores: np.ndarray,
                           worker_ids: Optional[np.ndarray] = None,
                           model_cid: str = "",
                           timestamp: Optional[float] = None,
                           pool=None,
                           staleness: Optional[np.ndarray] = None
                           ) -> np.ndarray:
        """Vectorized settlement: BadWorkers mask, stake-capped penalties,
        requester transfer, and the Merkle-committed round block — no
        per-worker Python loop. ``worker_ids`` defaults to all workers (the
        common full-participation round). ``timestamp`` lets the protocol
        seal blocks at logical (round-indexed) time so every node — and the
        threaded vs serial drivers — computes identical block hashes.
        ``pool`` (any object with ``map(list_of_thunks)``, e.g.
        ``repro.core.node.ShardWorkerPool``) runs the per-shard slices
        concurrently; the result is bit-identical with or without it.
        Composes prepare → shard fan-out → merge → seal over a single-task
        block, which is exactly the pre-multi-tenant settlement path.
        Returns the (len(scores),) penalty vector aligned with ``scores``."""
        prep = self.prepare_round_batch(round_index, scores, worker_ids,
                                        staleness=staleness)
        # fan the round out across contract shards (pure compute, no state
        # mutation — a shard failure aborts the round with nothing applied
        # and nothing committed)
        if pool is not None and len(prep.thunks) > 1 \
                and self.parallel_leaf_ok():
            results: List[ShardSettlement] = pool.map(prep.thunks)
        else:
            results = [t() for t in prep.thunks]
        seal = self.finish_round_batch(prep, results, model_cid=model_cid)
        blk = self.ledger.append_block(
            seal.txs, timestamp=timestamp,
            record_shards=seal.shards or None,
            shard_trees=seal.trees or None,
            record_delta=seal.delta,
            chunk_size=seal.chunk_size, task_id=self.task_id)
        self.note_block(round_index, prep.ids, blk.index)
        return seal.penalties

    def settle_round(self, round_index: int, scores: Dict[str, float],
                     model_cid: str = "") -> Dict[str, float]:
        """Deprecated scalar settlement — use ``settle_round_batch`` (or
        ``contract.legacy.settle_round`` for intentionally scalar
        callers)."""
        warnings.warn(
            "TrustContract.settle_round is deprecated; use "
            "settle_round_batch (or contract.legacy.settle_round)",
            DeprecationWarning, stacklevel=2)
        return self._settle_round_scalar(round_index, scores, model_cid)

    def _settle_round_scalar(self, round_index: int,
                             scores: Dict[str, float],
                             model_cid: str = "") -> Dict[str, float]:
        """Legacy scalar API: score dict in, penalties dict out (bad workers
        only, matching the original loop). Thin wrapper over the batch path;
        dict order is normalized exactly like the original ``sorted`` loop."""
        unknown = set(scores) - set(self._index)
        if unknown:
            raise ContractError(f"scores from non-participants: {unknown}")
        names = sorted(scores)
        ids = np.asarray([self._index[n] for n in names], np.int64)
        s = np.asarray([float(scores[n]) for n in names], np.float64)
        pen = self.settle_round_batch(round_index, s, worker_ids=ids,
                                      model_cid=model_cid)
        bad = s < self.T
        return {n: float(p) for n, p, b in zip(names, pen, bad) if b}

    # -- task finalization (Alg. 1 steps 6 & 8), vectorized -------------------

    def finalize(self, timestamp: Optional[float] = None) -> Dict[str, float]:
        """Refund remaining stakes; pay top-k by mean score (``argpartition``
        selection, stable tie-break by join order). Returns payouts."""
        if self.closed:
            raise ContractError("already finalized")
        self.closed = True
        W = self.num_workers
        refund = self.stake.copy()                       # Refund(w) = D(w)
        self.balance += refund
        self.stake[:] = 0.0
        reward = np.zeros(W, np.float64)
        k = min(self.k, W)
        if W and k > 0:                                  # k<=0: refunds only
            mean = self.score_sum / np.maximum(self.score_count, 1)
            if k < W:
                # argpartition finds the k-th mean; membership is then made
                # tie-stable by hand (strictly-better workers + boundary
                # ties in join order) — matching the legacy stable sort
                kth = mean[np.argpartition(-mean, k - 1)[k - 1]]
                above = np.nonzero(mean > kth)[0]
                ties = np.nonzero(mean == kth)[0]
                top = np.concatenate([above, ties[: k - len(above)]])
            else:
                top = np.arange(W)
            share = self.reward_pool / k                 # R_total / k
            reward[top] = share
            self.balance += reward
            self.reward_pool = 0.0
        ids = np.arange(W)
        records = encode_settlement_records(-1, ids, np.zeros(W), -refund,
                                            np.zeros(W)) if W else None
        txs = self.pending
        self.pending = []
        txs.append({"type": "finalize_batch", "workers": W,
                    "refund_total": float(refund.sum()),
                    "reward_total": float(reward.sum()),
                    "top_k": int(min(self.k, W)) if W else 0})
        self.ledger.append_block(txs, timestamp=timestamp,
                                 record_batch=records,
                                 chunk_size=self.merkle_chunk_size,
                                 task_id=self.task_id)
        payout = refund + reward
        return {self._names[i]: float(payout[i]) for i in range(W)}

    # -- per-worker audit -----------------------------------------------------

    def record_position(self, round_index: int, worker_id: int) -> int:
        """Where a worker's record sits in the round's block commit: dense
        rounds commit only the participating records (the position is the
        worker's rank among the round's ids); sparse (delta) rounds commit
        the *full population* with record index == worker id — so idle
        workers are provable in every delta block too."""
        if self._round_full_cover.get(round_index):
            return int(worker_id)
        ids = self._round_ids[round_index]
        return int(np.nonzero(ids == worker_id)[0][0])

    def proof(self, round_index: int, worker) -> SettlementProof:
        """O(log(W/k) + k) typed proof that worker ``worker`` (id or name)
        was settled as recorded in ``round_index``'s block: the record's
        chunk (the k records sharing its Merkle leaf, ``offset`` locating
        the record within it), the node path to the block root —
        chunk-in-shard, shard-in-task, and (on multi-task blocks)
        task-in-block levels concatenated — and the decoded record view.
        Verify with ``proof.verify(head)`` against any trusted head (a
        ``Block``, a light client's ``BlockHeader``, or a root string)."""
        wid = worker if isinstance(worker, (int, np.integer)) \
            else self._index[worker]
        block_index = self._round_blocks[round_index]
        pos = self.record_position(round_index, int(wid))
        return build_settlement_proof(self.ledger, block_index, pos,
                                      task_id=self.task_id,
                                      decode=decode_settlement_record)

    def settlement_proof(self, round_index: int, worker) -> Dict:
        """Deprecated dict view of :meth:`proof` — bit-identical to the
        pre-redesign output (property-tested); new code should carry the
        typed ``SettlementProof``."""
        return self.proof(round_index, worker).as_legacy_dict()

    def verify_settlement(self, proof) -> bool:
        """Deprecated wrapper over ``SettlementProof.verify``: accepts the
        legacy proof dict (or a ``SettlementProof``) and checks it against
        this ledger's committed block head. Malformed (attacker-supplied)
        proofs are rejected, never raised on."""
        try:
            sp = proof if isinstance(proof, SettlementProof) \
                else SettlementProof.from_legacy(proof)
            head = self.ledger.blocks[sp.block_index]
        except (TypeError, ValueError, IndexError, KeyError):
            # any malformed shape — unsized chunk, non-buffer leaf, missing
            # keys, out-of-chain block index — is rejected, never raised on
            return False
        return sp.verify(head)

    def _worker_scores(self, index: int) -> List[float]:
        out = []
        for ids, s in self._score_log:
            pos = np.nonzero(ids == index)[0]
            if len(pos):
                out.append(float(s[pos[0]]))
        return out

    # -- fork support (repro.net): state snapshot / restore ------------------

    def snapshot(self) -> Dict[str, object]:
        """Deep-enough copy of all consensus-visible contract state (plus
        the audit maps that keep ``proof``/``settlement_proof`` working),
        keyed for ``restore``. A network node snapshots after every
        applied block so a fork-choice reorg can roll state back to the
        common ancestor and replay the winning branch
        (``repro.net.fork_choice``). O(W) per call — sized for the
        simulated-network harness, not the million-worker dense path."""
        return {
            "stake": self.stake.copy(),
            "balance": self.balance.copy(),
            "penalized_rounds": self.penalized_rounds.copy(),
            "score_sum": self.score_sum.copy(),
            "score_count": self.score_count.copy(),
            "reward_pool": self.reward_pool,
            "requester_balance": self.requester_balance,
            "closed": self.closed,
            "pending": list(self.pending),
            "score_log": list(self._score_log),
            "round_blocks": dict(self._round_blocks),
            "round_ids": dict(self._round_ids),
            "round_full_cover": dict(self._round_full_cover),
            "pop_records": None if self._pop_records is None
            else self._pop_records.copy(),
            "last_commit": self._last_commit,
            "rounds_since_base": self._rounds_since_base,
        }

    def restore(self, snap: Dict[str, object]) -> None:
        """Roll state back to a ``snapshot``. The snapshot stays valid
        (restoring copies again), so one ancestor snapshot can anchor
        several competing replays. Enrollment cannot be rolled back
        (names/ids are append-only): restoring across a population change
        raises."""
        if len(snap["stake"]) != self.num_workers:
            raise ContractError(
                f"snapshot covers {len(snap['stake'])} workers, contract "
                f"has {self.num_workers} — enrollment is not rollbackable")
        self.stake = snap["stake"].copy()
        self.balance = snap["balance"].copy()
        self.penalized_rounds = snap["penalized_rounds"].copy()
        self.score_sum = snap["score_sum"].copy()
        self.score_count = snap["score_count"].copy()
        self.reward_pool = snap["reward_pool"]
        self.requester_balance = snap["requester_balance"]
        self.closed = snap["closed"]
        self.pending = list(snap["pending"])
        self._score_log = list(snap["score_log"])
        self._round_blocks = dict(snap["round_blocks"])
        self._round_ids = dict(snap["round_ids"])
        self._round_full_cover = dict(snap["round_full_cover"])
        pop = snap["pop_records"]
        self._pop_records = None if pop is None else pop.copy()
        self._last_commit = snap["last_commit"]
        self._rounds_since_base = snap["rounds_since_base"]

    # -- conservation invariant (property tests) -----------------------------

    def total_value(self) -> float:
        """Money is conserved: pool + requester + stakes + balances."""
        return (self.reward_pool + self.requester_balance +
                float(self.stake.sum()) + float(self.balance.sum()))


class LegacyContractAPI:
    """Explicit namespace for the scalar per-worker contract API.

    ``contract.legacy.join(name)`` and ``contract.legacy.settle_round(r,
    scores_dict)`` keep the original single-worker semantics (thin,
    equivalence-tested wrappers over the batch path) for small demos and
    back-compat callers — without the ``DeprecationWarning`` that calling
    ``join``/``settle_round`` directly on the contract now emits. The
    documented surface is ``join_batch`` / ``settle_round_batch``."""

    __slots__ = ("_contract",)

    def __init__(self, contract: TrustContract) -> None:
        self._contract = contract

    def join(self, worker_id: str) -> None:
        """Scalar enrollment (one-row batch)."""
        self._contract._join_scalar(worker_id)

    def settle_round(self, round_index: int, scores: Dict[str, float],
                     model_cid: str = "") -> Dict[str, float]:
        """Scalar settlement: score dict in, bad-worker penalties out."""
        return self._contract._settle_round_scalar(round_index, scores,
                                                   model_cid)
