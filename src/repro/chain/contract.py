"""Smart-contract state machine executing the paper's Algorithm 1.

Steps (paper §III.E):
  1. Requester deploys, depositing D (task reward pool).
  2. Each worker joins by staking F.
  3. Per round: workers submit evaluation scores S(w).
  4. BadWorkers = {w | S(w) < T}.
     Pen(w) = F · P / 100, deducted from the stake.
  5. D(w) = F − Pen(w).
  6. Refund(w) = D(w) at task end.
  7. Collected penalties transfer to the requester.
  8. TopKWorkers split the reward pool: Reward(w) = R_total / k.

Array-native state: accounts are a struct-of-arrays (numpy ``stake`` /
``balance`` / ``penalized_rounds`` / ``score_sum`` / ``score_count``
vectors indexed by integer worker id), so a round settles in O(1) Python
ops and O(W) vectorized numpy — ``settle_round_batch`` computes BadWorkers,
penalties, and the requester transfer without a per-worker loop, and
``finalize`` ranks top-k via ``argpartition``. Each settlement block
commits to the round's canonically-encoded per-worker records through a
chunked Merkle root (see ``chain.ledger``): records are encoded as one
contiguous fixed-width buffer (``RecordBatch``) and committed
``merkle_chunk_size`` records per leaf, so the commit hashes ~2·W/k nodes
instead of ~2·W while balances stay fully auditable — per-worker via
O(log(W/k) + k) proofs (``settlement_proof``: the record's chunk plus the
node path) rather than per-worker embedded transactions.

The legacy scalar API (``join`` / ``settle_round`` with a score dict /
dict-like ``workers`` access) is kept as a thin wrapper over the batch
path, so Algorithm 1 semantics are provably unchanged (see the
batch-vs-scalar equivalence property test in ``tests/test_chain.py``).
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Mapping, Optional, Tuple

import numpy as np

from repro.chain.ledger import Ledger, MerkleTree, RecordBatch


class ContractError(RuntimeError):
    pass


_RECORD_DTYPE = np.dtype([("round", "<i8"), ("worker", "<i8"),
                          ("score", "<f8"), ("penalty", "<f8"),
                          ("stake_after", "<f8")])


def encode_settlement_records(round_index: int, worker_ids: np.ndarray,
                              scores: np.ndarray, penalties: np.ndarray,
                              stakes_after: np.ndarray) -> RecordBatch:
    """Canonical fixed-width binary encoding of per-worker settlement
    records — the Merkle-committed data of a settlement block. Built
    vectorized into one contiguous buffer; the returned ``RecordBatch``
    indexes like a list of per-record bytes but lets the chunked Merkle
    commit slice whole leaves zero-copy."""
    n = len(worker_ids)
    rec = np.empty(n, dtype=_RECORD_DTYPE)
    rec["round"] = round_index
    rec["worker"] = worker_ids
    rec["score"] = scores
    rec["penalty"] = penalties
    rec["stake_after"] = stakes_after
    return RecordBatch(rec.tobytes(), _RECORD_DTYPE.itemsize)


def decode_settlement_record(leaf: bytes) -> Dict[str, float]:
    rec = np.frombuffer(leaf, dtype=_RECORD_DTYPE)[0]
    return {"round": int(rec["round"]), "worker": int(rec["worker"]),
            "score": float(rec["score"]), "penalty": float(rec["penalty"]),
            "stake_after": float(rec["stake_after"])}


class WorkerAccount:
    """Read/write *view* onto one worker's slice of the struct-of-arrays
    state — preserves the legacy ``contract.workers[wid].stake`` API."""

    __slots__ = ("_c", "_i")

    def __init__(self, contract: "TrustContract", index: int) -> None:
        self._c = contract
        self._i = index

    @property
    def stake(self) -> float:
        return float(self._c.stake[self._i])

    @stake.setter
    def stake(self, v: float) -> None:
        self._c.stake[self._i] = v

    @property
    def balance(self) -> float:
        return float(self._c.balance[self._i])

    @balance.setter
    def balance(self, v: float) -> None:
        self._c.balance[self._i] = v

    @property
    def penalized_rounds(self) -> int:
        return int(self._c.penalized_rounds[self._i])

    @property
    def scores(self) -> List[float]:
        """Score history of this worker across settled rounds (only rounds
        the worker was scored in)."""
        return self._c._worker_scores(self._i)


class _WorkersView(Mapping):
    """Mapping façade over the array state: accepts integer worker ids or
    registered string names (``"worker-3"``), yields account views."""

    def __init__(self, contract: "TrustContract") -> None:
        self._c = contract

    def _index(self, key) -> int:
        if isinstance(key, (int, np.integer)):
            if not 0 <= int(key) < self._c.num_workers:
                raise KeyError(key)
            return int(key)
        try:
            return self._c._index[key]
        except KeyError:
            raise KeyError(key) from None

    def __getitem__(self, key) -> WorkerAccount:
        return WorkerAccount(self._c, self._index(key))

    def __contains__(self, key) -> bool:
        try:
            self._index(key)
            return True
        except KeyError:
            return False

    def __iter__(self) -> Iterator[str]:
        return iter(self._c._names)

    def __len__(self) -> int:
        return self._c.num_workers

    def values(self):
        return (WorkerAccount(self._c, i)
                for i in range(self._c.num_workers))

    def items(self):
        return ((n, WorkerAccount(self._c, i))
                for i, n in enumerate(self._c._names))


class TrustContract:
    """One deployed FL task. Mirrors Algorithm 1 exactly — array-native."""

    def __init__(self, ledger: Ledger, *, requester_deposit: float,
                 worker_stake: float, penalty_pct: float,
                 trust_threshold: float, top_k: int,
                 merkle_chunk_size: int = 64) -> None:
        if requester_deposit <= 0:
            raise ContractError("deployment requires a positive deposit")
        if merkle_chunk_size < 1:
            raise ContractError("merkle_chunk_size must be >= 1")
        self.ledger = ledger
        self.F = worker_stake
        self.P = penalty_pct
        self.T = trust_threshold
        self.k = top_k
        self.merkle_chunk_size = merkle_chunk_size
        self.reward_pool = requester_deposit
        self.requester_balance = 0.0
        # struct-of-arrays account state (amortized-doubling capacity)
        self.stake = np.zeros(0, np.float64)
        self.balance = np.zeros(0, np.float64)
        self.penalized_rounds = np.zeros(0, np.int64)
        self.score_sum = np.zeros(0, np.float64)
        self.score_count = np.zeros(0, np.int64)
        self._names: List[str] = []
        self._index: Dict[str, int] = {}
        # audit trails: append-only settlement log (score history) plus
        # round → (block, settled ids) for O(log W) settlement proofs
        self._score_log: List[Tuple[np.ndarray, np.ndarray]] = []
        self._round_blocks: Dict[int, int] = {}
        self._round_ids: Dict[int, np.ndarray] = {}
        self.pending: List[dict] = [{"type": "deploy",
                                     "deposit": requester_deposit,
                                     "F": worker_stake, "P": penalty_pct,
                                     "T": trust_threshold, "k": top_k}]
        self.closed = False

    # -- enrollment ---------------------------------------------------------

    @property
    def num_workers(self) -> int:
        return len(self._names)

    @property
    def workers(self) -> _WorkersView:
        return _WorkersView(self)

    def _grow(self, n: int) -> None:
        old = len(self.stake)
        for attr in ("stake", "balance", "penalized_rounds",
                     "score_sum", "score_count"):
            arr = getattr(self, attr)
            out = np.zeros(old + n, arr.dtype)
            out[:old] = arr
            setattr(self, attr, out)

    def join_batch(self, count: int, *, name_prefix: str = "worker-",
                   start: Optional[int] = None) -> np.ndarray:
        """Enroll ``count`` workers in one vectorized transition (O(count)
        numpy, O(count) name registration). Returns their integer ids.
        The whole batch is a single on-chain join transaction."""
        if self.closed:
            raise ContractError("task closed")
        if count <= 0:
            raise ContractError("join_batch needs a positive count")
        base = self.num_workers
        start = base if start is None else start
        names = [f"{name_prefix}{start + i}" for i in range(count)]
        dup = [n for n in names if n in self._index]
        if dup:
            raise ContractError(f"already joined: {dup[:3]}")
        self._grow(count)
        self.stake[base:] = self.F
        for i, n in enumerate(names):
            self._index[n] = base + i
        self._names.extend(names)
        self.pending.append({"type": "join_batch", "count": count,
                             "first_id": base, "stake_each": self.F})
        return np.arange(base, base + count)

    def join(self, worker_id: str) -> None:
        """Legacy scalar enrollment (thin wrapper: one-row batch)."""
        if self.closed:
            raise ContractError("task closed")
        if worker_id in self._index:
            raise ContractError(f"{worker_id} already joined")
        base = self.num_workers
        self._grow(1)
        self.stake[base] = self.F
        self._index[worker_id] = base
        self._names.append(worker_id)
        self.pending.append({"type": "join", "worker": worker_id,
                             "stake": self.F})

    def worker_id(self, name: str) -> int:
        return self._index[name]

    def worker_name(self, index: int) -> str:
        return self._names[index]

    # -- per-round settlement (Alg. 1 steps 3-7), batch path ------------------

    def settle_round_batch(self, round_index: int, scores: np.ndarray,
                           worker_ids: Optional[np.ndarray] = None,
                           model_cid: str = "",
                           timestamp: Optional[float] = None) -> np.ndarray:
        """Vectorized settlement: BadWorkers mask, stake-capped penalties,
        requester transfer, and the Merkle-committed round block — no
        per-worker Python loop. ``worker_ids`` defaults to all workers (the
        common full-participation round). ``timestamp`` lets the protocol
        seal blocks at logical (round-indexed) time so every node — and the
        threaded vs serial drivers — computes identical block hashes.
        Returns the (len(scores),) penalty vector aligned with ``scores``."""
        if self.closed:
            raise ContractError("task closed")
        s = np.asarray(scores, np.float64).reshape(-1)
        if worker_ids is None:
            if len(s) != self.num_workers:
                raise ContractError(
                    f"expected {self.num_workers} scores, got {len(s)}")
            ids = np.arange(self.num_workers)
        else:
            ids = np.asarray(worker_ids, np.int64).reshape(-1)
            if len(ids) != len(s):
                raise ContractError("worker_ids/scores length mismatch")
            if len(ids) and (ids.min() < 0 or ids.max() >= self.num_workers):
                bad = ids[(ids < 0) | (ids >= self.num_workers)]
                raise ContractError(
                    f"scores from non-participants: {set(bad.tolist())}")
            if len(np.unique(ids)) != len(ids):
                raise ContractError("duplicate worker ids in settlement")

        bad = s < self.T                                  # BadWorkers
        stake_sel = self.stake[ids]
        pen = np.where(bad, np.minimum(self.F * self.P / 100.0, stake_sel),
                       0.0)                               # Pen(w), stake-capped
        stake_after = stake_sel - pen
        self.stake[ids] = stake_after
        self.penalized_rounds[ids] += bad
        self.requester_balance += float(pen.sum())        # step 7
        self.score_sum[ids] += s
        self.score_count[ids] += 1
        self._score_log.append((ids, s))

        records = encode_settlement_records(round_index, ids, s, pen,
                                            stake_after)
        txs = self.pending
        self.pending = []
        txs.append({"type": "settlement_batch", "round": round_index,
                    "workers": int(len(ids)), "bad_count": int(bad.sum()),
                    "total_penalty": float(pen.sum())})
        if model_cid:
            txs.append({"type": "model", "round": round_index,
                        "cid": model_cid})
        blk = self.ledger.append_block(
            txs, timestamp=timestamp,
            record_batch=records if len(records) else None,
            chunk_size=self.merkle_chunk_size)
        self._round_blocks[round_index] = blk.index
        self._round_ids[round_index] = ids
        return pen

    def settle_round(self, round_index: int, scores: Dict[str, float],
                     model_cid: str = "") -> Dict[str, float]:
        """Legacy scalar API: score dict in, penalties dict out (bad workers
        only, matching the original loop). Thin wrapper over the batch path;
        dict order is normalized exactly like the original ``sorted`` loop."""
        unknown = set(scores) - set(self._index)
        if unknown:
            raise ContractError(f"scores from non-participants: {unknown}")
        names = sorted(scores)
        ids = np.asarray([self._index[n] for n in names], np.int64)
        s = np.asarray([float(scores[n]) for n in names], np.float64)
        pen = self.settle_round_batch(round_index, s, worker_ids=ids,
                                      model_cid=model_cid)
        bad = s < self.T
        return {n: float(p) for n, p, b in zip(names, pen, bad) if b}

    # -- task finalization (Alg. 1 steps 6 & 8), vectorized -------------------

    def finalize(self, timestamp: Optional[float] = None) -> Dict[str, float]:
        """Refund remaining stakes; pay top-k by mean score (``argpartition``
        selection, stable tie-break by join order). Returns payouts."""
        if self.closed:
            raise ContractError("already finalized")
        self.closed = True
        W = self.num_workers
        refund = self.stake.copy()                       # Refund(w) = D(w)
        self.balance += refund
        self.stake[:] = 0.0
        reward = np.zeros(W, np.float64)
        k = min(self.k, W)
        if W and k > 0:                                  # k<=0: refunds only
            mean = self.score_sum / np.maximum(self.score_count, 1)
            if k < W:
                # argpartition finds the k-th mean; membership is then made
                # tie-stable by hand (strictly-better workers + boundary
                # ties in join order) — matching the legacy stable sort
                kth = mean[np.argpartition(-mean, k - 1)[k - 1]]
                above = np.nonzero(mean > kth)[0]
                ties = np.nonzero(mean == kth)[0]
                top = np.concatenate([above, ties[: k - len(above)]])
            else:
                top = np.arange(W)
            share = self.reward_pool / k                 # R_total / k
            reward[top] = share
            self.balance += reward
            self.reward_pool = 0.0
        ids = np.arange(W)
        records = encode_settlement_records(-1, ids, np.zeros(W), -refund,
                                            np.zeros(W)) if W else None
        txs = self.pending
        self.pending = []
        txs.append({"type": "finalize_batch", "workers": W,
                    "refund_total": float(refund.sum()),
                    "reward_total": float(reward.sum()),
                    "top_k": int(min(self.k, W)) if W else 0})
        self.ledger.append_block(txs, timestamp=timestamp,
                                 record_batch=records,
                                 chunk_size=self.merkle_chunk_size)
        payout = refund + reward
        return {self._names[i]: float(payout[i]) for i in range(W)}

    # -- per-worker audit -----------------------------------------------------

    def settlement_proof(self, round_index: int, worker) -> Dict:
        """O(log(W/k) + k) auditable proof that worker ``worker`` (id or
        name) was settled as recorded in ``round_index``'s block: the
        record's chunk (the k records sharing its Merkle leaf, ``offset``
        locating the record within it) plus the node path to the root."""
        wid = worker if isinstance(worker, (int, np.integer)) \
            else self._index[worker]
        block_index = self._round_blocks[round_index]
        ids = self._round_ids[round_index]
        pos = int(np.nonzero(ids == wid)[0][0])
        chunk, offset = self.ledger.record_chunk(block_index, pos)
        return {"block_index": block_index, "leaf_index": pos,
                "leaf": chunk[offset], "chunk": chunk, "offset": offset,
                "proof": self.ledger.merkle_proof(block_index, pos),
                "root": self.ledger.blocks[block_index].records_root,
                "record": decode_settlement_record(chunk[offset])}

    def verify_settlement(self, proof: Dict) -> bool:
        """Self-contained check of a ``settlement_proof`` dict: the claimed
        record must sit at its offset in the chunk, the decoded ``record``
        view must match the authenticated leaf bytes, the chunk must hash
        to the root through the node path, and the root must match the
        block's on-chain commitment. Malformed (attacker-supplied) proofs
        are rejected, never raised on."""
        chunk = proof.get("chunk", [proof["leaf"]])
        offset = proof.get("offset", 0)
        if not (isinstance(offset, int) and 0 <= offset < len(chunk)):
            return False
        if chunk[offset] != proof["leaf"]:
            return False
        if "record" in proof:       # the human-readable view is part of the
            try:                    # claim — it must decode from the leaf
                if decode_settlement_record(proof["leaf"]) != proof["record"]:
                    return False
            except (ValueError, IndexError):
                return False
        return MerkleTree.verify(b"".join(chunk), proof["proof"],
                                 proof["root"]) and \
            proof["root"] == self.ledger.blocks[
                proof["block_index"]].records_root

    def _worker_scores(self, index: int) -> List[float]:
        out = []
        for ids, s in self._score_log:
            pos = np.nonzero(ids == index)[0]
            if len(pos):
                out.append(float(s[pos[0]]))
        return out

    # -- conservation invariant (property tests) -----------------------------

    def total_value(self) -> float:
        """Money is conserved: pool + requester + stakes + balances."""
        return (self.reward_pool + self.requester_balance +
                float(self.stake.sum()) + float(self.balance.sum()))
