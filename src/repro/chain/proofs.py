"""Unified settlement-proof surface + batched Merkle multiproofs.

This module is the single proof/verify surface of the chain stack. It
replaces four historically-separate entry points — ``MerkleTree.verify``
(the hashing primitive), ``Ledger.merkle_proof``/``verify_record`` (bare
node paths), ``TrustContract.settlement_proof``/``verify_settlement``
(untyped dicts), and the per-commit ``record_proof`` methods — with two
typed objects:

``SettlementProof``
    One record's claim against one block: the leaf chunk, the record's
    offset within it, the three-level ``(side, digest)`` node path
    (chunk-in-shard, shard-in-task, task-in-block — exactly the encoding
    every commit flavor emits), and the committed root. ``verify(head)``
    checks the whole claim against a trusted head (a ``Block``, a light
    client's ``BlockHeader``, or a bare root hex string) for every block
    flavor — dense, ``ShardedCommit``, ``DeltaCommit``, and
    ``MultiTaskCommit`` blocks all produce the same path encoding. The
    legacy dict/``verify_settlement`` shapes round-trip losslessly
    (``as_legacy_dict``/``from_legacy``), so the deprecated wrappers emit
    bit-identical proofs.

``ProofBatch``
    A batched multiproof for many records of one task in one block,
    deduplicating shared path structure: each distinct Merkle node is
    shipped (or computed) exactly once, so adjacent workers share all but
    O(log(W/k)) siblings and a 1k-worker batch ships far fewer digests
    than 1k independent proofs. The verifier (``verify_proof_batch``)
    recomputes the block root bottom-up with **one framed sha256 pass per
    tree level** (the ``batch_leaf_digests`` framing from
    ``chain.ledger`` — one packed uint8 matrix, one C call per node row)
    instead of per-record Python hash loops, then checks that every
    claimed record's leaf actually feeds the recomputed root
    (connectivity), and that the root matches the trusted header.
    Tampered or malformed batches are rejected (``False``), never raised
    on.

Wire model: a batch names interior nodes with small structural keys —
``("S", shard, level, pos)`` inside a shard subtree, ``("U", level, pos)``
on the cross-shard super levels, ``("T", level, pos)`` on the cross-task
level, and ``ROOT_KEY`` for the block root. ``plan`` is an ordered list
of levels whose entries are either ``("h", parent, left, right)`` (hash
two children) or ``("p", parent, child)`` (odd-node promotion / stage
alias). The verifier executes the plan level by level; because a node
value may never be redefined and parent links are only created by actual
hash/promotion steps, the recomputed root is fully determined by the
shipped chunks and siblings — there is no way to splice a forged record
into a verifying batch without a SHA-256 collision.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.chain.ledger import (_LEAF_PREFIX, _NODE_PREFIX, Block,
                                DeltaCommit, Ledger, MerkleTree,
                                RecordBatch, _framed_digests)

__all__ = ["BlockHeader", "SettlementProof", "ProofBatch", "ROOT_KEY",
           "build_proof_batch", "verify_proof_batch", "header_of",
           "build_settlement_proof"]


# -- light-client headers ------------------------------------------------------


@dataclass(frozen=True)
class BlockHeader:
    """What a light client holds per block: the consensus-visible block
    body (transactions are O(tasks) summaries — settlement data lives
    off-chain behind ``records_root``) plus the sealed hash. Hashing
    delegates to ``Block.compute_hash`` so header hashes are bit-identical
    to full-node block hashes by construction."""

    index: int
    prev_hash: str
    transactions: Tuple[dict, ...]
    timestamp: float
    records_root: str
    task_roots: Optional[Dict[str, str]]
    hash: str

    def compute_hash(self) -> str:
        return Block(self.index, self.prev_hash, list(self.transactions),
                     self.timestamp, records_root=self.records_root,
                     task_roots=dict(self.task_roots)
                     if self.task_roots else None).compute_hash()


def header_of(blk: Block) -> BlockHeader:
    """The serving-side projection of a sealed block."""
    return BlockHeader(blk.index, blk.prev_hash, tuple(blk.transactions),
                       blk.timestamp, blk.records_root,
                       dict(blk.task_roots) if blk.task_roots else None,
                       blk.hash)


def _expected_root(head: Union[str, Block, BlockHeader]) -> Optional[str]:
    """The records root a head vouches for (None → unusable head)."""
    root = head if isinstance(head, str) else getattr(head, "records_root",
                                                      None)
    return root if isinstance(root, str) and root else None


# -- single-record unified proof -----------------------------------------------


@dataclass(frozen=True)
class SettlementProof:
    """One settlement record's typed, self-contained audit claim.

    ``chunk`` is the k records sharing the Merkle leaf, ``offset`` the
    record's position within it (``leaf`` resolves the record bytes);
    ``path`` is the full node path to the block's ``records_root`` and
    ``root`` the claimed root. ``record`` optionally carries the decoded
    human-readable view (part of the claim — it must re-decode from the
    leaf bytes). ``verify(head)`` is the single verification entry point
    for every block flavor."""

    block_index: int
    leaf_index: int
    chunk: Tuple[bytes, ...]
    offset: int
    path: Tuple[Tuple[str, str], ...]
    root: str
    task_id: Optional[str] = None
    record: Optional[Dict[str, Any]] = None

    @property
    def leaf(self) -> bytes:
        """The proven record's bytes."""
        return self.chunk[self.offset]

    def verify(self, head: Union[str, Block, BlockHeader]) -> bool:
        """Check the whole claim against a trusted ``head``: the decoded
        ``record`` view (when present) must match the leaf bytes, the
        chunk must hash to ``root`` through ``path`` (one hashing rule —
        ``MerkleTree.verify`` — for dense/sharded/delta/multi-task
        blocks), and ``root`` must equal the head's commitment (with the
        head's block index matching, when it carries one). Malformed
        proofs are rejected, never raised on."""
        try:
            if not (isinstance(self.offset, int)
                    and 0 <= self.offset < len(self.chunk)):
                return False
            if self.record is not None:
                from repro.chain.contract import decode_settlement_record
                if decode_settlement_record(self.leaf) != self.record:
                    return False
            if not MerkleTree.verify(b"".join(self.chunk), self.path,
                                     self.root):
                return False
            root = _expected_root(head)
            if root is None or self.root != root:
                return False
            if isinstance(head, str):    # bare root: no index to check
                return True
            idx = getattr(head, "index", self.block_index)
            return idx == self.block_index
        except (TypeError, ValueError, IndexError, KeyError):
            return False

    # -- legacy dict round-trip ------------------------------------------------

    def as_legacy_dict(self) -> Dict[str, Any]:
        """The exact pre-redesign ``settlement_proof`` dict (bit-identical
        keys and values) — what the deprecated wrappers return."""
        return {"block_index": self.block_index,
                "leaf_index": self.leaf_index,
                "leaf": self.leaf,
                "chunk": list(self.chunk),
                "offset": self.offset,
                "proof": [tuple(p) for p in self.path],
                "root": self.root,
                "record": self.record}

    @classmethod
    def from_legacy(cls, proof: Dict[str, Any],
                    task_id: Optional[str] = None) -> "SettlementProof":
        """Adopt a legacy proof dict, preserving its defaulting rules
        (``chunk`` defaults to ``[leaf]``, ``offset`` to 0). Raises on
        shapes the legacy verifier rejected structurally (the caller
        converts to a ``False`` verdict)."""
        chunk = proof.get("chunk", [proof["leaf"]])
        offset = proof.get("offset", 0)
        if not (isinstance(offset, int) and 0 <= offset < len(chunk)):
            raise ValueError("offset out of range")
        if chunk[offset] != proof["leaf"]:
            raise ValueError("leaf does not sit at its claimed offset")
        return cls(block_index=proof["block_index"],
                   leaf_index=proof.get("leaf_index", -1),
                   chunk=tuple(chunk), offset=offset,
                   path=tuple(tuple(p) for p in proof["proof"]),
                   root=proof["root"], task_id=task_id,
                   record=proof.get("record"))


def build_settlement_proof(ledger: Ledger, block_index: int,
                           record_index: int,
                           task_id: Optional[str] = None,
                           decode=None) -> SettlementProof:
    """The canonical single-record proof builder every wrapper delegates
    to: chunk + offset + three-level path + committed root, straight off
    the block's stored commit. ``decode`` (optional ``leaf → dict``)
    attaches the decoded record view to the claim."""
    commit = ledger.commit(block_index)
    chunk, offset = commit.record_chunk(record_index, task_id)
    return SettlementProof(
        block_index=block_index, leaf_index=record_index,
        chunk=tuple(chunk), offset=offset,
        path=tuple(commit.record_proof(record_index, task_id)),
        root=ledger.blocks[block_index].records_root,
        task_id=commit._resolve(task_id),
        record=decode(chunk[offset]) if decode is not None else None)


# -- batched multiproofs -------------------------------------------------------


ROOT_KEY: Tuple = ("R",)

NodeKey = Tuple  # ("S", shard, lvl, pos) | ("U", lvl, pos) | ("T", lvl, pos)


@dataclass
class ProofBatch:
    """A deduplicated multiproof for ``records`` of one task in one block.

    ``records`` holds ``(record_index, leaf_key, offset)`` per requested
    record; ``chunks`` ships each referenced leaf chunk once (records in
    the same chunk share the entry); ``siblings`` ships each off-path
    digest once; ``plan`` is the level-ordered recomputation schedule (see
    module docstring). ``worker_ids``/``round_index`` are serving-side
    convenience labels — the cryptographic claim is the records' decoded
    contents against the recomputed root."""

    block_index: int
    task_id: Optional[str]
    root: str
    record_size: int
    records: List[Tuple[int, NodeKey, int]]
    chunks: Dict[NodeKey, bytes]
    siblings: Dict[NodeKey, str]
    plan: List[List[Tuple]]
    worker_ids: Optional[List[int]] = None
    round_index: Optional[int] = None

    def __len__(self) -> int:
        return len(self.records)

    @property
    def num_digests(self) -> int:
        """Digests shipped over the wire — the dedup win vs. the sum of
        independent path lengths."""
        return len(self.siblings)

    def record_bytes(self, i: int) -> bytes:
        """The i-th requested record's raw bytes, sliced out of its
        (verified) leaf chunk."""
        _, key, off = self.records[i]
        rs = self.record_size
        return bytes(self.chunks[key][off * rs:(off + 1) * rs])

    def decoded(self, i: int) -> Dict[str, Any]:
        """The i-th record's human-readable settlement view."""
        from repro.chain.contract import decode_settlement_record
        return decode_settlement_record(self.record_bytes(i))


def _walk_levels(levels: Sequence[List[bytes]], active: Dict[int, NodeKey],
                 keyf, top_key: NodeKey,
                 siblings: Dict[NodeKey, str]) -> List[List[Tuple]]:
    """Plan the lift of ``active`` (position → node key at ``levels[0]``)
    to the stage's single ``top_key`` node, recording off-path sibling
    digests in ``siblings``. Mirrors ``_combine``'s pairing rule exactly
    (odd nodes promote unpaired), so the client's replay reproduces the
    committed digests bit for bit."""
    plan: List[List[Tuple]] = []
    cur = dict(active)
    if len(levels) == 1:
        # single-node stage (one leaf / one shard / one task): the stage's
        # only node IS its top — alias it so the next stage can consume it
        plan.append([("p", top_key, cur[0])])
        return plan
    for lvl in range(len(levels) - 1):
        level = levels[lvl]
        top = lvl == len(levels) - 2
        entries: List[Tuple] = []
        nxt: Dict[int, NodeKey] = {}
        for pos in sorted(cur):
            sib = pos ^ 1
            if sib in cur and sib < pos:
                continue                     # the left partner handles us
            parent = pos // 2
            pkey = top_key if top else keyf(lvl + 1, parent)
            if sib >= len(level):            # odd node promoted unpaired
                entries.append(("p", pkey, cur[pos]))
            else:
                if sib in cur:
                    skey = cur[sib]
                else:
                    skey = keyf(lvl, sib)
                    if skey not in siblings:
                        siblings[skey] = level[sib].hex()
                left, right = ((cur[pos], skey) if pos % 2 == 0
                               else (skey, cur[pos]))
                entries.append(("h", pkey, left, right))
            nxt[parent] = pkey
        plan.append(entries)
        cur = nxt
    return plan


def build_proof_batch(ledger: Ledger, block_index: int,
                      record_indices: Sequence[int],
                      task_id: Optional[str] = None,
                      worker_ids: Optional[Sequence[int]] = None,
                      round_index: Optional[int] = None) -> ProofBatch:
    """Build one task's deduplicated multiproof for ``record_indices`` in
    block ``block_index``, resolving through whichever commit flavor the
    block stored (dense/sharded single tree, incremental ``DeltaCommit``
    overlay, multi-task third level). Read-only over sealed state — safe
    to call from reader threads while the settler appends new blocks."""
    mtc = ledger.commit(block_index)
    blk = ledger.blocks[block_index]
    tid = mtc._resolve(task_id)
    commit = mtc.commits[tid]
    k = commit.chunk_size
    if isinstance(commit, DeltaCommit):
        trees = {0: commit.tree}
        sup: Sequence[List[bytes]] = [[commit.root_digest]]

        def locate(ri: int) -> Tuple[int, int]:
            if not 0 <= ri < commit.num_records:
                raise IndexError(f"record index {ri} out of range")
            return 0, ri
    else:
        trees = dict(enumerate(commit.trees))
        sup = commit.super_levels
        locate = commit._locate

    shards = getattr(commit, "shards", None)
    chunks: Dict[NodeKey, bytes] = {}
    records: List[Tuple[int, NodeKey, int]] = []
    by_shard: Dict[int, Dict[int, NodeKey]] = {}
    record_size = 0
    for ri in record_indices:
        ri = int(ri)
        s, local = locate(ri)
        leaf_pos = local // k
        key = ("S", s, 0, leaf_pos)
        if key not in chunks:
            shard = None if shards is None else shards[s]
            if isinstance(shard, RecordBatch):
                # fixed-width contiguous storage: the whole leaf chunk is
                # one zero-copy buffer slice (the batched-build fast path)
                stop = min(leaf_pos * k + k, len(shard))
                chunks[key] = bytes(shard.chunk_bytes(leaf_pos * k, stop))
                record_size = record_size or shard.itemsize
            else:
                chunk_list, off = commit.record_chunk(ri)
                chunks[key] = b"".join(chunk_list)
                record_size = record_size or len(chunk_list[off])
        records.append((ri, key, local % k))
        by_shard.setdefault(s, {})[leaf_pos] = key

    siblings: Dict[NodeKey, str] = {}
    # shard stages merge level-aligned: level l of every involved shard
    # lands in one plan level (they are independent, and the verifier
    # hashes each plan level in a single framed pass)
    plan: List[List[Tuple]] = []
    for s in sorted(by_shard):
        stage = _walk_levels(trees[s].levels, by_shard[s],
                             lambda lvl, pos, s=s: ("S", s, lvl, pos),
                             ("U", 0, s), siblings)
        for i, entries in enumerate(stage):
            if i == len(plan):
                plan.append([])
            plan[i].extend(entries)
    tpos = mtc.task_ids.index(tid)
    plan += _walk_levels(sup, {s: ("U", 0, s) for s in by_shard},
                         lambda lvl, pos: ("U", lvl, pos),
                         ("T", 0, tpos), siblings)
    plan += _walk_levels(mtc.task_levels, {tpos: ("T", 0, tpos)},
                         lambda lvl, pos: ("T", lvl, pos),
                         ROOT_KEY, siblings)
    return ProofBatch(block_index=block_index, task_id=tid,
                      root=blk.records_root, record_size=record_size,
                      records=records, chunks=chunks, siblings=siblings,
                      plan=plan,
                      worker_ids=None if worker_ids is None
                      else [int(w) for w in worker_ids],
                      round_index=round_index)


def verify_proof_batch(batch: ProofBatch,
                       head: Union[str, Block, BlockHeader]) -> bool:
    """Client-side batch verification against a trusted ``head``.

    Recomputes every leaf digest and every interior level with one framed
    sha256 pass per level, forbids node redefinition (shipped siblings
    may never override computed values and vice versa), requires the
    recomputed ``ROOT_KEY`` to equal the head's ``records_root``, and
    checks each claimed record slices validly out of its chunk *and* that
    its leaf is connected to the root through actual hash/promotion steps.
    Any tampered or malformed batch returns ``False`` — never raises."""
    try:
        root = _expected_root(head)
        if root is None or batch.root != root:
            return False
        if not isinstance(head, str) and \
                getattr(head, "index", batch.block_index) != batch.block_index:
            return False
        values: Dict[NodeKey, bytes] = {}
        # leaf digests: one framed pass per chunk-length class
        by_len: Dict[int, List[Tuple[NodeKey, bytes]]] = {}
        for key, chunk in batch.chunks.items():
            chunk = bytes(chunk)
            if not chunk:
                return False
            by_len.setdefault(len(chunk), []).append((key, chunk))
        for ln, items in by_len.items():
            framed = np.empty((len(items), 1 + ln), np.uint8)
            framed[:, 0] = _LEAF_PREFIX[0]
            for i, (_, chunk) in enumerate(items):
                framed[i, 1:] = np.frombuffer(chunk, np.uint8)
            for (key, _), d in zip(items, _framed_digests(framed)):
                if key in values:
                    return False
                values[key] = d
        for key, hx in batch.siblings.items():
            d = bytes.fromhex(hx)
            if len(d) != 32 or key in values:
                return False
            values[key] = d
        # interior levels: one framed 65-byte-row pass per plan level
        parent: Dict[NodeKey, NodeKey] = {}
        for entries in batch.plan:
            hsteps = [e for e in entries if e[0] == "h"]
            if hsteps:
                framed = np.empty((len(hsteps), 65), np.uint8)
                framed[:, 0] = _NODE_PREFIX[0]
                for i, (_, _, lk, rk) in enumerate(hsteps):
                    framed[i, 1:33] = np.frombuffer(values[lk], np.uint8)
                    framed[i, 33:65] = np.frombuffer(values[rk], np.uint8)
                for (_, pk, lk, rk), d in zip(hsteps,
                                              _framed_digests(framed)):
                    if pk in values:
                        return False
                    values[pk] = d
                    parent[lk] = pk
                    parent[rk] = pk
            for e in entries:
                if e[0] == "p":
                    _, pk, ck = e
                    if pk in values:
                        return False
                    values[pk] = values[ck]
                    parent[ck] = pk
                elif e[0] != "h":
                    return False
        if ROOT_KEY not in values or values[ROOT_KEY].hex() != root:
            return False
        # per-record claims: valid slice + leaf connected to the root
        rs = batch.record_size
        if not (isinstance(rs, int) and rs > 0):
            return False
        limit = len(parent) + 1
        for _, key, off in batch.records:
            chunk = batch.chunks[key]
            if not (isinstance(off, int) and 0 <= off
                    and (off + 1) * rs <= len(chunk)):
                return False
            cur, steps = key, 0
            while cur != ROOT_KEY:
                cur = parent[cur]        # KeyError: unconnected → reject
                steps += 1
                if steps > limit:
                    return False
        return True
    except (TypeError, ValueError, IndexError, KeyError):
        return False
