"""Content-addressed artifact store — the IPFS stand-in.

Model weights are serialized (msgpack of flattened numpy leaves,
compressed) and stored under their SHA-256 content hash; cluster heads
"publish" aggregates here and other clusters "fetch by hash", exactly the
paper's workflow. Retrieval verifies the hash (tamper evidence).

Compression prefers zstd; containers without ``zstandard`` fall back to
stdlib zlib (same API, blobs stay self-consistent within a process/run).
"""
from __future__ import annotations

import hashlib
from typing import Any, Dict, Optional

import jax
import msgpack
import numpy as np

try:
    import zstandard as _zstd

    def _compress(data: bytes) -> bytes:
        return _zstd.ZstdCompressor(level=3).compress(data)

    def _decompress(blob: bytes) -> bytes:
        return _zstd.ZstdDecompressor().decompress(blob)
except ModuleNotFoundError:
    import zlib

    def _compress(data: bytes) -> bytes:
        return zlib.compress(data, 6)

    def _decompress(blob: bytes) -> bytes:
        return zlib.decompress(blob)


def _pack_tree(tree: Any) -> bytes:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [
            {"dtype": str(np.asarray(x).dtype), "shape": list(np.asarray(x).shape),
             "data": np.ascontiguousarray(
                 np.asarray(x, dtype=np.float32) if str(np.asarray(x).dtype) == "bfloat16"
                 else np.asarray(x)).tobytes()}
            for x in leaves
        ],
    }
    return _compress(msgpack.packb(payload))


def _unpack_leaves(blob: bytes):
    payload = msgpack.unpackb(_decompress(blob))
    out = []
    for leaf in payload["leaves"]:
        dt = leaf["dtype"]
        arr = np.frombuffer(leaf["data"],
                            dtype=np.float32 if dt == "bfloat16" else dt)
        out.append(arr.reshape(leaf["shape"]))
    return out, payload["treedef"]


class QuotaExceeded(RuntimeError):
    """A put would push its owner past the store's per-owner byte quota.

    Carries ``owner``, the owner's current logical ``used`` bytes, the
    rejected blob's ``requested`` size, and the configured ``quota``. The
    put is rejected atomically — no store state (global or per-owner
    accounting) changes."""

    def __init__(self, owner: str, used: int, requested: int,
                 quota: int) -> None:
        super().__init__(
            f"owner {owner!r} quota exceeded: {used} + {requested} bytes "
            f"> quota {quota}")
        self.owner = owner
        self.used = used
        self.requested = requested
        self.quota = quota


class IPFSStore:
    """In-process content-addressed store with hash-verified retrieval.

    Multi-tenant accounting: a store shared by several federated tasks on
    one chain node tags puts with an ``owner`` (task id), tracking
    per-owner put counts and logical bytes. Content addressing dedups
    across owners — two tasks publishing an identical tree store one blob
    (counted in ``dedup_hits``) while each owner's logical usage is still
    attributed.

    ``owner_quota_bytes`` (0 = unlimited) enforces a per-owner cap on
    *logical* bytes — dedup'd puts still count against their owner, so one
    tenant cannot ride another tenant's identical blobs to unlimited
    attribution. An over-quota put raises ``QuotaExceeded`` before any
    state changes; anonymous (ownerless) puts are never quota'd."""

    def __init__(self, owner_quota_bytes: int = 0) -> None:
        if owner_quota_bytes < 0:
            raise ValueError("owner_quota_bytes must be >= 0")
        self._store: Dict[str, bytes] = {}
        self.owner_quota_bytes = owner_quota_bytes
        self.bytes_stored = 0
        self.puts = 0
        self.gets = 0
        self.dedup_hits = 0
        self.puts_by_owner: Dict[str, int] = {}
        self.bytes_by_owner: Dict[str, int] = {}
        # streaming (read-path) accounting: byte-range reads served to
        # checkpoint-streaming clients (repro.serve)
        self.reads = 0
        self.bytes_read = 0

    def put_tree(self, tree: Any, owner: str = None) -> str:
        blob = _pack_tree(tree)
        cid = hashlib.sha256(blob).hexdigest()
        if owner is not None and self.owner_quota_bytes:
            used = self.bytes_by_owner.get(owner, 0)
            if used + len(blob) > self.owner_quota_bytes:
                raise QuotaExceeded(owner, used, len(blob),
                                    self.owner_quota_bytes)
        if cid not in self._store:
            self._store[cid] = blob
            self.bytes_stored += len(blob)
        else:
            self.dedup_hits += 1
        self.puts += 1
        if owner is not None:
            self.puts_by_owner[owner] = self.puts_by_owner.get(owner, 0) + 1
            self.bytes_by_owner[owner] = \
                self.bytes_by_owner.get(owner, 0) + len(blob)
        return cid

    def put_blob(self, blob: bytes, owner: str = None) -> str:
        """Store an already-serialized blob under its content address —
        how a gossiped artifact (a peer cluster's aggregate, shipped as
        raw bytes over ``repro.net``) enters the local store. Same dedup
        and per-owner quota accounting as ``put_tree``."""
        cid = hashlib.sha256(blob).hexdigest()
        if owner is not None and self.owner_quota_bytes:
            used = self.bytes_by_owner.get(owner, 0)
            if used + len(blob) > self.owner_quota_bytes:
                raise QuotaExceeded(owner, used, len(blob),
                                    self.owner_quota_bytes)
        if cid not in self._store:
            self._store[cid] = blob
            self.bytes_stored += len(blob)
        else:
            self.dedup_hits += 1
        self.puts += 1
        if owner is not None:
            self.puts_by_owner[owner] = self.puts_by_owner.get(owner, 0) + 1
            self.bytes_by_owner[owner] = \
                self.bytes_by_owner.get(owner, 0) + len(blob)
        return cid

    def get_leaves(self, cid: str):
        blob = self._store[cid]
        if hashlib.sha256(blob).hexdigest() != cid:    # tamper check
            raise ValueError(f"content hash mismatch for {cid}")
        self.gets += 1
        return _unpack_leaves(blob)[0]

    def blob_size(self, cid: str) -> int:
        """Stored (compressed) byte size of a blob — what a streaming
        server paginates over the wire."""
        return len(self._store[cid])

    def read_blob(self, cid: str, start: int = 0,
                  stop: Optional[int] = None) -> bytes:
        """Raw byte-range read of a stored blob. No hash check here — a
        streaming client verifies the *reassembled* blob against its
        content address (the cid), which is what makes bounded-chunk
        checkpoint streaming tamper-evident end to end without the server
        materializing whole blobs per request."""
        if start < 0:
            raise ValueError("start must be >= 0")
        blob = self._store[cid]
        part = blob[start:len(blob) if stop is None else stop]
        self.reads += 1
        self.bytes_read += len(part)
        return part

    def has(self, cid: str) -> bool:
        return cid in self._store

    def tamper(self, cid: str, blob: bytes) -> None:
        """Test hook: corrupt a stored object in place."""
        self._store[cid] = blob
