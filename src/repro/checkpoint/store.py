"""Checkpointing: msgpack+zstd PyTree snapshots with chain-recorded hashes.

A checkpoint is the IPFS blob format (content-addressed) written to disk;
``save`` optionally records the cid on the ledger so restarts are auditable
(the paper's §III.D traceability property, extended to training state).
"""
from __future__ import annotations

import os
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.chain.ipfs import _pack_tree, _unpack_leaves
from repro.chain.ledger import Ledger, sha256


def save(path: str, tree: Any, *, step: int = 0,
         ledger: Optional[Ledger] = None) -> str:
    blob = _pack_tree({"step": np.int64(step), "tree": tree})
    cid = sha256(blob)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = f"{path}.tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)                      # atomic publish
    if ledger is not None:
        ledger.append_block([{"type": "checkpoint", "step": step, "cid": cid}])
    return cid


def restore(path: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure/dtypes of ``like``."""
    with open(path, "rb") as f:
        blob = f.read()
    leaves, _ = _unpack_leaves(blob)
    step = int(np.asarray(leaves[0]))
    like_leaves, treedef = jax.tree.flatten(like)
    rest = leaves[1:]
    if len(rest) != len(like_leaves):
        raise ValueError(f"checkpoint has {len(rest)} leaves, expected "
                         f"{len(like_leaves)}")
    out = [np.asarray(r).astype(l.dtype).reshape(l.shape)
           for r, l in zip(rest, like_leaves)]
    return jax.tree.unflatten(treedef, out), step


def verify(path: str, cid: str) -> bool:
    with open(path, "rb") as f:
        return sha256(f.read()) == cid
