"""Chain read path: batched proof serving + light-client verification.

The paper's §III architecture splits chain participants into heavy nodes
(cluster heads / the blockchain committee, who hold full settlement
state) and everyone else — workers, requesters, auditors — who must be
able to *check* what the chain settled without replaying it. This
package is that read path, in two halves:

**Server half** — :class:`ChainReadServer` wraps a live
:class:`~repro.core.node.ChainNode` (or a bare ledger + contracts) and
serves three things, all lock-free against the node's settler threads:

* an O(1) head-sync handshake (``sync_head``): the client states its
  ``(height, block_hash)`` and gets back either a "you're current"
  token or exactly the header delta it is missing;
* batched settlement proofs (``get_proofs``): one deduplicated Merkle
  multiproof per ``(task, round, worker_ids)`` request, resolving
  through every commit flavor the chain produces (dense, sharded,
  delta-overlay, multi-task) — adjacent workers share all but
  O(log(W/k)) sibling digests;
* content-addressed checkpoint streaming (``checkpoint_manifest`` /
  ``checkpoint_chunk``): bounded byte-range reads of published model
  blobs out of the :class:`~repro.chain.ipfs.IPFSStore`, under
  per-client serve quotas.

**Client half** — :class:`LightClient` holds *only block headers*. It
verifies the header chain link by link on sync (hash recomputation, so
header hashes are bit-identical to full-node block hashes), verifies
proof batches with one framed sha256 pass per Merkle level, re-anchors
stale proofs by syncing forward, and reassembles + content-verifies
streamed checkpoints. A tampered header, proof, or checkpoint never
verifies; a light client therefore audits any worker's settlement
record — score, penalty, stake, staleness — against nothing but the
chain head, which is the paper's trust-penalization transparency claim
made concrete.
"""
from repro.chain.ipfs import QuotaExceeded
from repro.chain.proofs import (BlockHeader, ProofBatch, SettlementProof,
                                header_of)
from repro.serve.client import (HeaderVerificationError, LightClient,
                                StaleProofError)
from repro.serve.server import (ChainReadServer, CheckpointManifest,
                                HeadSync, RoundNotSettled)

__all__ = [
    "ChainReadServer", "LightClient", "HeadSync", "CheckpointManifest",
    "RoundNotSettled", "StaleProofError", "HeaderVerificationError",
    "QuotaExceeded", "BlockHeader", "ProofBatch", "SettlementProof",
    "header_of",
]
