"""Client half of the chain read path: the header-only light client.

A ``LightClient`` holds nothing but verified block headers. Sync
verifies the chain link by link (index continuity, ``prev_hash``
linkage, full hash recomputation — header hashes are bit-identical to
full-node block hashes by construction), so a server cannot feed a
client headers it didn't seal. Proof batches then verify against the
client's *own* header for the claimed block, one framed sha256 pass per
Merkle level; checkpoints stream in bounded chunks and verify against
their content address. The server is untrusted throughout — every
answer is checked, and a stale answer re-anchors by syncing forward.
"""
from __future__ import annotations

import hashlib
from typing import Any, Dict, List, Optional, Sequence

from repro.chain.ipfs import _unpack_leaves
from repro.chain.ledger import Ledger
from repro.chain.proofs import BlockHeader, ProofBatch, verify_proof_batch

__all__ = ["LightClient", "StaleProofError", "HeaderVerificationError"]


class HeaderVerificationError(ValueError):
    """A served header fails chain verification (bad index, broken
    ``prev_hash`` link, or a hash that doesn't recompute)."""


class StaleProofError(RuntimeError):
    """A proof batch references a block beyond the client's synced
    head — sync first, then re-verify (the proof itself may be fine)."""

    def __init__(self, block_index: int, height: int) -> None:
        super().__init__(
            f"proof targets block {block_index} but only {height} "
            f"headers are synced")
        self.block_index = block_index
        self.height = height


class LightClient:
    """Header-only verifying client of a :class:`ChainReadServer`.

    State is just ``headers`` — the verified chain prefix. Everything
    else (proofs, records, checkpoints) is fetched on demand and checked
    against those headers before being believed."""

    def __init__(self, server, client_id: Optional[str] = None) -> None:
        self.server = server
        self.client_id = client_id
        self.headers: List[BlockHeader] = []
        # resets received while already holding verified headers — i.e.
        # the server's chain reorged out from under us (repro.net fork
        # choice) and we re-verified the winning fork from genesis
        self.reorg_resyncs = 0

    @property
    def height(self) -> int:
        return len(self.headers)

    # -- header sync -----------------------------------------------------------

    def _verify_and_adopt(self, headers: Sequence[BlockHeader],
                          base: List[BlockHeader]) -> List[BlockHeader]:
        prev = base[-1].hash if base else Ledger.GENESIS_HASH
        index = len(base)
        out = list(base)
        for h in headers:
            if h.index != index:
                raise HeaderVerificationError(
                    f"expected header {index}, got {h.index}")
            if h.prev_hash != prev:
                raise HeaderVerificationError(
                    f"header {h.index} does not link to {prev[:12]}…")
            if h.compute_hash() != h.hash:
                raise HeaderVerificationError(
                    f"header {h.index} hash does not recompute")
            out.append(h)
            prev = h.hash
            index += 1
        return out

    def sync(self) -> int:
        """One head-sync handshake: verify and adopt whatever delta the
        server returns (or the full chain on ``reset`` — which, against
        a ``repro.net`` replica, is how a reorg reaches light clients:
        the dead-fork claim misses, and the winning fork is re-verified
        from genesis, counted in ``reorg_resyncs``). Returns the number
        of headers gained (possibly negative across a reorg onto a
        shorter-but-heavier fork); raises ``HeaderVerificationError`` —
        leaving local state untouched — on any bad header."""
        claim_hash = self.headers[-1].hash if self.headers else None
        reply = self.server.sync_head(len(self.headers), claim_hash)
        if reply.current:
            return 0
        if reply.reset and self.headers:
            self.reorg_resyncs += 1
        base = [] if reply.reset else self.headers
        adopted = self._verify_and_adopt(reply.headers, base)
        gained = len(adopted) - len(self.headers)
        self.headers = adopted
        return gained

    # -- proof verification ----------------------------------------------------

    def verify_batch(self, batch: ProofBatch) -> bool:
        """Verify a proof batch against the client's own header for its
        block. ``StaleProofError`` means the client hasn't synced that
        far; any cryptographic failure returns ``False``."""
        if not 0 <= batch.block_index < len(self.headers):
            raise StaleProofError(batch.block_index, len(self.headers))
        return verify_proof_batch(batch, self.headers[batch.block_index])

    def fetch_proofs(self, task_id: Optional[str],
                     worker_ids: Sequence[int],
                     round_index: Optional[int] = None) -> ProofBatch:
        """Fetch a batch from the server (unverified — pair with
        ``verify_batch``)."""
        return self.server.get_proofs(task_id, worker_ids,
                                      round_index=round_index)

    def audit(self, task_id: Optional[str], worker_id: int,
              round_index: Optional[int] = None) -> Dict[str, Any]:
        """End-to-end audit of one worker's settlement record: fetch its
        proof, re-anchor by syncing if the proof outruns our headers,
        verify, and return the decoded record — raising ``ValueError``
        if the server's answer does not verify or names a different
        worker."""
        batch = self.fetch_proofs(task_id, [int(worker_id)],
                                  round_index=round_index)
        try:
            ok = self.verify_batch(batch)
        except StaleProofError:
            self.sync()
            ok = self.verify_batch(batch)
        if not ok:
            raise ValueError(
                f"settlement proof for worker {worker_id} rejected")
        record = batch.decoded(0)
        if record["worker"] != int(worker_id):
            raise ValueError(
                f"proof is for worker {record['worker']}, "
                f"not {worker_id}")
        return record

    # -- checkpoint streaming --------------------------------------------------

    def fetch_checkpoint(self, cid: str):
        """Stream a published checkpoint in bounded chunks, verify the
        reassembled bytes against their content address, and return the
        decoded model leaves. Oversized chunks and content mismatches
        raise ``ValueError`` — a tampered store cannot slip a forged
        checkpoint past the cid."""
        manifest = self.server.checkpoint_manifest(cid)
        parts = []
        for i in range(manifest.num_chunks):
            part = self.server.checkpoint_chunk(cid, i,
                                                client_id=self.client_id)
            if len(part) > manifest.chunk_bytes:
                raise ValueError(f"chunk {i} exceeds the manifest bound")
            parts.append(part)
        blob = b"".join(parts)
        if hashlib.sha256(blob).hexdigest() != cid:
            raise ValueError(f"content hash mismatch for {cid}")
        return _unpack_leaves(blob)[0]
