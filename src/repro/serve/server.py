"""Server half of the chain read path.

``ChainReadServer`` answers read queries over a *live* chain node while
its settler pool keeps appending blocks. It takes no locks; correctness
rests on the ledger's publication-order contract (see
``Ledger._seal``): a block's commit is registered before the block is
appended, appends are GIL-atomic, and sealed state is immutable. Every
read here therefore only ever sees fully-constructed, frozen data — a
reader can at worst be one block behind, never torn.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.chain.ipfs import QuotaExceeded
from repro.chain.proofs import (BlockHeader, ProofBatch, build_proof_batch,
                                header_of)

__all__ = ["ChainReadServer", "HeadSync", "CheckpointManifest",
           "RoundNotSettled"]


class RoundNotSettled(LookupError):
    """The requested round has no sealed settlement block yet — the
    asynchronous settler simply hasn't gotten there. Retryable."""

    def __init__(self, task_id: Optional[str], round_index: int) -> None:
        super().__init__(
            f"round {round_index} of task {task_id!r} is not settled yet")
        self.task_id = task_id
        self.round_index = round_index


@dataclass(frozen=True)
class HeadSync:
    """Reply to a head-sync handshake. ``current`` means the client's
    claimed head is the chain head (``headers`` is empty); otherwise
    ``headers`` is the delta to append. ``reset`` means the claimed head
    was unknown (fork/garbage/genesis) and ``headers`` is the full chain
    to re-adopt from genesis."""

    current: bool
    headers: Tuple[BlockHeader, ...]
    reset: bool


@dataclass(frozen=True)
class CheckpointManifest:
    """Streaming plan for one content-addressed checkpoint blob:
    total ``size`` bytes served as ``num_chunks`` chunks of at most
    ``chunk_bytes`` each. The cid is the sha256 of the reassembled
    bytes — the client's end-to-end tamper check."""

    cid: str
    size: int
    chunk_bytes: int
    num_chunks: int


class ChainReadServer:
    """Batched proof-serving read API over a live chain node.

    Wraps either a :class:`~repro.core.node.ChainNode` (tasks and their
    contracts are resolved live, so tasks added after the server exists
    are served too) or bare parts (``ledger`` + a ``contracts`` mapping
    and optional ``ipfs``) for chain-only deployments. All methods are
    safe to call from any number of reader threads concurrently with
    settlement — they never block the settler and the settler never
    blocks them."""

    def __init__(self, node=None, *, ledger=None, contracts=None,
                 ipfs=None, max_batch: int = 4096,
                 chunk_bytes: int = 1 << 18,
                 serve_quota_bytes: int = 0) -> None:
        if node is not None:
            ledger = node.ledger
            ipfs = node.ipfs if ipfs is None else ipfs
        elif contracts is not None and not isinstance(contracts, dict):
            contracts = {contracts.task_id: contracts}   # single contract
        if ledger is None and contracts:
            ledger = next(iter(contracts.values())).ledger
        if ledger is None:
            raise ValueError("need a node, a ledger, or a contract")
        if max_batch <= 0 or chunk_bytes <= 0 or serve_quota_bytes < 0:
            raise ValueError("max_batch/chunk_bytes must be positive, "
                             "serve_quota_bytes >= 0")
        self._node = node
        self.ledger = ledger
        self.ipfs = ipfs
        self._contracts = contracts or {}
        self.max_batch = max_batch
        self.chunk_bytes = chunk_bytes
        self.serve_quota_bytes = serve_quota_bytes
        self._quota_lock = threading.Lock()
        self.bytes_served_by_client: Dict[str, int] = {}
        # per-(contract, round) sorted-id index for sparse/partial rounds;
        # settled rounds are immutable, so cached entries never go stale
        self._pos_cache: Dict[Tuple[int, int],
                              Tuple[np.ndarray, np.ndarray]] = {}
        # serving stats (monotonic counters; approximate under races,
        # which is fine — they are telemetry, not consensus state)
        self.head_syncs = 0
        self.head_resets = 0
        self.proof_batches = 0
        self.proofs_served = 0
        self.digests_shipped = 0
        self.chunks_streamed = 0

    # -- task resolution -------------------------------------------------------

    def _contract(self, task_id: Optional[str]):
        """The live TrustContract for ``task_id`` (None → sole task)."""
        if self._node is not None:
            tasks = self._node.tasks
            if task_id is None:
                if len(tasks) != 1:
                    raise ValueError(
                        "task_id required on a multi-task node")
                task = next(iter(tasks.values()))
            else:
                task = tasks[task_id]
            contract = task.contract
        else:
            if task_id is None:
                if len(self._contracts) != 1:
                    raise ValueError(
                        "task_id required with multiple contracts")
                contract = next(iter(self._contracts.values()))
            else:
                contract = self._contracts[task_id]
        if contract is None:
            raise ValueError(f"task {task_id!r} runs without a contract")
        return contract

    # -- head sync -------------------------------------------------------------

    @property
    def height(self) -> int:
        return len(self.ledger.blocks)

    def sync_head(self, height: int = 0,
                  block_hash: Optional[str] = None) -> HeadSync:
        """O(1) handshake: the client claims ``(height, block_hash)``
        (its header count and last header's hash). If the claim matches
        our chain, the reply carries exactly the missing suffix —
        empty when the client is current. An unrecognized claim gets a
        full ``reset`` resync from genesis. Since ``repro.net``, a
        reset is a *real signal*, not just corrupt client state: a
        fork-choice reorg (``Ledger.rollback_to`` + ``adopt_block``)
        replaces chain suffixes in place, so a client that last synced
        the losing fork presents a dead head and must re-verify from
        genesis — the sync_head-mismatch path is how a served replica
        observes its upstream's reorg (counted in ``head_resets``)."""
        self.head_syncs += 1
        blocks = self.ledger.blocks        # snapshot ref; append-only
        n = len(blocks)
        if 0 < height <= n and blocks[height - 1].hash == block_hash:
            delta = blocks[height:n]
            return HeadSync(current=not delta,
                            headers=tuple(header_of(b) for b in delta),
                            reset=False)
        self.head_resets += 1
        return HeadSync(current=False,
                        headers=tuple(header_of(b) for b in blocks[:n]),
                        reset=True)

    # -- settlement proofs -----------------------------------------------------

    def latest_settled_round(self, task_id: Optional[str] = None) -> int:
        """Highest round whose settlement block is published. Retries
        the (lock-free) dict scan if the settler mutates the round map
        mid-iteration; raises ``RoundNotSettled`` when no round of the
        task has ever settled."""
        contract = self._contract(task_id)
        n = len(self.ledger.blocks)
        while True:
            try:
                best = -1
                for r, bi in contract._round_blocks.items():
                    if bi < n and r > best:
                        best = r
                break
            except RuntimeError:           # dict grew during iteration
                continue
        if best < 0:
            raise RoundNotSettled(task_id, -1)
        return best

    def _positions(self, contract, round_index: int,
                   worker_ids: Sequence[int]) -> np.ndarray:
        """Record positions of ``worker_ids`` inside the round's
        settlement block. Full-participation rounds are the identity
        (record index == worker id); sparse rounds binary-search the
        round's sorted id vector."""
        wids = np.asarray(worker_ids, np.int64)
        if wids.ndim != 1 or len(wids) == 0:
            raise ValueError("worker_ids must be a non-empty 1-d sequence")
        if contract._round_full_cover.get(round_index):
            if len(wids) and (wids.min() < 0
                              or wids.max() >= contract.num_workers):
                raise KeyError("worker id out of range for round")
            return wids
        ckey = (id(contract), round_index)
        cached = self._pos_cache.get(ckey)
        if cached is None:
            ids = contract._round_ids[round_index]  # immutable once noted
            order = np.argsort(ids, kind="stable")
            cached = self._pos_cache[ckey] = (ids[order], order)
        sids, order = cached
        at = np.searchsorted(sids, wids)
        ok = (at < len(sids)) & (sids[np.minimum(at, len(sids) - 1)]
                                 == wids)
        if not ok.all():
            missing = wids[~ok][:5].tolist()
            raise KeyError(
                f"workers {missing} have no record in round {round_index}")
        return order[at]

    def get_proofs(self, task_id: Optional[str],
                   worker_ids: Sequence[int],
                   round_index: Optional[int] = None) -> ProofBatch:
        """One deduplicated multiproof covering ``worker_ids``'s
        settlement records for ``round_index`` (default: latest settled)
        of ``task_id``. Raises ``RoundNotSettled`` for unsettled rounds,
        ``KeyError`` for workers absent from a sparse round, and
        ``ValueError`` for oversized batches."""
        if len(worker_ids) > self.max_batch:
            raise ValueError(
                f"batch of {len(worker_ids)} exceeds max_batch="
                f"{self.max_batch}")
        contract = self._contract(task_id)
        if round_index is None:
            round_index = self.latest_settled_round(task_id)
        block_index = contract._round_blocks.get(round_index)
        if block_index is None or block_index >= len(self.ledger.blocks):
            raise RoundNotSettled(task_id, round_index)
        pos = self._positions(contract, round_index, worker_ids)
        batch = build_proof_batch(self.ledger, block_index, pos,
                                  task_id=contract.task_id,
                                  worker_ids=worker_ids,
                                  round_index=round_index)
        self.proof_batches += 1
        self.proofs_served += len(batch)
        self.digests_shipped += batch.num_digests
        return batch

    # -- checkpoint streaming --------------------------------------------------

    def _ipfs(self):
        if self.ipfs is None:
            raise ValueError("this server has no artifact store attached")
        return self.ipfs

    def checkpoint_manifest(self, cid: str) -> CheckpointManifest:
        """Chunking plan for streaming the blob behind ``cid``."""
        size = self._ipfs().blob_size(cid)
        num = max(1, -(-size // self.chunk_bytes))
        return CheckpointManifest(cid=cid, size=size,
                                  chunk_bytes=self.chunk_bytes,
                                  num_chunks=num)

    def checkpoint_chunk(self, cid: str, index: int,
                         client_id: Optional[str] = None) -> bytes:
        """One bounded byte-range of the blob behind ``cid``. With a
        ``serve_quota_bytes`` budget configured, each ``client_id``'s
        cumulative streamed bytes are capped (``QuotaExceeded``) — the
        read-side mirror of the store's per-owner put quotas."""
        store = self._ipfs()
        size = store.blob_size(cid)
        start = index * self.chunk_bytes
        if index < 0 or start >= size:
            raise IndexError(f"chunk {index} out of range for {cid}")
        stop = min(start + self.chunk_bytes, size)
        if self.serve_quota_bytes and client_id is not None:
            with self._quota_lock:
                used = self.bytes_served_by_client.get(client_id, 0)
                if used + (stop - start) > self.serve_quota_bytes:
                    raise QuotaExceeded(client_id, used, stop - start,
                                        self.serve_quota_bytes)
                self.bytes_served_by_client[client_id] = \
                    used + (stop - start)
        self.chunks_streamed += 1
        return store.read_blob(cid, start, stop)
