"""Pallas TPU kernel: fused per-worker trust statistics.

One HBM sweep over the (W, D) update matrix produces, against the consensus
c = mean_w u_w:

    dot[w] = <u_w, c>      sq_u[w] = ‖u_w‖²      sq_c = ‖c‖²

i.e. everything ``EvaluatePerformance`` needs for the cosine + norm terms,
without W+2 separate reductions. The consensus tile is recomputed in-VMEM
from the update tile (a (1,W)·(W,BD) row mean) — cheaper than a second HBM
stream of c. Accumulation across D tiles uses the sequential TPU grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _kernel(upd_ref, dot_ref, squ_ref, sqc_ref):
    i = pl.program_id(0)
    u = upd_ref[...].astype(jnp.float32)          # (W, BD)
    c = jnp.mean(u, axis=0, keepdims=True)        # (1, BD) consensus tile

    dot_tile = jnp.sum(u * c, axis=1)[None, :]    # (1, W)
    squ_tile = jnp.sum(u * u, axis=1)[None, :]    # (1, W)
    sqc_tile = jnp.sum(c * c).reshape(1, 1)       # (1, 1)

    @pl.when(i == 0)
    def _init():
        dot_ref[...] = dot_tile
        squ_ref[...] = squ_tile
        sqc_ref[...] = sqc_tile

    @pl.when(i > 0)
    def _acc():
        dot_ref[...] += dot_tile
        squ_ref[...] += squ_tile
        sqc_ref[...] += sqc_tile


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def trust_score_stats(updates: jax.Array, *, block_d: int = 2048,
                      interpret: bool = False):
    """updates: (W, D) -> (dot (W,), sq_u (W,), sq_c ()) in f32."""
    W, D = updates.shape
    block_d = max(LANE, (block_d // LANE) * LANE)
    D_pad = -(-D // block_d) * block_d
    if D_pad != D:
        updates = jnp.pad(updates, ((0, 0), (0, D_pad - D)))

    dot, squ, sqc = pl.pallas_call(
        _kernel,
        grid=(D_pad // block_d,),
        in_specs=[pl.BlockSpec((W, block_d), lambda i: (0, i),
                               memory_space=pltpu.VMEM)],
        out_specs=[
            pl.BlockSpec((1, W), lambda i: (0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((1, W), lambda i: (0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, W), jnp.float32),
            jax.ShapeDtypeStruct((1, W), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(updates)
    return dot[0], squ[0], sqc[0, 0]
