"""Pallas kernel layer — the SDFL-B compute hot-spots.

Module map
----------
``pack``
    Flat-pack layer: a param pytree as ONE contiguous (W, D) matrix.
    ``PackSpec`` is the static slice metadata (leaf order, per-leaf
    offset/size/shape into the flat axis, pack dtype, total width D);
    rows are ``[leaf0.ravel() | leaf1.ravel() | ...]`` in
    ``jax.tree.leaves`` order. Dtype policy: the pack stores deltas in
    the tree's (uniform) param dtype — bf16 deltas carry full *relative*
    precision — and every kernel upcasts tiles to f32 on read. Trees
    mixing leaf dtypes are not packable and stay on the per-leaf path.

``trust_score``
    One-sweep trust statistics over the packed (W, D) update matrix:
    per-worker <u_w, c> / ‖u_w‖² plus ‖c‖² vs the consensus mean, in a
    single streamed HBM pass (column-blocked, full-W tiles).

``trust_agg``
    Trust-weighted aggregate Σ_w w_w·u_w → (D,) f32, one streamed pass.

``fused_round``
    The fused device-resident trust round: chains ``trust_score`` +
    ``trust_agg`` over one packed matrix (2 streamed passes over the
    update volume — the information floor, since aggregation weights
    depend on global statistics of the whole matrix), plus the 2-D-grid
    async kernel folding pending buffers + participation masking into
    the same sweep. Backend dispatch lives here: TPU runs the Pallas
    kernels natively, CPU runs the identical flat-jnp reference math
    (``SDFLB_FUSED_INTERPRET=1`` forces interpret-mode Pallas — the CI
    kernel-correctness smoke). Also the analytic HBM accounting
    (``streamed_bytes`` / ``update_passes``) behind the benchmark gate.

``ref``
    Exact jnp references for every kernel (the property-test oracles).

``ops``
    Jit'd public wrappers — what ``core``/``models`` import. Engagement:
    ``core.fl_step`` routes steps 3–5 of the round through this package
    when ``FederationConfig.fused_trust_path`` allows (auto-on for
    unsharded flat/CNN trees with one leaf dtype; per-leaf jnp reference
    otherwise).

``swa_decode`` / ``ssd_scan``
    LLM-zoo hot loops (sliding-window decode attention; Mamba2/mLSTM
    SSD chunk scan) — unrelated to the trust round.
"""
