"""Fused device-resident trust round — one-sweep scoring + aggregation.

The per-leaf reference path in ``core.fl_step`` streams the W×D update
volume ~5 times per round (dot/sq_u/sq_c reductions in
``trust.update_stats``, then the weighted aggregate). The aggregation
weights depend on *global* statistics of the whole matrix, so one pass is
information-theoretically impossible without a W×D intermediate — the
floor is two streamed passes, and this module hits it:

  pass 1  ``fused_stats``     one HBM sweep producing dot/sq_u/sq_c
                              (the ``trust_score`` kernel: consensus
                              recomputed in-VMEM per tile, no second
                              stream of c)
  (O(W))  score/weight math   ``trust.scores_from_stats`` +
                              ``trust_weights`` — W-sized, runs off the
                              hot path, pipelined by XLA against the
                              second pass's prologue
  pass 2  ``fused_agg``       one MXU sweep for the weighted aggregate
                              (sync), or ``fused_async_agg`` — a NEW
                              kernel that in the same sweep folds the
                              pending buffer (total = pending + update),
                              emits the staleness-discounted aggregate
                              AND the flushed new pending, so the async
                              path's buffer logic costs no extra pass
                              over the update matrix

Dispatch: on TPU the Pallas kernels run natively; on CPU/CI the flat-jnp
references (``kernels.ref``) execute the identical packed math (interpret
mode is for kernel-correctness tests — set ``SDFLB_FUSED_INTERPRET=1`` to
force the Pallas bodies through the interpreter end-to-end).

Tiling: the sync kernels hold full-W column blocks in VMEM, so
``block_d_for`` shrinks the D tile as W grows (W ≲ 16k bf16 / 12k f32 at
the 128-lane floor — the 10k-cohort target fits; beyond that the
per-leaf path remains). The async kernel tiles BOTH dims (grid =
D-tiles × W-tiles, aggregate accumulated over the inner W axis), so its
cohort size is unbounded; its pending buffer persists padded to the tile
grid (``pending_shape``) so no per-round pad/slice copies are needed.

``streamed_bytes``/``update_passes`` compute the chain's exact HBM
traffic from the BlockSpec geometry (every index map visits each element
once per call) — XLA's ``cost_analysis`` cannot see through a fused
kernel body, so the benchmark gate counts the kernel's bytes this way
and uses cost_analysis only for the unfused comparison.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref
from repro.kernels.trust_agg import trust_agg
from repro.kernels.trust_score import trust_score_stats

LANE = 128
SUBLANE = 8
# VMEM budget for one streamed tile (the pipeline double-buffers on top)
_VMEM_TILE_BUDGET = 8 * 1024 * 1024

INTERPRET = jax.default_backend() != "tpu"
# CI smoke knob: force the Pallas bodies through the interpreter instead
# of the flat-jnp reference dispatch (kernel-correctness end-to-end)
FORCE_KERNEL = os.environ.get("SDFLB_FUSED_INTERPRET", "") == "1"


def _use_kernel() -> bool:
    return (not INTERPRET) or FORCE_KERNEL


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def block_d_for(W: int, itemsize: int) -> int:
    """Lane-aligned D tile for the full-W-block kernels: as wide as the
    VMEM tile budget allows at this W, capped at 2048 and floored at one
    lane (the floor can exceed the budget for W ≳ 12k f32 — documented
    ceiling of the sync kernels)."""
    lanes = _VMEM_TILE_BUDGET // max(1, W * itemsize * LANE)
    return int(min(2048, max(LANE, lanes * LANE)))


# -- async kernel geometry ----------------------------------------------------

BLOCK_W = 256      # W tile of the async kernel (sublane-aligned)
BLOCK_D_ASYNC = 512


def pending_shape(W: int, D: int) -> tuple:
    """Persistent (W_pad, D_pad) storage shape of the flat async pending
    buffer — padded once at init to the async kernel's tile grid so
    rounds never pad/slice the (W, D) volume."""
    bw = min(BLOCK_W, _round_up(W, SUBLANE))
    return (_round_up(W, bw), _round_up(D, BLOCK_D_ASYNC))


# -- the async fused kernel ---------------------------------------------------


def _async_kernel(w_ref, keep_ref, upd_ref, pend_ref, agg_ref, newp_ref):
    """One (BW, BD) tile: total = pending + update; emit the flushed new
    pending and accumulate the weighted aggregate over the inner W axis.

    w_ref: (1, BW) weight slice · keep_ref: (BW, 1) keep mask slice
    upd_ref/pend_ref/newp_ref: (BW, BD) · agg_ref: (1, BD) accumulator.
    """
    wi = pl.program_id(1)                    # inner: W tiles
    u = upd_ref[...].astype(jnp.float32)
    total = pend_ref[...] + u
    newp_ref[...] = total * keep_ref[...]
    part = jnp.dot(w_ref[...], total, preferred_element_type=jnp.float32)

    @pl.when(wi == 0)
    def _init():
        agg_ref[...] = part

    @pl.when(wi > 0)
    def _acc():
        agg_ref[...] += part


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_async_agg_kernel(updates, pending, weights, keep, *,
                           interpret: bool = False):
    """updates: (W, D); pending: ``pending_shape(W, D)`` f32;
    weights/keep: (W,) → (agg (D,) f32, new_pending (W_pad, D_pad) f32).

    One streamed pass over the update volume computes the weighted
    aggregate of (pending + update) AND the flushed pending
    (``total·keep``) — the async path's whole post-score data motion.
    """
    W, D = updates.shape
    Wp, Dp = pending.shape
    assert (Wp, Dp) == pending_shape(W, D), \
        f"pending {pending.shape} != pending_shape({W},{D})"
    bw = min(BLOCK_W, Wp)
    bd = min(BLOCK_D_ASYNC, Dp)
    upd = jnp.pad(updates, ((0, Wp - W), (0, Dp - D)))
    w_row = jnp.pad(weights.astype(jnp.float32), (0, Wp - W)).reshape(1, Wp)
    keep_col = jnp.pad(keep.astype(jnp.float32), (0, Wp - W)).reshape(Wp, 1)

    agg, newp = pl.pallas_call(
        _async_kernel,
        grid=(Dp // bd, Wp // bw),           # W tiles innermost: accumulate
        in_specs=[
            pl.BlockSpec((1, bw), lambda d, w: (0, w),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((bw, 1), lambda d, w: (w, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((bw, bd), lambda d, w: (w, d),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((bw, bd), lambda d, w: (w, d),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, bd), lambda d, w: (0, d),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((bw, bd), lambda d, w: (w, d),
                         memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, Dp), jnp.float32),
            jax.ShapeDtypeStruct((Wp, Dp), jnp.float32),
        ],
        interpret=interpret,
    )(w_row, keep_col, upd, pending)
    return agg[0, :D], newp


# -- dispatching public entry points ------------------------------------------


def fused_stats(updates: jax.Array):
    """Pass 1: (W, D) → (dot (W,), sq_u (W,), sq_c ()) vs the inclusive
    consensus, in one HBM sweep."""
    if _use_kernel():
        bd = block_d_for(*_wd_itemsize(updates))
        return trust_score_stats(updates, block_d=bd, interpret=INTERPRET)
    return ref.trust_score_ref(updates)


def fused_agg(updates: jax.Array, weights: jax.Array) -> jax.Array:
    """Pass 2 (sync): (W, D) × (W,) → (D,) f32 weighted aggregate."""
    if _use_kernel():
        bd = block_d_for(*_wd_itemsize(updates))
        return trust_agg(updates, weights, block_d=bd, interpret=INTERPRET)
    return ref.trust_agg_ref(updates, weights)


def fused_async_agg(updates, pending, weights, keep):
    """Pass 2 (async): see ``fused_async_agg_kernel``. The flat-jnp
    dispatch mirrors the padded pending geometry exactly."""
    if _use_kernel():
        return fused_async_agg_kernel(updates, pending, weights, keep,
                                      interpret=INTERPRET)
    W, D = updates.shape
    Wp, Dp = pending.shape
    upd = jnp.pad(updates, ((0, Wp - W), (0, Dp - D)))
    wp = jnp.pad(weights.astype(jnp.float32), (0, Wp - W))
    kp = jnp.pad(keep.astype(jnp.float32), (0, Wp - W))
    agg, newp = ref.fused_async_agg_ref(upd, pending, wp, kp)
    return agg[:D], newp


def _wd_itemsize(updates):
    return updates.shape[0], jnp.dtype(updates.dtype).itemsize


# -- exact HBM accounting (BlockSpec geometry) --------------------------------


def streamed_bytes(W: int, D: int, dtype, *, async_mode: bool = False):
    """Exact per-round HBM traffic of the fused chain, from the kernels'
    BlockSpec geometry (each index map visits every element exactly once
    per call). Returns {update_read, other, total} in bytes."""
    isz = jnp.dtype(dtype).itemsize
    upd = W * D * isz
    stats_out = (2 * W + 1) * 4
    if async_mode:
        Wp, Dp = pending_shape(W, D)
        update_read = 2 * upd                     # stats pass + agg pass
        other = (Wp * Dp * 4) * 2 + Dp * 4 \
            + (2 * Wp) * 4 + stats_out            # pending r/w, agg, rows
    else:
        update_read = 2 * upd
        other = D * 4 + W * 4 + stats_out         # aggregate out, weights
    return {"update_read": float(update_read), "other": float(other),
            "total": float(update_read + other)}


def update_passes(W: int, D: int, dtype, *, async_mode: bool = False
                  ) -> float:
    """How many times the fused chain streams the W×D update volume
    (the benchmark/CI gate asserts ≤ 2)."""
    isz = jnp.dtype(dtype).itemsize
    return streamed_bytes(W, D, dtype,
                          async_mode=async_mode)["update_read"] / (W * D * isz)
