"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def trust_agg_ref(updates: jax.Array, weights: jax.Array) -> jax.Array:
    """updates: (W, D), weights: (W,) -> (D,) = Σ_w weights[w]·updates[w]."""
    return jnp.einsum("w,wd->d", weights.astype(jnp.float32),
                      updates.astype(jnp.float32))


def trust_score_ref(updates: jax.Array):
    """updates: (W, D) -> (dot (W,), sq_u (W,), sq_c ()) against the
    consensus c = mean_w updates."""
    u = updates.astype(jnp.float32)
    c = jnp.mean(u, axis=0)
    dot = u @ c
    sq_u = jnp.sum(u * u, axis=1)
    sq_c = jnp.sum(c * c)
    return dot, sq_u, sq_c


def fused_async_agg_ref(updates: jax.Array, pending: jax.Array,
                        weights: jax.Array, keep: jax.Array):
    """Flat async aggregate+flush: total = pending + updates (f32);
    agg = Σ_w weights[w]·total[w]; new_pending = total·keep[:, None].
    updates/pending: (W, D); weights/keep: (W,) → ((D,) f32, (W, D) f32).
    """
    total = pending.astype(jnp.float32) + updates.astype(jnp.float32)
    agg = jnp.einsum("w,wd->d", weights.astype(jnp.float32), total)
    new_pending = total * keep.astype(jnp.float32)[:, None]
    return agg, new_pending


def swa_decode_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                   cur_index: int, window: int) -> jax.Array:
    """q: (B, H, hd); caches: (B, S, KV, hd). Single-token sliding-window
    decode attention -> (B, H, hd)."""
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qs = q.reshape(B, KV, G, hd).astype(jnp.float32) * (hd ** -0.5)
    s = jnp.einsum("bkgh,bskh->bkgs", qs, k_cache.astype(jnp.float32))
    pos = jnp.arange(S)
    valid = (pos <= cur_index) & ((cur_index - pos) < window)
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
