"""Pallas TPU kernel: fused SSD / decay-attention chunk scan.

The compute hot-spot of Mamba2 (zamba2-7b) and mLSTM (xlstm-1.3b): for each
(batch, head) the full chunked linear-attention-with-decay recurrence

    y_t = q_t · h_t,   h_t = exp(a_t)·h_{t-1} + i_t · k_t ⊗ v_t

is computed in ONE kernel: the grid's chunk axis is sequential on TPU, so
the inter-chunk state h (dk × dv) lives in VMEM scratch across grid steps —
no HBM round-trip of per-chunk states (the pure-jnp path materializes
(B, n_chunks, H, dk, dv) f32 states + a lax.scan). Intra-chunk work is the
(Q × Q) decay-masked score matmul on the MXU.

Tiling: grid (B, H, n_chunks); per-tile operands q/k (Q, dk), v (Q, dv),
gates (Q,) — Q and the head dims are lane-aligned by ops.py padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, a_ref, i_ref,     # (1,1,Q,dk)×2,(1,1,Q,dv),(1,1,Q)×2
            y_ref,                                  # (1,1,Q,dv)
            h_scr,                                  # VMEM (dk, dv) f32
            *, num_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    q = q_ref[0, 0, 0].astype(jnp.float32)          # (Q, dk)
    k = k_ref[0, 0, 0].astype(jnp.float32)
    v = v_ref[0, 0, 0].astype(jnp.float32)          # (Q, dv)
    a = a_ref[0, 0, 0].astype(jnp.float32)          # (Q,)
    i = i_ref[0, 0, 0].astype(jnp.float32)

    Q = q.shape[0]
    cum = jnp.cumsum(a)                             # (Q,)
    # L[t, s] = exp(cum_t - cum_s) for t >= s (decay s+1..t)
    diff = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    gated = scores * L * i[None, :]
    y_intra = jax.lax.dot(gated, v, preferred_element_type=jnp.float32)

    # inter-chunk: state before this chunk, decayed to each position
    h = h_scr[...]
    y_inter = jax.lax.dot(q, h, preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]

    y_ref[0, 0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(total)·h + Σ_s exp(total - cum_s)·i_s·k_s⊗v_s
    total = cum[Q - 1]
    w = (jnp.exp(total - cum) * i)[:, None]         # (Q, 1)
    h_scr[...] = h * jnp.exp(total) + jax.lax.dot_general(
        k * w, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(q, k, v, a, i, *, chunk: int = 256, interpret: bool = False):
    """q, k: (B, S, H, dk); v: (B, S, H, dv); a, i: (B, S, H).
    Returns y (B, S, H, dv) — the full decay-attention recurrence.
    Requires S % chunk == 0."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    def to_tiles(x, d):
        # (B,S,H,d) -> (B,H,nc,Q,d)
        return jnp.moveaxis(x, 2, 1).reshape(B, H, nc, chunk, d)

    qt, kt, vt = to_tiles(q, dk), to_tiles(k, dk), to_tiles(v, dv)
    at = jnp.moveaxis(a, 2, 1).reshape(B, H, nc, chunk)
    it = jnp.moveaxis(i, 2, 1).reshape(B, H, nc, chunk)

    y = pl.pallas_call(
        functools.partial(_kernel, num_chunks=nc),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, dk), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, dk), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, dv), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, dv),
                               lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nc, chunk, dv), v.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, at, it)
    return jnp.moveaxis(y.reshape(B, H, S, dv), 1, 2)
