"""Pallas TPU kernel: trust-weighted aggregation of W worker updates.

The cluster-head hot loop — ``out[d] = Σ_w weights[w] · updates[w, d]`` over
the flattened update matrix. One HBM pass over the (W, D) matrix instead of
W separate accumulations: a (1, W) × (W, BD) MXU matmul per VMEM tile of BD
lanes. The weight row sits in VMEM whole (W is small); D is tiled 128-lane
aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _kernel(w_ref, upd_ref, out_ref):
    # w_ref: (1, W) f32 ; upd_ref: (W, BD) ; out_ref: (1, BD) f32
    out_ref[...] = jnp.dot(w_ref[...],
                           upd_ref[...].astype(jnp.float32),
                           preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def trust_agg(updates: jax.Array, weights: jax.Array, *, block_d: int = 2048,
              interpret: bool = False) -> jax.Array:
    """updates: (W, D) any float dtype; weights: (W,) -> (D,) f32.

    D is padded to a multiple of ``block_d`` (itself lane-aligned); the pad
    contributes zeros and is sliced off.
    """
    W, D = updates.shape
    block_d = max(LANE, (block_d // LANE) * LANE)
    D_pad = -(-D // block_d) * block_d
    if D_pad != D:
        updates = jnp.pad(updates, ((0, 0), (0, D_pad - D)))
    w_row = weights.astype(jnp.float32).reshape(1, W)

    out = pl.pallas_call(
        _kernel,
        grid=(D_pad // block_d,),
        in_specs=[
            pl.BlockSpec((1, W), lambda i: (0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((W, block_d), lambda i: (0, i),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((1, D_pad), jnp.float32),
        interpret=interpret,
    )(w_row, updates)
    return out[0, :D]
