"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels run with ``interpret=True`` — the body
executes in Python on CPU for correctness; on TPU they compile natively.
``INTERPRET`` flips automatically from the backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.trust_agg import trust_agg as _trust_agg
from repro.kernels.trust_score import trust_score_stats as _trust_score_stats
from repro.kernels.swa_decode import swa_decode as _swa_decode
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan
# fused trust-round chain (flat-pack path) — backend-dispatching wrappers
from repro.kernels.fused_round import (fused_agg, fused_async_agg,  # noqa: F401
                                       fused_stats, pending_shape)

INTERPRET = jax.default_backend() != "tpu"


def trust_weighted_aggregate(updates, weights, *, block_d: int = 2048):
    """(W, D) updates × (W,) weights -> (D,) f32 aggregate."""
    return _trust_agg(updates, weights, block_d=block_d, interpret=INTERPRET)


def trust_stats(updates, *, block_d: int = 2048):
    """(W, D) -> (dot (W,), sq_u (W,), sq_c ()) vs consensus mean."""
    return _trust_score_stats(updates, block_d=block_d, interpret=INTERPRET)


def sliding_window_decode(q, k_cache, v_cache, cur_index, *, window: int,
                          block_s: int = 512):
    """Single-token sliding-window decode attention (B,H,hd)."""
    return _swa_decode(q, k_cache, v_cache, cur_index, window=window,
                       block_s=block_s, interpret=INTERPRET)


def ssd_chunk_scan(q, k, v, a, i, *, chunk: int = 256):
    """Fused SSD/decay-attention recurrence (Mamba2/mLSTM hot loop):
    (B,S,H,dk)×(B,S,H,dv) with per-step log-decay a and input gate i."""
    return _ssd_scan(q, k, v, a, i, chunk=chunk, interpret=INTERPRET)


def aggregate_pytree(updates, weights):
    """Trust-weighted aggregation over a pytree with leading worker dim —
    flattens to one (W, D) matrix per leaf and runs the kernel; small leaves
    fall back to einsum (kernel launch not worth it)."""
    def leaf(u):
        W = u.shape[0]
        flat = u.reshape(W, -1)
        if flat.shape[1] < 1024:
            return jnp.einsum("w,wd->d", weights.astype(jnp.float32),
                              flat.astype(jnp.float32)).reshape(u.shape[1:])
        return trust_weighted_aggregate(flat, weights).reshape(u.shape[1:])
    return jax.tree.map(leaf, updates)
