"""Pallas TPU kernel: sliding-window single-token decode attention.

The sub-quadratic long-context serve path (h2o-danube SWA; zamba2's shared
attention in long-context mode): one query token per sequence attends to at
most ``window`` cache slots. Only the ceil(window/BS)+1 KV blocks that can
intersect the window are streamed from HBM — cache length S never enters
the work term. Online softmax accumulates across the sequential KV-block
grid dim in VMEM scratch; ``cur_index`` arrives by scalar prefetch and
drives the block index map (dynamic window start).

Layout: per (batch, kv-head) program, q tile (G, hd) — the GQA group — and
KV tiles (BS, hd). G and hd are padded to MXU/lane alignment in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(idx_ref,                      # scalar prefetch: [cur_index]
            q_ref, k_ref, v_ref,          # (1,1,G,hd), (1,1,BS,hd) ×2
            o_ref,                        # (1,1,G,hd)
            m_scr, l_scr, acc_scr,        # VMEM scratch (G,1),(G,1),(G,hd)
            *, window: int, block_s: int, num_blocks: int):
    j = pl.program_id(2)
    cur = idx_ref[0]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    start_blk = jnp.maximum(cur - window + 1, 0) // block_s
    pos = (start_blk + j) * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_s), 1)                          # (1, BS)
    valid = (pos <= cur) & ((cur - pos) < window)

    q = q_ref[0, 0].astype(jnp.float32)                      # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                      # (BS, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, BS)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                                      # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == num_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "block_s", "interpret"))
def swa_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
               cur_index, *, window: int, block_s: int = 512,
               interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); caches: (B, S, KV, hd); cur_index: scalar int32.
    Returns (B, H, hd). Requires S % block_s == 0 (cache is allocated
    block-aligned by the serving layer)."""
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = hd ** -0.5
    num_blocks = min(-(-window // block_s) + 1, S // block_s)

    qt = (q.astype(jnp.float32) * scale).reshape(B, KV, G, hd)
    kt = jnp.moveaxis(k_cache, 2, 1)                         # (B, KV, S, hd)
    vt = jnp.moveaxis(v_cache, 2, 1)
    idx = jnp.asarray(cur_index, jnp.int32).reshape(1)

    def kv_index(b, kv, j, idx_ref):
        start_blk = jnp.maximum(idx_ref[0] - window + 1, 0) // block_s
        return (b, kv, start_blk + j, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, num_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, kv, j, idx: (b, kv, 0, 0)),
            pl.BlockSpec((1, 1, block_s, hd), kv_index),
            pl.BlockSpec((1, 1, block_s, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, kv, j, idx: (b, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, window=window, block_s=block_s,
                          num_blocks=num_blocks),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(idx, qt, kt, vt)
    return out.reshape(B, H, hd)
