"""Flat-pack layer: a param pytree as ONE contiguous (W, D) matrix.

The fused trust path (``kernels.fused_round``) streams the whole cohort's
update volume through Pallas kernels, which want a single dense matrix —
not a pytree of per-layer stacks. This module is the stax2-style
"unzip" of a param tree into static metadata + flat storage:

  ``PackSpec``       static slice metadata (treedef + per-leaf shape/
                     size/offset + pack dtype + total width D). Built
                     once per model from the global param tree; every
                     packed row shares the layout
                     ``[leaf0.ravel() | leaf1.ravel() | ...]`` in
                     ``jax.tree.leaves`` order.
  ``pack_delta``     per-worker update deltas (new − global) computed
                     directly into the (W, D) matrix in the pack dtype —
                     the per-leaf delta pytree is never materialized as
                     a user-level artifact (XLA fuses the subtract into
                     the concat).
  ``pack_stack``     (W, ...)-leaf pytree → (W, D)   (async pending).
  ``unpack_vector``  (D,) → param-shaped pytree — the ONE reassembly per
                     round (the aggregated global update).
  ``unpack_stack``   (W, D) → (W, ...)-leaf pytree (tests/tooling).

Dtype policy: the pack dtype is the tree's common leaf dtype (bf16 deltas
carry full *relative* precision, matching the per-leaf path's storage
rule); trees mixing dtypes are not ``packable`` and keep the per-leaf
reference path. All kernels upcast tiles to f32 on read; ``unpack_vector``
preserves its input dtype (the f32 aggregate).

Specs are shape-only: building one from ``jax.eval_shape`` structs works,
so launch tooling can size packs without touching device memory.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class PackSpec(NamedTuple):
    """Static slice metadata of a flat-packed param tree."""
    treedef: Any                          # jax treedef of the template
    shapes: Tuple[Tuple[int, ...], ...]   # per-leaf shapes (no W dim)
    sizes: Tuple[int, ...]                # per-leaf element counts
    offsets: Tuple[int, ...]              # per-leaf start column in the pack
    dtype: Any                            # common storage dtype of the pack
    total: int                            # D: columns of the packed matrix

    def slices(self):
        """Debug/audit view: (offset, size, shape) per leaf, pack order."""
        return tuple(zip(self.offsets, self.sizes, self.shapes))


def packable(tree) -> bool:
    """True iff every leaf shares one floating dtype — the precondition
    for a lossless single-dtype pack (mixed-dtype trees keep the
    per-leaf reference path)."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return False
    dt = jnp.result_type(leaves[0])
    return all(jnp.result_type(x) == dt for x in leaves) \
        and jnp.issubdtype(dt, jnp.floating)


def pack_spec(tree) -> PackSpec:
    """Build the static layout from a template param tree (arrays or
    ShapeDtypeStructs; leading W dims must NOT be present)."""
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("cannot pack an empty tree")
    shapes = tuple(tuple(x.shape) for x in leaves)
    sizes = tuple(math.prod(s) for s in shapes)
    offsets, off = [], 0
    for s in sizes:
        offsets.append(off)
        off += s
    dtype = jnp.result_type(*leaves)
    return PackSpec(treedef, shapes, sizes, tuple(offsets),
                    jnp.dtype(dtype), off)


def pack_delta(new_params_w, global_params, spec: PackSpec) -> jax.Array:
    """Per-worker update deltas straight into the (W, D) pack.

    Numerically identical to the per-leaf path's update rule: the delta
    is computed in f32 and stored in the pack dtype
    (``(new_f32 − global_f32).astype(pack_dtype)``)."""
    new_leaves = jax.tree.leaves(new_params_w)
    g_leaves = jax.tree.leaves(global_params)
    W = new_leaves[0].shape[0]
    cols = []
    for a, g in zip(new_leaves, g_leaves):
        d = (a.astype(jnp.float32)
             - g.astype(jnp.float32)[None]).astype(spec.dtype)
        cols.append(d.reshape(W, -1))
    return jnp.concatenate(cols, axis=1)


def pack_stack(tree_w, spec: PackSpec, dtype=None) -> jax.Array:
    """(W, ...)-leaf pytree → (W, D) in ``dtype`` (default: pack dtype)."""
    leaves = jax.tree.leaves(tree_w)
    W = leaves[0].shape[0]
    dt = spec.dtype if dtype is None else jnp.dtype(dtype)
    return jnp.concatenate(
        [x.reshape(W, -1).astype(dt) for x in leaves], axis=1)


def unpack_vector(vec: jax.Array, spec: PackSpec):
    """(D,) → param-shaped pytree, preserving the vector's dtype. The
    one reassembly per fused round (the aggregated global update)."""
    leaves = [vec[o:o + s].reshape(shape)
              for o, s, shape in spec.slices()]
    return jax.tree.unflatten(spec.treedef, leaves)


def unpack_stack(mat: jax.Array, spec: PackSpec):
    """(W, D) → (W, ...)-leaf pytree, preserving the matrix's dtype."""
    W = mat.shape[0]
    leaves = [mat[:, o:o + s].reshape((W,) + shape)
              for o, s, shape in spec.slices()]
    return jax.tree.unflatten(spec.treedef, leaves)
