"""Small XLA-client compatibility helpers shared by launch tooling and
tests (kept free of import side effects — ``launch.dryrun`` sets XLA flags
at import time, so anything that wants these helpers without forcing a
512-device host platform imports them from here)."""
from __future__ import annotations

from typing import Any, Dict


def normalize_cost_analysis(cost: Any) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` returns a plain dict on some
    jax/jaxlib versions but a one-element list of per-module dicts on
    others (e.g. jaxlib 0.4.36's PyClient). Normalize to one flat dict
    ({} when the backend offers no analysis) so callers can just
    ``.get("flops")``."""
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        merged: Dict[str, float] = {}
        for entry in cost:
            for k, v in entry.items():
                # per-module entries: costs are additive across modules
                if isinstance(v, (int, float)) and k in merged \
                        and isinstance(merged[k], (int, float)):
                    merged[k] += v
                else:
                    merged[k] = v
        return merged
    return dict(cost)
