"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface the
test suite uses, installed by ``tests/conftest.py`` only when the real
package is absent (the CI image pins the real one; the hermetic dev
container may not ship it).

Covered surface: ``@given(**strategies)``, ``@settings(max_examples=...,
deadline=...)``, ``assume``, and the strategies ``integers``, ``floats``,
``booleans``, ``sampled_from``, ``data``. Examples are drawn from a
deterministic per-test PRNG (seeded from the test's qualified name), so
failures are reproducible run-to-run; there is no shrinking — the
falsifying example is reported verbatim.
"""
from __future__ import annotations

import hashlib
import inspect
import sys
import types
from typing import Any, Callable, Dict

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any],
                 label: str = "strategy") -> None:
        self._draw = draw
        self._label = label

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return self._label


class _DataStrategy(_Strategy):
    """Marker for ``st.data()`` — materializes to a ``_DataObject``."""

    def __init__(self) -> None:
        super().__init__(lambda rng: None, "data()")


class _DataObject:
    def __init__(self, rng: np.random.Generator) -> None:
        self._rng = rng

    def draw(self, strategy: _Strategy, label: str = "") -> Any:
        return strategy._draw(self._rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)),
                     f"integers({min_value}, {max_value})")


def floats(min_value: float, max_value: float, **_: Any) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)),
                     f"floats({min_value}, {max_value})")


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans()")


def sampled_from(elements) -> _Strategy:
    seq = list(elements)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))],
                     f"sampled_from({seq!r})")


def data() -> _DataStrategy:
    return _DataStrategy()


class _Unsatisfied(Exception):
    pass


def assume(condition: bool) -> bool:
    if not condition:
        raise _Unsatisfied
    return True


def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES,
             **_: Any) -> Callable:
    """Decorator factory; only ``max_examples`` is honored (``deadline`` et
    al. are accepted and ignored)."""

    def deco(fn: Callable) -> Callable:
        fn._fallback_max_examples = max_examples
        return fn

    return deco


# profile API parity (HYPOTHESIS_PROFILE=ci in CI): the fallback is always
# deterministic — examples derive from the test's qualified name — so
# profiles are accepted and ignored
settings.register_profile = lambda name, **kw: None
settings.load_profile = lambda name: None


def given(**strategies: _Strategy) -> Callable:
    def deco(fn: Callable) -> Callable:
        seed = int.from_bytes(
            hashlib.sha256(fn.__qualname__.encode()).digest()[:8], "big")

        def wrapper() -> None:
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(seed)
            ran = 0
            attempts = 0
            while ran < n and attempts < 10 * n:
                attempts += 1
                kwargs: Dict[str, Any] = {
                    name: (_DataObject(rng) if isinstance(s, _DataStrategy)
                           else s._draw(rng))
                    for name, s in strategies.items()}
                try:
                    fn(**kwargs)
                except _Unsatisfied:
                    continue
                except BaseException as exc:
                    shown = {k: v for k, v in kwargs.items()
                             if not isinstance(v, _DataObject)}
                    raise AssertionError(
                        "falsifying example (hypothesis fallback): "
                        f"{fn.__qualname__}({shown!r})") from exc
                ran += 1

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # empty signature so pytest does not mistake drawn args for fixtures
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco


def install() -> None:
    """Register ``hypothesis`` / ``hypothesis.strategies`` modules backed by
    this fallback. No-op if the real package is importable."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "data"):
        setattr(st, name, globals()[name])
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
