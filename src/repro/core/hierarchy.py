"""Cluster-hierarchical aggregation — the paper's semi-decentralized topology
as mesh collectives.

Workers carry a leading dim W on every update leaf. W is laid out
``(num_clusters, workers_per_cluster)``; on the production mesh W is sharded
over the ``data`` (and ``pod``) axes, so:

  stage 1 (cluster-head FedAvg)   : trust-weighted mean over the
                                    workers_per_cluster sub-dim → an
                                    intra-cluster (grouped) all-reduce on ICI
  stage 2 (head↔head exchange)    : trust-weighted mean over clusters → the
                                    cross-cluster/cross-pod all-reduce

``mode="head_gather"`` is the paper-faithful variant: stage 1 is an
all-gather to the rotating cluster head's slot followed by the head's local
reduction (a physically-central head, as in the paper's socket protocol);
``mode="allreduce"`` is the TPU-native leaderless version (beyond-paper —
same math, cheaper collective). Both return identical values.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FederationConfig


def _cluster_view(x, C: int):
    """(W, ...) -> (C, Wc, ...)"""
    W = x.shape[0]
    return x.reshape(C, W // C, *x.shape[1:])


def aggregate_fused(updates, weights):
    """Beyond-paper optimized default: Σ_w weights_w · u_w as a single
    weighted reduction (identical value to the two-stage ``aggregate`` when
    cluster weights are the member sums — the hierarchy telescopes). One
    collective, no (C, ...) head tensors materialized."""
    def agg_leaf(u):
        wshape = (-1,) + (1,) * (u.ndim - 1)
        return jnp.sum(u.astype(jnp.float32) * weights.reshape(wshape), axis=0)
    return jax.tree.map(agg_leaf, updates)


def aggregate(updates, weights, fed: FederationConfig, *,
              cluster_weights=None):
    """Two-level trust-weighted aggregation.

    updates: pytree, every leaf (W, ...). weights: (W,) — already combining
    trust × participation × staleness, normalized over W (sum == 1).
    cluster_weights: optional (C,) override for the head↔head stage (defaults
    to the clusters' summed member weights — unbiased).

    Returns the aggregated update (leaves without the W dim) — mathematically
    Σ_w weights_w · u_w, computed through the two-stage topology so the
    compiled collective schedule matches the paper's architecture.
    """
    C = fed.num_clusters
    w_cl = _cluster_view(weights, C)                        # (C, Wc)
    member_total = jnp.sum(w_cl, axis=1)                    # (C,)
    if cluster_weights is None:
        cluster_weights = member_total                      # unbiased default
    cluster_weights = cluster_weights / jnp.maximum(jnp.sum(cluster_weights), 1e-12)
    # stage-1 normalized weights within each cluster
    w_intra = w_cl / jnp.maximum(member_total, 1e-12)[:, None]

    def agg_leaf(u):
        uc = _cluster_view(u.astype(jnp.float32), C)        # (C, Wc, ...)
        bshape = (C, uc.shape[1]) + (1,) * (uc.ndim - 2)
        head = jnp.sum(uc * w_intra.reshape(bshape), axis=1)      # stage 1
        gshape = (C,) + (1,) * (head.ndim - 1)
        return jnp.sum(head * cluster_weights.reshape(gshape), axis=0)  # stage 2

    return jax.tree.map(agg_leaf, updates)


def aggregate_head_gather(updates, weights, fed: FederationConfig):
    """Paper-faithful stage 1: every member's update is *gathered* at the
    cluster head slot (head = slot 0 after rotation — the caller rolls the
    worker dim so the current head sits at sub-index 0), which performs the
    reduction alone; other slots idle. Compiles to an all-gather + local
    reduce instead of a reduce-scatter/all-reduce. Same value as
    ``aggregate``."""
    C = fed.num_clusters
    w_cl = _cluster_view(weights, C)
    member_total = jnp.sum(w_cl, axis=1)
    cluster_weights = member_total / jnp.maximum(jnp.sum(member_total), 1e-12)
    w_intra = w_cl / jnp.maximum(member_total, 1e-12)[:, None]

    def agg_leaf(u):
        uc = _cluster_view(u.astype(jnp.float32), C)
        Wc = uc.shape[1]
        # head-gather: materialize all member updates "at" the head slot
        gathered = jnp.broadcast_to(uc[:, None], (C, 1) + uc.shape[1:])[:, 0]
        bshape = (C, Wc) + (1,) * (uc.ndim - 2)
        head = jnp.sum(gathered * w_intra.reshape(bshape), axis=1)
        gshape = (C,) + (1,) * (head.ndim - 1)
        return jnp.sum(head * cluster_weights.reshape(gshape), axis=0)

    return jax.tree.map(agg_leaf, updates)


def broadcast_to_workers(agg, W: int):
    """Global model/update redistributed to every worker (heads publish to
    IPFS + workers pull — on mesh, a broadcast along data)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (W,) + x.shape), agg)


def rotate_heads(x, offsets):
    """Head rotation: roll each cluster's member axis so the round's head is
    at sub-index 0. offsets: (C,) ints from on-chain randomness."""
    C = offsets.shape[0]

    def roll_leaf(u):
        uc = _cluster_view(u, C)
        idx = (jnp.arange(uc.shape[1])[None, :] + offsets[:, None]) % uc.shape[1]
        rolled = jnp.take_along_axis(
            uc, idx.reshape(C, uc.shape[1], *([1] * (uc.ndim - 2))), axis=1)
        return rolled.reshape(u.shape)

    return jax.tree.map(roll_leaf, x)
