"""Event-driven asynchronous FL simulator (host level).

Models the paper's §III.E asynchronous functionality faithfully: workers
have heterogeneous speeds, random delays, and failure probability; updates
arrive whenever a worker finishes, and the aggregator folds them in without
waiting for a synchronization barrier.

This module is the *arrival frontier* of the event-driven node
(``core.node.ChainNode.run_events``): each ``FederatedTask`` owns one
``AsyncScheduler`` (its per-task clock), and the node repeatedly pops the
task whose next aggregation event is earliest in simulated time, runs one
staleness-weighted round for that task's arrived cohort, and seals the
cohort on-chain (arrival frontier → staleness-weighted aggregate → cohort
seal). Determinism contract:

- heap ties break on ``(time, round, worker_id)`` — a worker's *earlier*
  local round always lands before any same-instant later round, and worker
  id orders within a round — so event traces are reproducible run-to-run;
- each scheduler draws from a per-task sub-RNG seeded from
  ``(seed, sha256(task_id))``, so co-tenant tasks on one node have
  independent but reproducible arrival streams regardless of the order the
  node interleaves them.

``next_aggregation()`` yields (time, participation mask, staleness
snapshot) per aggregation tick; ``advance_until(t)`` folds every arrival up
to an externally-chosen instant into the pending buffer without
aggregating. The jit path (``async_agg``) consumes the masks this simulator
produces; ``arrival_times()`` exposes per-update arrival instants so
benchmarks can measure settlement latency (seal time − arrival time) per
update rather than per round.
"""
from __future__ import annotations

import hashlib
import heapq
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class WorkerProfile:
    speed: float              # mean seconds per local training round
    jitter: float = 0.2       # lognormal sigma on the duration
    failure_prob: float = 0.0  # chance a round's update is lost entirely


def _task_key(task_id: str) -> int:
    """Stable 64-bit integer key for a task id (independent of PYTHONHASHSEED)."""
    return int.from_bytes(hashlib.sha256(task_id.encode()).digest()[:8], "big")


class AsyncScheduler:
    """Simulates arrival times; yields (time, participation mask, staleness
    snapshot) per aggregation tick.

    Arrivals accumulate in a pending buffer (at most one counted arrival per
    worker per tick — a worker finishing twice inside one window just
    refreshes nothing and keeps training). ``next_aggregation`` drains the
    event heap until the buffer holds ``buffer_size`` distinct updates or
    ``max_wait`` simulated seconds pass, then flushes the buffer as one
    aggregation event.
    """

    def __init__(self, profiles: List[WorkerProfile], *, seed: int = 0,
                 buffer_size: int = 8, max_wait: float = float("inf"),
                 task_id: Optional[str] = None) -> None:
        self.profiles = profiles
        self.task_id = task_id
        # per-task sub-RNG: co-tenant tasks sharing one node seed still get
        # independent, reproducible arrival streams
        self.rng = (np.random.default_rng(seed) if task_id is None
                    else np.random.default_rng((seed, _task_key(task_id))))
        self.buffer_size = buffer_size
        self.max_wait = max_wait
        self.now = 0.0
        # heap entries are (time, round, worker): ties resolve round-first
        # then worker id, so traces are deterministic run-to-run
        self._heap: List[Tuple[float, int, int]] = []
        W = len(profiles)
        self._pending = np.zeros(W, bool)
        self._pending_count = 0
        self._arrival_time = np.full(W, np.nan)
        self.last_arrival_times = np.full(W, np.nan)
        self.staleness = np.zeros(W, np.int64)
        self.agg_round = 0
        for w in range(W):
            self._schedule(w, 0)

    def _schedule(self, w: int, rnd: int) -> None:
        prof = self.profiles[w]
        dur = prof.speed * float(self.rng.lognormal(0.0, prof.jitter))
        heapq.heappush(self._heap, (self.now + dur, rnd, w))

    def _pop_arrival(self) -> None:
        """Pop the earliest arrival, apply the loss draw, fold into pending."""
        t, rnd, w = heapq.heappop(self._heap)
        self.now = t
        lost = self.rng.random() < self.profiles[w].failure_prob
        if not lost and not self._pending[w]:
            self._pending[w] = True
            self._arrival_time[w] = t
            self._pending_count += 1
        # the worker starts its next local round immediately
        self._schedule(w, rnd + 1)

    def advance_until(self, deadline: float) -> int:
        """Advance the clock to ``deadline`` (finite), folding every arrival
        with time <= deadline into the pending buffer without aggregating.
        Returns the pending-update count."""
        if not np.isfinite(deadline):
            raise ValueError("advance_until needs a finite deadline")
        while self._heap and self._heap[0][0] <= deadline:
            self._pop_arrival()
        self.now = max(self.now, deadline)
        return self._pending_count

    def next_aggregation(self) -> Tuple[float, np.ndarray, np.ndarray]:
        """Advance until ``buffer_size`` updates are pending (or max_wait
        passes), then flush the buffer as one aggregation event.
        Returns (time, participation mask (W,), staleness snapshot (W,))."""
        W = len(self.profiles)
        deadline = self.now + self.max_wait
        # at most W distinct arrivals exist per tick: a buffer_size > W with
        # infinite max_wait would otherwise spin forever (heap never drains —
        # every pop reschedules the worker)
        need = min(self.buffer_size, W)
        while self._pending_count < need and self._heap:
            if self._heap[0][0] > deadline:
                break
            self._pop_arrival()
        if self._pending_count < need and np.isfinite(deadline):
            # max_wait elapsed before the buffer filled: the aggregator
            # waited the full window, so the clock advances to the deadline
            self.now = max(self.now, deadline)
        mask = self._pending.astype(np.int64)
        self.last_arrival_times = np.where(self._pending, self._arrival_time,
                                           np.nan)
        snap = self.staleness.copy()
        self.staleness = np.where(mask > 0, 0, self.staleness + 1)
        self.agg_round += 1
        self._pending[:] = False
        self._pending_count = 0
        self._arrival_time[:] = np.nan
        return self.now, mask, snap

    def arrival_times(self) -> np.ndarray:
        """Per-worker arrival instant of the update included in the *last*
        aggregation event (NaN for workers not in the cohort)."""
        return self.last_arrival_times

    def sync_round_time(self) -> float:
        """For comparison: a synchronous round waits for the *slowest*
        worker (expected duration)."""
        durs = [p.speed * float(self.rng.lognormal(0.0, p.jitter))
                for p in self.profiles]
        return max(durs)


def heterogeneous_profiles(W: int, *, straggler_frac: float = 0.25,
                           straggler_slowdown: float = 4.0,
                           base_speed: float = 1.0, failure_prob: float = 0.0,
                           seed: int = 0) -> List[WorkerProfile]:
    rng = np.random.default_rng(seed)
    profiles = []
    n_strag = int(round(W * straggler_frac))
    slow = set(rng.choice(W, size=n_strag, replace=False).tolist())
    for w in range(W):
        s = base_speed * (straggler_slowdown if w in slow else 1.0)
        profiles.append(WorkerProfile(speed=s * float(rng.uniform(0.8, 1.2)),
                                      failure_prob=failure_prob))
    return profiles


def heavy_tailed_profiles(W: int, *, shape: float = 1.5,
                          base_speed: float = 1.0, jitter: float = 0.3,
                          failure_prob: float = 0.0,
                          seed: int = 0) -> List[WorkerProfile]:
    """Pareto(shape) heavy-tailed worker speeds plus dropout: most workers
    run near ``base_speed``, a long tail runs arbitrarily slower — the churn
    regime where a sync barrier's round time is dominated by the tail."""
    rng = np.random.default_rng(seed)
    slowdown = 1.0 + rng.pareto(shape, size=W)
    return [WorkerProfile(speed=base_speed * float(s), jitter=jitter,
                          failure_prob=failure_prob) for s in slowdown]
