"""Event-driven asynchronous FL simulator (host level).

Models the paper's §III.E asynchronous functionality faithfully: workers
have heterogeneous speeds, random delays, and failure probability; updates
arrive whenever a worker finishes, and the aggregator folds them in without
waiting for a synchronization barrier. Used by tests/benchmarks to compare
sync vs async wall-clock and straggler resilience; the jit path
(``async_agg``) consumes the per-round participation masks this simulator
produces.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass
class WorkerProfile:
    speed: float              # mean seconds per local training round
    jitter: float = 0.2       # lognormal sigma on the duration
    failure_prob: float = 0.0  # chance a round's update is lost entirely


class AsyncScheduler:
    """Simulates arrival times; yields (time, participation mask) per
    aggregation tick."""

    def __init__(self, profiles: List[WorkerProfile], *, seed: int = 0,
                 buffer_size: int = 8, max_wait: float = float("inf")) -> None:
        self.profiles = profiles
        self.rng = np.random.default_rng(seed)
        self.buffer_size = buffer_size
        self.max_wait = max_wait
        self.now = 0.0
        self._heap: List[Tuple[float, int, int]] = []
        self.staleness = np.zeros(len(profiles), np.int64)
        self.agg_round = 0
        for w in range(len(profiles)):
            self._schedule(w, 0)

    def _schedule(self, w: int, rnd: int) -> None:
        prof = self.profiles[w]
        dur = prof.speed * float(self.rng.lognormal(0.0, prof.jitter))
        heapq.heappush(self._heap, (self.now + dur, w, rnd))

    def next_aggregation(self) -> Tuple[float, np.ndarray, np.ndarray]:
        """Advance until ``buffer_size`` updates arrive (or max_wait passes).
        Returns (time, participation mask (W,), staleness snapshot (W,))."""
        W = len(self.profiles)
        mask = np.zeros(W, np.int64)
        deadline = self.now + self.max_wait
        arrived = 0
        # at most W distinct arrivals exist per tick: a buffer_size > W with
        # infinite max_wait would otherwise spin forever (heap never drains —
        # every pop reschedules the worker)
        need = min(self.buffer_size, W)
        while arrived < need and self._heap:
            t, w, rnd = self._heap[0]
            if t > deadline:
                break
            heapq.heappop(self._heap)
            self.now = t
            lost = self.rng.random() < self.profiles[w].failure_prob
            if not lost and not mask[w]:
                mask[w] = 1
                arrived += 1
            # the worker starts its next local round immediately
            self._schedule(w, rnd + 1)
        if arrived < need and np.isfinite(deadline):
            # max_wait elapsed before the buffer filled: the aggregator
            # waited the full window, so the clock advances to the deadline
            self.now = max(self.now, deadline)
        snap = self.staleness.copy()
        self.staleness = np.where(mask > 0, 0, self.staleness + 1)
        self.agg_round += 1
        return self.now, mask, snap

    def sync_round_time(self) -> float:
        """For comparison: a synchronous round waits for the *slowest*
        worker (expected duration)."""
        durs = [p.speed * float(self.rng.lognormal(0.0, p.jitter))
                for p in self.profiles]
        return max(durs)


def heterogeneous_profiles(W: int, *, straggler_frac: float = 0.25,
                           straggler_slowdown: float = 4.0,
                           base_speed: float = 1.0, failure_prob: float = 0.0,
                           seed: int = 0) -> List[WorkerProfile]:
    rng = np.random.default_rng(seed)
    profiles = []
    n_strag = int(round(W * straggler_frac))
    slow = set(rng.choice(W, size=n_strag, replace=False).tolist())
    for w in range(W):
        s = base_speed * (straggler_slowdown if w in slow else 1.0)
        profiles.append(WorkerProfile(speed=s * float(rng.uniform(0.8, 1.2)),
                                      failure_prob=failure_prob))
    return profiles
