"""Client-selection strategies (paper §II: "careful planning, fine-tuning of
communication protocols, client selection strategies, and trust mechanisms
become crucial").

Selects the per-round participation mask consumed by ``fl_step``/
``async_agg``. All strategies are deterministic given (seed, round)."""
from __future__ import annotations


import numpy as np

from repro.core.reputation import ReputationBook


def select_random(W: int, k: int, *, seed: int, round_index: int) -> np.ndarray:
    rng = np.random.default_rng(seed * 1_000_003 + round_index)
    mask = np.zeros(W, np.int64)
    mask[rng.choice(W, size=min(k, W), replace=False)] = 1
    return mask


def select_by_reputation(book: ReputationBook, k: int, *, seed: int,
                         round_index: int, explore: float = 0.1) -> np.ndarray:
    """Top-reputation selection with ε-greedy exploration so new/penalized
    workers can rebuild reputation (avoids starvation)."""
    W = len(book.scores)
    rng = np.random.default_rng(seed * 7_368_787 + round_index)
    k = min(k, W)
    n_explore = (max(1, int(round(k * explore)))
                 if explore > 0 and k < W else 0)
    ranked = np.argsort(-book.scores)
    chosen = list(ranked[: k - n_explore])
    rest = [w for w in range(W) if w not in chosen]
    if n_explore and rest:
        chosen += list(rng.choice(rest, size=min(n_explore, len(rest)),
                                  replace=False))
    mask = np.zeros(W, np.int64)
    mask[chosen] = 1
    return mask


def select_per_cluster(W: int, num_clusters: int, k_per_cluster: int, *,
                       seed: int, round_index: int) -> np.ndarray:
    """Balanced selection: k workers from every cluster (keeps the two-level
    aggregation well-conditioned — no empty cluster heads)."""
    wpc = W // num_clusters
    rng = np.random.default_rng(seed * 97 + round_index)
    mask = np.zeros(W, np.int64)
    for c in range(num_clusters):
        pick = rng.choice(wpc, size=min(k_per_cluster, wpc), replace=False)
        mask[c * wpc + pick] = 1
    return mask
