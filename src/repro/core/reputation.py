"""Reputation: persistent trust across rounds + reputation-aware leader
selection (the paper's §VI.E future-work item: "leaders chosen at random
might be bad workers and affect the performance of the model by pushing bad
weights").

ReputationBook keeps an EMA of per-worker scores plus the on-chain penalty
history; ``leader_weights`` turns that into a sampling distribution for
cluster-head election so low-reputation workers rarely lead — while keeping
rotation stochastic (on-chain randomness) so no worker dominates (paper
§III.A requirement).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


class ReputationBook:
    def __init__(self, num_workers: int, *, ema: float = 0.8,
                 prior: float = 0.5) -> None:
        self.ema = ema
        self.scores = np.full(num_workers, prior, np.float64)
        self.penalties = np.zeros(num_workers, np.int64)
        self.rounds = 0

    def update(self, round_scores: Sequence[float],
               penalized: Sequence[int] = ()) -> None:
        """Vectorized: ``penalized`` is either a (W,) boolean mask or an
        array/sequence of penalized worker indices — no Python loop."""
        s = np.asarray(round_scores, np.float64)
        self.scores = self.ema * self.scores + (1 - self.ema) * s
        p = np.asarray(penalized)
        if p.size:
            if p.dtype == bool:
                self.penalties += p
            else:
                np.add.at(self.penalties, p.astype(np.int64), 1)
        self.rounds += 1

    def leader_weights(self, members: Sequence[int],
                       *, floor: float = 0.05) -> np.ndarray:
        """Sampling weights over a cluster's members: reputation discounted
        by penalty history, floored so rotation never fully excludes anyone
        (the paper's dynamism requirement)."""
        rep = self.scores[list(members)]
        pen = self.penalties[list(members)]
        w = np.maximum(rep / (1.0 + pen), floor)
        return w / w.sum()

    def elect(self, members: Sequence[int], rng_seed: int) -> int:
        """Deterministic reputation-weighted election from on-chain
        randomness — every node derives the same leader."""
        rng = np.random.default_rng(rng_seed)
        return int(rng.choice(len(members), p=self.leader_weights(members)))


def reputation_cluster_weights(book: ReputationBook, num_clusters: int,
                               workers_per_cluster: int) -> np.ndarray:
    """(C,) cluster weights for the head↔head stage: clusters led/populated
    by reputable workers carry more weight (paper §VI.B fairness)."""
    rep = book.scores.reshape(num_clusters, workers_per_cluster)
    w = rep.mean(axis=1)
    return w / w.sum()
