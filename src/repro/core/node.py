"""ChainNode — a multi-tenant chain node serving N concurrent federated
tasks on one ledger with fair cross-task settlement.

The paper's SDFL-B design treats the blockchain layer as shared
infrastructure: many collaborative learning tasks settle on the same
chain. This module is that substrate, split into two layers:

``ChainNode`` owns the chain-side singletons — the ``Ledger``, the
``IPFSStore``, one shared ``ShardWorkerPool`` of shard-hashing threads,
and the cross-task settlement scheduler (``_SettlerPool``). A per-task
``FederatedTask`` handle owns everything task-scoped: model/optimizer
state, the jitted round function, its ``TrustContract`` (deployed on the
node's ledger under its ``task_id``), reputation, cluster exchange, and
round history. ``repro.core.protocol.SDFLBProtocol`` is a thin one-task
compatibility wrapper over a private node.

Ticks and blocks. The node is driven in *ticks*: ``run_tick(batches)``
runs one round for every task that fires this tick (tasks may run at
independent, asynchronous cadences — simply omit a task from a tick), and
all rounds of one tick settle into ONE block committing the canonical
``task_id → super-root`` map (``MultiTaskCommit`` in ``chain.ledger``).
Settlement proofs are three-level — chunk-in-shard, shard-in-task,
task-in-block — and ``verify_chain(deep=True)`` recurses through tasks.
A tick in which a single task fires seals a bit-identical block to the
single-tenant driver (no ``task_roots`` in the hashed body, no ``task``
tag on transactions), so an N=1 node reproduces the PR-3 sharded driver's
chain byte for byte (property-tested).

Fairness and determinism. Within a tick, tasks are processed in canonical
(sorted ``task_id``) order and their contract-shard thunks are interleaved
round-robin — shard 0 of every task, then shard 1, … — through the shared
pool, so no task's settlement starves behind a bigger co-tenant. Ticks
drain FIFO through a bounded queue (``pipeline_depth``), so every
submitted round settles within its tick: ordering is seed-reproducible
and starvation-free by construction. Each task's round-r head rotation
consumes the head of the block that settled *its own* round r−1
(published per (task, round) by the scheduler), never the racy live
chain head.

Failure isolation. A failing shard aborts only its own task's round:
shard thunks are pure, so the failing task's state and commit are simply
excluded from the tick's block while co-tenant tasks settle normally.
The failure is sticky *per task* — the task's later queued rounds are
drained and discarded, and every subsequent interaction with that task
raises a ``TaskSettlementError`` carrying the failing ``task_id`` and
round index. Only a failure of the shared block seal itself (after every
surviving task's merge) poisons the whole node.

Event-driven settlement (the paper's §III.E async pillar, first-class).
``run_events`` replaces the lockstep tick cadence with an *arrival
frontier*: each async task owns an ``async_sim.AsyncScheduler`` (its
per-task simulated clock — heavy-tailed speeds, jitter, dropout), and the
node repeatedly pops the task whose next aggregation event is earliest in
simulated time, then runs ONE round for THAT task only: arrival frontier →
staleness-weighted aggregate → cohort seal. The arrived cohort is the
round's participation mask, the jitted round weights it by trust ×
``(1+staleness)^-alpha`` (``core.async_agg``), and settlement seals
exactly that cohort — under ``sparse_settlement`` as a PR-6 ``DeltaCommit``
whose changed set is the cohort, so idle workers stay proof-covered while
the seal costs O(cohort), not O(W). Each worker's pre-round staleness is
mirrored host-side (``FederatedTask.staleness``, kept in lockstep with the
device ``AsyncState``) and recorded in the on-chain settlement records, so
staleness-discounted penalties and payouts are auditable. Slow tasks never
stall fast ones: a straggling co-tenant simply has later event times, and
every event seals independently through the same settler pipeline as
``run_tick``. The degenerate case — every worker arrives every event,
staleness identically 0 — is bit-identical to driving ``run_tick`` with
full participation (property-tested: block hashes, penalties, payouts,
elections).
"""
from __future__ import annotations

import heapq
import os
import queue
import threading
import time
import weakref
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.chain.contract import RoundPrep, ShardSettlement, TrustContract
from repro.chain.ipfs import IPFSStore
from repro.chain.ledger import Ledger
from repro.configs.base import FederationConfig, ModelConfig, TrainConfig
from repro.core import async_agg, fl_step
from repro.core.async_sim import AsyncScheduler, WorkerProfile
from repro.core.gossip import ClusterExchange
from repro.core.reputation import ReputationBook
from repro.models import api


class TaskSettlementError(RuntimeError):
    """One task's round failed to settle. Carries the failing ``task_id``
    and ``round_index``; co-tenant tasks on the same node are unaffected
    (their rounds keep settling), while this task's later rounds are
    discarded and every further interaction with it re-raises."""

    def __init__(self, task_id: str, round_index: int,
                 note: str = "background chain settlement failed") -> None:
        super().__init__(
            f"task {task_id!r} round {round_index}: {note}; the task's "
            f"settler lane has stopped (its unsettled rounds were "
            f"discarded)")
        self.task_id = task_id
        self.round_index = round_index


@dataclass
class RoundRecord:
    round_index: int
    scores: np.ndarray
    weights: np.ndarray
    losses: np.ndarray
    penalties: np.ndarray          # (W,) settlement penalties; zeros until
                                   # the round is settled (pipelined driver)
    heads: List[int]
    model_cid: str                 # "" until settled
    wall_time: float
    chain_time: float              # chain work charged to the training
                                   # thread during this tick (threaded
                                   # settler: the queue handoff only)
    participation: Optional[np.ndarray] = None
    staleness: Optional[np.ndarray] = None  # (W,) pre-round staleness of each
                                   # worker's update (event-driven rounds;
                                   # None on sync rounds) — what the
                                   # settlement records commit on-chain
    sim_time: float = 0.0          # simulated event time this round sealed
                                   # at (run_events; 0.0 under run_tick)
    arrival_times: Optional[np.ndarray] = None  # (W,) simulated arrival
                                   # instant of each cohort update (NaN off
                                   # the cohort); sim_time - arrival_times
                                   # is per-update settlement latency
    settled: bool = False
    settle_time: float = 0.0       # host chain work on the settler thread
                                   # (contract + Merkle + IPFS); set when
                                   # the round settles


@dataclass
class _PendingRound:
    record: RoundRecord
    params: Any                    # round's resulting global params (device);
                                   # None when running without a chain
    scores: np.ndarray


@dataclass
class _TickPending:
    """One tick's worth of rounds awaiting settlement: the unit the
    scheduler queues, settles, and seals into one block."""
    tick: int
    entries: List[Tuple[str, _PendingRound]]   # (task_id, pending), sorted


@dataclass
class _StartedRound:
    """A dispatched-but-unfinished round: the device is computing, the
    host has not yet rotated heads or synced scores."""
    round_index: int
    out: Any
    t0: float
    participation: Optional[np.ndarray]
    staleness: Optional[np.ndarray] = None   # pre-round host staleness mirror


class ShardWorkerPool:
    """N shard-worker threads, each draining its own task queue.

    ``map`` fans one batch of shard thunks out — thunk i always lands on
    queue i mod N, so with the node's round-robin interleave consecutive
    thunks (= different tasks' shards) spread across workers and a given
    slot stays FIFO across rounds — and blocks at the merge barrier until
    every thunk finished, then re-raises the lowest-index failure
    (deterministic, whichever thread hit it first). ``map_collect``
    returns per-thunk ``("ok", value)`` / ``("err", exc)`` outcomes
    instead of raising, which is what lets a multi-task node fail one
    task's shards without discarding its co-tenants' results. Thunks must
    be pure compute (the contract's ``settle_shard`` mutates nothing), so
    dropping a failed task's sibling results is safe.

    Workers hold only a weak reference to the pool and wake periodically
    while idle, so an abandoned (never-finalized) node's shard threads
    exit instead of living for the rest of the process."""

    _IDLE_POLL_S = 2.0

    def __init__(self, num_threads: int) -> None:
        self.num_threads = max(1, int(num_threads))
        self._queues: List["queue.Queue"] = [queue.Queue()
                                             for _ in range(self.num_threads)]
        self._stopped = False
        ref = weakref.ref(self)
        self._threads = [
            threading.Thread(target=self._work, args=(q, ref), daemon=True,
                             name=f"sdflb-shard-worker-{i}")
            for i, q in enumerate(self._queues)]
        for t in self._threads:
            t.start()

    @staticmethod
    def _work(q: "queue.Queue", pool_ref: "weakref.ref") -> None:
        while True:
            try:
                item = q.get(timeout=ShardWorkerPool._IDLE_POLL_S)
            except queue.Empty:
                if pool_ref() is None:         # owner got collected
                    return
                continue
            if item is None:                   # stop sentinel
                return
            fn, i, out, cv, remaining = item
            try:
                out[i] = ("ok", fn())
            except BaseException as e:
                out[i] = ("err", e)
            finally:
                del fn, item                   # don't pin results while idle
                with cv:
                    remaining[0] -= 1
                    cv.notify_all()

    def start_collect(self, thunks):
        """Enqueue ``thunks[i]`` on worker i mod N and return immediately
        with a handle for ``finish_collect`` — lets the caller overlap its
        own work with the pool's."""
        if self._stopped:
            raise RuntimeError("shard pool already stopped")
        thunks = list(thunks)
        out: list = [None] * len(thunks)
        cv = threading.Condition()
        remaining = [len(thunks)]
        for i, fn in enumerate(thunks):
            self._queues[i % self.num_threads].put((fn, i, out, cv,
                                                    remaining))
        return out, cv, remaining

    @staticmethod
    def finish_collect(handle) -> list:
        """Block at the merge barrier of a ``start_collect`` handle; return
        the in-order list of per-thunk outcomes ``("ok", value)`` /
        ``("err", exception)`` (never raises for a thunk failure)."""
        out, cv, remaining = handle
        with cv:
            cv.wait_for(lambda: remaining[0] == 0)
        return out

    def map_collect(self, thunks) -> list:
        """``start_collect`` + ``finish_collect`` in one call."""
        return self.finish_collect(self.start_collect(thunks))

    def map(self, thunks) -> list:
        """Like ``map_collect`` but returns the bare results, raising the
        first (by index) failure after all thunks finished."""
        out = self.map_collect(thunks)
        for tag, val in out:
            if tag == "err":
                raise val
        return [val for _, val in out]

    def stop(self) -> None:
        """Terminate the workers (idempotent); outstanding queue items run
        first since the sentinel sits behind them."""
        if self._stopped:
            return
        self._stopped = True
        for q in self._queues:
            q.put(None)
        for t in self._threads:
            t.join()


# -- cross-task block settlement ----------------------------------------------


@dataclass
class TaskRoundWork:
    """One task's round as handed to ``settle_tasks_block``: the contract,
    the validated score vector, and the (already published) model cid."""
    task_id: str
    contract: TrustContract
    round_index: int
    scores: np.ndarray
    model_cid: str = ""
    worker_ids: Optional[np.ndarray] = None
    staleness: Optional[np.ndarray] = None   # aligned with scores


def _interleave_shard_thunks(task_order: List[str],
                             preps: Dict[str, RoundPrep]
                             ) -> List[Tuple[str, int, Callable]]:
    """Round-robin schedule across tasks: shard 0 of every task (in
    canonical task order), then shard 1, … — the fairness rule that keeps
    a small task's settlement from starving behind a big co-tenant."""
    sched: List[Tuple[str, int, Callable]] = []
    depth = 0
    while True:
        layer = [(tid, depth, preps[tid].thunks[depth])
                 for tid in task_order if depth < len(preps[tid].thunks)]
        if not layer:
            return sched
        sched.extend(layer)
        depth += 1


def settle_tasks_block(ledger: Ledger, work: List[TaskRoundWork],
                       timestamp: Optional[float] = None,
                       pool: Optional[ShardWorkerPool] = None
                       ) -> Tuple[Optional[Any], Dict[str, np.ndarray],
                                  Dict[str, BaseException]]:
    """Settle several tasks' rounds into ONE multi-task block.

    Per task: prepare (validation + pure shard thunks) → shard fan-out →
    deterministic merge → one shared block seal committing every surviving
    task's super-root under the canonical ``task_id → super-root`` map.
    Shard thunks of tasks whose leaves clear the contract's GIL gate are
    interleaved round-robin through the shared ``pool`` (deterministic
    results either way — the pool only changes who hashes); the rest run
    inline on the calling thread.

    Shard re-planning: the node owns the fan-out budget. When N tasks
    share the pool, each pooled task's shard count is re-planned to
    ``min(its settlement_shards, ceil(2·pool_threads / N))`` so the total
    thunk count stays matched to the pool — cross-task parallelism
    replaces within-task parallelism as N grows, instead of N·S micro
    thunks convoying on the GIL. This is consensus-invisible: shard
    boundaries are subtree-aligned, so the committed super-roots, proofs,
    and block hashes are identical for every execution granularity
    (property-tested).

    Failure isolation: a task failing in prepare or in any of its shard
    thunks is excluded from the block with *nothing* of it applied or
    committed (shard thunks are pure; its merge never runs), while the
    surviving tasks settle normally. Returns ``(block, penalties_by_task,
    errors_by_task)`` — ``block`` is None when no task survived. With one
    task in ``work`` the sealed block is bit-identical to that task's
    ``settle_round_batch``. Only a failure of the shared seal itself
    raises (node-fatal)."""
    work = sorted(work, key=lambda w: w.task_id)
    if len({w.task_id for w in work}) != len(work):
        raise ValueError("duplicate task_id in one settlement block")
    errors: Dict[str, BaseException] = {}
    preps: Dict[str, RoundPrep] = {}
    results: Dict[str, List[ShardSettlement]] = {}
    pooled: List[str] = []
    inline: List[str] = []
    # fan-out budget: tasks that want the pool split ~2 thunks per worker
    # thread between them (consensus-invisible — see the docstring)
    pool_wanting = [w.task_id for w in work
                    if pool is not None
                    and w.contract.settlement_shards > 1
                    and w.contract.parallel_leaf_ok()]
    eff_shards: Dict[str, int] = {}
    if pool_wanting:
        per = max(1, -(-2 * pool.num_threads // len(pool_wanting)))
        for w in work:
            if w.task_id in pool_wanting:
                eff_shards[w.task_id] = min(w.contract.settlement_shards,
                                            per)
    for w in work:
        try:
            preps[w.task_id] = w.contract.prepare_round_batch(
                w.round_index, w.scores, w.worker_ids,
                shards=eff_shards.get(w.task_id),
                staleness=w.staleness)
        except BaseException as e:
            errors[w.task_id] = e
            continue
        if w.task_id in eff_shards:
            pooled.append(w.task_id)   # even a 1-thunk task: parallel
        else:                          # ACROSS tasks through the pool
            inline.append(w.task_id)

    # enqueue the pooled fan-out first, run the inline tasks' thunks on
    # the calling thread while the workers hash, then collect at the merge
    # barrier: tick latency is max(pool, inline), not their sum
    sched = _interleave_shard_thunks(pooled, preps) if pooled else []
    handle = pool.start_collect([t for _, _, t in sched]) if sched else None
    for tid in inline:
        try:
            results[tid] = [t() for t in preps[tid].thunks]
        except BaseException as e:
            errors[tid] = e
    if handle is not None:
        out = pool.finish_collect(handle)
        shard_res: Dict[str, List[Optional[ShardSettlement]]] = {
            tid: [None] * len(preps[tid].thunks) for tid in pooled}
        shard_err: Dict[str, Tuple[int, BaseException]] = {}
        for (tid, i, _), (tag, val) in zip(sched, out):
            if tag == "ok":
                shard_res[tid][i] = val
            elif tid not in shard_err or i < shard_err[tid][0]:
                shard_err[tid] = (i, val)      # lowest-shard-index failure
        for tid in pooled:
            if tid in shard_err:
                errors[tid] = shard_err[tid][1]
            else:
                results[tid] = shard_res[tid]

    survivors = [w for w in work if w.task_id in results]
    penalties: Dict[str, np.ndarray] = {}
    seals = {}
    for w in survivors:
        seal = w.contract.finish_round_batch(
            preps[w.task_id], results[w.task_id], model_cid=w.model_cid)
        seals[w.task_id] = seal
        penalties[w.task_id] = seal.penalties
    if not seals:
        return None, penalties, errors
    if len(seals) == 1:
        # single-task tick: the exact single-tenant block layout (no task
        # tags, no task_roots map) — bit-identical to settle_round_batch
        (tid, seal), = seals.items()
        blk = ledger.append_block(
            seal.txs, timestamp=timestamp,
            record_shards=seal.shards or None,
            shard_trees=seal.trees or None,
            record_delta=seal.delta,
            chunk_size=seal.chunk_size, task_id=tid)
    else:
        txs = [{**tx, "task": tid}
               for tid, seal in seals.items() for tx in seal.txs]
        # a sparse task contributes its prebuilt incremental commit;
        # dense co-tenants build theirs from the shard parts as before
        commits = {tid: seal.delta if seal.delta is not None
                   else Ledger._build_commit(None, seal.shards or None,
                                             seal.trees or None,
                                             seal.chunk_size)
                   for tid, seal in seals.items()}
        blk = ledger.append_multi_block(txs, timestamp, commits)
    # O(1) integrity check of the block just sealed (linkage + recomputed
    # hash) — a full verify_chain here would be O(R^2) over a run
    if blk.prev_hash != ledger.blocks[blk.index - 1].hash \
            or blk.hash != blk.compute_hash():
        raise RuntimeError(f"block {blk.index} failed verification "
                           f"after sealing tick settlement")
    for w in survivors:
        w.contract.note_block(w.round_index, preps[w.task_id].ids, blk.index)
    return blk, penalties, errors


# -- the cross-task settlement scheduler --------------------------------------


_FATAL_NOTE = ("chain node settlement failed; the settler has stopped "
               "(unsettled rounds were discarded)")


class _SettlerPool:
    """Background cross-task settlement scheduler: a coordinator daemon
    thread consuming a bounded FIFO queue of pending *ticks*, settling
    each tick's tasks through ``ChainNode._settle_tick`` (which fans every
    task's contract shards round-robin through the shared
    ``ShardWorkerPool`` and seals one block at the merge barrier), and
    publishing the resulting chain head per (task, round).

    The training thread interacts through ``submit`` (the queue handoff —
    blocks only when ``depth`` ticks are already in flight),
    ``wait_task(task_id, r)`` (returns the head of the block that settled
    that task's round r — the only point the pipeline couples back to
    chain state, because round r+1's on-chain randomness needs it), and
    ``flush``. With ``depth == 0`` there is no thread: ``submit`` settles
    the tick inline on the caller (the serial reference driver).

    Failures are sticky *per task*: a task whose round failed keeps its
    co-tenants settling, but its own later rounds are drained and
    discarded and every interaction with it raises a
    ``TaskSettlementError`` naming the task and the failing round. A
    failure of the shared seal itself (raised out of ``_settle_tick``) is
    node-fatal and poisons every interaction.

    The node is held through a weak reference and the worker wakes
    periodically while idle, so an abandoned (never-closed) node is still
    garbage-collectable and its settler thread exits instead of pinning
    params/ledger for the life of the process."""

    _IDLE_POLL_S = 2.0

    def __init__(self, settle_fn: Callable[["_TickPending"], list],
                 depth: int) -> None:
        # weak: the thread must not keep the owning node alive
        self._settle = weakref.WeakMethod(settle_fn)
        self._threaded = depth > 0
        self._cv = threading.Condition()
        self._submitted_tick = -1
        self._settled_tick = -1
        self._task_settled: Dict[str, int] = {}
        self._task_heads: Dict[str, Dict[int, str]] = {}
        self._task_errors: Dict[str, Tuple[int, BaseException]] = {}
        self._error: Optional[BaseException] = None
        self._stopped = False
        self._thread = None
        if self._threaded:
            self._q: "queue.Queue" = queue.Queue(maxsize=depth)
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="sdflb-settler-coordinator")
            self._thread.start()

    def register_task(self, task_id: str,
                      initial_head: Optional[str]) -> None:
        """Seed a task's head bookkeeping: its round −1 'head' is the chain
        head at registration (genesis on a fresh node) — what round 0's
        rotation consumes."""
        with self._cv:
            self._task_settled[task_id] = -1
            self._task_heads[task_id] = ({-1: initial_head}
                                         if initial_head is not None else {})

    # -- worker side ---------------------------------------------------------

    def _mark_discarded(self, tp: "_TickPending") -> None:
        with self._cv:
            for tid, p in tp.entries:
                self._task_settled[tid] = max(
                    self._task_settled.get(tid, -1), p.record.round_index)
            self._settled_tick = max(self._settled_tick, tp.tick)
            self._cv.notify_all()

    def _apply(self, tick: int, outcomes: list) -> None:
        with self._cv:
            for tid, ridx, head, err in outcomes:
                if err is not None and tid not in self._task_errors:
                    self._task_errors[tid] = (ridx, err)
                self._task_settled[tid] = max(
                    self._task_settled.get(tid, -1), ridx)
                if head is not None:
                    self._task_heads.setdefault(tid, {})[ridx] = head
            self._settled_tick = max(self._settled_tick, tick)
            self._cv.notify_all()

    def _settle_or_poison(self, tp: "_TickPending") -> None:
        """Run one tick through the node's settle, recording per-task
        outcomes; an exception escaping the settle itself is node-fatal."""
        settle = self._settle()
        if settle is None:                     # owner got collected
            self._mark_discarded(tp)
            return
        with self._cv:
            fatal = self._error is not None
        if fatal:
            # after a node-fatal failure drain-and-discard: never commit
            # later ticks on top of a half-settled chain, but keep waking
            # flush()/wait callers
            self._mark_discarded(tp)
            return
        try:
            outcomes = settle(tp)
        except BaseException as e:             # sticky; surfaced on the
            with self._cv:                     # training thread
                self._error = e
            self._mark_discarded(tp)
            return
        self._apply(tp.tick, outcomes)

    def _loop(self) -> None:
        while True:
            try:
                tp = self._q.get(timeout=self._IDLE_POLL_S)
            except queue.Empty:
                if self._settle() is None:     # owner got collected
                    return
                continue
            if tp is None:                     # stop sentinel
                return
            try:
                self._settle_or_poison(tp)
            finally:
                # frame locals survive across iterations — dropping them
                # keeps the idle thread from pinning the node (and settled
                # rounds' params) against garbage collection
                del tp

    # -- training-thread side ------------------------------------------------

    def _check_fatal(self) -> None:
        if self._error is not None:
            raise RuntimeError(_FATAL_NOTE) from self._error

    def _check_task(self, task_id: str) -> None:
        if task_id in self._task_errors:
            ridx, e = self._task_errors[task_id]
            raise TaskSettlementError(task_id, ridx) from e

    def check_task(self, task_id: str) -> None:
        """Raise this task's sticky settlement error (or the node-fatal
        one) if any; no-op for a healthy task."""
        with self._cv:
            self._check_fatal()
            self._check_task(task_id)

    def task_error(self, task_id: str
                   ) -> Optional[Tuple[int, BaseException]]:
        with self._cv:
            return self._task_errors.get(task_id)

    def submit(self, tp: "_TickPending") -> None:
        with self._cv:
            self._check_fatal()
            if self._stopped:
                raise RuntimeError("settler already stopped")
            self._submitted_tick = tp.tick
        if self._threaded:
            self._q.put(tp)                    # bounded: backpressure
        else:
            self._settle_or_poison(tp)         # inline reference driver
            with self._cv:
                fatal = self._error is not None
            if fatal:
                self._check_fatal()

    def wait_task(self, task_id: str, round_index: int) -> Optional[str]:
        """Block until the task's ``round_index`` is settled; return the
        hash of the block that settled it (None when running without a
        ledger)."""
        with self._cv:
            self._cv.wait_for(
                lambda: self._task_settled.get(task_id, -1) >= round_index
                or task_id in self._task_errors or self._error is not None)
            self._check_fatal()
            self._check_task(task_id)
            heads = self._task_heads.setdefault(task_id, {})
            head = heads.get(round_index)
            # prune heads no one can ask for again (heads are consumed in
            # round order; keep the latest two for idempotent re-reads)
            for k in [k for k in heads if k < round_index - 1]:
                del heads[k]
            return head

    def flush(self, check: Optional[str] = "__all__") -> None:
        """Drain the queue: block until everything submitted has settled.
        ``check`` selects which sticky errors re-raise afterwards — a
        task_id for that task only, ``"__all__"`` for any (node-fatal
        always re-raises), None for node-fatal only (the multi-task
        driver's drain: per-task errors stay with their tasks)."""
        with self._cv:
            self._cv.wait_for(lambda: self._settled_tick
                              >= self._submitted_tick
                              or self._error is not None)
            self._check_fatal()
            if check == "__all__":
                if self._task_errors:
                    self._check_task(sorted(self._task_errors)[0])
            elif check is not None:
                self._check_task(check)

    def stop(self) -> None:
        """Drain best-effort (never raises), then terminate the
        coordinator (idempotent)."""
        with self._cv:
            self._cv.wait_for(lambda: self._settled_tick
                              >= self._submitted_tick
                              or self._error is not None)
            if self._stopped:
                return
            self._stopped = True
        if self._thread is not None:
            self._q.put(None)
            self._thread.join()


# -- per-task handle ----------------------------------------------------------


class FederatedTask:
    """One federated learning task on a (possibly multi-tenant)
    ``ChainNode``: model + optimizer state, the jitted round function, a
    ``TrustContract`` deployed on the node's ledger under this
    ``task_id``, reputation, cluster exchange, and round history. Create
    through ``ChainNode.create_task``; drive through
    ``ChainNode.run_tick``."""

    def __init__(self, node: "ChainNode", task_id: str, cfg: ModelConfig,
                 fed: FederationConfig, tc: TrainConfig, *, seed: int = 0,
                 adversary: Optional[Callable] = None,
                 reputation_leaders: bool = False,
                 profiles: Optional[List[WorkerProfile]] = None) -> None:
        self.node = node
        self.task_id = task_id
        self.cfg, self.fed, self.tc = cfg, fed, tc
        self.use_blockchain = node.use_blockchain
        self.W = fl_step.num_workers(fed)
        self.rng = jax.random.PRNGKey(seed)
        self.np_rng = np.random.default_rng(seed)
        self.adversary = adversary    # fn(worker_batch dict, round) -> batch

        key, self.rng = jax.random.split(self.rng)
        self.global_params, _ = api.init(cfg, key, tp=1)
        self.opt_state = fl_step.init_worker_opt(self.global_params, fed, tc)
        self._round_fn = jax.jit(fl_step.make_fl_round(cfg, fed, tc))
        # eval fns jitted once here (re-wrapping jax.jit per call would
        # recompile on every invocation)
        loss_fn = api.loss_fn(cfg)
        self._eval_fn = jax.jit(loss_fn)
        self._eval_per_worker_fn = jax.jit(
            jax.vmap(lambda p, b: loss_fn(p, b)[1], in_axes=(None, 0)))

        self.async_state = None
        self.scheduler = None
        # event-driven state: this task's arrival frontier (its per-task
        # simulated clock) and the host-side mirror of the device
        # AsyncState's staleness — the pre-round snapshot the settlement
        # records commit on-chain without a device sync
        self.arrival: Optional[AsyncScheduler] = None
        self.staleness: Optional[np.ndarray] = None
        if fed.async_mode:
            # pending-buffer layout must match the path make_fl_round takes
            # (flat (W_pad, D_pad) matrix on the fused path, pytree otherwise)
            self.async_state = fl_step.init_async_state_for(
                cfg, fed, self.global_params, self.W)
            self.staleness = np.zeros(self.W, np.int64)
            if profiles is not None:
                if len(profiles) != self.W:
                    raise ValueError(
                        f"{len(profiles)} arrival profiles for {self.W} "
                        f"workers")
                self.arrival = AsyncScheduler(
                    profiles, seed=seed, task_id=task_id,
                    buffer_size=fed.buffer_size, max_wait=fed.max_wait)
        elif profiles is not None:
            raise ValueError("arrival profiles need fed.async_mode=True")

        self.contract: Optional[TrustContract] = None
        self.exchange: Optional[ClusterExchange] = None
        if node.use_blockchain:
            self.contract = TrustContract(
                node.ledger, requester_deposit=fed.requester_deposit,
                worker_stake=fed.worker_stake, penalty_pct=fed.penalty_pct,
                trust_threshold=fed.trust_threshold, top_k=fed.top_k_rewarded,
                merkle_chunk_size=fed.merkle_chunk_size,
                settlement_shards=fed.settlement_shards,
                sparse_settlement=fed.sparse_settlement,
                sparse_rebase_every=fed.sparse_rebase_every,
                staleness_alpha=(fed.staleness_alpha if fed.async_mode
                                 else 0.0),
                task_id=task_id)
            self.contract.join_batch(self.W)   # integer ids, one batch tx
            self.exchange = ClusterExchange(node.ipfs, node.ledger,
                                            fed.num_clusters)
        self.history: List[RoundRecord] = []
        self.heads = [0] * fed.num_clusters
        # reputation (EMA of scores + penalty history) drives head election
        # when reputation_leaders=True — addresses the paper's §VI.E
        # bad-leader concern while keeping rotation stochastic
        self.reputation = ReputationBook(self.W)
        self.reputation_leaders = reputation_leaders

    # -- chain-side conveniences ---------------------------------------------

    @property
    def ledger(self) -> Optional[Ledger]:
        return self.node.ledger

    @property
    def ipfs(self) -> Optional[IPFSStore]:
        return self.node.ipfs

    @property
    def round_index(self) -> int:
        return len(self.history)

    # -- head rotation from on-chain randomness ------------------------------

    def _rotate_heads(self, round_index: int,
                      head_hash: Optional[str] = None) -> List[int]:
        """``head_hash``: the chain head the rotation must see — the block
        that settled *this task's* round r−1, published per (task, round)
        by the node's scheduler; defaults to the live ledger head (only
        reachable for a task driven outside ``run_tick``)."""
        if self.use_blockchain:
            if head_hash is None:
                head_hash = self.node.ledger.head.hash
            seed = Ledger.randomness_from(head_hash, round_index)
        else:
            seed = (self.fed.head_rotation_seed * 1_000_003 + round_index)
        wpc = self.fed.workers_per_cluster
        if self.reputation_leaders:
            self.heads = [
                self.reputation.elect(range(c * wpc, (c + 1) * wpc),
                                      rng_seed=seed + c)
                for c in range(self.fed.num_clusters)]
        else:
            rng = np.random.default_rng(seed)
            self.heads = [int(rng.integers(0, wpc))
                          for _ in range(self.fed.num_clusters)]
        return self.heads

    # -- one round, split around the tick's settlement handoff ---------------

    def _dispatch_round(self, batch: Dict[str, np.ndarray],
                        participation: Optional[np.ndarray]
                        ) -> _StartedRound:
        """Dispatch this round's jitted step — async, no barrier. batch
        leaves: (W, B, ...) — a single local step per round (paper's
        setup); reshaped to (W, 1, B, ...) for the step function."""
        t0 = time.monotonic()
        ridx = len(self.history)
        batch = {k: jnp.asarray(v)[:, None] for k, v in batch.items()}
        if self.adversary is not None:
            batch = self.adversary(batch, ridx)
        self.rng, rkey = jax.random.split(self.rng)
        part = (None if participation is None
                else jnp.asarray(participation, jnp.int32))
        stale = None
        if self.fed.async_mode:
            if participation is not None:
                # snapshot the pre-round staleness (what the jit round's
                # discount sees) for the settlement records, then age the
                # host mirror by the same rule the device applies
                stale = self.staleness.copy()
                self.staleness = async_agg.host_staleness_update(
                    self.staleness, participation)
            out, self.async_state = self._round_fn(
                self.global_params, self.opt_state, batch, rkey,
                part, self.async_state)
        else:
            out = self._round_fn(self.global_params, self.opt_state, batch,
                                 rkey, part)
        self.global_params, self.opt_state = out.global_params, out.opt_state
        try:                       # start device→host copy of the scores
            out.scores.copy_to_host_async()
        except AttributeError:     # backend without async host copies
            pass
        return _StartedRound(ridx, out, t0, participation, stale)

    def _finish_round(self, st: _StartedRound, chain_time: float
                      ) -> Tuple[RoundRecord, _PendingRound]:
        """Rotate heads for this round and sync its scores. On-chain
        randomness needs the block that settled this task's round r−1 (and
        reputation election its scores), so this is the one point the
        pipeline consumes settled state: block on the scheduler's
        published per-task head. Without chain or reputation election the
        rotation seed is settlement-free and rounds run arbitrarily deep
        into the queue."""
        head_hash = None
        if self.use_blockchain or self.reputation_leaders:
            head_hash = self.node._settler.wait_task(self.task_id,
                                                     st.round_index - 1)
        heads = self._rotate_heads(st.round_index, head_hash)
        # the only training-path sync point: this round's scores
        scores = np.asarray(st.out.scores)
        # the tick's settlement handoff ran between dispatch and here —
        # charge it to chain_time, not the training time
        train_time = time.monotonic() - st.t0 - chain_time
        rec = RoundRecord(
            round_index=st.round_index, scores=scores,
            weights=np.asarray(st.out.weights),
            losses=np.asarray(st.out.losses),
            penalties=np.zeros(self.W, np.float64), heads=heads,
            model_cid="", wall_time=train_time + chain_time,
            chain_time=chain_time,
            participation=None if st.participation is None
            else np.asarray(st.participation),
            staleness=st.staleness)
        # chainless settlement only reads scores — don't pin up to
        # pipeline_depth extra param trees in the queue for nothing
        pending = _PendingRound(
            rec, self.global_params if self.use_blockchain else None, scores)
        self.history.append(rec)
        return rec, pending

    # -- settle-side hooks (run on the scheduler thread) ----------------------

    def _pre_settle(self, p: _PendingRound) -> str:
        """IPFS publication + cross-cluster cid registration for one round
        (paper §III.A): one put of the (identical) global tree; every
        cluster head registers the cid for the hash exchange."""
        ridx = p.record.round_index
        cid = self.node.ipfs.put_tree(p.params, owner=self.task_id)
        for c in range(self.fed.num_clusters):
            self.exchange.register(ridx, c, cid)
        self.contract.pending.extend(self.exchange.round_transactions(ridx))
        return cid

    def _post_settle(self, p: _PendingRound,
                     penalties: Optional[np.ndarray], model_cid: str,
                     t0: float) -> None:
        """Reputation update + record bookkeeping once the round's block
        (if any) is sealed."""
        if self.use_blockchain:
            p.record.model_cid = model_cid
            bad = p.scores < self.contract.T
            if penalties is not None and len(penalties) != self.W:
                # sparse round: scatter the participants' penalties back
                # into a (W,) vector; idle workers owe nothing this round
                mask = np.asarray(p.record.participation).astype(bool)
                full = np.zeros(self.W, np.float64)
                full[mask] = penalties
                penalties = full
                bad &= mask            # idle workers were not judged
            p.record.penalties = penalties
        else:
            bad = np.zeros(self.W, bool)
        self.reputation.update(p.scores, penalized=bad)
        p.record.settle_time = time.monotonic() - t0
        p.record.settled = True

    # -- evaluation ------------------------------------------------------------

    def evaluate(self, eval_batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        batch = {k: jnp.asarray(v) for k, v in eval_batch.items()}
        loss, metrics = self._eval_fn(self.global_params, batch)
        return {k: float(v) for k, v in metrics.items()}

    def evaluate_per_worker(self, batch_w: Dict[str, np.ndarray]):
        """Per-worker eval accuracy of the *global* model on each worker's
        local shard (the per-worker curves of Figs. 5/6)."""
        metrics = self._eval_per_worker_fn(
            self.global_params,
            {k: jnp.asarray(v) for k, v in batch_w.items()})
        return {k: np.asarray(v) for k, v in metrics.items()}

    def finalize(self, timestamp: Optional[float] = None
                 ) -> Dict[str, float]:
        """Drain this task's in-flight rounds (re-raising its sticky error
        if any), then run Algorithm 1's finalization (refunds + top-k
        rewards) in its own single-task block."""
        self.node._flush_for(self.task_id)
        if self.contract is not None:
            if timestamp is None:
                timestamp = float(len(self.history) + 1)
            return self.contract.finalize(timestamp=timestamp)
        return {}


# -- the node -----------------------------------------------------------------


class ChainNode:
    """One chain node serving N concurrent federated tasks on one ledger.

    Owns the shared chain substrate — ``Ledger``, ``IPFSStore``, one
    ``ShardWorkerPool``, and the cross-task settlement scheduler — while
    per-task state lives in ``FederatedTask`` handles registered through
    ``create_task``. Drive with ``run_tick({task_id: batch, ...})``; tasks
    run at independent cadences by simply not firing every tick. See the
    module docstring for the tick/block layout, fairness, and failure
    isolation rules.

    Read path (``read_server()``): proof serving is lock-free by design,
    so readers never block — or wait on — the settler write path. The
    invariants that make this safe: ``Ledger._seal`` registers a block's
    commit *before* publishing the block (so any block a reader can see
    has resolvable proofs), sealed commits/blocks are immutable, and the
    contract's round bookkeeping (``note_block``) is written only after
    the seal — a reader that cannot resolve a round yet simply treats it
    as not-yet-settled and retries after its next head sync. Readers
    resolve tasks by key lookup on ``tasks`` (never iteration), so
    concurrent ``create_task`` registration is safe too."""

    def __init__(self, *, use_blockchain: bool = True,
                 pipeline_depth: int = 2,
                 settler_pool_size: int = 0,
                 ipfs_owner_quota_bytes: int = 0) -> None:
        self.use_blockchain = use_blockchain
        self.pipeline_depth = pipeline_depth
        self.settler_pool_size = settler_pool_size
        self.ledger = Ledger() if use_blockchain else None
        # per-owner (task) byte quota on the shared artifact store: a
        # tenant publishing past it fails its own rounds (QuotaExceeded
        # surfaces as that task's TaskSettlementError) without touching
        # co-tenants — the storage half of multi-tenant fairness
        self.ipfs = IPFSStore(owner_quota_bytes=ipfs_owner_quota_bytes) \
            if use_blockchain else None
        self.tasks: Dict[str, FederatedTask] = {}
        self._tick = 0
        self._pending: Optional[_TickPending] = None
        # event-driven frontier: task_id → (next event sim-time, cohort
        # mask) already drawn from the task's arrival scheduler but not yet
        # run — kept across run_events calls so resuming never skips or
        # re-draws an event
        self._event_frontier: Dict[
            str, Tuple[float, np.ndarray, np.ndarray]] = {}
        # shard workers spawn lazily at task registration, only when some
        # task's settlement is sharded, the driver is threaded, and the
        # contract's leaf-size gate could ever feed them (an explicit
        # settler_pool_size forces the spawn) — the shard *partition* (and
        # hence every block hash) is identical either way, the pool only
        # changes who hashes it
        self._shard_pool: Optional[ShardWorkerPool] = None
        self._settler = _SettlerPool(self._settle_tick, pipeline_depth)
        # seal-broadcast hooks (repro.net): called with each freshly
        # sealed block + its commit, on the settler thread
        self._seal_listeners: List[Callable] = []
        self._closed = False

    # -- task registry --------------------------------------------------------

    def create_task(self, task_id: str, cfg: ModelConfig,
                    fed: FederationConfig, tc: TrainConfig, *, seed: int = 0,
                    adversary: Optional[Callable] = None,
                    reputation_leaders: bool = False,
                    profiles: Optional[List[WorkerProfile]] = None
                    ) -> FederatedTask:
        """Register a new federated task (deploys its ``TrustContract`` on
        the shared ledger). Tasks may join a running node; in-flight ticks
        are drained first so the joining task's round-0 randomness derives
        from a deterministic chain head (every round run before the
        registration, never a racing settler append). ``profiles`` (one
        ``async_sim.WorkerProfile`` per worker; needs ``fed.async_mode``)
        attaches the task's arrival frontier so ``run_events`` can drive it
        event-by-event."""
        if self._closed:
            raise RuntimeError("chain node already closed")
        if task_id in self.tasks:
            raise ValueError(f"task {task_id!r} already registered")
        self.drain()
        task = FederatedTask(self, task_id, cfg, fed, tc, seed=seed,
                             adversary=adversary,
                             reputation_leaders=reputation_leaders,
                             profiles=profiles)
        self.tasks[task_id] = task
        self._settler.register_task(
            task_id, self.ledger.head.hash if self.ledger is not None
            else None)
        self._maybe_spawn_pool(task)
        return task

    def _maybe_spawn_pool(self, task: FederatedTask) -> None:
        if self.pipeline_depth <= 0 or task.contract is None \
                or task.fed.settlement_shards <= 1:
            return
        size = self.settler_pool_size or min(
            max(t.fed.settlement_shards for t in self.tasks.values()),
            os.cpu_count() or 1)
        if size <= 1 or not (self.settler_pool_size > 0
                             or task.contract.parallel_fanout_possible()):
            return
        if self._shard_pool is None or self._shard_pool.num_threads < size:
            # drain in-flight ticks before swapping the pool the scheduler
            # reads (cheap: no-op unless a later task registration grows it
            # mid-run)
            self._settler.flush(check=None)
            old, self._shard_pool = self._shard_pool, ShardWorkerPool(size)
            if old is not None:
                old.stop()

    @property
    def task_errors(self) -> Dict[str, Tuple[int, BaseException]]:
        """Sticky per-task settlement failures: task_id → (round, error)."""
        return {tid: err for tid in sorted(self.tasks)
                if (err := self._settler.task_error(tid)) is not None}

    def add_seal_listener(self, fn: Callable) -> None:
        """Register ``fn(block, commit)`` to run after every block this
        node seals — the broadcast hook a ``repro.net`` gossip layer
        attaches to flood freshly sealed blocks to peers. Listeners run
        on the settler thread, after the block is published on the
        ledger; a listener exception is node-fatal (like any settler
        fault), so broadcast hooks should catch their own transport
        errors."""
        self._seal_listeners.append(fn)

    def ingest_peer_blocks(self, blocks, commits=None) -> int:
        """Adopt externally sealed blocks (gossiped by a peer node) onto
        this node's chain head, oldest-first, after draining in-flight
        local ticks so the adoption races no settler append. ``commits``
        maps block index → ``MultiTaskCommit`` for blocks that commit
        records (shipped alongside the block over the wire). Each block
        is verified on receipt by ``Ledger.adopt_block`` (linkage, hash
        recomputation, commit super-root). Returns how many blocks were
        adopted. Per-contract account state is *not* replayed here —
        that is ``repro.net.SettlementNode``'s job; this hook is for
        proof-serving replicas that track a remote chain."""
        if self._closed:
            raise RuntimeError("chain node already closed")
        if self.ledger is None:
            raise RuntimeError("blockchain disabled on this node")
        self.drain()
        commits = commits or {}
        n = 0
        for blk in blocks:
            self.ledger.adopt_block(blk, commits.get(blk.index))
            n += 1
        return n

    def read_server(self, **kwargs) -> "object":
        """A ``repro.serve.ChainReadServer`` over this live node: head-sync
        handshakes, batched settlement-proof fetch, and checkpoint
        streaming for light clients, served lock-free off the published
        chain state (see the class docstring's read-path invariants) while
        the ``_SettlerPool`` keeps sealing."""
        from repro.serve import ChainReadServer
        return ChainReadServer(self, **kwargs)

    # -- one node tick ---------------------------------------------------------

    def run_tick(self, batches: Dict[str, Dict[str, np.ndarray]],
                 participation: Optional[Dict[str, np.ndarray]] = None
                 ) -> Dict[str, RoundRecord]:
        """Run one round for every task in ``batches`` (canonical sorted
        order) and queue them to settle together in this tick's block.
        Tasks at slower cadences simply don't appear every tick. Raises a
        poisoned task's ``TaskSettlementError`` up front — drop that task
        from ``batches`` to keep driving the others (their rounds from a
        partially-failed tick are already recorded in their histories and
        settle normally)."""
        participation = participation or {}
        tids = sorted(batches)
        for tid in tids:
            if tid not in self.tasks:
                raise KeyError(f"unknown task {tid!r}")
            self._settler.check_task(tid)
        tick = self._tick
        self._tick += 1
        # 1. dispatch every firing task's jitted round — async, no barrier
        started = {tid: self.tasks[tid]._dispatch_round(
            batches[tid], participation.get(tid)) for tid in tids}
        # 2. hand the previous tick's rounds to the settler (threaded: a
        #    queue put; depth 0: settle inline) — either way it overlaps
        #    this tick's device compute
        tc0 = time.monotonic()
        self._hand_off_pending()
        chain_time = time.monotonic() - tc0
        # 3. per task: rotate heads (blocking only on the settled head of
        #    its *own* previous round) and sync scores. A task poisoned
        #    mid-tick raises out of its wait — finish every OTHER task
        #    first (their rounds are recorded and queued normally; only
        #    the poisoned task's dispatched round is dropped), then
        #    re-raise the failure
        recs: Dict[str, RoundRecord] = {}
        entries: List[Tuple[str, _PendingRound]] = []
        failures: List[BaseException] = []
        for tid in tids:
            try:
                rec, pending = self.tasks[tid]._finish_round(started[tid],
                                                             chain_time)
            except BaseException as e:
                failures.append(e)
                continue
            recs[tid] = rec
            entries.append((tid, pending))
        if entries:
            self._pending = _TickPending(tick, entries)
        if failures:
            raise failures[0]
        return recs

    def run_events(self, batch_fns: Dict[str, Callable[[int], Dict]],
                   *, events: int) -> Dict[str, List[RoundRecord]]:
        """Drive the node event-by-event for ``events`` aggregation events
        across the tasks in ``batch_fns`` (each ``task_id → fn(round_index)
        → batch`` — called lazily, only when that task's event fires).

        Every task must be async (``fed.async_mode``) with an arrival
        frontier attached (``create_task(..., profiles=...)``). The node
        repeatedly pops the task whose next aggregation event is earliest
        in simulated time (ties break on task_id — deterministic) and runs
        one ``run_tick`` round for that task alone: participation = the
        arrived cohort, aggregation staleness-weighted on device,
        settlement sealing exactly that cohort through the normal settler
        pipeline (one block per event). An event whose window closed with
        an empty cohort (every arrival lost) still consumes simulated time
        but runs no round. Records carry ``sim_time`` (the event's
        simulated seal time) and ``staleness`` (the cohort's pre-round
        staleness, also committed in the on-chain records).

        Returns ``task_id → [RoundRecord, ...]`` for the rounds run (tasks
        whose events never fired within the budget map to ``[]``).
        Frontier state persists on the node, so consecutive calls continue
        the same simulation; a poisoned task raises its
        ``TaskSettlementError`` out of its event exactly like ``run_tick``.
        """
        tids = sorted(batch_fns)
        for tid in tids:
            if tid not in self.tasks:
                raise KeyError(f"unknown task {tid!r}")
            if self.tasks[tid].arrival is None:
                raise ValueError(
                    f"task {tid!r} has no arrival frontier — register it "
                    f"with create_task(..., profiles=[...]) and "
                    f"fed.async_mode=True to drive it event-by-event")
        heap: List[Tuple[float, str]] = []
        for tid in tids:
            if tid not in self._event_frontier:
                arrival = self.tasks[tid].arrival
                t, mask, _ = arrival.next_aggregation()
                self._event_frontier[tid] = (t, mask,
                                             arrival.arrival_times().copy())
            heap.append((self._event_frontier[tid][0], tid))
        heapq.heapify(heap)
        out: Dict[str, List[RoundRecord]] = {tid: [] for tid in tids}
        for _ in range(int(events)):
            if not heap:
                break
            t, tid = heapq.heappop(heap)
            _, mask, at = self._event_frontier.pop(tid)
            task = self.tasks[tid]
            if mask.sum() > 0:
                rec = self.run_tick(
                    {tid: batch_fns[tid](task.round_index)},
                    participation={tid: mask})[tid]
                rec.sim_time = t
                rec.arrival_times = at
                out[tid].append(rec)
            nt, nmask, _ = task.arrival.next_aggregation()
            self._event_frontier[tid] = (nt, nmask,
                                         task.arrival.arrival_times().copy())
            heapq.heappush(heap, (nt, tid))
        return out

    def _hand_off_pending(self) -> None:
        tp, self._pending = self._pending, None
        if tp is not None:
            self._settler.submit(tp)       # queue handoff; work happens on
                                           # the settler thread (depth > 0)

    # -- settlement of one tick (runs on the scheduler thread) ----------------

    def _settle_tick(self, tp: _TickPending) -> list:
        """Settle one tick: per task IPFS publication + contract
        settlement, all surviving tasks sealed into one multi-task block
        at logical (tick-indexed) time. Returns per-task outcomes
        ``(task_id, round_index, head, error)``; raising is node-fatal."""
        outcomes: list = []
        live: List[Tuple[FederatedTask, _PendingRound, float]] = []
        work: List[TaskRoundWork] = []
        for tid, p in tp.entries:
            ridx = p.record.round_index
            if self._settler.task_error(tid) is not None:
                # drain-and-discard: never settle later rounds of a task
                # on top of its half-settled lane
                outcomes.append((tid, ridx, None, None))
                continue
            task = self.tasks[tid]
            t0 = time.monotonic()
            if not self.use_blockchain:
                task._post_settle(p, None, "", t0)
                outcomes.append((tid, ridx, None, None))
                continue
            try:
                cid = task._pre_settle(p)
            except BaseException as e:
                outcomes.append((tid, ridx, None, e))
                continue
            live.append((task, p, t0))
            scores, wids = p.scores, None
            stale = p.record.staleness
            if task.contract.sparse_settlement \
                    and p.record.participation is not None:
                # sparse settlement: the round's *changed set* is the
                # participating workers — idle workers' records carry
                # over into the delta commit unhashed
                mask = np.asarray(p.record.participation).astype(bool)
                wids = np.nonzero(mask)[0].astype(np.int64)
                scores = p.scores[wids]
                if stale is not None:
                    stale = stale[wids]
            work.append(TaskRoundWork(tid, task.contract, ridx, scores,
                                      cid, worker_ids=wids, staleness=stale))
        if work:
            # logical timestamp: every node (and the serial reference
            # driver) seals byte-identical blocks for the same tick
            blk, pens, errors = settle_tasks_block(
                self.ledger, work, timestamp=float(tp.tick + 1),
                pool=self._shard_pool)
            for listener in self._seal_listeners:
                listener(blk, self.ledger._commits.get(blk.index))
            for (task, p, t0), w in zip(live, work):
                if w.task_id in errors:
                    outcomes.append((w.task_id, w.round_index, None,
                                     errors[w.task_id]))
                else:
                    task._post_settle(p, pens[w.task_id], w.model_cid, t0)
                    outcomes.append((w.task_id, w.round_index, blk.hash,
                                     None))
        return outcomes

    # -- draining / teardown ---------------------------------------------------

    def flush(self) -> None:
        """Settle every round still in flight: hand off the trailing
        pending tick and drain the scheduler queue. Idempotent and safe to
        call mid-queue. Re-raises the first sticky task error (for the
        multi-task drain that leaves per-task errors with their tasks,
        use ``drain``)."""
        self._hand_off_pending()
        self._settler.flush()

    def drain(self) -> None:
        """Like ``flush`` but re-raises only a node-fatal error — a
        poisoned task keeps its ``TaskSettlementError`` for its own
        interactions while co-tenants proceed."""
        self._hand_off_pending()
        self._settler.flush(check=None)

    def _flush_for(self, task_id: str) -> None:
        self._hand_off_pending()
        self._settler.flush(check=task_id)

    def finalize_task(self, task_id: str,
                      timestamp: Optional[float] = None) -> Dict[str, float]:
        return self.tasks[task_id].finalize(timestamp)

    def finalize(self) -> Dict[str, Dict[str, float]]:
        """Drain, finalize every healthy task (refunds + top-k payouts,
        one block each), close the node. Poisoned tasks are skipped —
        inspect ``task_errors``. Returns per-task payout maps."""
        self.drain()
        payouts: Dict[str, Dict[str, float]] = {}
        for tid in sorted(self.tasks):
            task = self.tasks[tid]
            if self._settler.task_error(tid) is not None:
                continue
            if task.contract is not None and task.contract.closed:
                continue
            payouts[tid] = task.finalize()
        self.close()
        return payouts

    def close(self) -> None:
        """Stop the scheduler and shard workers (drains best-effort,
        never raises; idempotent)."""
        self._closed = True
        self._settler.stop()
        if self._shard_pool is not None:
            self._shard_pool.stop()
            self._shard_pool = None
