"""Asynchronous functionality — jit-compatible buffered/staleness-weighted
aggregation (the production path; ``async_sim`` is the event-driven host
simulator).

Round model: each round a participation mask says which workers' updates
*arrived*. Arrived updates are weighted by trust × staleness-discount and
aggregated through the cluster hierarchy; absent workers accumulate
staleness and their pending local progress is folded in when they next
arrive (FedBuff-style server buffer of capacity ``fed.buffer_size`` is the
special case where the mask has at most ``buffer_size`` ones).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederationConfig
from repro.core import hierarchy, trust


class AsyncState(NamedTuple):
    staleness: jax.Array      # (W,) rounds since the worker's last inclusion
    pending: object           # pytree (W, ...): accumulated unsent updates


def init_async_state(updates_like, W: int) -> AsyncState:
    pending = jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32),
                           updates_like)
    return AsyncState(staleness=jnp.zeros((W,), jnp.int32), pending=pending)


def host_staleness_update(staleness, mask):
    """Host-side (numpy) mirror of the jit path's staleness rule: arrived
    workers reset to 0, everyone else ages by one round.

    The event-driven node keeps this mirror in ``FederatedTask`` so the
    *pre-round* staleness snapshot can be recorded in on-chain settlement
    records without a device sync; it must stay in lockstep with
    ``async_round``'s ``new_staleness`` (and ``AsyncScheduler.staleness``) —
    there is an agreement property test."""
    m = np.asarray(mask) > 0
    return np.where(m, 0, np.asarray(staleness, np.int64) + 1)


def effective_weights(scores, mask, staleness,
                      fed: FederationConfig) -> jax.Array:
    """The async round's normalized aggregation weights:
    trust × penalization-filter × participation × staleness-discount.
    Shared by the per-leaf reference (``async_round``) and the fused
    flat-pack path (``fl_step``) so the two can only differ in the
    aggregation's reduction order, never in the weight math."""
    discount = trust.staleness_discount(staleness, fed.staleness_alpha)
    w = trust.trust_weights(scores, fed, participation=mask) * discount
    return w / jnp.maximum(jnp.sum(w), 1e-12)


def async_round(updates, scores, mask, state: AsyncState,
                fed: FederationConfig) -> Tuple[object, AsyncState, jax.Array]:
    """One asynchronous aggregation round.

    updates: pytree (W, ...) — this round's locally-computed updates.
    scores:  (W,) trust scores. mask: (W,) 0/1 arrivals.
    Returns (aggregated_update, new_state, effective_weights)."""
    maskf = mask.astype(jnp.float32)
    # arrivals contribute their accumulated pending + fresh update
    total = jax.tree.map(
        lambda p, u: p + u.astype(jnp.float32), state.pending, updates)
    w = effective_weights(scores, mask, state.staleness, fed)
    agg = hierarchy.aggregate(total, w, fed)

    # arrived workers flush their buffer & reset staleness. The keep-mask
    # (1 − arrivals) is computed once per round and only *broadcast* per
    # leaf — an arrived worker's pending is zeroed exactly, so re-running
    # the flush (or the next round) can never aggregate the same buffered
    # update twice (see the double-count regression test).
    keep = 1.0 - maskf
    new_pending = jax.tree.map(
        lambda t: t * keep.reshape((-1,) + (1,) * (t.ndim - 1)), total)
    new_staleness = jnp.where(mask > 0, 0, state.staleness + 1)
    return agg, AsyncState(new_staleness, new_pending), w
