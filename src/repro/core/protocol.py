"""SDFLBProtocol — one-task compatibility wrapper over a private
``ChainNode`` (see ``repro.core.node``, where the orchestration now
lives).

Historically this module held the whole host-level driver: enrollment +
staking, the jitted ``fl_step`` dispatch, trust scoring + on-chain
settlement, IPFS publication, head rotation from on-chain randomness,
the background settler pool, and the sharded Merkle commits. The
multi-tenant refactor carved that into two layers — ``ChainNode`` (the
shared chain substrate: ledger, IPFS store, shard worker pool, cross-task
settlement scheduler) and ``FederatedTask`` (everything task-scoped) —
because the paper's blockchain is shared infrastructure: many federated
tasks settle on one chain.

``SDFLBProtocol`` keeps the original single-task API intact by driving a
private node with exactly one task: ``run_round`` is a one-task
``run_tick``, and every attribute of the old protocol (``ledger``,
``contract``, ``history``, ``heads``, ``reputation``, ``global_params``,
``_shard_pool``, …) resolves onto the task or the node. With one task,
every block hash, proof, election, penalty, and payout is bit-identical
to the pre-refactor sharded driver — the single-task tick seals the exact
single-tenant block layout (property-tested in
``tests/test_multi_task_node.py`` and pinned by the serial-vs-threaded
equivalence tests).

Pipelining semantics are unchanged: ``run_round`` dispatches round r's
jitted step, hands round r−1's host chain work to the node's settler
(``fed.pipeline_depth``; 0 settles inline, reproducing the serial
reference driver), and blocks only where round r's on-chain randomness
consumes round r−1's block head. Settled state (ledger blocks, contract
balances, reputation, per-round ``penalties``/``model_cid``/
``settle_time``) is written by the settler thread; read it after
``flush()`` (idempotent, safe mid-queue), or rely on rounds ≤ r−1 being
settled once ``run_round(r)`` returns whenever head rotation consumes
chain heads. Settler exceptions re-raise on the training thread at the
next ``run_round``/``flush`` (now as ``TaskSettlementError``, naming the
task and the failing round).

Sparse settlement rides the same API: with ``fed.sparse_settlement`` the
``participation`` mask passed to ``run_round`` doubles as the round's
settlement *changed set* — only participating workers' records re-hash
into the block's delta commit (see ``chain.contract``), while every block
still commits and proves the full population. ``ipfs_owner_quota_bytes``
caps this task's logical bytes on the artifact store (``QuotaExceeded``
surfaces as a ``TaskSettlementError``).

Event-driven mode: construct with ``fed.async_mode=True`` and
``arrival_profiles`` (one ``async_sim.WorkerProfile`` per worker), then
drive with ``run_events(batch_fn, events=N)`` — the single-task view of
``ChainNode.run_events`` (arrival frontier → staleness-weighted aggregate
→ cohort seal; see ``repro.core.node``).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.configs.base import FederationConfig, ModelConfig, TrainConfig
# re-exports: these classes lived here before the multi-tenant refactor
from repro.core.node import (ChainNode, FederatedTask, RoundRecord,
                             ShardWorkerPool, TaskSettlementError,
                             _PendingRound, _SettlerPool)

__all__ = ["SDFLBProtocol", "ChainNode", "FederatedTask", "RoundRecord",
           "ShardWorkerPool", "TaskSettlementError", "_PendingRound",
           "_SettlerPool"]


class SDFLBProtocol:
    """One federated task on a private single-tenant ``ChainNode``.
    ``use_blockchain=False`` reproduces the paper's Fig. 2 ablation
    (identical learning dynamics, no chain work)."""

    def __init__(self, cfg: ModelConfig, fed: FederationConfig,
                 tc: TrainConfig, *, use_blockchain: bool = True,
                 seed: int = 0,
                 adversary=None,
                 reputation_leaders: bool = False,
                 ipfs_owner_quota_bytes: int = 0,
                 arrival_profiles=None) -> None:
        self._node = ChainNode(use_blockchain=use_blockchain,
                               pipeline_depth=fed.pipeline_depth,
                               settler_pool_size=fed.settler_pool_size,
                               ipfs_owner_quota_bytes=ipfs_owner_quota_bytes)
        self._task = self._node.create_task(
            fed.task_id, cfg, fed, tc, seed=seed, adversary=adversary,
            reputation_leaders=reputation_leaders,
            profiles=arrival_profiles)

    # everything the old monolithic protocol exposed lives on the task
    # (model/contract/history/reputation/...) or the node (ledger/ipfs/
    # _shard_pool/...) — resolve attribute reads AND writes there, task
    # first, so post-construction tweaks like `proto.fed = replace(...)`
    # or `proto.adversary = fn` keep reaching the state the driver reads
    def __getattr__(self, name: str):
        if name.startswith("__"):
            raise AttributeError(name)
        d = self.__dict__
        for obj in (d.get("_task"), d.get("_node")):
            if obj is not None and hasattr(obj, name):
                return getattr(obj, name)
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")

    def __setattr__(self, name: str, value) -> None:
        if not name.startswith("_"):
            d = self.__dict__
            for obj in (d.get("_task"), d.get("_node")):
                # forward plain instance attributes only (properties like
                # .ledger live on the class and stay read-only)
                if obj is not None and name in getattr(obj, "__dict__", {}):
                    setattr(obj, name, value)
                    return
        object.__setattr__(self, name, value)

    @property
    def node(self) -> ChainNode:
        """The underlying (single-tenant) chain node."""
        return self._node

    @property
    def task(self) -> FederatedTask:
        """The underlying task handle."""
        return self._task

    # -- one full protocol round ----------------------------------------------

    def run_round(self, batch: Dict[str, np.ndarray],
                  participation: Optional[np.ndarray] = None) -> RoundRecord:
        """batch leaves: (W, B, ...) — a single local step per round
        (paper's setup). One single-task node tick."""
        tid = self._task.task_id
        recs = self._node.run_tick(
            {tid: batch},
            participation=None if participation is None
            else {tid: participation})
        return recs[tid]

    def run_events(self, batch_fn, *, events: int) -> list:
        """Event-driven driver (``ChainNode.run_events``) for this one
        task: needs ``fed.async_mode`` and ``arrival_profiles`` at
        construction. ``batch_fn(round_index) → batch`` is called lazily
        per event. Returns this task's new ``RoundRecord`` list."""
        tid = self._task.task_id
        return self._node.run_events({tid: batch_fn}, events=events)[tid]

    def flush(self) -> None:
        """Settle every round still in flight: hand off the trailing
        pending round and drain the settler queue. Idempotent and safe to
        call mid-queue (no-op when nothing is pending)."""
        self._node.flush()

    # -- evaluation ------------------------------------------------------------

    def evaluate(self, eval_batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        return self._task.evaluate(eval_batch)

    def evaluate_per_worker(self, batch_w: Dict[str, np.ndarray]):
        """Per-worker eval accuracy of the *global* model on each worker's
        local shard (the per-worker curves of Figs. 5/6)."""
        return self._task.evaluate_per_worker(batch_w)

    def finalize(self) -> Dict[str, float]:
        payouts = self._task.finalize(
            timestamp=float(len(self._task.history) + 1))
        self._node.close()         # stops the settler and shard workers
        return payouts
