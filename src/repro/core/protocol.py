"""SDFLBProtocol — host-level orchestration of the paper's full workflow
(§III.B/C): enrollment + staking on the contract, clustered local training
(the jitted ``fl_step``), trust scoring + on-chain settlement per round,
IPFS publication of cluster/global aggregates, deterministic head rotation
from on-chain randomness, and optional asynchronous arrivals.

Threaded multi-round pipeline: ``run_round`` dispatches round r's jitted
``_round_fn`` and hands round r−1's host-side chain work (contract
settlement, chunked Merkle commitment, IPFS publication) to a background
*settler pool* (``_SettlerPool``) — a coordinator thread draining a
bounded queue of pending rounds (``fed.pipeline_depth``; 0 settles inline,
reproducing the serial driver) that fans each round's per-shard contract
slices (``fed.settlement_shards``) out to N shard-worker threads
(``ShardWorkerPool``, sized by ``fed.settler_pool_size``) over per-shard
queues, and seals the block over the cross-shard super-root only at the
merge barrier, after every shard succeeded. Chain work therefore never
occupies the training thread — the training-path ``chain_time`` is the
queue handoff only, multiple rounds can be in flight, and within a round
the shard subtrees hash in parallel. Shard boundaries are Merkle-subtree
aligned, so shard count never changes block hashes: S=1, S=8 and the
serial driver produce byte-identical chains (property-tested).

Decision sequences are byte-identical to the serial driver: the settler
publishes each settled round's chain head, and round r's head rotation
blocks only at the point it consumes the head of round r−1's block
(reputation-weighted election likewise waits for reputation through round
r−1 before electing). Blocks are sealed at logical (round-indexed)
timestamps, so serial and threaded runs — and every node re-deriving the
chain — agree on block hashes, on-chain randomness, and elections.
Settled state (ledger blocks, contract balances, reputation, per-round
``penalties``/``model_cid``/``settle_time``) is written by the settler
thread; read it after ``flush()`` (called by ``finalize``, idempotent,
safe to call mid-queue — it drains the backlog), or rely on the fact that
rounds ≤ r−1 are settled once ``run_round(r)`` returns whenever head
rotation consumes chain heads. Settler exceptions are re-raised on the
training thread at the next ``run_round``/``flush``.

Chain work is array-native end to end: workers are integer ids on the
struct-of-arrays contract (``settle_round_batch``), blocks commit
per-worker records via a chunked Merkle root (``fed.merkle_chunk_size``
records per leaf — ~2·W/k hashes per commit) rather than W transaction
dicts, and the round's global model is serialized to IPFS once, with the C
cluster heads registering the same cid (identical fully-synchronized tree
— one put, C registrations).

Runs the paper's small-scale experiments end-to-end on CPU (Figs. 2-6);
the same jitted round is what the production launcher shards over pods.
"""
from __future__ import annotations

import os
import queue
import threading
import time
import weakref
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.chain.contract import TrustContract
from repro.chain.ipfs import IPFSStore
from repro.chain.ledger import Ledger
from repro.configs.base import FederationConfig, ModelConfig, TrainConfig
from repro.core import async_agg, fl_step
from repro.core.gossip import ClusterExchange
from repro.core.reputation import ReputationBook
from repro.models import api


@dataclass
class RoundRecord:
    round_index: int
    scores: np.ndarray
    weights: np.ndarray
    losses: np.ndarray
    penalties: np.ndarray          # (W,) settlement penalties; zeros until
                                   # the round is settled (pipelined driver)
    heads: List[int]
    model_cid: str                 # "" until settled
    wall_time: float
    chain_time: float              # chain work charged to the training
                                   # thread during this call (threaded
                                   # settler: the queue handoff only)
    participation: Optional[np.ndarray] = None
    settled: bool = False
    settle_time: float = 0.0       # host chain work on the settler thread
                                   # (contract + Merkle + IPFS); set when
                                   # the round settles


@dataclass
class _PendingRound:
    record: RoundRecord
    params: Any                    # round's resulting global params (device);
                                   # None when running without a chain
    scores: np.ndarray


class ShardWorkerPool:
    """N shard-worker threads, each draining its own task queue.

    ``map`` fans one round's shard thunks out — shard i always lands on
    queue i mod N, so a given contract shard runs on the same worker and
    its work stays FIFO across rounds — and blocks at the merge barrier
    until every thunk finished, then re-raises the lowest-shard-index
    failure (deterministic, whichever thread hit it first). Thunks must be
    pure compute (the contract's ``settle_shard`` mutates nothing), so
    after a failure the survivors' results are simply dropped.

    Workers hold only a weak reference to the pool and wake periodically
    while idle, so an abandoned (never-finalized) protocol's shard threads
    exit instead of living for the rest of the process."""

    _IDLE_POLL_S = 2.0

    def __init__(self, num_threads: int) -> None:
        self.num_threads = max(1, int(num_threads))
        self._queues: List["queue.Queue"] = [queue.Queue()
                                             for _ in range(self.num_threads)]
        self._stopped = False
        ref = weakref.ref(self)
        self._threads = [
            threading.Thread(target=self._work, args=(q, ref), daemon=True,
                             name=f"sdflb-shard-worker-{i}")
            for i, q in enumerate(self._queues)]
        for t in self._threads:
            t.start()

    @staticmethod
    def _work(q: "queue.Queue", pool_ref: "weakref.ref") -> None:
        while True:
            try:
                item = q.get(timeout=ShardWorkerPool._IDLE_POLL_S)
            except queue.Empty:
                if pool_ref() is None:         # owner got collected
                    return
                continue
            if item is None:                   # stop sentinel
                return
            fn, i, out, cv, remaining = item
            try:
                out[i] = ("ok", fn())
            except BaseException as e:
                out[i] = ("err", e)
            finally:
                del fn, item                   # don't pin results while idle
                with cv:
                    remaining[0] -= 1
                    cv.notify_all()

    def map(self, thunks) -> list:
        """Run ``thunks[i]`` on worker i mod N; return their results in
        order, or raise the first (by index) failure after all finished."""
        if self._stopped:
            raise RuntimeError("shard pool already stopped")
        thunks = list(thunks)
        if not thunks:
            return []
        out: list = [None] * len(thunks)
        cv = threading.Condition()
        remaining = [len(thunks)]
        for i, fn in enumerate(thunks):
            self._queues[i % self.num_threads].put((fn, i, out, cv,
                                                    remaining))
        with cv:
            cv.wait_for(lambda: remaining[0] == 0)
        for tag, val in out:
            if tag == "err":
                raise val
        return [val for _, val in out]

    def stop(self) -> None:
        """Terminate the workers (idempotent); outstanding queue items run
        first since the sentinel sits behind them."""
        if self._stopped:
            return
        self._stopped = True
        for q in self._queues:
            q.put(None)
        for t in self._threads:
            t.join()


class _SettlerPool:
    """Background settlement pool: a coordinator daemon thread consuming a
    bounded queue of pending rounds, settling each in submission order —
    fanning its contract shards out to the ``ShardWorkerPool`` and sealing
    the block at the merge barrier — and publishing the resulting chain
    head per round.

    The training thread interacts through three calls: ``submit`` (the
    queue handoff — blocks only when ``depth`` rounds are already in
    flight), ``wait_settled(r)`` (returns round r's published chain head,
    blocking until the settler has produced it — the *only* point the
    pipeline couples back to chain state, because round r+1's on-chain
    randomness needs round r's block hash), and ``flush`` (drain
    everything submitted; idempotent). A settle exception — including a
    single shard failing at the fan-out, which aborts its round before
    anything was applied or committed (shards mutate nothing; the merge
    runs only after all of them succeed, so no half-settled super-root
    ever reaches the chain) — is sticky: the coordinator stops settling
    (queued rounds are drained and discarded so nothing commits on top of
    a half-settled chain) and every subsequent interaction re-raises on
    the training thread.

    The protocol is held through a weak reference and the worker wakes
    periodically while idle, so an abandoned (never-finalized) protocol is
    still garbage-collectable and its settler threads exit instead of
    pinning params/ledger for the life of the process."""

    _IDLE_POLL_S = 2.0

    def __init__(self, settle_fn: Callable[["_PendingRound"], Optional[str]],
                 depth: int, initial_head: Optional[str],
                 shard_pool: Optional[ShardWorkerPool] = None) -> None:
        # weak: the thread must not keep the owning protocol alive
        self._settle = weakref.WeakMethod(settle_fn)
        self.shard_pool = shard_pool
        self._q: "queue.Queue" = queue.Queue(maxsize=max(depth, 1))
        self._cv = threading.Condition()
        self._submitted = -1
        self._settled = -1
        self._heads: Dict[int, Optional[str]] = {-1: initial_head}
        self._error: Optional[BaseException] = None
        self._stopped = False
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="sdflb-settler-coordinator")
        self._thread.start()

    # -- worker side ---------------------------------------------------------

    def _loop(self) -> None:
        while True:
            try:
                item = self._q.get(timeout=self._IDLE_POLL_S)
            except queue.Empty:
                if self._settle() is None:         # owner got collected
                    return
                continue
            if item is None:                       # stop sentinel
                return
            ridx = item.record.round_index
            settle = self._settle()
            with self._cv:
                failed = self._error is not None
            if settle is None or failed:
                # after a failure (or owner collection) drain-and-discard:
                # never commit later rounds on top of a half-settled chain,
                # but keep waking flush()/submit() callers
                del item, settle
                with self._cv:
                    self._settled = max(self._settled, ridx)
                    self._cv.notify_all()
                continue
            try:
                head = settle(item)
            except BaseException as e:             # sticky; surfaced on the
                with self._cv:                     # training thread
                    self._error = e
                    self._settled = max(self._settled, ridx)
                    self._cv.notify_all()
                continue
            finally:
                # frame locals survive across iterations — dropping them
                # here keeps the idle thread from pinning the protocol (and
                # the settled round's params) against garbage collection
                del item, settle
            with self._cv:
                self._settled = ridx
                if head is not None:   # chainless runs never consume heads —
                    self._heads[ridx] = head   # don't grow the dict forever
                self._cv.notify_all()

    # -- training-thread side ------------------------------------------------

    def _check_error(self) -> None:
        if self._error is not None:
            raise RuntimeError(
                "background chain settlement failed; the settler has "
                "stopped (unsettled rounds were discarded)") from self._error

    def submit(self, pending: "_PendingRound") -> None:
        with self._cv:
            self._check_error()
            if self._stopped:
                raise RuntimeError("settler already stopped")
            self._submitted = pending.record.round_index
        self._q.put(pending)                       # bounded: backpressure

    def wait_settled(self, round_index: int) -> Optional[str]:
        """Block until round ``round_index`` is settled; return its
        published chain head hash (None when running without a ledger)."""
        with self._cv:
            self._cv.wait_for(lambda: self._settled >= round_index
                              or self._error is not None)
            self._check_error()
            head = self._heads.get(round_index)
            # prune heads no one can ask for again (heads are consumed in
            # round order; keep the latest two for idempotent re-reads)
            for k in [k for k in self._heads if k < round_index - 1]:
                del self._heads[k]
            return head

    def flush(self) -> None:
        """Drain the queue: block until everything submitted has settled."""
        with self._cv:
            self._cv.wait_for(lambda: self._settled >= self._submitted
                              or self._error is not None)
            self._check_error()

    def stop(self) -> None:
        """Flush, then terminate the coordinator and shard workers
        (idempotent)."""
        self.flush()
        if not self._stopped:
            self._stopped = True
            self._q.put(None)
            self._thread.join()
            if self.shard_pool is not None:
                self.shard_pool.stop()


class SDFLBProtocol:
    """One federated task. ``use_blockchain=False`` reproduces the paper's
    Fig. 2 ablation (identical learning dynamics, no chain work)."""

    def __init__(self, cfg: ModelConfig, fed: FederationConfig,
                 tc: TrainConfig, *, use_blockchain: bool = True,
                 seed: int = 0,
                 adversary: Optional[Callable] = None,
                 reputation_leaders: bool = False) -> None:
        self.cfg, self.fed, self.tc = cfg, fed, tc
        self.use_blockchain = use_blockchain
        self.W = fl_step.num_workers(fed)
        self.rng = jax.random.PRNGKey(seed)
        self.np_rng = np.random.default_rng(seed)
        self.adversary = adversary    # fn(worker_batch dict, worker_id) -> batch

        key, self.rng = jax.random.split(self.rng)
        self.global_params, _ = api.init(cfg, key, tp=1)
        self.opt_state = fl_step.init_worker_opt(self.global_params, fed, tc)
        self._round_fn = jax.jit(fl_step.make_fl_round(cfg, fed, tc))
        # eval fns jitted once here (re-wrapping jax.jit per call would
        # recompile on every invocation)
        loss_fn = api.loss_fn(cfg)
        self._eval_fn = jax.jit(loss_fn)
        self._eval_per_worker_fn = jax.jit(
            jax.vmap(lambda p, b: loss_fn(p, b)[1], in_axes=(None, 0)))

        self.async_state = None
        self.scheduler = None
        if fed.async_mode:
            updates_like = jax.tree.map(
                lambda x: jnp.zeros((self.W,) + x.shape, jnp.float32),
                self.global_params)
            self.async_state = async_agg.init_async_state(updates_like, self.W)

        self.ledger = Ledger() if use_blockchain else None
        self.ipfs = IPFSStore() if use_blockchain else None
        self.contract = None
        if use_blockchain:
            self.contract = TrustContract(
                self.ledger, requester_deposit=fed.requester_deposit,
                worker_stake=fed.worker_stake, penalty_pct=fed.penalty_pct,
                trust_threshold=fed.trust_threshold, top_k=fed.top_k_rewarded,
                merkle_chunk_size=fed.merkle_chunk_size,
                settlement_shards=fed.settlement_shards)
            self.contract.join_batch(self.W)   # integer ids, one batch tx
        self.history: List[RoundRecord] = []
        self.heads = [0] * fed.num_clusters
        # reputation (EMA of scores + penalty history) drives head election
        # when reputation_leaders=True — addresses the paper's §VI.E
        # bad-leader concern while keeping rotation stochastic
        self.reputation = ReputationBook(self.W)
        self.reputation_leaders = reputation_leaders
        self.exchange = (ClusterExchange(self.ipfs, self.ledger,
                                         fed.num_clusters)
                         if use_blockchain else None)
        self._pending: Optional[_PendingRound] = None
        # depth > 0: chain work runs on the settler pool; 0: inline (the
        # serial reference driver the equivalence property test pins).
        # Shard workers spawn only when settlement is sharded, threaded,
        # and the contract's leaf-size gate could ever feed them (an
        # explicit settler_pool_size forces the spawn) — the shard
        # *partition* (and hence every block hash) is identical either
        # way, the pool only changes who hashes it.
        self._settler: Optional[_SettlerPool] = None
        self._shard_pool: Optional[ShardWorkerPool] = None
        if fed.pipeline_depth > 0:
            pool_size = fed.settler_pool_size or \
                min(fed.settlement_shards, os.cpu_count() or 1)
            if use_blockchain and fed.settlement_shards > 1 \
                    and pool_size > 1 \
                    and (fed.settler_pool_size > 0
                         or self.contract.parallel_fanout_possible()):
                self._shard_pool = ShardWorkerPool(pool_size)
            self._settler = _SettlerPool(
                self._settle_one, fed.pipeline_depth,
                self.ledger.head.hash if self.ledger is not None else None,
                shard_pool=self._shard_pool)

    # -- head rotation from on-chain randomness ------------------------------

    def _rotate_heads(self, round_index: int,
                      head_hash: Optional[str] = None) -> List[int]:
        """``head_hash``: the chain head the rotation must see (round
        r−1's block) — published by the settler in threaded mode; defaults
        to the live ledger head (serial mode, where it is the same block)."""
        if self.ledger is not None:
            if head_hash is None:
                head_hash = self.ledger.head.hash
            seed = Ledger.randomness_from(head_hash, round_index)
        else:
            seed = (self.fed.head_rotation_seed * 1_000_003 + round_index)
        wpc = self.fed.workers_per_cluster
        if self.reputation_leaders:
            self.heads = [
                self.reputation.elect(range(c * wpc, (c + 1) * wpc),
                                      rng_seed=seed + c)
                for c in range(self.fed.num_clusters)]
        else:
            rng = np.random.default_rng(seed)
            self.heads = [int(rng.integers(0, wpc))
                          for _ in range(self.fed.num_clusters)]
        return self.heads

    # -- deferred chain work (runs on the settler thread at depth > 0) --------

    def _settle_one(self, p: _PendingRound) -> Optional[str]:
        """Settle one pending round: IPFS publication, cross-cluster cid
        registration, contract settlement with the chunked Merkle commit,
        and the reputation update. Returns the resulting chain head hash
        (the block other rounds' randomness derives from)."""
        t0 = time.monotonic()
        ridx = p.record.round_index
        head = None
        if self.use_blockchain:
            # one IPFS put of the (identical) global tree; every cluster
            # head registers the cid for the cross-cluster hash exchange
            # (paper §III.A)
            cid = self.ipfs.put_tree(p.params)
            for c in range(self.fed.num_clusters):
                self.exchange.register(ridx, c, cid)
            self.contract.pending.extend(self.exchange.round_transactions(ridx))
            # logical timestamp: every node (and the serial reference
            # driver) seals byte-identical blocks for the same round; shard
            # slices fan out to the worker pool when one exists
            pen = self.contract.settle_round_batch(
                ridx, p.scores, model_cid=cid, timestamp=float(ridx + 1),
                pool=self._shard_pool)
            p.record.model_cid = cid
            p.record.penalties = pen
            # O(1) integrity check of the block just sealed (linkage +
            # recomputed hash) — a full verify_chain here would rehash
            # every prior block each round, O(R^2) over a run
            blk = self.ledger.head
            if (blk.prev_hash != self.ledger.blocks[blk.index - 1].hash
                    or blk.hash != blk.compute_hash()):
                raise RuntimeError(
                    f"round {ridx}: sealed block failed verification")
            head = blk.hash
            bad = p.scores < self.contract.T
        else:
            bad = np.zeros(self.W, bool)
        self.reputation.update(p.scores, penalized=bad)
        p.record.settle_time = time.monotonic() - t0
        p.record.settled = True
        return head

    def _hand_off_pending(self) -> None:
        p, self._pending = self._pending, None
        if p is None:
            return
        if self._settler is not None:
            self._settler.submit(p)        # queue handoff; work happens on
        else:                              # the settler thread
            self._settle_one(p)

    def flush(self) -> None:
        """Settle every round still in flight: hand off the trailing
        pending round and drain the settler queue. Idempotent and safe to
        call mid-queue (no-op when nothing is pending)."""
        self._hand_off_pending()
        if self._settler is not None:
            self._settler.flush()

    # -- one full protocol round ----------------------------------------------

    def run_round(self, batch: Dict[str, np.ndarray],
                  participation: Optional[np.ndarray] = None) -> RoundRecord:
        """batch leaves: (W, B, ...) — a single local step per round (paper's
        setup); reshaped to (W, 1, B, ...) for the step function."""
        t0 = time.monotonic()
        ridx = len(self.history)

        batch = {k: jnp.asarray(v)[:, None] for k, v in batch.items()}
        if self.adversary is not None:
            batch = self.adversary(batch, ridx)
        self.rng, rkey = jax.random.split(self.rng)
        part = (None if participation is None
                else jnp.asarray(participation, jnp.int32))

        # 1. dispatch this round's jitted step — async, no barrier
        if self.fed.async_mode:
            out, self.async_state = self._round_fn(
                self.global_params, self.opt_state, batch, rkey,
                part, self.async_state)
        else:
            out = self._round_fn(self.global_params, self.opt_state, batch,
                                 rkey, part)
        self.global_params, self.opt_state = out.global_params, out.opt_state
        try:                       # start device→host copy of the scores
            out.scores.copy_to_host_async()
        except AttributeError:     # backend without async host copies
            pass

        # 2. hand the previous round's host chain work to the settler
        #    (threaded: a queue put; depth 0: settle inline) — either way it
        #    overlaps this round's device compute
        tc0 = time.monotonic()
        self._hand_off_pending()
        chain_time = time.monotonic() - tc0

        # 3. rotate heads for this round. On-chain randomness needs round
        #    r−1's block hash (and reputation election its scores), so this
        #    is the one point the pipeline consumes settled state: block on
        #    the settler's published head for round r−1 — exactly the chain
        #    head the serial driver sees. Without chain or reputation
        #    election the rotation seed is settlement-free and rounds run
        #    arbitrarily deep into the queue.
        head_hash = None
        if self._settler is not None and (self.use_blockchain
                                          or self.reputation_leaders):
            head_hash = self._settler.wait_settled(ridx - 1)
        heads = self._rotate_heads(ridx, head_hash)

        # 4. the only training-path sync point: this round's scores
        scores = np.asarray(out.scores)
        train_time = time.monotonic() - t0 - chain_time

        rec = RoundRecord(
            round_index=ridx, scores=scores, weights=np.asarray(out.weights),
            losses=np.asarray(out.losses),
            penalties=np.zeros(self.W, np.float64), heads=heads,
            model_cid="", wall_time=train_time + chain_time,
            chain_time=chain_time,
            participation=None if participation is None
            else np.asarray(participation))
        # chainless settlement only reads scores — don't pin up to
        # pipeline_depth extra param trees in the queue for nothing
        self._pending = _PendingRound(
            rec, self.global_params if self.use_blockchain else None, scores)
        self.history.append(rec)
        return rec

    # -- evaluation ------------------------------------------------------------

    def evaluate(self, eval_batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        batch = {k: jnp.asarray(v) for k, v in eval_batch.items()}
        loss, metrics = self._eval_fn(self.global_params, batch)
        return {k: float(v) for k, v in metrics.items()}

    def evaluate_per_worker(self, batch_w: Dict[str, np.ndarray]) -> np.ndarray:
        """Per-worker eval accuracy of the *global* model on each worker's
        local shard (the per-worker curves of Figs. 5/6)."""
        metrics = self._eval_per_worker_fn(
            self.global_params,
            {k: jnp.asarray(v) for k, v in batch_w.items()})
        return {k: np.asarray(v) for k, v in metrics.items()}

    def finalize(self) -> Dict[str, float]:
        self.flush()               # drain every in-flight pipelined round
        if self._settler is not None:
            self._settler.stop()   # stops the shard workers too
            self._settler = None
            self._shard_pool = None
        if self.contract is not None:
            return self.contract.finalize(
                timestamp=float(len(self.history) + 1))
        return {}
