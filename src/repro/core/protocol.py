"""SDFLBProtocol — host-level orchestration of the paper's full workflow
(§III.B/C): enrollment + staking on the contract, clustered local training
(the jitted ``fl_step``), trust scoring + on-chain settlement per round,
IPFS publication of cluster/global aggregates, deterministic head rotation
from on-chain randomness, and optional asynchronous arrivals.

Runs the paper's small-scale experiments end-to-end on CPU (Figs. 2-6);
the same jitted round is what the production launcher shards over pods.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.chain.contract import TrustContract
from repro.chain.ipfs import IPFSStore
from repro.chain.ledger import Ledger
from repro.configs.base import FederationConfig, ModelConfig, TrainConfig
from repro.core import async_agg, async_sim, fl_step
from repro.core.gossip import ClusterExchange
from repro.core.reputation import ReputationBook
from repro.models import api


@dataclass
class RoundRecord:
    round_index: int
    scores: np.ndarray
    weights: np.ndarray
    losses: np.ndarray
    penalties: Dict[str, float]
    heads: List[int]
    model_cid: str
    wall_time: float
    chain_time: float
    participation: Optional[np.ndarray] = None


class SDFLBProtocol:
    """One federated task. ``use_blockchain=False`` reproduces the paper's
    Fig. 2 ablation (identical learning dynamics, no chain work)."""

    def __init__(self, cfg: ModelConfig, fed: FederationConfig,
                 tc: TrainConfig, *, use_blockchain: bool = True,
                 seed: int = 0,
                 adversary: Optional[Callable] = None,
                 reputation_leaders: bool = False) -> None:
        self.cfg, self.fed, self.tc = cfg, fed, tc
        self.use_blockchain = use_blockchain
        self.W = fl_step.num_workers(fed)
        self.rng = jax.random.PRNGKey(seed)
        self.np_rng = np.random.default_rng(seed)
        self.adversary = adversary    # fn(worker_batch dict, worker_id) -> batch

        key, self.rng = jax.random.split(self.rng)
        self.global_params, _ = api.init(cfg, key, tp=1)
        self.opt_state = fl_step.init_worker_opt(self.global_params, fed, tc)
        self._round_fn = jax.jit(fl_step.make_fl_round(cfg, fed, tc))

        self.async_state = None
        self.scheduler = None
        if fed.async_mode:
            updates_like = jax.tree.map(
                lambda x: jnp.zeros((self.W,) + x.shape, jnp.float32),
                self.global_params)
            self.async_state = async_agg.init_async_state(updates_like, self.W)

        self.ledger = Ledger() if use_blockchain else None
        self.ipfs = IPFSStore() if use_blockchain else None
        self.contract = None
        if use_blockchain:
            self.contract = TrustContract(
                self.ledger, requester_deposit=fed.requester_deposit,
                worker_stake=fed.worker_stake, penalty_pct=fed.penalty_pct,
                trust_threshold=fed.trust_threshold, top_k=fed.top_k_rewarded)
            for w in range(self.W):
                self.contract.join(f"worker-{w}")
        self.history: List[RoundRecord] = []
        self.heads = [0] * fed.num_clusters
        # reputation (EMA of scores + penalty history) drives head election
        # when reputation_leaders=True — addresses the paper's §VI.E
        # bad-leader concern while keeping rotation stochastic
        self.reputation = ReputationBook(self.W)
        self.reputation_leaders = reputation_leaders
        self.exchange = (ClusterExchange(self.ipfs, self.ledger,
                                         fed.num_clusters)
                         if use_blockchain else None)

    # -- head rotation from on-chain randomness ------------------------------

    def _rotate_heads(self, round_index: int) -> List[int]:
        if self.ledger is not None:
            seed = self.ledger.randomness(round_index)
        else:
            seed = (self.fed.head_rotation_seed * 1_000_003 + round_index)
        wpc = self.fed.workers_per_cluster
        if self.reputation_leaders:
            self.heads = [
                self.reputation.elect(range(c * wpc, (c + 1) * wpc),
                                      rng_seed=seed + c)
                for c in range(self.fed.num_clusters)]
        else:
            rng = np.random.default_rng(seed)
            self.heads = [int(rng.integers(0, wpc))
                          for _ in range(self.fed.num_clusters)]
        return self.heads

    # -- one full protocol round ----------------------------------------------

    def run_round(self, batch: Dict[str, np.ndarray],
                  participation: Optional[np.ndarray] = None) -> RoundRecord:
        """batch leaves: (W, B, ...) — a single local step per round (paper's
        setup); reshaped to (W, 1, B, ...) for the step function."""
        t0 = time.monotonic()
        ridx = len(self.history)
        heads = self._rotate_heads(ridx)

        batch = {k: jnp.asarray(v)[:, None] for k, v in batch.items()}
        if self.adversary is not None:
            batch = self.adversary(batch, ridx)
        self.rng, rkey = jax.random.split(self.rng)
        part = (None if participation is None
                else jnp.asarray(participation, jnp.int32))

        if self.fed.async_mode:
            out, self.async_state = self._round_fn(
                self.global_params, self.opt_state, batch, rkey,
                part, self.async_state)
        else:
            out = self._round_fn(self.global_params, self.opt_state, batch,
                                 rkey, part)
        out = jax.block_until_ready(out)
        self.global_params, self.opt_state = out.global_params, out.opt_state
        scores = np.asarray(out.scores)
        train_time = time.monotonic() - t0

        # ---- blockchain work (scored + penalized on-chain, model on IPFS) ----
        tc0 = time.monotonic()
        penalties: Dict[str, float] = {}
        cid = ""
        if self.use_blockchain:
            cid = self.ipfs.put_tree(self.global_params)
            # cluster heads publish the round's global model for the
            # cross-cluster hash exchange (paper §III.A)
            for c in range(self.fed.num_clusters):
                self.exchange.publish(ridx, c, self.global_params)
            self.contract.pending.extend(self.exchange.round_transactions(ridx))
            penalties = self.contract.settle_round(
                ridx, {f"worker-{w}": float(scores[w]) for w in range(self.W)},
                model_cid=cid)
            assert self.ledger.verify_chain()
        self.reputation.update(
            scores, penalized=[int(k.split("-")[1]) for k in penalties])
        chain_time = time.monotonic() - tc0

        rec = RoundRecord(
            round_index=ridx, scores=scores, weights=np.asarray(out.weights),
            losses=np.asarray(out.losses), penalties=penalties, heads=heads,
            model_cid=cid, wall_time=train_time + chain_time,
            chain_time=chain_time,
            participation=None if participation is None
            else np.asarray(participation))
        self.history.append(rec)
        return rec

    # -- evaluation ------------------------------------------------------------

    def evaluate(self, eval_batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        loss_fn = api.loss_fn(self.cfg)
        batch = {k: jnp.asarray(v) for k, v in eval_batch.items()}
        loss, metrics = jax.jit(loss_fn)(self.global_params, batch)
        return {k: float(v) for k, v in metrics.items()}

    def evaluate_per_worker(self, batch_w: Dict[str, np.ndarray]) -> np.ndarray:
        """Per-worker eval accuracy of the *global* model on each worker's
        local shard (the per-worker curves of Figs. 5/6)."""
        loss_fn = api.loss_fn(self.cfg)

        def one(b):
            return loss_fn(self.global_params, b)[1]
        metrics = jax.jit(jax.vmap(one))(
            {k: jnp.asarray(v) for k, v in batch_w.items()})
        return {k: np.asarray(v) for k, v in metrics.items()}

    def finalize(self) -> Dict[str, float]:
        if self.contract is not None:
            return self.contract.finalize()
        return {}
