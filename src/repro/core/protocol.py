"""SDFLBProtocol — host-level orchestration of the paper's full workflow
(§III.B/C): enrollment + staking on the contract, clustered local training
(the jitted ``fl_step``), trust scoring + on-chain settlement per round,
IPFS publication of cluster/global aggregates, deterministic head rotation
from on-chain randomness, and optional asynchronous arrivals.

Pipelined round driver: ``run_round`` dispatches round r's jitted
``_round_fn`` *before* doing round r−1's host-side chain work, so contract
settlement / Merkle commitment / IPFS publication overlap device execution
instead of serializing behind a ``block_until_ready`` barrier. Scores are
fetched with an async device→host copy; the only sync point is reading the
materialized scores of the round just dispatched. Settlement therefore
trails training by exactly one round; ``flush()`` (called by ``finalize``
and safe to call any time) settles the trailing round. Decision sequences
are unchanged versus the serial driver: head rotation for round r still
sees the chain head of round r−1's block, and reputation-weighted election
still sees scores through round r−1.

Chain work is array-native end to end: workers are integer ids on the
struct-of-arrays contract (``settle_round_batch``), blocks commit per-worker
records via a Merkle root rather than W transaction dicts, and the round's
global model is serialized to IPFS once, with the C cluster heads
registering the same cid (identical fully-synchronized tree — one put, C
registrations).

Runs the paper's small-scale experiments end-to-end on CPU (Figs. 2-6);
the same jitted round is what the production launcher shards over pods.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.chain.contract import TrustContract
from repro.chain.ipfs import IPFSStore
from repro.chain.ledger import Ledger
from repro.configs.base import FederationConfig, ModelConfig, TrainConfig
from repro.core import async_agg, fl_step
from repro.core.gossip import ClusterExchange
from repro.core.reputation import ReputationBook
from repro.models import api


@dataclass
class RoundRecord:
    round_index: int
    scores: np.ndarray
    weights: np.ndarray
    losses: np.ndarray
    penalties: np.ndarray          # (W,) settlement penalties; zeros until
                                   # the round is settled (pipelined driver)
    heads: List[int]
    model_cid: str                 # "" until settled
    wall_time: float
    chain_time: float              # host chain work done during this call
                                   # (the *previous* round's settlement)
    participation: Optional[np.ndarray] = None
    settled: bool = False


@dataclass
class _PendingRound:
    record: RoundRecord
    params: Any                    # round's resulting global params (device)
    scores: np.ndarray


class SDFLBProtocol:
    """One federated task. ``use_blockchain=False`` reproduces the paper's
    Fig. 2 ablation (identical learning dynamics, no chain work)."""

    def __init__(self, cfg: ModelConfig, fed: FederationConfig,
                 tc: TrainConfig, *, use_blockchain: bool = True,
                 seed: int = 0,
                 adversary: Optional[Callable] = None,
                 reputation_leaders: bool = False) -> None:
        self.cfg, self.fed, self.tc = cfg, fed, tc
        self.use_blockchain = use_blockchain
        self.W = fl_step.num_workers(fed)
        self.rng = jax.random.PRNGKey(seed)
        self.np_rng = np.random.default_rng(seed)
        self.adversary = adversary    # fn(worker_batch dict, worker_id) -> batch

        key, self.rng = jax.random.split(self.rng)
        self.global_params, _ = api.init(cfg, key, tp=1)
        self.opt_state = fl_step.init_worker_opt(self.global_params, fed, tc)
        self._round_fn = jax.jit(fl_step.make_fl_round(cfg, fed, tc))
        # eval fns jitted once here (re-wrapping jax.jit per call would
        # recompile on every invocation)
        loss_fn = api.loss_fn(cfg)
        self._eval_fn = jax.jit(loss_fn)
        self._eval_per_worker_fn = jax.jit(
            jax.vmap(lambda p, b: loss_fn(p, b)[1], in_axes=(None, 0)))

        self.async_state = None
        self.scheduler = None
        if fed.async_mode:
            updates_like = jax.tree.map(
                lambda x: jnp.zeros((self.W,) + x.shape, jnp.float32),
                self.global_params)
            self.async_state = async_agg.init_async_state(updates_like, self.W)

        self.ledger = Ledger() if use_blockchain else None
        self.ipfs = IPFSStore() if use_blockchain else None
        self.contract = None
        if use_blockchain:
            self.contract = TrustContract(
                self.ledger, requester_deposit=fed.requester_deposit,
                worker_stake=fed.worker_stake, penalty_pct=fed.penalty_pct,
                trust_threshold=fed.trust_threshold, top_k=fed.top_k_rewarded)
            self.contract.join_batch(self.W)   # integer ids, one batch tx
        self.history: List[RoundRecord] = []
        self.heads = [0] * fed.num_clusters
        # reputation (EMA of scores + penalty history) drives head election
        # when reputation_leaders=True — addresses the paper's §VI.E
        # bad-leader concern while keeping rotation stochastic
        self.reputation = ReputationBook(self.W)
        self.reputation_leaders = reputation_leaders
        self.exchange = (ClusterExchange(self.ipfs, self.ledger,
                                         fed.num_clusters)
                         if use_blockchain else None)
        self._pending: Optional[_PendingRound] = None

    # -- head rotation from on-chain randomness ------------------------------

    def _rotate_heads(self, round_index: int) -> List[int]:
        if self.ledger is not None:
            seed = self.ledger.randomness(round_index)
        else:
            seed = (self.fed.head_rotation_seed * 1_000_003 + round_index)
        wpc = self.fed.workers_per_cluster
        if self.reputation_leaders:
            self.heads = [
                self.reputation.elect(range(c * wpc, (c + 1) * wpc),
                                      rng_seed=seed + c)
                for c in range(self.fed.num_clusters)]
        else:
            rng = np.random.default_rng(seed)
            self.heads = [int(rng.integers(0, wpc))
                          for _ in range(self.fed.num_clusters)]
        return self.heads

    # -- deferred chain work (round r settles during round r+1's device exec) -

    def _settle_pending(self) -> None:
        p, self._pending = self._pending, None
        if p is None:
            return
        ridx = p.record.round_index
        if self.use_blockchain:
            # one IPFS put of the (identical) global tree; every cluster
            # head registers the cid for the cross-cluster hash exchange
            # (paper §III.A)
            cid = self.ipfs.put_tree(p.params)
            for c in range(self.fed.num_clusters):
                self.exchange.register(ridx, c, cid)
            self.contract.pending.extend(self.exchange.round_transactions(ridx))
            pen = self.contract.settle_round_batch(ridx, p.scores,
                                                   model_cid=cid)
            p.record.model_cid = cid
            p.record.penalties = pen
            assert self.ledger.verify_chain()
            bad = p.scores < self.contract.T
        else:
            bad = np.zeros(self.W, bool)
        self.reputation.update(p.scores, penalized=bad)
        p.record.settled = True

    def flush(self) -> None:
        """Settle the trailing round (no-op when nothing is pending)."""
        self._settle_pending()

    # -- one full protocol round ----------------------------------------------

    def run_round(self, batch: Dict[str, np.ndarray],
                  participation: Optional[np.ndarray] = None) -> RoundRecord:
        """batch leaves: (W, B, ...) — a single local step per round (paper's
        setup); reshaped to (W, 1, B, ...) for the step function."""
        t0 = time.monotonic()
        ridx = len(self.history)

        batch = {k: jnp.asarray(v)[:, None] for k, v in batch.items()}
        if self.adversary is not None:
            batch = self.adversary(batch, ridx)
        self.rng, rkey = jax.random.split(self.rng)
        part = (None if participation is None
                else jnp.asarray(participation, jnp.int32))

        # 1. dispatch this round's jitted step — async, no barrier
        if self.fed.async_mode:
            out, self.async_state = self._round_fn(
                self.global_params, self.opt_state, batch, rkey,
                part, self.async_state)
        else:
            out = self._round_fn(self.global_params, self.opt_state, batch,
                                 rkey, part)
        self.global_params, self.opt_state = out.global_params, out.opt_state
        try:                       # start device→host copy of the scores
            out.scores.copy_to_host_async()
        except AttributeError:     # backend without async host copies
            pass

        # 2. previous round's host chain work overlaps this round's compute
        tc0 = time.monotonic()
        self._settle_pending()
        chain_time = time.monotonic() - tc0

        # 3. rotate heads for this round — the chain head is now the
        #    previous round's block, exactly as in the serial driver
        heads = self._rotate_heads(ridx)

        # 4. the only training-path sync point: this round's scores
        scores = np.asarray(out.scores)
        train_time = time.monotonic() - t0 - chain_time

        rec = RoundRecord(
            round_index=ridx, scores=scores, weights=np.asarray(out.weights),
            losses=np.asarray(out.losses),
            penalties=np.zeros(self.W, np.float64), heads=heads,
            model_cid="", wall_time=train_time + chain_time,
            chain_time=chain_time,
            participation=None if participation is None
            else np.asarray(participation))
        self._pending = _PendingRound(rec, self.global_params, scores)
        self.history.append(rec)
        return rec

    # -- evaluation ------------------------------------------------------------

    def evaluate(self, eval_batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        batch = {k: jnp.asarray(v) for k, v in eval_batch.items()}
        loss, metrics = self._eval_fn(self.global_params, batch)
        return {k: float(v) for k, v in metrics.items()}

    def evaluate_per_worker(self, batch_w: Dict[str, np.ndarray]) -> np.ndarray:
        """Per-worker eval accuracy of the *global* model on each worker's
        local shard (the per-worker curves of Figs. 5/6)."""
        metrics = self._eval_per_worker_fn(
            self.global_params,
            {k: jnp.asarray(v) for k, v in batch_w.items()})
        return {k: np.asarray(v) for k, v in metrics.items()}

    def finalize(self) -> Dict[str, float]:
        self.flush()               # settle the trailing pipelined round
        if self.contract is not None:
            return self.contract.finalize()
        return {}
