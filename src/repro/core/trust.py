"""Trust scoring — ``EvaluatePerformance`` of Algorithm 1, in JAX.

The paper evaluates workers on "model updates, protocol adherence, and
contribution quality". We quantify that with three jit-compatible terms over
the per-worker update vectors u_w and provisional consensus c = mean_w u_w:

  cosine   : cos(u_w, c)                      — directional agreement
  norm     : exp(-|log(‖u_w‖ / median‖u‖)|)   — magnitude plausibility
  loss     : relative local-loss improvement  — contribution quality

S(w) = w_cos·cos⁺ + w_norm·norm + w_loss·loss ∈ [0, 1].

Statistics are computed per-leaf and reduced (never materializing a (W, D)
matrix for billion-parameter models) on the reference path;
``update_stats_flat`` is the fused flat-pack variant (the ``trust_score``
Pallas kernel: one HBM sweep over the packed (W, D) update matrix) that
``fl_step`` engages via ``FederationConfig.fused_trust_path`` on flat/CNN
param trees. Both paths feed the same ``scores_from_stats`` — the score,
LOO-consensus, and penalization-filter math is shared, so the fused round
can only differ by reduction order.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FederationConfig


class TrustStats(NamedTuple):
    dot: jax.Array        # (W,)  <u_w, c>  vs INCLUSIVE consensus c = mean_w u
    sq_u: jax.Array       # (W,)  ‖u_w‖²
    sq_c: jax.Array       # ()    ‖c‖²
    loss_delta: jax.Array  # (W,) loss_before - loss_after (per worker)


def update_stats(updates, loss_before, loss_after) -> TrustStats:
    """updates: pytree with leading worker dim W on every leaf.

    No reshapes: reductions run over the leaves' natural axes so sharded
    layouts survive (reshaping a model-sharded (W, L, d, ff) leaf to (W, D)
    would force a full all-gather of every update)."""
    leaves = [x.astype(jnp.float32) for x in jax.tree.leaves(updates)]

    def red(x):
        return tuple(range(1, x.ndim))

    dot = sum(jnp.sum(x * jnp.mean(x, axis=0, keepdims=True), axis=red(x))
              for x in leaves)
    sq_u = sum(jnp.sum(jnp.square(x), axis=red(x)) for x in leaves)
    sq_c = sum(jnp.sum(jnp.square(jnp.mean(x, axis=0))) for x in leaves)
    return TrustStats(dot=dot, sq_u=sq_u, sq_c=sq_c,
                      loss_delta=loss_before - loss_after)


def update_stats_flat(updates_flat, loss_before, loss_after) -> TrustStats:
    """Fused-path twin of ``update_stats``: one streamed HBM pass over the
    flat-packed (W, D) update matrix (``kernels.fused_round.fused_stats``
    — Pallas on TPU, the identical flat-jnp reference on CPU)."""
    from repro.kernels import ops
    dot, sq_u, sq_c = ops.fused_stats(updates_flat)
    return TrustStats(dot=dot, sq_u=sq_u, sq_c=sq_c,
                      loss_delta=loss_before - loss_after)


def scores_from_stats(stats: TrustStats, fed: FederationConfig) -> jax.Array:
    """S(w) ∈ [0,1] per worker.

    The cosine term uses the LEAVE-ONE-OUT consensus c_w = mean_{v≠w} u_v —
    with the inclusive mean a strong attacker drags the consensus toward
    itself and scores *higher* than honest workers. LOO quantities derive
    algebraically from the inclusive stats (one HBM pass still suffices):

        <u_w, c_w>  = (W·<u_w,c> − ‖u_w‖²) / (W−1)
        ‖c_w‖²      = (W²‖c‖² − 2W·<u_w,c> + ‖u_w‖²) / (W−1)²
    """
    W = stats.dot.shape[0]
    if W > 1:
        dot_loo = (W * stats.dot - stats.sq_u) / (W - 1)
        sq_c_loo = (W * W * stats.sq_c - 2 * W * stats.dot
                    + stats.sq_u) / ((W - 1) ** 2)
    else:
        dot_loo, sq_c_loo = stats.dot, jnp.broadcast_to(stats.sq_c, (1,))
    norm_u = jnp.sqrt(stats.sq_u)
    cos = dot_loo / jnp.maximum(
        norm_u * jnp.sqrt(jnp.maximum(sq_c_loo, 0.0)), 1e-12)
    cos_term = jnp.clip(cos, 0.0, 1.0)

    med = jnp.median(norm_u)
    norm_term = jnp.exp(-jnp.abs(jnp.log(
        jnp.maximum(norm_u, 1e-12) / jnp.maximum(med, 1e-12))))

    # loss improvement relative to the cohort's best improvement
    best = jnp.maximum(jnp.max(stats.loss_delta), 1e-12)
    loss_term = jnp.clip(stats.loss_delta / best, 0.0, 1.0)

    s = (fed.w_cosine * cos_term + fed.w_norm * norm_term
         + fed.w_loss * loss_term)
    total = fed.w_cosine + fed.w_norm + fed.w_loss
    return s / total


def trust_weights(scores: jax.Array, fed: FederationConfig,
                  participation=None) -> jax.Array:
    """Aggregation weights: bad workers (S < T) are zeroed (the penalization
    filter); survivors weighted by score (soft) or uniformly (hard).
    ``participation``: optional (W,) 0/1 mask (async rounds)."""
    good = (scores >= fed.trust_threshold).astype(jnp.float32)
    w = good * (scores if fed.soft_trust_weighting else 1.0)
    if participation is not None:
        w = w * participation.astype(jnp.float32)
    # fall back to uniform if everything was filtered (keeps training alive)
    total = jnp.sum(w)
    uniform = (jnp.ones_like(w) if participation is None
               else participation.astype(jnp.float32))
    w = jnp.where(total > 0, w, uniform)
    return w / jnp.maximum(jnp.sum(w), 1e-12)


def staleness_discount(staleness: jax.Array, alpha: float) -> jax.Array:
    """Async functionality: 1/(1+s)^α staleness weighting."""
    return (1.0 + staleness.astype(jnp.float32)) ** (-alpha)
