"""The jit-compiled SDFL-B round — the framework's ``train_step``.

Workers carry an explicit leading dim W on params/optimizer-state/batch
(W = num_clusters × workers_per_cluster [× pods]). On the production mesh W
is sharded over the ``data`` (× ``pod``) axes, so "worker w" is a
data-parallel slot whose model is TP-sharded over ``model``. Because the
worker dim is a *batch* dim (vmap), per-worker gradients stay separate —
no implicit cross-worker psum — and the paper's aggregation (trust-weighted,
cluster-hierarchical, optionally asynchronous) is applied explicitly:

  1. broadcast global params to all workers
  2. ``local_steps`` of per-worker SGD(momentum) on the worker's own shard
  3. per-worker update u_w = params_w − global
  4. trust statistics + scores (core.trust — Algorithm 1's evaluation)
  5. hierarchy.aggregate: intra-cluster FedAvg (cluster head) then
     trust-weighted head↔head exchange; async mode folds in staleness
     discounting + pending buffers (core.async_agg)
  6. new global = global + aggregate

Steps 3–5 have two implementations. The per-leaf reference streams the
W×D update volume ~5 times (a full updates pytree, then three reductions
per leaf, then the aggregate). The fused flat-pack path
(``FederationConfig.fused_trust_path``, auto-on for unsharded flat/CNN
trees) computes the deltas directly into ONE contiguous (W, D) matrix
(``kernels.pack``) and chains the Pallas trust kernels
(``kernels.fused_round``) — two streamed passes total, the pytree
reassembled exactly once for the global update. Both paths share the
score/weight math in ``core.trust``/``core.async_agg`` and are
property-tested equivalent (``tests/test_fused_round.py``).

Host-level protocol work (contract settlement, ledger blocks, IPFS
publication, head rotation bookkeeping) happens *between* jitted rounds in
``core.protocol``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FederationConfig, ModelConfig, TrainConfig
from repro.core import async_agg, hierarchy, trust
from repro.kernels import fused_round, ops, pack
from repro.models import api
from repro.optim import clip_grads, init_opt, opt_update


class RoundOutput(NamedTuple):
    global_params: object
    opt_state: object
    scores: jax.Array          # (W,) trust scores S(w)
    weights: jax.Array         # (W,) effective aggregation weights
    losses: jax.Array          # (W,) final local loss per worker
    metrics: dict


def num_workers(fed: FederationConfig, *, pods: int = 1) -> int:
    return fed.num_clusters * fed.workers_per_cluster * pods


def fused_round_enabled(cfg: ModelConfig, fed: FederationConfig, params,
                        *, constrained: bool = False) -> bool:
    """Static (trace-time) decision for the flat-pack fused trust path.

    ``auto`` engages only where flattening is free: an unsharded
    (no mesh constraints — reshaping a model-sharded leaf to (W, D)
    would force a full all-gather) flat/CNN param tree with one leaf
    dtype. ``on`` forces it for any packable tree; ``off`` keeps the
    per-leaf reference everywhere.
    """
    knob = fed.fused_trust_path
    if knob == "off":
        return False
    ok = pack.packable(params)
    if knob == "on":
        if not ok:
            raise ValueError(
                "fused_trust_path='on' requires a packable param tree "
                "(uniform floating leaf dtype)")
        return True
    if knob != "auto":
        raise ValueError(f"fused_trust_path must be auto|on|off, "
                         f"got {knob!r}")
    return ok and cfg.family == "cnn" and not constrained


def init_async_state_for(cfg: ModelConfig, fed: FederationConfig,
                         global_params, W: int) -> async_agg.AsyncState:
    """Async state matching the path ``make_fl_round`` will take: on the
    fused path the pending buffer is a flat (W_pad, D_pad) f32 matrix
    (padded once to the async kernel's tile grid — see
    ``fused_round.pending_shape``); otherwise the per-leaf pytree."""
    if fused_round_enabled(cfg, fed, global_params):
        spec = pack.pack_spec(global_params)
        return async_agg.AsyncState(
            staleness=jnp.zeros((W,), jnp.int32),
            pending=jnp.zeros(fused_round.pending_shape(W, spec.total),
                              jnp.float32))
    updates_like = jax.tree.map(
        lambda x: jnp.zeros((W,) + x.shape, jnp.float32), global_params)
    return async_agg.init_async_state(updates_like, W)


def make_fl_round(cfg: ModelConfig, fed: FederationConfig, tc: TrainConfig,
                  worker_constraint=None, param_constraint=None):
    """Builds the synchronous FL-round function (jit-able / lowerable).

    ``worker_constraint``: optional fn(tree_with_leading_W_dim) -> tree that
    applies sharding constraints pinning the worker dim to the data mesh
    axes (launch/specs.py builds it). Without it GSPMD may replicate every
    worker's parameter copy on every data slot — catastrophic at scale.

    ``param_constraint``: optional fn(per-worker param tree) -> tree applied
    *inside* the differentiated worker loss. Cotangents inherit sharding
    constraints, so this pins the per-layer grad stacks to the parameter
    sharding (otherwise the backward scan may emit fully-replicated f32
    grad stacks).
    """
    loss_fn = api.loss_fn(cfg, remat=tc.remat, kv_chunk=tc.kv_chunk)
    wsc = worker_constraint or (lambda t: t)
    pwsc = param_constraint or (lambda t: t)
    constrained = (worker_constraint is not None
                   or param_constraint is not None)

    def worker_train(params, opt, batch, rng):
        """One worker: ``local_steps`` SGD steps on its own data."""

        def one_step(carry, step_batch):
            p, o, r = carry
            r, sub = (jax.random.split(r) if r is not None else (None, None))
            (l, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                p, step_batch, sub)
            grads = clip_grads(grads, tc.grad_clip)
            p, o = opt_update(p, grads, o, tc)
            return (p, o, r), l

        if tc.local_steps == 1:
            step_batch = jax.tree.map(lambda x: x[0], batch)
            (p, o, _), l = one_step((params, opt, rng), step_batch)
            losses = l[None]
        else:
            (p, o, _), losses = jax.lax.scan(one_step, (params, opt, rng), batch)
        return p, o, losses

    def fl_round(global_params, opt_state, batch, rngs=None,
                 participation=None, async_state=None):
        """batch leaves: (W, local_steps, per_worker_batch, ...).
        participation: optional (W,) 0/1; async_state: async_agg.AsyncState.
        """
        W = jax.tree.leaves(batch)[0].shape[0]
        # trace-time path selection: dtypes/structure only, no data
        use_fused = fused_round_enabled(cfg, fed, global_params,
                                        constrained=constrained)
        params_w = wsc(hierarchy.broadcast_to_workers(global_params, W))
        rngs_w = (jax.random.split(rngs, W) if rngs is not None else None)
        if tc.local_steps == 1:
            # single local step: keep only grad computation inside vmap so
            # the per-worker grads can be sharding-constrained before the
            # (elementwise, stack-friendly) optimizer update — otherwise the
            # stacked f32 grads replicate across the model axis.
            def worker_grad(p, b, r):
                step_batch = jax.tree.map(lambda x: x[0], b)

                def loss_c(p_, b_, r_):
                    return loss_fn(pwsc(p_), b_, r_)
                (l, m), g = jax.value_and_grad(loss_c, has_aux=True)(
                    p, step_batch, r)
                return clip_grads(g, tc.grad_clip), l
            vm = jax.vmap(worker_grad,
                          in_axes=(0, 0, 0 if rngs is not None else None))
            grads, l_pre = vm(params_w, batch, rngs_w)
            new_p, new_opt = opt_update(params_w, wsc(grads), opt_state, tc)
            if fed.w_loss > 0:
                # contribution quality needs a live loss delta: re-evaluate
                # the SAME batch (and dropout rng — the mask cancels) at the
                # post-step params. Without this, a single local step would
                # yield losses[:,0] == losses[:,-1] and the paper's
                # loss-improvement term would silently contribute nothing.
                def worker_loss(p, b, r):
                    step_batch = jax.tree.map(lambda x: x[0], b)
                    return loss_fn(pwsc(p), step_batch, r)[0]
                vl = jax.vmap(worker_loss,
                              in_axes=(0, 0, 0 if rngs is not None else None))
                l_post = vl(new_p, batch, rngs_w)
                losses = jnp.stack([l_pre, l_post], axis=1)
            else:
                losses = l_pre[:, None]
        else:
            vm = jax.vmap(worker_train,
                          in_axes=(0, 0, 0, 0 if rngs is not None else None))
            new_p, new_opt, losses = vm(params_w, opt_state, batch, rngs_w)
        new_p = wsc(new_p)

        metrics = {"mean_loss": jnp.mean(losses[:, -1]),
                   "mean_loss_delta": jnp.mean(losses[:, 0] - losses[:, -1])}
        if use_fused:
            # flat-pack fused path: deltas land directly in ONE contiguous
            # (W, D) matrix (param dtype — bf16 deltas carry full *relative*
            # precision), trust stats + weighted aggregation chain the
            # fused kernels (2 streamed HBM passes over the update volume),
            # and the pytree is reassembled exactly once from the (D,)
            # aggregate. Every aggregation ``mode`` telescopes to the same
            # Σ w·u, so the fused sum is value-identical to the hierarchy.
            spec = pack.pack_spec(global_params)
            upd_flat = pack.pack_delta(new_p, global_params, spec)
            stats = trust.update_stats_flat(upd_flat,
                                            losses[:, 0], losses[:, -1])
            scores = trust.scores_from_stats(stats, fed)
            if fed.async_mode:
                assert async_state is not None and participation is not None
                weights = async_agg.effective_weights(
                    scores, participation, async_state.staleness, fed)
                keep = 1.0 - participation.astype(jnp.float32)
                agg_flat, new_pending = ops.fused_async_agg(
                    upd_flat, async_state.pending, weights, keep)
                new_staleness = jnp.where(participation > 0, 0,
                                          async_state.staleness + 1)
                new_async = async_agg.AsyncState(new_staleness, new_pending)
                metrics["cohort_size"] = jnp.sum(participation > 0)
                metrics["mean_staleness"] = jnp.mean(
                    async_state.staleness.astype(jnp.float32))
            else:
                weights = trust.trust_weights(scores, fed,
                                              participation=participation)
                agg_flat = ops.fused_agg(upd_flat, weights)
                new_async = async_state
            agg = pack.unpack_vector(agg_flat, spec)
        else:
            # per-leaf reference: deltas are stored in the param dtype (bf16
            # deltas carry full *relative* precision; trust stats and
            # aggregation upcast per-leaf)
            updates = wsc(jax.tree.map(
                lambda a, g: (a.astype(jnp.float32)
                              - g.astype(jnp.float32)[None]).astype(a.dtype),
                new_p, global_params))
            stats = trust.update_stats(updates, losses[:, 0], losses[:, -1])
            scores = trust.scores_from_stats(stats, fed)

            if fed.async_mode:
                # first-class async round variant: staleness-weighted
                # buffered aggregation over the arrived cohort
                # (core.async_agg), with the cohort/staleness telemetry the
                # event-driven node reports
                assert async_state is not None and participation is not None
                agg, new_async, weights = async_agg.async_round(
                    updates, scores, participation, async_state, fed)
                metrics["cohort_size"] = jnp.sum(participation > 0)
                metrics["mean_staleness"] = jnp.mean(
                    async_state.staleness.astype(jnp.float32))
            else:
                weights = trust.trust_weights(scores, fed,
                                              participation=participation)
                if fed.mode == "head_gather":
                    agg = hierarchy.aggregate_head_gather(updates, weights,
                                                          fed)
                elif fed.mode == "two_stage":
                    agg = hierarchy.aggregate(updates, weights, fed)
                else:   # "allreduce": fused (identical value, one collective)
                    agg = hierarchy.aggregate_fused(updates, weights)
                new_async = async_state

        new_global = jax.tree.map(
            lambda g, a: (g.astype(jnp.float32) + a).astype(g.dtype),
            global_params, agg)
        out = RoundOutput(new_global, new_opt, scores, weights,
                          losses[:, -1], metrics)
        if fed.async_mode:
            return out, new_async
        return out

    return fl_round


def init_worker_opt(global_params, fed: FederationConfig, tc: TrainConfig,
                    *, pods: int = 1):
    """Per-worker optimizer state: leading W dim on every leaf."""
    W = num_workers(fed, pods=pods)
    single = init_opt(global_params, tc)
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (W,) + x.shape),
                        single)
