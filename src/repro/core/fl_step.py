"""The jit-compiled SDFL-B round — the framework's ``train_step``.

Workers carry an explicit leading dim W on params/optimizer-state/batch
(W = num_clusters × workers_per_cluster [× pods]). On the production mesh W
is sharded over the ``data`` (× ``pod``) axes, so "worker w" is a
data-parallel slot whose model is TP-sharded over ``model``. Because the
worker dim is a *batch* dim (vmap), per-worker gradients stay separate —
no implicit cross-worker psum — and the paper's aggregation (trust-weighted,
cluster-hierarchical, optionally asynchronous) is applied explicitly:

  1. broadcast global params to all workers
  2. ``local_steps`` of per-worker SGD(momentum) on the worker's own shard
  3. per-worker update u_w = params_w − global
  4. trust statistics + scores (core.trust — Algorithm 1's evaluation)
  5. hierarchy.aggregate: intra-cluster FedAvg (cluster head) then
     trust-weighted head↔head exchange; async mode folds in staleness
     discounting + pending buffers (core.async_agg)
  6. new global = global + aggregate

Host-level protocol work (contract settlement, ledger blocks, IPFS
publication, head rotation bookkeeping) happens *between* jitted rounds in
``core.protocol``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FederationConfig, ModelConfig, TrainConfig
from repro.core import async_agg, hierarchy, trust
from repro.models import api
from repro.optim import clip_grads, init_opt, opt_update


class RoundOutput(NamedTuple):
    global_params: object
    opt_state: object
    scores: jax.Array          # (W,) trust scores S(w)
    weights: jax.Array         # (W,) effective aggregation weights
    losses: jax.Array          # (W,) final local loss per worker
    metrics: dict


def num_workers(fed: FederationConfig, *, pods: int = 1) -> int:
    return fed.num_clusters * fed.workers_per_cluster * pods


def make_fl_round(cfg: ModelConfig, fed: FederationConfig, tc: TrainConfig,
                  worker_constraint=None, param_constraint=None):
    """Builds the synchronous FL-round function (jit-able / lowerable).

    ``worker_constraint``: optional fn(tree_with_leading_W_dim) -> tree that
    applies sharding constraints pinning the worker dim to the data mesh
    axes (launch/specs.py builds it). Without it GSPMD may replicate every
    worker's parameter copy on every data slot — catastrophic at scale.

    ``param_constraint``: optional fn(per-worker param tree) -> tree applied
    *inside* the differentiated worker loss. Cotangents inherit sharding
    constraints, so this pins the per-layer grad stacks to the parameter
    sharding (otherwise the backward scan may emit fully-replicated f32
    grad stacks).
    """
    loss_fn = api.loss_fn(cfg, remat=tc.remat, kv_chunk=tc.kv_chunk)
    wsc = worker_constraint or (lambda t: t)
    pwsc = param_constraint or (lambda t: t)

    def worker_train(params, opt, batch, rng):
        """One worker: ``local_steps`` SGD steps on its own data."""

        def one_step(carry, step_batch):
            p, o, r = carry
            r, sub = (jax.random.split(r) if r is not None else (None, None))
            (l, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                p, step_batch, sub)
            grads = clip_grads(grads, tc.grad_clip)
            p, o = opt_update(p, grads, o, tc)
            return (p, o, r), l

        if tc.local_steps == 1:
            step_batch = jax.tree.map(lambda x: x[0], batch)
            (p, o, _), l = one_step((params, opt, rng), step_batch)
            losses = l[None]
        else:
            (p, o, _), losses = jax.lax.scan(one_step, (params, opt, rng), batch)
        return p, o, losses

    def fl_round(global_params, opt_state, batch, rngs=None,
                 participation=None, async_state=None):
        """batch leaves: (W, local_steps, per_worker_batch, ...).
        participation: optional (W,) 0/1; async_state: async_agg.AsyncState.
        """
        W = jax.tree.leaves(batch)[0].shape[0]
        params_w = wsc(hierarchy.broadcast_to_workers(global_params, W))
        rngs_w = (jax.random.split(rngs, W) if rngs is not None else None)
        if tc.local_steps == 1:
            # single local step: keep only grad computation inside vmap so
            # the per-worker grads can be sharding-constrained before the
            # (elementwise, stack-friendly) optimizer update — otherwise the
            # stacked f32 grads replicate across the model axis.
            def worker_grad(p, b, r):
                step_batch = jax.tree.map(lambda x: x[0], b)

                def loss_c(p_, b_, r_):
                    return loss_fn(pwsc(p_), b_, r_)
                (l, m), g = jax.value_and_grad(loss_c, has_aux=True)(
                    p, step_batch, r)
                return clip_grads(g, tc.grad_clip), l
            vm = jax.vmap(worker_grad,
                          in_axes=(0, 0, 0 if rngs is not None else None))
            grads, l = vm(params_w, batch, rngs_w)
            new_p, new_opt = opt_update(params_w, wsc(grads), opt_state, tc)
            losses = l[:, None]
        else:
            vm = jax.vmap(worker_train,
                          in_axes=(0, 0, 0, 0 if rngs is not None else None))
            new_p, new_opt, losses = vm(params_w, opt_state, batch, rngs_w)
        new_p = wsc(new_p)

        # deltas are stored in the param dtype (bf16 deltas carry full
        # *relative* precision; trust stats and aggregation upcast per-leaf)
        updates = wsc(jax.tree.map(
            lambda a, g: (a.astype(jnp.float32)
                          - g.astype(jnp.float32)[None]).astype(a.dtype),
            new_p, global_params))
        stats = trust.update_stats(updates, losses[:, 0], losses[:, -1])
        scores = trust.scores_from_stats(stats, fed)

        metrics = {"mean_loss": jnp.mean(losses[:, -1])}
        if fed.async_mode:
            # first-class async round variant: staleness-weighted buffered
            # aggregation over the arrived cohort (core.async_agg), with the
            # cohort/staleness telemetry the event-driven node reports
            assert async_state is not None and participation is not None
            agg, new_async, weights = async_agg.async_round(
                updates, scores, participation, async_state, fed)
            metrics["cohort_size"] = jnp.sum(participation > 0)
            metrics["mean_staleness"] = jnp.mean(
                async_state.staleness.astype(jnp.float32))
        else:
            weights = trust.trust_weights(scores, fed,
                                          participation=participation)
            if fed.mode == "head_gather":
                agg = hierarchy.aggregate_head_gather(updates, weights, fed)
            elif fed.mode == "two_stage":
                agg = hierarchy.aggregate(updates, weights, fed)
            else:   # "allreduce": fused (identical value, one collective)
                agg = hierarchy.aggregate_fused(updates, weights)
            new_async = async_state

        new_global = jax.tree.map(
            lambda g, a: (g.astype(jnp.float32) + a).astype(g.dtype),
            global_params, agg)
        out = RoundOutput(new_global, new_opt, scores, weights,
                          losses[:, -1], metrics)
        if fed.async_mode:
            return out, new_async
        return out

    return fl_round


def init_worker_opt(global_params, fed: FederationConfig, tc: TrainConfig,
                    *, pods: int = 1):
    """Per-worker optimizer state: leading W dim on every leaf."""
    W = num_workers(fed, pods=pods)
    single = init_opt(global_params, tc)
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (W,) + x.shape),
                        single)
