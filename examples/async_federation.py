"""Scenario: asynchronous SDFL-B with stragglers and failures.

8 workers, 25% of them 6x slower and occasionally dropping updates. The
event-driven scheduler decides when enough updates arrived (buffer of 4);
staleness-discounted aggregation folds late updates in when they show up.
Compares simulated wall-clock against the synchronous barrier.

    PYTHONPATH=src python examples/async_federation.py
"""

from repro.configs.base import FederationConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core import async_sim
from repro.core.protocol import SDFLBProtocol
from repro.data.datasets import make_federated_mnist


def main() -> None:
    W = 8
    fed = FederationConfig(num_clusters=2, workers_per_cluster=4,
                           trust_threshold=0.2, async_mode=True,
                           staleness_alpha=0.5)
    tc = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd")
    proto = SDFLBProtocol(get_config("paper-net"), fed, tc, seed=0)
    ds = make_federated_mnist(W, samples=4096, seed=0)
    profiles = async_sim.heterogeneous_profiles(
        W, straggler_frac=0.25, straggler_slowdown=6.0, failure_prob=0.05,
        seed=0)
    sched = async_sim.AsyncScheduler(profiles, seed=0, buffer_size=4)

    ev = ds.eval_batch(512)
    sync_clock = 0.0
    for r in range(30):
        t, mask, staleness = sched.next_aggregation()
        sync_clock += sched.sync_round_time()
        proto.run_round(ds.round_batches(32), participation=mask)
        if (r + 1) % 10 == 0:
            m = proto.evaluate(ev)
            print(f"agg {r + 1:3d}  async_clock={t:7.2f}s "
                  f"(sync would be {sync_clock:7.2f}s)  "
                  f"arrived={mask.sum()}/{W}  acc={m['accuracy']:.3f}")
    proto.finalize()
    print(f"\nasync speedup vs slowest-worker barrier: "
          f"{sync_clock / t:.2f}x")


if __name__ == "__main__":
    main()
