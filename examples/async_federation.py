"""Scenario: asynchronous SDFL-B with stragglers, failures, and a
co-tenant straggler task — the event-driven node end to end.

Task "fast": 8 workers, 25% of them 6x slower and occasionally dropping
updates (churn). The node's arrival frontier decides when enough updates
arrived (buffer of 4); staleness-discounted aggregation folds late updates
in when they show up, and each event seals exactly the arrived cohort
on-chain with its staleness in the settlement records. Task "slow" shares
the same chain node with 10x slower workers — events interleave by
simulated time, so the straggler task never stalls the fast one.

    PYTHONPATH=src python examples/async_federation.py
"""
import numpy as np

from repro.configs.base import FederationConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core import async_sim
from repro.core.node import ChainNode


def _fed(task_id: str) -> FederationConfig:
    return FederationConfig(num_clusters=2, workers_per_cluster=4,
                            trust_threshold=0.2, async_mode=True,
                            staleness_alpha=0.5, buffer_size=4,
                            task_id=task_id)


def main() -> None:
    W, events = 8, 45
    cfg = get_config("paper-net")
    tc = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd")
    node = ChainNode(pipeline_depth=2)

    # churn profile: 25% stragglers 6x slower, 5% of updates lost
    churn = async_sim.heterogeneous_profiles(
        W, straggler_frac=0.25, straggler_slowdown=6.0, failure_prob=0.05,
        seed=0)
    fast = node.create_task("fast", cfg, _fed("fast"), tc, seed=0,
                            profiles=churn)
    slow_profiles = [async_sim.WorkerProfile(speed=10.0, jitter=0.2)
                     for _ in range(W)]
    node.create_task("slow", cfg, _fed("slow"), tc, seed=1,
                     profiles=slow_profiles)

    from repro.data.datasets import make_federated_mnist
    ds = {tid: make_federated_mnist(W, samples=4096, seed=i)
          for i, tid in enumerate(("fast", "slow"))}
    ev = ds["fast"].eval_batch(512)

    sync_barrier = async_sim.AsyncScheduler(churn, seed=0, buffer_size=W)
    fns = {tid: (lambda r, d=d: d.round_batches(32))
           for tid, d in ds.items()}
    recs, printed = {"fast": [], "slow": []}, 0
    for _ in range(events // 5):
        new = node.run_events(fns, events=5)
        for tid in recs:
            recs[tid].extend(new[tid])
        while len(recs["fast"]) >= printed + 10:
            printed += 10
            rec = recs["fast"][printed - 1]
            m = fast.evaluate(ev)
            cohort = rec.participation > 0
            lat = rec.sim_time - rec.arrival_times[cohort]
            print(f"event {printed:3d}  t={rec.sim_time:7.2f}s  "
                  f"arrived={int(cohort.sum())}/{W}  "
                  f"seal_latency_p95={np.percentile(lat, 95):.2f}s  "
                  f"acc={m['accuracy']:.3f}")
    node.flush()
    t = recs["fast"][-1].sim_time
    sync_clock = sum(sync_barrier.sync_round_time()
                     for _ in range(len(recs["fast"])))
    print(f"\nfast task: {len(recs['fast'])} events, "
          f"slow co-tenant: {len(recs['slow'])} events "
          f"(chain never waits for the straggler task)")
    print(f"async speedup vs slowest-worker barrier: {sync_clock / t:.2f}x")

    # per-worker staleness / penalty summary, straight off the chain
    print(f"\n{'worker':>6} {'events':>7} {'max_stale':>9} "
          f"{'penalty':>9} {'stake':>7}")
    n_events = np.zeros(W, int)
    max_stale = np.zeros(W, int)
    for rec in recs["fast"]:
        n_events += rec.participation > 0
        max_stale = np.maximum(max_stale, rec.staleness)
    pen = fast.reputation.penalties
    for w in range(W):
        print(f"{w:>6} {n_events[w]:>7} {max_stale[w]:>9} "
              f"{pen[w]:>9.2f} {fast.contract.stake[w]:>7.2f}")

    assert node.ledger.verify_chain(deep=True)
    # an external auditor: header-only light client fetches + verifies
    # worker 0's last cohort record straight off the read server
    from repro.serve import LightClient
    auditor = LightClient(node.read_server())
    auditor.sync()
    record = auditor.audit("fast", 0,
                           round_index=recs["fast"][-1].round_index)
    print(f"\nchain deep-verified; light-client audit of worker 0's last "
          f"settlement record (staleness on-chain): {record}")
    node.finalize()


if __name__ == "__main__":
    main()
