"""Scenario: multi-node semi-decentralized settlement, end to end.

Three chain replicas (one per cluster head) drive four settlement rounds
over a deterministic simulated network, through escalating faults:

1. **fault-free** — scores, cluster aggregates, and sealed blocks gossip
   over lossy links; every replica converges to one byte-identical chain
   with bit-equal contract state (checked against a from-scratch replay
   of the canonical chain).
2. **partition → forks → rejoin** — a 2-round split leaves the minority
   replica on its own fork; fork choice (longest valid chain, cumulative
   seal-trust tiebreak) reorgs it back onto the winner, rolling contract
   state back and replaying it forward block by block.
3. **byzantine head** — an equivocating head seals two conflicting
   blocks for the same slot; honest replicas detect the conflict on
   receipt, seal equivocation evidence on-chain, blanket-reject the
   offender, and slash its head worker's stake.
4. **light client across the reorg** — a ``LightClient`` synced to the
   minority fork observes the rejoin as a header ``reset`` (the
   sync_head mismatch is a real reorg signal) and re-verifies settlement
   proofs against the winning chain.

    PYTHONPATH=src python examples/decentralized_network.py
"""
import numpy as np

from repro.net import (LinkSpec, NetworkHarness, contract_fingerprint,
                       head_worker, replay_chain)
from repro.serve import ChainReadServer, LightClient


def fault_free() -> None:
    print("== 1. fault-free convergence over lossy links ==")
    h = NetworkHarness(3, seed=11,
                       link=LinkSpec(latency=0.02, jitter=0.02, loss=0.1))
    h.run(4)
    h.sync()
    heads = {n.ledger.head.hash for n in h.nodes}
    assert len(heads) == 1 and h.converged()
    n0 = h.nodes[0]
    _, replayed = replay_chain(n0.ledger.blocks, n0.ledger._commits,
                               h.workers_per_node)
    assert contract_fingerprint(replayed) == contract_fingerprint(n0.contract)
    print(f"  3 replicas, head {n0.ledger.head.hash[:12]}…, "
          f"{h.net.delivered} msgs delivered "
          f"({h.net.dropped_loss} lost), state bit-equal to replay\n")


def partition_rejoin() -> None:
    print("== 2. partition -> forks -> rejoin ==")
    h = NetworkHarness(3, seed=4, partition_rounds=[(1, 3, ((0, 1), (2,)))])
    h.run(3)
    forked = h.nodes[2].ledger.head.hash != h.nodes[0].ledger.head.hash
    print(f"  during split: minority on its own fork = {forked}")
    h.run(1)
    assert h.converged()
    print(f"  after rejoin: minority reorged {h.nodes[2].reorgs}x onto the "
          f"majority fork, all {len(h.nodes[0].ledger.blocks)} blocks "
          f"byte-identical, rounds settled = "
          f"{sorted(h.nodes[0].contract._round_blocks)}\n")


def byzantine_head() -> NetworkHarness:
    print("== 3. equivocating byzantine head ==")
    byz = 1
    h = NetworkHarness(3, seed=2, byzantine={byz: "equivocate"})
    h.run(4)
    honest = h.honest_nodes()
    n = honest[0]
    txs = [tx for b in n.ledger.blocks for tx in b.transactions
           if isinstance(tx, dict)]
    ev = next(tx for tx in txs if tx.get("type") == "equivocation")
    w = head_worker(ev["round"], byz, h.workers_per_node)
    print(f"  node {byz} equivocated in round {ev['round']}: "
          f"{len(ev['blocks'])} conflicting blocks seen")
    print(f"  evidence on-chain, head worker {w} slashed: stake "
          f"{n.contract.stake[w]:.1f} (full stake is "
          f"{n.contract.F:.1f}), penalized "
          f"{int(n.contract.penalized_rounds[w])}x")
    assert all(tx["proposer"] != byz for tx in txs
               if tx.get("type") == "seal")
    print(f"  no byzantine seal canonicalized; rounds "
          f"{sorted(n.contract._round_blocks)} still settled by honest "
          f"backups\n")
    return h


def light_client_reorg() -> None:
    print("== 4. light client across the reorg ==")
    h = NetworkHarness(3, seed=3, partition_rounds=[(1, 3, ((0, 1), (2,)))])
    minority = h.nodes[2]
    server = ChainReadServer(ledger=minority.ledger,
                             contracts={None: minority.contract})
    client = LightClient(server)
    h.run(3)
    client.sync()
    fork_head = client.headers[-1].hash[:12]
    h.run(2)
    client.sync()
    r = server.latest_settled_round(None)
    batch = server.get_proofs(None, list(range(h.workers_per_node)),
                              round_index=r)
    assert client.verify_batch(batch)
    print(f"  client tracked fork {fork_head}…; reorg observed as "
          f"{client.reorg_resyncs} reset resync "
          f"(server counted {server.head_resets}); now on "
          f"{client.headers[-1].hash[:12]}… with round-{r} proofs "
          f"verified\n")


def main() -> None:
    np.set_printoptions(precision=3)
    fault_free()
    partition_rejoin()
    byzantine_head()
    light_client_reorg()
    print("all scenarios converged.")


if __name__ == "__main__":
    main()
