"""Scenario: the generic-codebase claim (paper §VI.D) — the same SDFL-B
protocol federating an assigned LLM architecture (pick any of the 10 via
--arch; smoke-size on CPU, full-size on a real mesh via launch/train.py).

    PYTHONPATH=src python examples/federated_llm.py --arch qwen2-moe-a2.7b
"""
import argparse

import numpy as np

from repro.configs.base import FederationConfig, TrainConfig
from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.core.protocol import SDFLBProtocol
from repro.data.datasets import synthetic_tokens


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--rounds", type=int, default=5)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    fed = FederationConfig(num_clusters=2, workers_per_cluster=2,
                           trust_threshold=0.1)
    tc = TrainConfig(optimizer="adamw", lr=3e-4, grad_clip=1.0, remat=False)
    proto = SDFLBProtocol(cfg, fed, tc, use_blockchain=True, seed=0)

    for r in range(args.rounds):
        data = synthetic_tokens(4, 2, 128, cfg.vocab_size, seed=r)
        rec = proto.run_round(data)
        print(f"round {r + 1}: mean_loss={float(np.mean(rec.losses)):.3f} "
              f"trust={rec.scores.round(2).tolist()}")
    proto.finalize()
    print("ledger verified:", proto.ledger.verify_chain())


if __name__ == "__main__":
    main()
