"""Scenario: trust penalization defending against poisoning workers.

8 workers in 2 clusters; two of them label-flip every round. Shows the
trust scores separating attackers from honest workers, stake erosion via
Algorithm 1 penalties, and the accuracy protection vs an unprotected run.

    PYTHONPATH=src python examples/poisoning_defense.py
"""

from repro.configs.base import FederationConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core.protocol import SDFLBProtocol
from repro.data.datasets import make_federated_mnist

BAD = (0, 5)


def flip(batch, round_index):
    labels = batch["labels"]
    for w in BAD:
        labels = labels.at[w].set(9 - labels[w])
    return {**batch, "labels": labels}


def run(trust_on: bool) -> dict:
    fed = FederationConfig(num_clusters=2, workers_per_cluster=4,
                           trust_threshold=0.45 if trust_on else -1.0,
                           soft_trust_weighting=trust_on, penalty_pct=5.0)
    tc = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd")
    proto = SDFLBProtocol(get_config("paper-net"), fed, tc, seed=0,
                          adversary=flip)
    ds = make_federated_mnist(8, samples=4096, seed=0)
    for _ in range(40):
        rec = proto.run_round(ds.round_batches(32))
    acc = proto.evaluate(ds.eval_batch(512))["accuracy"]
    proto.flush()   # pipelined driver: settle the trailing round first
    stakes = {w: proto.contract.workers[f"worker-{w}"].stake for w in range(8)}
    proto.finalize()
    return {"acc": acc, "scores": rec.scores, "stakes": stakes}


def main() -> None:
    on = run(True)
    off = run(False)
    print("final trust scores (defended run):")
    for w in range(8):
        tag = "ATTACKER" if w in BAD else "honest"
        print(f"  worker {w} [{tag:8s}]  S={on['scores'][w]:.3f}  "
              f"stake_left={on['stakes'][w]:.1f}")
    print(f"\naccuracy with trust penalization   : {on['acc']:.3f}")
    print(f"accuracy without (uniform weights) : {off['acc']:.3f}")


if __name__ == "__main__":
    main()
