"""Scenario: trust penalization defending against poisoning attacks.

Two attack levels, same defense:

- **worker-level** (the default): 8 workers in 2 clusters; two of them
  label-flip every round. Trust scores separate the attackers, stakes
  erode via Algorithm 1 penalties, accuracy is protected vs an
  unprotected run.
- **head-level** (``--head``): a byzantine *cluster head* poisons its
  entire cluster's contribution — every worker of cluster 0 ships
  flipped labels, standing in for a head that corrupts the cluster
  aggregate before publication. Same attacker count as the worker-level
  run, but *coherent*: the whole rogue cluster pulls in one poisoned
  direction instead of two scattered workers. The same per-worker trust
  scoring still catches it (the rogue cluster's workers all score low),
  soft trust weighting squeezes the poisoned cluster out of the global
  model, and the stake of every worker behind the rogue head erodes.

    PYTHONPATH=src python examples/poisoning_defense.py [--head]
"""
import sys

from repro.configs.base import FederationConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core.protocol import SDFLBProtocol
from repro.data.datasets import make_federated_mnist

BAD = (0, 5)                  # worker-level attackers (scattered)
HEAD_CLUSTER_WORKERS = (0, 1)     # cluster 0 of 4 behind a byzantine head


def flip(batch, round_index):
    labels = batch["labels"]
    for w in BAD:
        labels = labels.at[w].set(9 - labels[w])
    return {**batch, "labels": labels}


def head_flip(batch, round_index):
    """Head-level poisoning: the rogue head taints its whole cluster."""
    labels = batch["labels"]
    for w in HEAD_CLUSTER_WORKERS:
        labels = labels.at[w].set(9 - labels[w])
    return {**batch, "labels": labels}


def run(trust_on: bool, *, head_level: bool = False, rounds: int = 40,
        samples: int = 4096, eval_samples: int = 512) -> dict:
    # head-level: 4 clusters of 2 so the rogue head owns a whole (small)
    # cluster; worker-level: the original 2x4 layout
    fed = FederationConfig(num_clusters=4 if head_level else 2,
                           workers_per_cluster=2 if head_level else 4,
                           trust_threshold=0.45 if trust_on else -1.0,
                           soft_trust_weighting=trust_on, penalty_pct=5.0)
    tc = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd")
    proto = SDFLBProtocol(get_config("paper-net"), fed, tc, seed=0,
                          adversary=head_flip if head_level else flip)
    ds = make_federated_mnist(8, samples=samples, seed=0)
    for _ in range(rounds):
        rec = proto.run_round(ds.round_batches(32))
    acc = proto.evaluate(ds.eval_batch(eval_samples))["accuracy"]
    proto.flush()   # pipelined driver: settle the trailing round first
    stakes = {w: proto.contract.workers[f"worker-{w}"].stake for w in range(8)}
    proto.finalize()
    return {"acc": acc, "scores": rec.scores, "stakes": stakes}


def main(head_level: bool = False) -> None:
    on = run(True, head_level=head_level)
    off = run(False, head_level=head_level)
    attackers = set(HEAD_CLUSTER_WORKERS if head_level else BAD)
    label = "byzantine head (cluster 0)" if head_level else "poisoning workers"
    print(f"attack: {label}")
    print("final trust scores (defended run):")
    for w in range(8):
        tag = "ATTACKER" if w in attackers else "honest"
        print(f"  worker {w} [{tag:8s}]  S={on['scores'][w]:.3f}  "
              f"stake_left={on['stakes'][w]:.1f}")
    print(f"\naccuracy with trust penalization   : {on['acc']:.3f}")
    print(f"accuracy without (uniform weights) : {off['acc']:.3f}")


if __name__ == "__main__":
    main(head_level="--head" in sys.argv[1:])
