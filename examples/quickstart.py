"""Quickstart: the paper's experiment in ~40 lines.

Three workers train the paper's MNIST CNN under the SDFL-B protocol —
cluster aggregation, trust scoring, on-chain settlement, IPFS-published
models — then the contract is finalized and rewards paid.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.base import FederationConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core.protocol import SDFLBProtocol
from repro.data.datasets import make_federated_mnist
from repro.serve import LightClient


def main() -> None:
    fed = FederationConfig(num_clusters=1, workers_per_cluster=3,
                           trust_threshold=0.2)
    tc = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd")  # paper §IV
    proto = SDFLBProtocol(get_config("paper-net"), fed, tc,
                          use_blockchain=True, seed=0)
    ds = make_federated_mnist(3, samples=2048, seed=0)
    eval_batch = ds.eval_batch(512)

    for round_index in range(30):
        rec = proto.run_round(ds.round_batches(64))
        if (round_index + 1) % 10 == 0:
            metrics = proto.evaluate(eval_batch)
            # the pipelined driver settles a round during the next round's
            # device step, so the freshest settled cid is the previous one
            settled = next((r for r in reversed(proto.history) if r.settled),
                           rec)
            print(f"round {round_index + 1:3d}  "
                  f"acc={metrics['accuracy']:.3f}  "
                  f"loss={metrics['loss']:.3f}  "
                  f"trust={rec.scores.round(2).tolist()}  "
                  f"heads={rec.heads}  cid={settled.model_cid[:12]}…")

    # audit a worker without trusting the node: a light client holds only
    # verified headers, fetches a settlement proof, and checks it itself
    auditor = LightClient(proto.node.read_server())
    auditor.sync()
    record = auditor.audit(None, 0)
    print(f"\nlight-client audit (headers only, {auditor.height} synced): "
          f"worker 0 settled round {record['round']} with "
          f"score={record['score']:.3f} stake={record['stake_after']:.1f}")

    payouts = proto.finalize()
    print("ledger verified:", proto.ledger.verify_chain(),
          f"({len(proto.ledger.blocks)} blocks, {proto.ipfs.puts} IPFS puts)")
    print("payouts:", {k: round(v, 2) for k, v in payouts.items()})


if __name__ == "__main__":
    main()
