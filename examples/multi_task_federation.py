"""Multi-tenant chain node: three federated tasks sharing one ledger.

The paper's blockchain layer is shared infrastructure — many collaborative
learning tasks settle on the same chain. Here one ``ChainNode`` serves
three heterogeneous MNIST federations (different worker counts, Merkle
chunk sizes, shard counts, and round cadences). Ticks where several tasks
fire seal ONE multi-task block committing the canonical
``task_id → super-root`` map; solo ticks seal the classic single-task
layout. Settlement proofs are three-level (chunk-in-shard, shard-in-task,
task-in-block) and a failing task would abort only its own round.

    PYTHONPATH=src python examples/multi_task_federation.py
"""
from repro.configs.base import FederationConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core.node import ChainNode
from repro.data.datasets import make_federated_mnist


def main() -> None:
    tc = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd")  # paper §IV
    cfg = get_config("paper-net")
    node = ChainNode(pipeline_depth=2)

    # three tenants: W=6 sharded task, W=4 two-cluster task, W=2 small task
    feds = {
        "hospital-fl": FederationConfig(
            num_clusters=2, workers_per_cluster=3, trust_threshold=0.3,
            top_k_rewarded=3, merkle_chunk_size=2, settlement_shards=2),
        "bank-fl": FederationConfig(
            num_clusters=2, workers_per_cluster=2, trust_threshold=0.4,
            top_k_rewarded=2, merkle_chunk_size=1),
        "iot-fl": FederationConfig(
            num_clusters=1, workers_per_cluster=2, trust_threshold=0.2,
            top_k_rewarded=1, merkle_chunk_size=4),
    }
    cadence = {"hospital-fl": 1, "bank-fl": 2, "iot-fl": 3}  # rounds/tick
    tasks = {tid: node.create_task(tid, cfg, fed, tc, seed=i)
             for i, (tid, fed) in enumerate(feds.items())}
    data = {tid: make_federated_mnist(t.W, samples=1024, seed=i)
            for i, (tid, t) in enumerate(tasks.items())}
    evals = {tid: data[tid].eval_batch(256) for tid in tasks}

    ticks = 12
    for t in range(ticks):
        firing = {tid: data[tid].round_batches(32)
                  for tid in tasks if t % cadence[tid] == 0}
        node.run_tick(firing)
        print(f"tick {t:2d}  tasks={sorted(firing)}")
    node.flush()

    print(f"\nchain: {len(node.ledger.blocks)} blocks, "
          f"deep-verified={node.ledger.verify_chain(deep=True)}")
    multi = [b for b in node.ledger.blocks if b.task_roots]
    print(f"multi-task blocks: {len(multi)} "
          f"(e.g. block {multi[0].index} commits "
          f"{sorted(multi[0].task_roots)})")

    # a light client audits a co-tenant block's three-level proof without
    # trusting the node: synced headers + a batched proof fetch
    from repro.serve import LightClient
    auditor = LightClient(node.read_server())
    auditor.sync()
    batch = auditor.fetch_proofs("hospital-fl", list(range(6)),
                                 round_index=0)
    print(f"3-level proofs for all 6 hospital-fl workers, round 0: "
          f"{batch.num_digests} shared siblings, "
          f"verifies={auditor.verify_batch(batch)}, "
          f"worker 0 record={batch.decoded(0)}")

    payouts = node.finalize()
    for tid, task in tasks.items():
        rounds = len(task.history)
        pen_total = sum(float(r.penalties.sum()) for r in task.history)
        trust = task.reputation.scores.round(2).tolist()
        print(f"\n[{tid}] rounds={rounds}  "
              f"final_acc={task.evaluate(evals[tid])['accuracy']:.3f}")
        print(f"  trust (reputation EMA): {trust}")
        print(f"  penalties collected: {pen_total:.1f}  "
              f"requester balance: {task.contract.requester_balance:.1f}")
        print(f"  payouts: {({k: round(v, 1) for k, v in payouts[tid].items()})}")
        print(f"  ipfs puts: {node.ipfs.puts_by_owner[tid]}")
    print(f"\nshared store: {node.ipfs.puts} puts, "
          f"{node.ipfs.bytes_stored / 1e6:.1f} MB stored, "
          f"{node.ipfs.dedup_hits} deduped")


if __name__ == "__main__":
    main()
