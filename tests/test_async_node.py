"""Event-driven ChainNode (run_events): arrival frontier → staleness-
weighted aggregate → cohort seal.

Pins (a) the AsyncScheduler determinism contract — (time, round, worker)
heap tie-break, per-task sub-RNGs seeded from (seed, task_id), advance_until
semantics; (b) the host/device staleness-rule agreement; (c) the
sync-equivalence property: with uniform arrivals and zero staleness the
event-driven node's chain (block hashes, penalties, payouts, elections) is
byte-identical to run_tick; (d) cohort-settlement proofs for late/absent
workers in delta blocks with staleness committed on-chain; (e) staleness-
discounted penalties/payout credit at the contract layer; and (f) straggler
co-tenancy — a slow task never stalls a fast one."""
import dataclasses

import numpy as np
import pytest

from repro.chain.ledger import Ledger
from repro.chain.contract import TrustContract
from repro.configs.base import FederationConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core import async_agg, async_sim
from repro.core.async_sim import AsyncScheduler, WorkerProfile
from repro.core.node import ChainNode

TC = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd", remat=False)


# -- scheduler determinism ----------------------------------------------------


def _event_trace(task_id, n=10, seed=7):
    profiles = async_sim.heavy_tailed_profiles(6, failure_prob=0.1, seed=3)
    sched = AsyncScheduler(profiles, seed=seed, task_id=task_id,
                           buffer_size=3)
    return [(t, mask.tolist(), snap.tolist())
            for t, mask, snap in (sched.next_aggregation()
                                  for _ in range(n))]


def test_scheduler_per_task_subrng_reproducible_and_independent():
    """Same (seed, task_id) → identical event traces run-to-run; a
    different task_id gives an independent arrival stream (co-tenant tasks
    never share one RNG cursor, so node-level interleaving can't perturb
    either task's trace)."""
    assert _event_trace("alpha") == _event_trace("alpha")
    assert _event_trace("alpha") != _event_trace("beta")
    # and the task-less legacy constructor stays reproducible too
    assert _event_trace(None) == _event_trace(None)


def test_scheduler_tie_break_round_before_worker():
    """Heap ties resolve on (time, round, worker): at equal arrival times a
    worker's *earlier* local round lands first, regardless of worker id.
    With speeds (1, 2) and zero jitter, t=2 has worker 0's round 1 tied
    with worker 1's round 0 — round order must put worker 1 first (the old
    (time, worker, round) order would pop worker 0)."""
    profiles = [WorkerProfile(speed=1.0, jitter=0.0),
                WorkerProfile(speed=2.0, jitter=0.0)]
    sched = AsyncScheduler(profiles, seed=0, buffer_size=1)
    events = []
    for _ in range(5):
        t, mask, _ = sched.next_aggregation()
        events.append((t, int(np.nonzero(mask)[0][0])))
    assert events == [(1.0, 0), (2.0, 1), (2.0, 0), (3.0, 0), (4.0, 1)]


def test_advance_until_folds_arrivals_without_aggregating():
    """advance_until folds every arrival up to the deadline into the
    pending buffer (duplicates don't double-count) and moves the clock; the
    next aggregation event then completes from there."""
    profiles = [WorkerProfile(speed=1.0, jitter=0.0),
                WorkerProfile(speed=3.0, jitter=0.0)]
    sched = AsyncScheduler(profiles, seed=0, buffer_size=2)
    # worker 0 arrives at t=1 and t=2 (second is a duplicate), worker 1 not
    # until t=3
    assert sched.advance_until(2.5) == 1
    assert sched.now == 2.5
    t, mask, snap = sched.next_aggregation()
    assert t == 3.0 and mask.tolist() == [1, 1] and snap.tolist() == [0, 0]
    # per-update arrival instants for latency measurement
    assert sched.arrival_times().tolist() == [1.0, 3.0]
    with pytest.raises(ValueError):
        sched.advance_until(float("inf"))


def test_host_staleness_mirror_matches_device_rule():
    """The host mirror (what settlement records commit) must stay in
    lockstep with the jitted async_round's AsyncState.staleness under any
    participation sequence."""
    import jax.numpy as jnp
    W = 5
    fed = FederationConfig(num_clusters=1, workers_per_cluster=W,
                           async_mode=True, trust_threshold=0.0)
    updates = {"w": jnp.ones((W, 3), jnp.float32)}
    state = async_agg.init_async_state(updates, W)
    mirror = np.zeros(W, np.int64)
    rng = np.random.default_rng(0)
    scores = jnp.ones(W, jnp.float32)
    for _ in range(8):
        mask = rng.integers(0, 2, size=W)
        _, state, _ = async_agg.async_round(
            updates, scores, jnp.asarray(mask, jnp.int32), state, fed)
        mirror = async_agg.host_staleness_update(mirror, mask)
        np.testing.assert_array_equal(np.asarray(state.staleness), mirror)


# -- node-level: sync equivalence, cohort proofs, co-tenancy ------------------


def _paper_async_fed(**kw):
    base = dict(num_clusters=2, workers_per_cluster=2, async_mode=True,
                trust_threshold=0.3, top_k_rewarded=3, merkle_chunk_size=1,
                pipeline_depth=2)
    base.update(kw)
    return FederationConfig(**base)


def _trace(node, task):
    return {
        "blocks": [b.hash for b in node.ledger.blocks],
        "heads": [tuple(r.heads) for r in task.history],
        "penalties": np.stack([r.penalties for r in task.history]),
        "cids": [r.model_cid for r in task.history],
        "reputation": (task.reputation.scores.copy(),
                       task.reputation.penalties.copy()),
    }


@pytest.mark.parametrize("seed", [0, 7])
def test_event_node_degenerate_sync_bit_identical_to_run_tick(seed):
    """Sync-equivalence property: with uniform arrivals (every worker in
    every cohort) and staleness identically zero, run_events produces a
    chain — block hashes, penalties, payouts, head elections, reputation —
    byte-identical to driving run_tick with full participation."""
    from repro.data.datasets import make_federated_mnist
    cfg = get_config("paper-net")
    fed = _paper_async_fed()
    W, rounds = 4, 5
    uniform = [WorkerProfile(speed=1.0, jitter=0.0, failure_prob=0.0)
               for _ in range(W)]
    runs = {}
    for mode in ("events", "ticks"):
        ds = make_federated_mnist(W, samples=512, seed=2)
        node = ChainNode(pipeline_depth=fed.pipeline_depth)
        task = node.create_task(
            "t", cfg, dataclasses.replace(fed, buffer_size=W), TC, seed=seed,
            profiles=uniform if mode == "events" else None)
        if mode == "events":
            recs = node.run_events(
                {"t": lambda r: ds.round_batches(32)}, events=rounds)["t"]
            assert [int(r.participation.sum()) for r in recs] == [W] * rounds
            assert all((r.staleness == 0).all() for r in recs)
        else:
            for _ in range(rounds):
                node.run_tick({"t": ds.round_batches(32)},
                              participation={"t": np.ones(W, np.int64)})
        node.flush()
        assert node.ledger.verify_chain(deep=True)
        trace = _trace(node, task)
        payouts = task.finalize()
        node.close()
        runs[mode] = (trace, payouts)
    ev, tk = runs["events"], runs["ticks"]
    assert ev[0]["blocks"] == tk[0]["blocks"]          # byte-identical chain
    assert ev[0]["heads"] == tk[0]["heads"]            # elections
    assert ev[0]["cids"] == tk[0]["cids"]
    np.testing.assert_array_equal(ev[0]["penalties"], tk[0]["penalties"])
    np.testing.assert_array_equal(ev[0]["reputation"][0], tk[0]["reputation"][0])
    np.testing.assert_array_equal(ev[0]["reputation"][1], tk[0]["reputation"][1])
    assert ev[1] == tk[1]                              # payouts


def test_event_node_cohort_delta_blocks_prove_late_and_absent_workers():
    """Under churn (stragglers + dropout) each event seals only the arrived
    cohort as a DeltaCommit, yet every worker stays proof-covered: an
    absent worker's inherited record verifies out of the delta block, an
    arrived worker's fresh record carries its on-chain staleness equal to
    the node's host mirror, and deep verification walks the overlay chain."""
    from repro.data.datasets import make_federated_mnist
    cfg = get_config("paper-net")
    fed = _paper_async_fed(buffer_size=2, sparse_settlement=True,
                           trust_threshold=0.0)
    W = 4
    profiles = async_sim.heterogeneous_profiles(
        W, straggler_frac=0.25, straggler_slowdown=6.0, failure_prob=0.1,
        seed=3)
    ds = make_federated_mnist(W, samples=512, seed=0)
    node = ChainNode(pipeline_depth=2)
    task = node.create_task("t", cfg, fed, TC, seed=1, profiles=profiles)
    recs = node.run_events({"t": lambda r: ds.round_batches(32)},
                           events=8)["t"]
    node.flush()
    assert node.ledger.verify_chain(deep=True)
    partial = [r for r in recs
               if r.round_index >= 1 and 0 < r.participation.sum() < W]
    assert partial, "churn profile produced no partial cohort"
    rec = partial[-1]
    arrived = int(np.nonzero(rec.participation)[0][0])
    absent = int(np.nonzero(rec.participation == 0)[0][0])
    # arrived worker: fresh record, staleness committed on-chain equals the
    # node's host mirror snapshot for that round
    pa = task.contract.settlement_proof(rec.round_index, arrived)
    assert task.contract.verify_settlement(pa)
    assert pa["record"]["round"] == rec.round_index
    assert pa["record"]["staleness"] == int(rec.staleness[arrived])
    # absent worker: inherited record (earlier round or genesis), still
    # provable out of this round's delta block
    pb = task.contract.settlement_proof(rec.round_index, absent)
    assert task.contract.verify_settlement(pb)
    assert pb["record"]["round"] < rec.round_index
    assert pb["record"]["worker"] == absent
    # penalties scattered back over the full population: idle workers owe 0
    assert rec.penalties.shape == (W,)
    assert (rec.penalties[rec.participation == 0] == 0).all()
    node.finalize()


def test_staleness_discounts_penalties_and_payout_credit():
    """Contract layer: with staleness_alpha > 0 a stale bad update is
    penalized at (1+s)^-alpha of the full penalty and a stale score earns
    (1+s)^-alpha payout credit; alpha=0 is bit-identical to the
    staleness-unaware path."""
    def settle(alpha, staleness):
        led = Ledger()
        c = TrustContract(led, requester_deposit=100.0, worker_stake=10.0,
                          penalty_pct=50.0, trust_threshold=0.5, top_k=1,
                          merkle_chunk_size=1, staleness_alpha=alpha)
        c.join_batch(2)
        pen = c.settle_round_batch(0, np.array([0.4, 0.4]),
                                   staleness=staleness, timestamp=1.0)
        return c, pen

    c, pen = settle(0.5, np.array([0, 3]))
    disc = (1.0 + 3) ** -0.5
    assert pen[0] == pytest.approx(5.0)            # full F·P/100
    assert pen[1] == pytest.approx(5.0 * disc)     # staleness-discounted
    assert c.score_sum[0] == pytest.approx(0.4)
    assert c.score_sum[1] == pytest.approx(0.4 * disc)
    assert c.total_value() == pytest.approx(100.0 + 2 * 10.0)  # conserved
    # the discount is part of the on-chain record
    pr = c.settlement_proof(0, 1)
    assert c.verify_settlement(pr) and pr["record"]["staleness"] == 3
    # alpha = 0: staleness recorded but economics unchanged
    c0, pen0 = settle(0.0, np.array([0, 3]))
    cn, penn = settle(0.0, None)
    np.testing.assert_array_equal(pen0, penn)
    assert pen0[1] == pytest.approx(5.0)
    np.testing.assert_array_equal(c0.score_sum, cn.score_sum)


def test_straggler_task_never_stalls_fast_cotenant():
    """Two co-tenant tasks, one an order of magnitude slower: events
    interleave by simulated time, the fast task keeps settling rounds while
    the straggler plods, both lanes verify, and the whole multi-task event
    trace is reproducible run-to-run (per-task sub-RNGs)."""
    from repro.data.datasets import make_federated_mnist
    cfg = get_config("paper-net")
    W, events = 4, 20

    def drive():
        node = ChainNode(pipeline_depth=2)
        tasks, fns = {}, {}
        for tid, speed, seed in (("fast", 1.0, 0), ("slow", 4.0, 1)):
            profiles = [WorkerProfile(speed=speed, jitter=0.1)
                        for _ in range(W)]
            fed = _paper_async_fed(task_id=tid, buffer_size=2,
                                   trust_threshold=0.0)
            tasks[tid] = node.create_task(tid, cfg, fed, TC, seed=seed,
                                          profiles=profiles)
            ds = make_federated_mnist(W, samples=256, seed=seed)
            fns[tid] = lambda r, ds=ds: ds.round_batches(16)
        out = node.run_events(fns, events=events)
        node.flush()
        assert node.ledger.verify_chain(deep=True)
        blocks = [b.hash for b in node.ledger.blocks]
        counts = {tid: len(out[tid]) for tid in out}
        sim_times = [r.sim_time for r in out["fast"] + out["slow"]]
        node.close()
        return blocks, counts, sim_times

    blocks, counts, sim_times = drive()
    # the fast task ran most of the events; the slow one still progressed
    assert counts["fast"] > counts["slow"] >= 1
    assert counts["fast"] + counts["slow"] == events
    # determinism regression: a second identical run reproduces the chain
    blocks2, counts2, sim_times2 = drive()
    assert blocks == blocks2 and counts == counts2 and sim_times == sim_times2


def test_event_knobs_wired_from_federation_config():
    """buffer_size / max_wait flow from FederationConfig into the task's
    arrival scheduler; profiles without async_mode are rejected."""
    cfg = get_config("paper-net")
    node = ChainNode(pipeline_depth=0)
    fed = _paper_async_fed(buffer_size=3, max_wait=5.0)
    profiles = [WorkerProfile(speed=1.0) for _ in range(4)]
    task = node.create_task("t", cfg, fed, TC, profiles=profiles)
    assert task.arrival.buffer_size == 3 and task.arrival.max_wait == 5.0
    assert task.arrival.task_id == "t"
    with pytest.raises(ValueError):
        node.create_task("sync", cfg,
                         FederationConfig(num_clusters=2,
                                          workers_per_cluster=2),
                         TC, profiles=profiles)
    with pytest.raises(KeyError):
        node.run_events({"missing": lambda r: {}}, events=1)
    node.close()
