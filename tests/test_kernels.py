"""Per-kernel allclose vs the pure-jnp oracles (interpret mode on CPU),
with hypothesis shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# trust_agg
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    w=st.integers(2, 24),
    d=st.integers(1, 6000),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    block_d=st.sampled_from([256, 1024, 2048]),
)
def test_trust_agg_sweep(w, d, dtype, block_d):
    key = jax.random.PRNGKey(w * 10007 + d)
    u = _rand(key, (w, d), jnp.dtype(dtype))
    wt = jax.random.uniform(jax.random.fold_in(key, 1), (w,))
    out = ops._trust_agg(u, wt, block_d=block_d, interpret=True)
    expect = ref.trust_agg_ref(u, wt)
    tol = 2e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


def test_trust_agg_matches_pytree_helper():
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (4, 3, 700)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (4, 2048))}
    wt = jnp.array([0.1, 0.2, 0.3, 0.4])
    out = ops.aggregate_pytree(tree, wt)
    for k in tree:
        expect = ref.trust_agg_ref(tree[k].reshape(4, -1), wt).reshape(
            tree[k].shape[1:])
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# trust_score
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    w=st.integers(2, 20),
    d=st.integers(2, 5000),
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_trust_score_sweep(w, d, dtype):
    key = jax.random.PRNGKey(w * 31 + d)
    u = _rand(key, (w, d), jnp.dtype(dtype))
    dot, squ, sqc = ops._trust_score_stats(u, interpret=True)
    rd, rs, rc = ref.trust_score_ref(u)
    tol = 1e-4 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(np.asarray(dot), np.asarray(rd), rtol=tol, atol=tol * d)
    np.testing.assert_allclose(np.asarray(squ), np.asarray(rs), rtol=tol, atol=tol * d)
    np.testing.assert_allclose(np.asarray(sqc), np.asarray(rc), rtol=tol, atol=tol * d)


# ---------------------------------------------------------------------------
# swa_decode
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    kv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([64, 128]),
    nblocks=st.integers(2, 6),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    data=st.data(),
)
def test_swa_decode_sweep(b, kv, g, hd, nblocks, dtype, data):
    block_s = 256
    S = nblocks * block_s
    window = data.draw(st.sampled_from([block_s, 2 * block_s, S]))
    cur = data.draw(st.integers(0, S - 1))
    H = kv * g
    key = jax.random.PRNGKey(b * 100 + kv * 10 + g + hd + nblocks)
    dt = jnp.dtype(dtype)
    q = _rand(key, (b, H, hd), dt)
    kc = _rand(jax.random.fold_in(key, 1), (b, S, kv, hd), dt)
    vc = _rand(jax.random.fold_in(key, 2), (b, S, kv, hd), dt)
    out = ops._swa_decode(q, kc, vc, cur, window=window, block_s=block_s,
                          interpret=True)
    expect = ref.swa_decode_ref(q, kc, vc, cur, window)
    tol = 2e-4 if dtype == "float32" else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_swa_decode_matches_model_decode_attention():
    """The kernel must agree with the model's jnp decode attention path."""
    from repro.models.layers import decode_attention
    key = jax.random.PRNGKey(7)
    B, H, KV, hd, S, win = 2, 8, 2, 64, 1024, 512
    q = jax.random.normal(key, (B, 1, H, hd))
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    for cur in [5, 511, 600, 1023]:
        a = decode_attention(q, kc, vc, cur_index=cur, window=win)[:, 0]
        b = ops._swa_decode(q[:, 0], kc, vc, cur, window=win, block_s=256,
                            interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ssd_scan (fused SSD chunk recurrence)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    dk=st.sampled_from([8, 16]),
    dv=st.sampled_from([8, 16]),
    nc=st.integers(2, 4),
    chunk=st.sampled_from([16, 32]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_ssd_scan_sweep(b, h, dk, dv, nc, chunk, dtype):
    from repro.kernels.ssd_scan import ssd_scan
    from repro.models.ssm import chunked_decay_attention
    S = nc * chunk
    dt = jnp.dtype(dtype)
    key = jax.random.PRNGKey(b * 1000 + h * 100 + dk + dv + nc + chunk)
    q = _rand(key, (b, S, h, dk), dt)
    k = _rand(jax.random.fold_in(key, 1), (b, S, h, dk), dt)
    v = _rand(jax.random.fold_in(key, 2), (b, S, h, dv), dt)
    a = -jax.random.uniform(jax.random.fold_in(key, 3), (b, S, h)) * 0.4
    i = jax.random.uniform(jax.random.fold_in(key, 4), (b, S, h))
    out = ssd_scan(q, k, v, a.astype(dt), i.astype(dt), chunk=chunk,
                   interpret=True)
    ref_out = chunked_decay_attention(q, k, v, a, i, chunk=chunk)
    tol = 3e-4 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out, np.float32),
                               rtol=tol, atol=tol)


def test_ssd_scan_matches_naive_recurrence():
    """End-to-end: kernel == strict sequential recurrence (not just the
    chunked jnp path)."""
    from repro.kernels.ssd_scan import ssd_scan
    from repro.models.ssm import decay_attention_step
    key = jax.random.PRNGKey(0)
    B, S, H, dk, dv = 1, 64, 2, 8, 4
    q = jax.random.normal(key, (B, S, H, dk))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, dk))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, dv))
    a = -jax.random.uniform(jax.random.fold_in(key, 3), (B, S, H)) * 0.3
    i = jnp.ones((B, S, H))
    out = ssd_scan(q, k, v, a, i, chunk=16, interpret=True)
    state = jnp.zeros((B, H, dk, dv))
    ys = []
    for t in range(S):
        y, state = decay_attention_step(q[:, t], k[:, t], v[:, t],
                                        a[:, t], i[:, t], state)
        ys.append(y)
    ref_out = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-4, atol=2e-4)
