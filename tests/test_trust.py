"""Trust scoring + aggregation invariants (hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.base import FederationConfig
from repro.core import async_agg, hierarchy, trust


def _updates(key, W, shapes=((8, 16), (32,))):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, (W,) + s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


def test_scores_in_unit_interval_and_penalize_flipped():
    fed = FederationConfig()
    key = jax.random.PRNGKey(0)
    W = 8
    upd = _updates(key, W)
    # worker 3 flips the sign of its update (classic poisoning)
    upd = {k: v.at[3].set(-3.0 * v[3]) for k, v in upd.items()}
    losses = jnp.ones((W,))
    stats = trust.update_stats(upd, losses, losses)
    s = trust.scores_from_stats(stats, fed)
    assert s.shape == (W,)
    assert float(jnp.min(s)) >= 0.0 and float(jnp.max(s)) <= 1.0
    assert float(s[3]) == float(jnp.min(s))          # attacker scored worst


def test_free_rider_scores_near_zero():
    fed = FederationConfig()
    upd = _updates(jax.random.PRNGKey(1), 6)
    upd = {k: v.at[0].set(0.0) for k, v in upd.items()}   # free rider
    losses = jnp.ones((6,))
    s = trust.scores_from_stats(trust.update_stats(upd, losses, losses), fed)
    assert float(s[0]) < 0.15


@settings(max_examples=25, deadline=None)
@given(w=st.integers(2, 16), seed=st.integers(0, 1000),
       soft=st.booleans())
def test_trust_weights_normalized(w, seed, soft):
    fed = FederationConfig(soft_trust_weighting=soft)
    scores = jax.random.uniform(jax.random.PRNGKey(seed), (w,))
    wt = trust.trust_weights(scores, fed)
    np.testing.assert_allclose(float(jnp.sum(wt)), 1.0, rtol=1e-5)
    assert float(jnp.min(wt)) >= 0.0


def test_trust_weights_all_filtered_falls_back_uniform():
    fed = FederationConfig(trust_threshold=2.0)   # nothing passes
    wt = trust.trust_weights(jnp.array([0.1, 0.5, 0.9]), fed)
    np.testing.assert_allclose(np.asarray(wt), np.ones(3) / 3, rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_two_stage_equals_fused_equals_head_gather(seed):
    """The three aggregation topologies are value-identical."""
    fed = FederationConfig(num_clusters=4, workers_per_cluster=4)
    W = 16
    key = jax.random.PRNGKey(seed)
    upd = _updates(key, W)
    wt = jax.random.uniform(jax.random.fold_in(key, 1), (W,))
    wt = wt / jnp.sum(wt)
    a = hierarchy.aggregate(upd, wt, fed)
    b = hierarchy.aggregate_fused(upd, wt)
    c = hierarchy.aggregate_head_gather(upd, wt, fed)
    for k in upd:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(c[k]),
                                   rtol=2e-5, atol=2e-5)


def test_aggregate_unbiased_uniform_mean():
    """Uniform weights must reproduce the plain FedAvg mean."""
    fed = FederationConfig(num_clusters=2, workers_per_cluster=3)
    W = 6
    upd = _updates(jax.random.PRNGKey(3), W)
    wt = jnp.ones((W,)) / W
    agg = hierarchy.aggregate(upd, wt, fed)
    for k in upd:
        np.testing.assert_allclose(np.asarray(agg[k]),
                                   np.asarray(jnp.mean(upd[k], axis=0)),
                                   rtol=1e-5, atol=1e-6)


def test_rotate_heads_is_permutation():
    x = {"p": jnp.arange(8.0)[:, None] * jnp.ones((8, 3))}
    rolled = hierarchy.rotate_heads(x, jnp.array([1, 3]))
    assert sorted(np.asarray(rolled["p"])[:, 0].tolist()) == list(range(8))


def test_staleness_discount_monotone():
    s = trust.staleness_discount(jnp.array([0, 1, 2, 5, 10]), 0.5)
    assert np.all(np.diff(np.asarray(s)) < 0)
    np.testing.assert_allclose(float(s[0]), 1.0)


def test_async_round_flushes_and_accumulates():
    fed = FederationConfig(num_clusters=2, workers_per_cluster=2,
                           async_mode=True)
    W = 4
    upd = _updates(jax.random.PRNGKey(4), W, shapes=((5,),))
    state = async_agg.init_async_state(upd, W)
    scores = jnp.ones((W,)) * 0.9
    mask = jnp.array([1, 1, 0, 0])
    agg, state1, wts = async_agg.async_round(upd, scores, mask, state, fed)
    # absent workers keep accumulating, staleness grows
    assert np.asarray(state1.staleness).tolist() == [0, 0, 1, 1]
    np.testing.assert_allclose(np.asarray(state1.pending["p0"][0]), 0.0)
    np.testing.assert_allclose(np.asarray(state1.pending["p0"][2]),
                               np.asarray(upd["p0"][2]), rtol=1e-6)
    # absent workers get zero weight this round
    assert float(wts[2]) == 0.0 and float(wts[3]) == 0.0
    # when worker 2 arrives next round, its pending + fresh update flush
    upd2 = _updates(jax.random.PRNGKey(5), W, shapes=((5,),))
    mask2 = jnp.array([0, 0, 1, 1])
    agg2, state2, wts2 = async_agg.async_round(upd2, scores, mask2, state1, fed)
    assert np.asarray(state2.staleness).tolist() == [1, 1, 0, 0]
    np.testing.assert_allclose(np.asarray(state2.pending["p0"][2]), 0.0)


def test_flushed_worker_cannot_double_count():
    """Regression (settler-pool PR): once a worker's buffered update is
    flushed by an arrival, replaying the flush in the same round — or the
    worker arriving again with nothing new — must contribute exactly zero;
    the hoisted keep-mask must zero pending bit-exactly, never rescale
    it."""
    fed = FederationConfig(num_clusters=2, workers_per_cluster=2,
                           async_mode=True, trust_threshold=0.0)
    W = 4
    upd = _updates(jax.random.PRNGKey(7), W, shapes=((6,), (3, 2)))
    state = async_agg.init_async_state(upd, W)
    scores = jnp.ones((W,)) * 0.9
    mask = jnp.array([1, 0, 0, 0])
    agg1, state1, _ = async_agg.async_round(upd, scores, mask, state, fed)
    # worker 0's buffer is flushed to exactly zero (no residual scaling)
    for k in state1.pending:
        assert float(jnp.max(jnp.abs(state1.pending[k][0]))) == 0.0
    # same-round replay: worker 0 "arrives" again with a zero fresh update —
    # its flushed buffer must not be aggregated a second time
    zero_upd = jax.tree.map(jnp.zeros_like, upd)
    agg2, state2, _ = async_agg.async_round(zero_upd, scores, mask, state1,
                                            fed)
    for k in agg2:
        np.testing.assert_allclose(np.asarray(agg2[k]), 0.0, atol=1e-7)
        assert float(jnp.max(jnp.abs(state2.pending[k][0]))) == 0.0
    # and the first aggregation really did carry worker 0's update
    assert any(float(jnp.max(jnp.abs(agg1[k]))) > 0 for k in agg1)
