"""Per-architecture smoke tests (reduced configs: 2 layers, d_model<=512,
<=4 experts): one forward + one train step on CPU, asserting shapes and
no-NaN; plus prefill↔decode consistency against the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FederationConfig, TrainConfig
from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.core import fl_step
from repro.models import api


def _mk_batch(cfg, key, B, S):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.num_patch_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return batch


def _no_drop(cfg):
    if cfg.moe.enabled:
        return cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=cfg.moe.num_experts / cfg.moe.top_k))
    return cfg


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe.enabled:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params, specs = api.init(cfg, key, tp=1)
    assert jax.tree.structure(params).num_leaves == \
        jax.tree.structure(specs).num_leaves
    B, S = 2, 64
    batch = _mk_batch(cfg, key, B, S)
    logits, aux = api.forward(params, cfg, batch)
    S_tot = S + (cfg.num_patch_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_tot, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One full SDFL-B round on the reduced config: loss finite, params
    move, trust scores in [0, 1]."""
    cfg = get_smoke_config(arch)
    fed = FederationConfig(num_clusters=2, workers_per_cluster=2,
                           trust_threshold=0.0)
    tc = TrainConfig(optimizer="adamw", lr=1e-3, remat=False, grad_clip=1.0)
    key = jax.random.PRNGKey(0)
    global_params, _ = api.init(cfg, key, tp=1)
    opt = fl_step.init_worker_opt(global_params, fed, tc)
    W, B, S = 4, 1, 32
    batch = _mk_batch(cfg, key, W * B, S)
    batch = {k: v.reshape((W, 1, B) + v.shape[1:]) for k, v in batch.items()}
    round_fn = jax.jit(fl_step.make_fl_round(cfg, fed, tc))
    out = round_fn(global_params, opt, batch)
    assert np.isfinite(float(out.metrics["mean_loss"]))
    s = np.asarray(out.scores)
    assert s.shape == (W,) and (s >= 0).all() and (s <= 1).all()
    # params moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) -
                                      b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(out.global_params),
                                jax.tree.leaves(global_params)))
    assert delta > 0
    assert not any(bool(jnp.any(jnp.isnan(x.astype(jnp.float32))))
                   for x in jax.tree.leaves(out.global_params))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    # f32: bf16 rounding can flip near-tie top-k routing between the scanned
    # and decode paths (a discontinuity of MoE itself, not a path bug)
    cfg = _no_drop(get_smoke_config(arch)).replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    params, _ = api.init(cfg, key, tp=1)
    B, S_prompt = 2, 16
    off = cfg.num_patch_tokens if cfg.family == "vlm" else 0
    cache_len = off + 32
    tk = jax.random.randint(jax.random.fold_in(key, 1), (B, 32), 0,
                            cfg.vocab_size)
    batch = _mk_batch(cfg, key, B, 32)
    batch["tokens"] = tk
    prompt = dict(batch, tokens=tk[:, :S_prompt])
    last_logits, cache = api.prefill(params, cfg, prompt, cache_len)
    steps = [last_logits[:, 0]]
    for t in range(S_prompt, S_prompt + 4):
        lg, cache = api.decode_step(params, cfg, cache, tk[:, t:t + 1],
                                    off + t)
        steps.append(lg[:, 0])
    dec = jnp.stack(steps, axis=1).astype(jnp.float32)
    full_logits, _ = api.forward(params, cfg, batch)
    ref = full_logits[:, off + S_prompt - 1: off + S_prompt + 4].astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(dec - ref))) / scale < 0.02


def test_loss_fn_matches_logits_xent():
    """Chunked hidden-side loss == naive full-logits cross entropy."""
    cfg = get_smoke_config("smollm-135m")
    key = jax.random.PRNGKey(0)
    params, _ = api.init(cfg, key, tp=1)
    batch = _mk_batch(cfg, key, 2, 64)
    loss, _ = api.loss_fn(cfg)(params, batch)
    logits, aux = api.forward(params, cfg, batch)
    naive = api._xent(logits[:, :-1, :], batch["labels"][:, 1:]) + aux
    np.testing.assert_allclose(float(loss), float(naive), rtol=1e-3)


def test_chunked_xent_matches_unchunked():
    key = jax.random.PRNGKey(0)
    B, S, d, V = 2, 128, 32, 50
    x = jax.random.normal(key, (B, S, d))
    head = jax.random.normal(jax.random.fold_in(key, 1), (d, V))
    tgt = jax.random.randint(jax.random.fold_in(key, 2), (B, S), -1, V)
    a = api._chunked_xent(x, head, tgt, seq_chunk=32)
    b = api._xent(x @ head, tgt)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)
