"""Model-component correctness: blocked attention vs direct softmax, SSD
chunked scan vs naive recurrence, MoE gather vs dense oracle, sliding
window masks, RoPE properties."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as S


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, causal=True, window=0):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qs = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32) / np.sqrt(hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qs, k.astype(jnp.float32))
    pos = jnp.arange(Sq)
    mask = jnp.ones((Sq, Sq), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window:
        mask &= (pos[:, None] - pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd)


@settings(max_examples=10, deadline=None)
@given(seq=st.sampled_from([128, 256]), kv_chunk=st.sampled_from([32, 64]),
       window=st.sampled_from([0, 48]), seed=st.integers(0, 100))
def test_blocked_attention_matches_naive(seq, kv_chunk, window, seed):
    key = jax.random.PRNGKey(seed)
    B, H, KV, hd = 2, 4, 2, 32
    q = jax.random.normal(key, (B, seq, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, seq, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, seq, KV, hd))
    pos = jnp.arange(seq)
    out = L.blocked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                              causal=True, window=window, kv_chunk=kv_chunk)
    expect = _naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# decay attention (SSD core): chunked == naive sequential recurrence
# ---------------------------------------------------------------------------

def _naive_decay_attention(q, k, v, a, i):
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    h = np.zeros((B, H, dk, dv), np.float64)
    out = np.zeros((B, T, H, dv), np.float64)
    qn, kn, vn = np.asarray(q, np.float64), np.asarray(k, np.float64), np.asarray(v, np.float64)
    an, inn = np.asarray(a, np.float64), np.asarray(i, np.float64)
    for t in range(T):
        h = h * np.exp(an[:, t])[..., None, None] + \
            inn[:, t][..., None, None] * kn[:, t][..., :, None] * vn[:, t][..., None, :]
        out[:, t] = np.einsum("bhd,bhdv->bhv", qn[:, t], h)
    return out


@settings(max_examples=8, deadline=None)
@given(chunk=st.sampled_from([8, 16, 32]), T=st.sampled_from([32, 64]),
       seed=st.integers(0, 50))
def test_chunked_decay_attention_matches_recurrence(chunk, T, seed):
    key = jax.random.PRNGKey(seed)
    B, H, dk, dv = 2, 3, 8, 5
    q = jax.random.normal(key, (B, T, H, dk))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, dk))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, dv))
    a = -jax.random.uniform(jax.random.fold_in(key, 3), (B, T, H)) * 0.5
    i = jax.random.uniform(jax.random.fold_in(key, 4), (B, T, H))
    out = S.chunked_decay_attention(q, k, v, a, i, chunk=chunk)
    expect = _naive_decay_attention(q, k, v, a, i)
    np.testing.assert_allclose(np.asarray(out, np.float64), expect,
                               rtol=1e-3, atol=1e-3)


def test_decay_attention_step_streams_like_chunked():
    """Prefill state hand-off: chunked(T) == chunked(T/2) + steps."""
    key = jax.random.PRNGKey(0)
    B, T, H, dk, dv = 1, 16, 2, 4, 3
    q = jax.random.normal(key, (B, T, H, dk))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, dk))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, dv))
    a = -jax.random.uniform(jax.random.fold_in(key, 3), (B, T, H)) * 0.3
    i = jnp.ones((B, T, H))
    full = S.chunked_decay_attention(q, k, v, a, i, chunk=4)
    half, state = S.chunked_decay_attention(
        q[:, :8], k[:, :8], v[:, :8], a[:, :8], i[:, :8], chunk=4,
        return_state=True)
    outs = [half]
    for t in range(8, T):
        y, state = S.decay_attention_step(q[:, t], k[:, t], v[:, t],
                                          a[:, t], i[:, t], state)
        outs.append(y[:, None])
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# MoE: gather (capacity) impl == dense mask oracle when nothing drops
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(e=st.sampled_from([4, 8]), k=st.sampled_from([1, 2]),
       seed=st.integers(0, 50))
def test_moe_gather_matches_dense(e, k, seed):
    key = jax.random.PRNGKey(seed)
    d, f, B, Sq = 16, 32, 2, 24
    cfg = MoEConfig(num_experts=e, top_k=k, d_ff_expert=f,
                    capacity_factor=float(e) / k)   # no drops
    params, _ = MOE.init_moe(key, d, cfg, tp=1, dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, Sq, d))
    out_g, aux_g = MOE.apply_moe(params, x, cfg, impl="gather")
    out_d, aux_d = MOE.apply_moe(params, x, cfg, impl="dense")
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_d),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(aux_g), float(aux_d), rtol=1e-5)


def test_moe_padded_experts_never_selected():
    key = jax.random.PRNGKey(0)
    cfg = MoEConfig(num_experts=3, top_k=2, d_ff_expert=8)
    params, _ = MOE.init_moe(key, 8, cfg, tp=4, dtype=jnp.float32)
    assert params["router"].shape[1] == 4        # padded to tp multiple
    x = jax.random.normal(key, (1, 16, 8))
    probs, _ = MOE._router_probs(params, x.reshape(16, 8), cfg)
    assert float(jnp.max(probs[:, 3])) < 1e-12   # pad expert masked


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 and adversarially-uniform tokens, outputs stay finite and
    dropped tokens fall back to shared/zero path."""
    key = jax.random.PRNGKey(0)
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=8,
                    num_shared_experts=1, d_ff_shared=8, capacity_factor=1.0)
    params, _ = MOE.init_moe(key, 8, cfg, tp=1, dtype=jnp.float32)
    x = jnp.ones((2, 32, 8))
    out, aux = MOE.apply_moe(params, x, cfg)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# RoPE / norms
# ---------------------------------------------------------------------------

def test_rope_preserves_norm_and_relative_phase():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 2, 16))
    pos = jnp.arange(8)
    r = L.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(r), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # dot(q_m, k_n) depends only on m - n
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 1, 16))
    def dot_at(m, n):
        qm = L.apply_rope(q, jnp.array([m]), 1e4)
        kn = L.apply_rope(k, jnp.array([n]), 1e4)
        return float(jnp.sum(qm * kn))
    np.testing.assert_allclose(dot_at(5, 3), dot_at(9, 7), rtol=1e-4)


def test_rms_norm_unit_scale():
    x = jnp.full((2, 4, 8), 3.0)
    out = L.rms_norm(x, jnp.ones((8,)))
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-4)


def test_slstm_state_streaming_matches_batch():
    """sLSTM full-sequence pass == two streamed halves."""
    key = jax.random.PRNGKey(0)
    d, H, B, T = 32, 4, 2, 12
    params, _ = S.init_slstm(key, d, H, tp=1, dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, T, d)) * 0.5
    full = S.apply_slstm(params, x, H)
    first, carry = S.apply_slstm(params, x[:, :6], H, return_state=True)
    second, _ = S.apply_slstm(params, x[:, 6:], H, carry=carry)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([first, second], 1)),
                               np.asarray(full), rtol=2e-4, atol=2e-4)
