"""Chain read path: unified ``SettlementProof``, batched multiproofs, and
the ``repro.serve`` server/light-client pair.

Pins (a) the unified proof surface verifying across every commit flavor
(dense, sharded, delta-overlay, multi-task) with the deprecated wrappers
emitting bit-identical proofs; (b) batched multiproof round-trips with
shared-path deduplication, and rejection of tampering at every level
(chunk bytes, shipped siblings, offsets, plan, root, and the stored
records themselves); (c) the light client's header-chain sync — full,
incremental, current-token, corrupt-header rejection — and stale-proof
re-anchoring; (d) bounded content-verified checkpoint streaming under
serve quotas; (e) the ``contract.legacy`` namespace and
DeprecationWarning shims; (f) lock-free reads while a writer settles."""
import hashlib
import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.chain.contract import TrustContract
from repro.chain.ipfs import IPFSStore
from repro.chain.ledger import Ledger
from repro.chain.proofs import (ROOT_KEY, BlockHeader, SettlementProof,
                                build_proof_batch, header_of,
                                verify_proof_batch)
from repro.serve import (ChainReadServer, HeaderVerificationError,
                         LightClient, QuotaExceeded, RoundNotSettled,
                         StaleProofError)


def _contract(W, *, sparse=False, shards=1, chunk=8, multi=None):
    c = TrustContract(Ledger(), requester_deposit=1e6, worker_stake=10.0,
                      penalty_pct=50.0, trust_threshold=0.5,
                      top_k=max(W // 4, 1), merkle_chunk_size=chunk,
                      sparse_settlement=sparse, settlement_shards=shards,
                      task_id=multi)
    c.join_batch(W)
    return c


def _settle(c, rounds=2, seed=0, cohort=None):
    rng = np.random.default_rng(seed)
    W = c.num_workers
    for r in range(rounds):
        if cohort:
            ids = np.sort(rng.choice(W, cohort, replace=False)).astype(
                np.int64)
            c.settle_round_batch(r, rng.random(cohort), worker_ids=ids,
                                 timestamp=float(r + 1))
        else:
            c.settle_round_batch(r, rng.random(W), timestamp=float(r + 1))
    return c


def _flavors():
    """One settled contract per commit flavor the chain produces."""
    return {
        "dense": _settle(_contract(64)),
        "sharded": _settle(_contract(64, shards=4)),
        "delta": _settle(_contract(64, sparse=True), cohort=16),
    }


# -- (a) unified SettlementProof across flavors -------------------------------


@pytest.mark.parametrize("flavor", ["dense", "sharded", "delta"])
def test_settlement_proof_roundtrip_all_flavors(flavor):
    c = _flavors()[flavor]
    for w in (0, 7, 63):
        sp = c.proof(1, w)
        blk = c.ledger.blocks[sp.block_index]
        assert sp.verify(blk)
        assert sp.verify(header_of(blk))          # light-client header
        assert sp.verify(blk.records_root)        # bare trusted root
        assert sp.record["worker"] == w
        assert c.verify_settlement(sp)            # typed input accepted


def test_settlement_proof_multi_task_block():
    """Two co-tenant tasks settling in one multi-task block: each task's
    proof resolves through the third (task) Merkle level, single and
    batched, and the serve path spans both tenants."""
    from repro.core.node import TaskRoundWork, settle_tasks_block
    ledger = Ledger()
    a = TrustContract(ledger, requester_deposit=1e4, worker_stake=1.0,
                      penalty_pct=10.0, trust_threshold=0.5, top_k=4,
                      merkle_chunk_size=4, task_id="a")
    b = TrustContract(ledger, requester_deposit=1e4, worker_stake=1.0,
                      penalty_pct=10.0, trust_threshold=0.5, top_k=4,
                      merkle_chunk_size=2, task_id="b")
    a.join_batch(16)
    b.join_batch(8)
    rng = np.random.default_rng(0)
    blk, _, errors = settle_tasks_block(
        ledger, [TaskRoundWork("a", a, 0, rng.random(16)),
                 TaskRoundWork("b", b, 0, rng.random(8))], timestamp=1.0)
    assert not errors and blk.task_roots and set(blk.task_roots) == \
        {"a", "b"}
    for contract, w in ((a, 11), (b, 5)):
        sp = contract.proof(0, w)
        assert sp.task_id == contract.task_id
        assert sp.verify(blk) and sp.verify(header_of(blk))
        assert contract.settlement_proof(0, w) == sp.as_legacy_dict()
    for tid, contract, wids in (("a", a, [0, 5, 11]), ("b", b, [0, 7])):
        batch = build_proof_batch(ledger, blk.index, wids, task_id=tid)
        assert verify_proof_batch(batch, blk)
        assert batch.task_id == tid
        assert [batch.decoded(i)["worker"] for i in range(len(wids))] \
            == wids
    srv = ChainReadServer(contracts={"a": a, "b": b})
    lc = LightClient(srv)
    assert lc.audit("b", 5)["worker"] == 5
    with pytest.raises(ValueError):
        srv.get_proofs(None, [0])                  # ambiguous tenant


def test_verify_rejects_wrong_head_and_garbage():
    c = _settle(_contract(32))
    sp = c.proof(0, 3)
    other = c.ledger.blocks[c._round_blocks[1]]
    assert not sp.verify(other)                  # wrong block
    assert not sp.verify("ab" * 32)              # wrong root
    assert not sp.verify("")                     # unusable head
    assert not sp.verify(None)
    bad = SettlementProof(**{**sp.__dict__, "offset": 99})
    assert not bad.verify(c.ledger.blocks[sp.block_index])


# -- (a) deprecated wrappers: bit-identical proofs ----------------------------


@settings(max_examples=20, deadline=None)
@given(chunk=st.sampled_from([1, 3, 8]), shards=st.sampled_from([1, 4]),
       w=st.integers(min_value=0, max_value=23))
def test_legacy_wrapper_bit_identity(chunk, shards, w):
    """The deprecated dict ``settlement_proof`` is exactly the typed
    proof's legacy projection, and ``Ledger.merkle_proof`` is its path."""
    c = _settle(_contract(24, chunk=chunk, shards=shards), rounds=1)
    sp = c.proof(0, w)
    legacy = c.settlement_proof(0, w)
    assert legacy == sp.as_legacy_dict()
    assert c.ledger.merkle_proof(sp.block_index, sp.leaf_index) == \
        list(sp.path)
    assert c.verify_settlement(legacy)
    rt = SettlementProof.from_legacy(legacy)
    assert rt.verify(c.ledger.blocks[sp.block_index])
    # ledger-level legacy verify agrees
    assert c.ledger.verify_record(sp.block_index, sp.leaf_index, sp.leaf)


def test_verify_settlement_rejects_malformed_dicts():
    c = _settle(_contract(16), rounds=1)
    good = c.settlement_proof(0, 2)
    assert not c.verify_settlement({})
    assert not c.verify_settlement({**good, "offset": 77})
    assert not c.verify_settlement({**good, "block_index": 10_000})
    assert not c.verify_settlement(
        {**good, "leaf": b"\x00" * len(good["leaf"])})


# -- (b) batched multiproofs ---------------------------------------------------


@pytest.mark.parametrize("flavor", ["dense", "sharded", "delta"])
def test_proof_batch_roundtrip_and_dedup(flavor):
    c = _flavors()[flavor]
    blk = c.ledger.blocks[c._round_blocks[1]]
    wids = list(range(0, 64, 3))
    pos = [c.record_position(1, w) for w in wids]
    batch = build_proof_batch(c.ledger, blk.index, pos,
                              worker_ids=wids, round_index=1)
    assert verify_proof_batch(batch, blk)
    assert verify_proof_batch(batch, header_of(blk))
    # every record decodes to the same view the single-proof path attests
    for i, w in enumerate(wids):
        assert batch.decoded(i) == c.proof(1, w).record
    # dedup: far fewer shipped digests than the sum of independent paths
    indep = sum(len(c.settlement_proof(1, w)["proof"]) for w in wids)
    assert batch.num_digests < indep / 2


def test_proof_batch_tamper_rejection_every_level():
    """Flipping any component — leaf chunk bytes, any shipped sibling,
    record offset, plan, claimed root, or the record's leaf assignment —
    must flip verification to False (never raise)."""
    c = _settle(_contract(64, shards=4))
    blk = c.ledger.blocks[c._round_blocks[1]]
    wids = [0, 9, 33, 63]

    def fresh():
        return build_proof_batch(c.ledger, blk.index, wids)

    assert verify_proof_batch(fresh(), blk)
    # chunk bytes (leaf level)
    b = fresh()
    key = next(iter(b.chunks))
    raw = bytearray(b.chunks[key])
    raw[5] ^= 1
    b.chunks[key] = bytes(raw)
    assert not verify_proof_batch(b, blk)
    # each shipped sibling digest (interior levels, one at a time)
    for skey in fresh().siblings:
        b = fresh()
        flipped = bytearray(bytes.fromhex(b.siblings[skey]))
        flipped[0] ^= 1
        b.siblings[skey] = flipped.hex()
        assert not verify_proof_batch(b, blk), f"sibling {skey}"
    # record offset out of its chunk
    b = fresh()
    ri, key, _ = b.records[0]
    b.records[0] = (ri, key, 10_000)
    assert not verify_proof_batch(b, blk)
    # record pointed at a key never lifted to the root
    b = fresh()
    b.chunks[("S", 99, 0, 0)] = b.chunks[key]
    b.records[0] = (ri, ("S", 99, 0, 0), 0)
    assert not verify_proof_batch(b, blk)
    # truncated plan: root never computed
    b = fresh()
    b.plan = b.plan[:-1]
    assert not verify_proof_batch(b, blk)
    # forged root claim
    b = fresh()
    b.root = "cd" * 32
    assert not verify_proof_batch(b, blk)
    # a sibling may not override a computed node
    b = fresh()
    b.siblings[ROOT_KEY] = blk.records_root
    assert not verify_proof_batch(b, blk)
    # tampering the *stored* records poisons freshly built batches too
    c.ledger.tamper_record(blk.index, 9, b"\x00" * 48)
    assert not verify_proof_batch(fresh(), blk)


# -- (c) head sync + stale re-anchoring ---------------------------------------


def _serving_pair(**kw):
    c = _settle(_contract(64), rounds=3)
    srv = ChainReadServer(contracts=c, **kw)
    return c, srv, LightClient(srv)


def test_head_sync_full_incremental_current():
    c, srv, lc = _serving_pair()
    gained = lc.sync()
    assert gained == lc.height == srv.height
    assert lc.sync() == 0                         # O(1) current token
    reply = srv.sync_head(lc.height, lc.headers[-1].hash)
    assert reply.current and not reply.headers and not reply.reset
    # incremental: settle one more round, delta is exactly one header
    c.settle_round_batch(3, np.random.default_rng(9).random(64),
                         timestamp=9.0)
    reply = srv.sync_head(lc.height, lc.headers[-1].hash)
    assert not reply.reset and len(reply.headers) == 1
    assert lc.sync() == 1
    # a client claiming an unknown head gets a full reset resync
    reply = srv.sync_head(2, "ff" * 32)
    assert reply.reset and len(reply.headers) == srv.height


def test_corrupt_headers_rejected_state_untouched():
    _, srv, lc = _serving_pair()
    lc.sync()
    h = lc.headers[1]
    for attr, val in (("hash", "f" * 64), ("prev_hash", "e" * 64),
                      ("index", 40), ("records_root", "d" * 64)):
        bad = list(lc.headers)
        bad[1] = BlockHeader(**{**h.__dict__, attr: val})
        victim = LightClient(srv)
        with pytest.raises(HeaderVerificationError):
            victim._verify_and_adopt(bad, [])
        assert victim.headers == []               # nothing adopted


def test_stale_proof_reanchors_after_sync():
    c, srv, lc = _serving_pair()
    lc.sync()
    c.settle_round_batch(3, np.random.default_rng(5).random(64),
                         timestamp=5.0)
    batch = lc.fetch_proofs(None, [4, 40], round_index=3)
    with pytest.raises(StaleProofError):
        lc.verify_batch(batch)
    lc.sync()
    assert lc.verify_batch(batch)                 # same batch, re-anchored
    rec = lc.audit(None, 4, round_index=3)        # audit path does it alone
    assert rec["worker"] == 4 and rec["round"] == 3


def test_server_round_and_batch_errors():
    c, srv, lc = _serving_pair(max_batch=8)
    with pytest.raises(RoundNotSettled):
        srv.get_proofs(None, [0], round_index=77)
    with pytest.raises(ValueError):
        srv.get_proofs(None, list(range(9)))      # over max_batch
    assert srv.latest_settled_round(None) == 2
    # partial dense round (unsorted cohort): present workers resolve
    # through the argsort index, absent ones are named in the KeyError
    cs = _contract(64)
    ids = np.array([40, 3, 17, 9, 55, 21, 0, 33], np.int64)
    cs.settle_round_batch(0, np.random.default_rng(3).random(len(ids)),
                          worker_ids=ids, timestamp=1.0)
    srv2 = ChainReadServer(contracts=cs)
    lc2 = LightClient(srv2)
    for w in (40, 0, 33):
        assert lc2.audit(None, w, round_index=0)["worker"] == w
    missing = next(w for w in range(64) if w not in set(ids.tolist()))
    with pytest.raises(KeyError):
        srv2.get_proofs(None, [missing], round_index=0)
    # sparse (delta-overlay) rounds cover the whole population — even a
    # worker outside the cohort is proof-served (round -1 = never settled)
    cd = _settle(_contract(64, sparse=True), rounds=1, cohort=8)
    srv3 = ChainReadServer(contracts=cd)
    idle = next(w for w in range(64)
                if w not in set(cd._round_ids[0].tolist()))
    assert LightClient(srv3).audit(None, idle, round_index=0)["worker"] \
        == idle


# -- (d) checkpoint streaming --------------------------------------------------


def test_checkpoint_stream_roundtrip_tamper_and_quota():
    c = _settle(_contract(16), rounds=1)
    ipfs = IPFSStore()
    tree = {"w": np.arange(4096, dtype=np.float32),
            "b": np.ones(7, np.float32)}
    cid = ipfs.put_tree(tree, owner="t")
    srv = ChainReadServer(contracts=c, ipfs=ipfs, chunk_bytes=512)
    lc = LightClient(srv, client_id="aud")
    leaves = lc.fetch_checkpoint(cid)
    assert any(np.asarray(x).size == 4096 for x in leaves)
    man = srv.checkpoint_manifest(cid)
    assert man.num_chunks == -(-man.size // 512) and srv.chunks_streamed \
        == man.num_chunks
    assert hashlib.sha256(
        b"".join(srv.checkpoint_chunk(cid, i)
                 for i in range(man.num_chunks))).hexdigest() == cid
    with pytest.raises(IndexError):
        srv.checkpoint_chunk(cid, man.num_chunks)
    # tamper: reassembled bytes no longer match the content address
    ipfs.tamper(cid, b"z" * man.size)
    with pytest.raises(ValueError, match="content hash"):
        LightClient(srv).fetch_checkpoint(cid)
    # per-client serve quota
    srv2 = ChainReadServer(contracts=c, ipfs=IPFSStore(), chunk_bytes=64,
                           serve_quota_bytes=128)
    cid2 = srv2.ipfs.put_tree(        # incompressible → blob > quota
        {"x": np.random.default_rng(0).random(500).astype(np.float32)})
    with pytest.raises(QuotaExceeded):
        LightClient(srv2, client_id="greedy").fetch_checkpoint(cid2)
    anon = LightClient(srv2)                       # quota needs a client_id
    assert anon.fetch_checkpoint(cid2)


# -- (e) legacy namespace + deprecation shims ---------------------------------


def test_legacy_namespace_and_deprecation_warnings():
    c = TrustContract(Ledger(), requester_deposit=100.0, worker_stake=5.0,
                      penalty_pct=20.0, trust_threshold=0.5, top_k=1)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        c.legacy.join("a")                        # namespace: no warning
        c.legacy.join("b")
    with pytest.deprecated_call():
        c.join("c")
    with pytest.deprecated_call():
        c.settle_round(0, {"a": 0.9, "b": 0.1, "c": 0.8})
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        pen = c.legacy.settle_round(1, {"a": 0.9, "b": 0.2, "c": 0.8})
    assert "b" in pen
    # shim and namespace share state: both rounds are on one chain
    assert {0, 1} <= set(c._round_blocks)
    sp = c.proof(1, "b")
    assert sp.verify(c.ledger.blocks[sp.block_index])


def test_node_read_server_end_to_end():
    """``ChainNode.read_server()`` serves a real node: a light client
    syncs the node's chain and audits a worker of a task it never ran."""
    from repro.configs.base import FederationConfig, TrainConfig
    from repro.configs.registry import get_config
    from repro.core.node import ChainNode
    from repro.data.datasets import make_federated_mnist

    node = ChainNode(pipeline_depth=2)
    fed = FederationConfig(num_clusters=1, workers_per_cluster=2,
                           trust_threshold=0.2, merkle_chunk_size=2)
    tc = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd", remat=False)
    node.create_task("t", get_config("paper-net"), fed, tc, seed=0)
    ds = make_federated_mnist(2, samples=256, seed=0)
    for _ in range(3):
        node.run_tick({"t": ds.round_batches(16)})
    node.flush()
    lc = LightClient(node.read_server())
    assert lc.sync() == len(node.ledger.blocks)
    rec = lc.audit("t", 1)
    assert rec["worker"] == 1 and rec["round"] >= 0
    node.finalize()


# -- (f) lock-free reads under live settlement --------------------------------


def test_concurrent_readers_never_see_torn_state():
    W, rounds = 2_000, 12
    c = _contract(W, chunk=64)
    srv = ChainReadServer(contracts=c)
    c.settle_round_batch(0, np.random.default_rng(0).random(W),
                         timestamp=1.0)
    stop = threading.Event()
    failures = []

    def writer():
        rng = np.random.default_rng(1)
        for r in range(1, rounds):
            c.settle_round_batch(r, rng.random(W), timestamp=float(r + 1))
        stop.set()

    def reader(i):
        lc = LightClient(srv)
        rng = np.random.default_rng((2, i))
        try:
            while not stop.is_set() or lc.height < srv.height:
                lc.sync()
                ids = rng.integers(0, W, size=32)
                r = srv.latest_settled_round(None)
                batch = srv.get_proofs(None, ids, round_index=r)
                try:
                    ok = lc.verify_batch(batch)
                except StaleProofError:
                    lc.sync()
                    ok = lc.verify_batch(batch)
                if not ok:
                    failures.append((i, r))
                    return
        except Exception as e:                     # pragma: no cover
            failures.append((i, repr(e)))

    threads = [threading.Thread(target=writer)] + \
        [threading.Thread(target=reader, args=(i,)) for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not failures
    assert srv.proofs_served > 0
