"""Multi-tenant chain node: N concurrent federated tasks on one ledger.

Pins the multi-task block layout (canonical task_id → super-root map over
per-task ShardedCommits), N ∈ {1, 2, 5} bit-identity of per-task commits
vs the single-tenant driver, task-isolation under tampering (corrupting
task A's records never invalidates task B's proofs), three-level
settlement-proof round-trips with malformed-proof rejection, deterministic
round-robin fairness of the shared settler pool, and per-task failure
isolation with task_id + round surfaced in the raised error."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.chain.contract import TrustContract
from repro.chain.ledger import (Ledger, MerkleTree, MultiTaskCommit,
                                ShardedCommit)
from repro.core.node import (ChainNode, TaskRoundWork, TaskSettlementError,
                             _interleave_shard_thunks, settle_tasks_block)
from repro.core.protocol import SDFLBProtocol


def _records(n, seed=0, size=40):
    rng = np.random.default_rng(seed)
    return [bytes(rng.bytes(size)) for _ in range(n)]


def _contract(led, tid, W, chunk=3, shards=1, deposit=1e4):
    c = TrustContract(led, requester_deposit=deposit, worker_stake=10.0,
                      penalty_pct=50.0, trust_threshold=0.5, top_k=5,
                      merkle_chunk_size=chunk, settlement_shards=shards,
                      task_id=tid)
    c.join_batch(W)
    return c


# -- commit layer -------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(n_tasks=st.integers(1, 5), base=st.integers(1, 40),
       k=st.integers(1, 6), seed=st.integers(0, 1000))
def test_multi_task_commit_layers_over_sharded_commits(n_tasks, base, k,
                                                       seed):
    """Property: a MultiTaskCommit over per-task ShardedCommits has (a) a
    single-task root bit-equal to the task's own super-root with an empty
    task path, and (b) for any N, per-record three-level proofs (the
    task's own proof + the task path) that verify against the combined
    root via the unchanged MerkleTree.verify."""
    recs = {f"t{i}": _records(base + 3 * i, seed + i)
            for i in range(n_tasks)}
    commits = {t: ShardedCommit([r], k) for t, r in recs.items()}
    mtc = MultiTaskCommit(commits)
    assert mtc.task_ids == sorted(recs)
    if n_tasks == 1:
        only = next(iter(commits.values()))
        assert mtc.root == only.root             # bit-identical to PR-3
        assert mtc.task_path("t0") == []
    for t, r in recs.items():
        assert mtc.task_roots()[t] == commits[t].root
        for ri in {0, len(r) - 1, len(r) // 2}:
            proof = mtc.record_proof(ri, t)
            assert proof == commits[t].record_proof(ri) + mtc.task_path(t)
            chunk, off = mtc.record_chunk(ri, t)
            assert chunk[off] == r[ri]
            assert MerkleTree.verify(b"".join(chunk), proof, mtc.root)
    assert mtc.recompute_root() == mtc.root


def test_multi_task_commit_rejects_bad_shapes():
    recs = _records(6)
    sc = ShardedCommit([recs], 2)
    with pytest.raises(ValueError):
        MultiTaskCommit({})
    with pytest.raises(ValueError):              # anonymous only when alone
        MultiTaskCommit({None: sc, "a": sc})
    mtc = MultiTaskCommit({"a": sc, "b": ShardedCommit([_records(4, 1)], 2)})
    with pytest.raises(KeyError):                # multi-task needs a task_id
        mtc.commit_for(None)
    with pytest.raises(KeyError):
        mtc.commit_for("ghost")


# -- N-task bit-identity vs the single-tenant driver --------------------------


@pytest.mark.parametrize("N", [1, 2, 5])
def test_cotenant_commits_bit_identical_to_standalone(N):
    """N ∈ {1, 2, 5} heterogeneous tasks (different W, chunk sizes, shard
    counts) co-committed per round through settle_tasks_block produce, for
    every task, the byte-identical super-root, penalties, and stakes it
    would commit running alone through settle_round_batch — and with N=1
    the whole block (hash included) is bit-identical to the single-tenant
    driver, regardless of task_id."""
    rng = np.random.default_rng(7)
    tids = [f"task-{i:02d}" for i in range(N)]
    Ws = [20 + 7 * i for i in range(N)]
    chunks = [1, 3, 4, 2, 8][:N]
    shards = [1, 2, 3, 2, 4][:N]
    rounds = 3
    scores = {tid: rng.random((rounds, W)) for tid, W in zip(tids, Ws)}

    solo = {}
    for i, tid in enumerate(tids):
        led = Ledger()
        c = _contract(led, tid, Ws[i], chunks[i], shards[i])
        for r in range(rounds):
            c.settle_round_batch(r, scores[tid][r], timestamp=float(r + 1))
        solo[tid] = {"roots": [b.records_root for b in led.blocks[1:]],
                     "hashes": [b.hash for b in led.blocks],
                     "stake": c.stake.copy(),
                     "requester": c.requester_balance}

    led = Ledger()
    cs = {tid: _contract(led, tid, Ws[i], chunks[i], shards[i])
          for i, tid in enumerate(tids)}
    blocks = []
    for r in range(rounds):
        work = [TaskRoundWork(tid, cs[tid], r, scores[tid][r])
                for tid in tids]
        blk, pens, errors = settle_tasks_block(led, work,
                                               timestamp=float(r + 1))
        assert not errors and set(pens) == set(tids)
        blocks.append(blk)
    assert led.verify_chain(deep=True)

    for tid in tids:
        # per-task super-roots are co-tenancy independent
        assert [led.task_roots(b.index)[tid] for b in blocks] \
            == solo[tid]["roots"]
        np.testing.assert_array_equal(cs[tid].stake, solo[tid]["stake"])
        assert cs[tid].requester_balance == solo[tid]["requester"]
    if N == 1:
        # the whole chain is bit-identical to the single-tenant driver
        assert [b.hash for b in led.blocks] == solo[tids[0]]["hashes"]
        assert all(b.task_roots is None for b in led.blocks)
    else:
        assert all(set(b.task_roots) == set(tids) for b in blocks)
        task_path_len = (N - 1).bit_length()
        for tid in tids:
            # three-level proof = the task's own two-level proof + the
            # cross-task path to the block root
            proof = cs[tid].settlement_proof(1, 0)
            assert cs[tid].verify_settlement(proof)
            assert len(proof["proof"]) >= task_path_len


def test_task_isolation_under_tampering():
    """Corrupting task A's stored records breaks A's proofs and deep chain
    verification but never invalidates task B's proofs — B's sibling
    digests are the stored task/shard roots, not A's bytes."""
    rng = np.random.default_rng(3)
    led = Ledger()
    a = _contract(led, "task-a", 24, chunk=2, shards=2)
    b = _contract(led, "task-b", 16, chunk=4, shards=1)
    sa, sb = rng.random((2, 24)), rng.random((2, 16))
    for r in range(2):
        blk, _, errors = settle_tasks_block(
            led, [TaskRoundWork("task-a", a, r, sa[r]),
                  TaskRoundWork("task-b", b, r, sb[r])],
            timestamp=float(r + 1))
        assert not errors
    assert led.verify_chain(deep=True)
    proofs_b = [b.settlement_proof(1, w) for w in range(16)]
    led.tamper_record(blk.index, 5, b"x" * 40, task_id="task-a")
    # A is broken at the chunk level and at deep verification …
    assert not led.verify_record(blk.index, 5, task_id="task-a")
    assert led.verify_chain() and not led.verify_chain(deep=True)
    # … while every one of B's settlements still proves and verifies
    for w, proof in enumerate(proofs_b):
        assert b.verify_settlement(proof)
        assert led.verify_record(blk.index, w, task_id="task-b")
    assert b.verify_settlement(b.settlement_proof(1, 3))


def test_three_level_proofs_roundtrip_and_malformed_rejection():
    """Three-level settlement proofs verify for every worker of every
    task; forgeries at each level (chunk record, shard sibling, task
    sibling) and malformed attacker-supplied shapes are rejected, never
    raised on."""
    rng = np.random.default_rng(11)
    led = Ledger()
    cs = {f"t{i}": _contract(led, f"t{i}", 12 + 4 * i, chunk=2,
                             shards=2 if i else 1) for i in range(3)}
    work = [TaskRoundWork(tid, c, 0, rng.random(c.num_workers))
            for tid, c in cs.items()]
    blk, _, errors = settle_tasks_block(led, work, timestamp=1.0)
    assert not errors
    task_path_len = (len(cs) - 1).bit_length()
    for tid, c in cs.items():
        for w in range(0, c.num_workers, 5):
            proof = c.settlement_proof(0, w)
            assert c.verify_settlement(proof)
            assert proof["root"] == blk.records_root
            assert len(proof["proof"]) >= task_path_len
            # chunk-level forgery
            assert not c.verify_settlement(dict(proof, leaf=b"\x01" * 40))
            # task-level forgery: the proof's tail crosses tasks
            doctored = list(proof["proof"])
            side, _ = doctored[-1]
            doctored[-1] = (side, "00" * 32)
            assert not c.verify_settlement(dict(proof, proof=doctored))
            # malformed shapes are rejected, never raised on
            assert not c.verify_settlement(dict(proof, proof=[("L", "zz")]))
            assert not c.verify_settlement(dict(proof, chunk=5))
            assert not c.verify_settlement(dict(proof, offset=-1))
            assert not c.verify_settlement({})
        # a worker of task A cannot replay its proof against task B's
        # record indices
        other = cs["t0"] if tid != "t0" else cs["t1"]
        p = c.settlement_proof(0, 1)
        assert not other.verify_settlement(
            dict(p, record=dict(p["record"], worker=99)))


def test_settle_tasks_block_rejects_duplicate_task_ids():
    led = Ledger()
    c = _contract(led, "t", 4)
    w = TaskRoundWork("t", c, 0, np.zeros(4))
    with pytest.raises(ValueError):
        settle_tasks_block(led, [w, w], timestamp=1.0)


# -- fairness / determinism ----------------------------------------------------


def test_shard_thunks_interleave_round_robin():
    """The shared pool's schedule takes shard 0 of every task (canonical
    order) before any task's shard 1 — no task starves behind a bigger
    co-tenant."""
    from repro.chain.contract import RoundPrep
    ids = np.arange(1)
    preps = {
        "a": RoundPrep(0, ids, ids.astype(float), ["a0", "a1", "a2"]),
        "b": RoundPrep(0, ids, ids.astype(float), ["b0"]),
        "c": RoundPrep(0, ids, ids.astype(float), ["c0", "c1"]),
    }
    sched = _interleave_shard_thunks(["a", "b", "c"], preps)
    assert [(t, i) for t, i, _ in sched] == [
        ("a", 0), ("b", 0), ("c", 0), ("a", 1), ("c", 1), ("a", 2)]


def test_cotenant_settlement_deterministic_across_runs_and_pools():
    """The same 2-task score stream seals byte-identical chains run to
    run, with and without the shared worker pool engaged (seed-reproducible
    ordering; the pool only changes who hashes)."""
    from repro.core.node import ShardWorkerPool

    def drive(pool):
        rng = np.random.default_rng(5)
        led = Ledger()
        a = _contract(led, "a", 40, chunk=2, shards=4)
        b = _contract(led, "b", 24, chunk=2, shards=3)
        a.min_parallel_leaf_bytes = 1        # force fan-out at tiny leaves
        b.min_parallel_leaf_bytes = 1
        for r in range(4):
            _, _, errors = settle_tasks_block(
                led, [TaskRoundWork("a", a, r, rng.random(40)),
                      TaskRoundWork("b", b, r, rng.random(24))],
                timestamp=float(r + 1), pool=pool)
            assert not errors
        return [blk.hash for blk in led.blocks]

    pool = ShardWorkerPool(2)
    try:
        serial = drive(None)
        assert drive(None) == serial         # run-to-run deterministic
        assert drive(pool) == serial         # pool-invariant
    finally:
        pool.stop()


# -- protocol-level: the ChainNode driver --------------------------------------


def _paper_setup():
    from repro.configs.base import FederationConfig, TrainConfig
    from repro.configs.registry import get_config

    cfg = get_config("paper-net")
    tc = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd", remat=False)
    fed = FederationConfig(num_clusters=2, workers_per_cluster=3,
                           trust_threshold=0.45, top_k_rewarded=3,
                           merkle_chunk_size=1)
    return cfg, tc, fed


def test_single_task_node_bit_identical_to_serial_wrapper():
    """An N=1 node driven through the raw multi-task API (threaded,
    sharded, arbitrary task_id) seals the byte-identical chain — blocks,
    heads, penalties, payouts — as the serial unsharded single-task
    wrapper: multi-tenancy is invisible until a second task actually
    shares a block."""
    from repro.data.datasets import make_federated_mnist

    cfg, tc, fed = _paper_setup()
    ds = make_federated_mnist(6, samples=768, seed=5)
    serial = SDFLBProtocol(
        cfg, dataclasses.replace(fed, pipeline_depth=0), tc,
        use_blockchain=True, seed=11)
    for _ in range(6):
        serial.run_round(ds.round_batches(32))
    serial_pay = serial.finalize()

    ds = make_federated_mnist(6, samples=768, seed=5)
    node = ChainNode(pipeline_depth=3, settler_pool_size=2)
    task = node.create_task(
        "an-arbitrary-name", cfg,
        dataclasses.replace(fed, settlement_shards=7), tc, seed=11)
    task.contract.min_parallel_leaf_bytes = 1    # force pool fan-out
    for _ in range(6):
        node.run_tick({"an-arbitrary-name": ds.round_batches(32)})
    node.flush()
    payouts = node.finalize()

    assert [b.hash for b in node.ledger.blocks[:-1]] \
        == [b.hash for b in serial.ledger.blocks[:-1]]
    assert [tuple(r.heads) for r in task.history] \
        == [tuple(r.heads) for r in serial.history]
    np.testing.assert_array_equal(
        np.stack([r.penalties for r in task.history]),
        np.stack([r.penalties for r in serial.history]))
    assert payouts["an-arbitrary-name"] == serial_pay
    assert node.ledger.verify_chain(deep=True)


def test_multi_task_node_end_to_end_heterogeneous_cadences():
    """Three heterogeneous tasks (different W, chunk sizes, cadences) on
    one node: all progress (starvation-free), co-tenant ticks seal
    multi-task blocks and solo ticks the single-task layout, the chain
    deep-verifies through every task, per-task value is conserved, and
    the shared IPFS store attributes per-owner usage."""
    from repro.configs.base import FederationConfig, TrainConfig
    from repro.configs.registry import get_config
    from repro.data.datasets import make_federated_mnist

    cfg = get_config("paper-net")
    tc = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd", remat=False)
    node = ChainNode(pipeline_depth=2)
    feds = {
        "mnist-a": FederationConfig(num_clusters=1, workers_per_cluster=3,
                                    trust_threshold=0.3, top_k_rewarded=2,
                                    merkle_chunk_size=2,
                                    settlement_shards=2),
        "mnist-b": FederationConfig(num_clusters=2, workers_per_cluster=2,
                                    trust_threshold=0.4, top_k_rewarded=3,
                                    merkle_chunk_size=1),
        "mnist-c": FederationConfig(num_clusters=1, workers_per_cluster=2,
                                    trust_threshold=0.2, top_k_rewarded=1,
                                    merkle_chunk_size=4),
    }
    cadence = {"mnist-a": 1, "mnist-b": 2, "mnist-c": 3}
    tasks = {tid: node.create_task(tid, cfg, fed, tc, seed=i)
             for i, (tid, fed) in enumerate(feds.items())}
    data = {tid: make_federated_mnist(t.W, samples=512, seed=i)
            for i, (tid, t) in enumerate(tasks.items())}
    ticks = 6
    for t in range(ticks):
        node.run_tick({tid: data[tid].round_batches(16)
                       for tid in tasks if t % cadence[tid] == 0})
    node.flush()
    for tid, task in tasks.items():
        assert len(task.history) == sum(
            1 for t in range(ticks) if t % cadence[tid] == 0)
        assert all(r.settled for r in task.history)
    blocks = node.ledger.blocks[1:]
    multi = [b for b in blocks if b.task_roots]
    solo = [b for b in blocks if b.task_roots is None]
    assert multi and solo                      # both layouts exercised
    assert set(multi[0].task_roots) == set(feds)   # tick 0: all three fire
    assert node.ledger.verify_chain(deep=True)
    # three-level proof out of a genuinely multi-task block
    a = tasks["mnist-a"].contract
    proof = a.settlement_proof(0, 1)
    assert proof["block_index"] == multi[0].index
    assert a.verify_settlement(proof)
    doctored = list(proof["proof"])
    doctored[-1] = (doctored[-1][0], "00" * 32)
    assert not a.verify_settlement(dict(proof, proof=doctored))
    # shared store attributes per-task usage
    assert node.ipfs.puts_by_owner == {
        tid: len(tasks[tid].history) for tid in tasks}
    payouts = node.finalize()
    assert set(payouts) == set(feds)
    for tid, task in tasks.items():
        expect = feds[tid].requester_deposit \
            + task.W * feds[tid].worker_stake
        assert abs(task.contract.total_value() - expect) < 1e-6


def test_task_joining_running_node_is_deterministic():
    """create_task on a running node drains in-flight ticks first, so the
    joining task's round-0 randomness derives from a deterministic chain
    head: re-driving the same program seals byte-identical chains."""
    from repro.configs.base import FederationConfig, TrainConfig
    from repro.configs.registry import get_config
    from repro.data.datasets import make_federated_mnist

    cfg = get_config("paper-net")
    tc = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd", remat=False)
    fed = FederationConfig(num_clusters=1, workers_per_cluster=2,
                           trust_threshold=0.2)

    def drive():
        node = ChainNode(pipeline_depth=2)
        a = node.create_task("early", cfg, fed, tc, seed=0)
        ds = make_federated_mnist(2, samples=256, seed=0)
        for _ in range(3):
            node.run_tick({"early": ds.round_batches(16)})
        b = node.create_task("late", cfg, fed, tc, seed=1)
        # registration drained the pipeline: every prior round is settled
        assert all(r.settled for r in a.history)
        ds2 = make_federated_mnist(2, samples=256, seed=1)
        for _ in range(3):
            node.run_tick({"early": ds.round_batches(16),
                           "late": ds2.round_batches(16)})
        node.flush()
        hashes = [blk.hash for blk in node.ledger.blocks]
        heads = [tuple(r.heads) for r in b.history]
        node.close()
        return hashes, heads

    assert drive() == drive()


def test_task_failure_isolated_and_error_names_task_and_round():
    """Satellite regression: a failing shard aborts only its own task's
    round — the raised TaskSettlementError carries the task_id AND the
    round index (the settle failure used to report only the round), the
    co-tenant keeps settling and finalizes normally, and the failed
    task's state/chain lane stays exactly as before the failing round."""
    from repro.configs.base import FederationConfig, TrainConfig
    from repro.configs.registry import get_config
    from repro.data.datasets import make_federated_mnist

    cfg = get_config("paper-net")
    tc = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd", remat=False)
    fed = FederationConfig(num_clusters=1, workers_per_cluster=3,
                           trust_threshold=0.2, merkle_chunk_size=1,
                           settlement_shards=3)
    node = ChainNode(pipeline_depth=2, settler_pool_size=2)
    a = node.create_task("task-a", cfg, fed, tc, seed=0)
    b = node.create_task("task-b", cfg, fed, tc, seed=1)
    dsa = make_federated_mnist(3, samples=256, seed=0)
    dsb = make_federated_mnist(3, samples=256, seed=1)

    orig = a.contract.settle_shard

    def failing_shard(round_index, ids, s, start, stop):
        if round_index >= 1:
            raise RuntimeError("shard worker died")
        return orig(round_index, ids, s, start, stop)

    a.contract.settle_shard = failing_shard
    node.run_tick({"task-a": dsa.round_batches(16),
                   "task-b": dsb.round_batches(16)})
    stake_before = a.contract.stake.copy()     # settled through round 0
    with pytest.raises(TaskSettlementError) as ei:
        for _ in range(3):
            node.run_tick({"task-a": dsa.round_batches(16),
                           "task-b": dsb.round_batches(16)})
    err = ei.value
    assert err.task_id == "task-a" and err.round_index == 1
    assert "'task-a'" in str(err) and "round 1" in str(err)
    assert isinstance(err, RuntimeError)       # wrapper-compatible
    # the co-tenant's round from the partially-failed tick was still
    # recorded and queued — only the poisoned task's round is dropped
    ticks_b_ran = len(b.history)
    assert ticks_b_ran > len(a.history)
    # the co-tenant keeps going: drop the poisoned task and drive on
    for _ in range(2):
        node.run_tick({"task-b": dsb.round_batches(16)})
    assert len(b.history) == ticks_b_ran + 2
    node.drain()                               # raises only node-fatal
    assert all(r.settled for r in b.history)
    with pytest.raises(TaskSettlementError):   # sticky, per task
        node.run_tick({"task-a": dsa.round_batches(16)})
    with pytest.raises(TaskSettlementError):
        node.flush()
    assert node.task_errors.keys() == {"task-a"}
    # task-a's lane froze before round 1: stakes untouched, round-1+ rounds
    # of task-a absent from every block, while task-b kept committing
    np.testing.assert_array_equal(a.contract.stake, stake_before)
    assert a.contract._round_blocks.keys() == {0}
    assert len(b.contract._round_blocks) == len(b.history)
    a_round0_settled = a.history[0].settled
    assert a_round0_settled and not any(r.settled for r in a.history[1:])
    payouts = node.finalize()                  # skips the poisoned task
    assert set(payouts) == {"task-b"}
    assert node.ledger.verify_chain(deep=True)
