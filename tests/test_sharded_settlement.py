"""Sharded multi-contract settlement: subtree-aligned shard planning,
cross-shard super-root commits (byte-identical to the flat commit for every
shard count), two-level settlement proofs with tamper detection at both the
shard and chunk level, the ShardWorkerPool, and the settler-pool protocol
driver (byte-identical chains vs the serial reference, sticky shard
failures that never commit a half-settled super-root)."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.chain.contract import TrustContract
from repro.chain.ledger import (Ledger, MerkleTree, ShardedCommit,
                                plan_shard_bounds)
from repro.core.protocol import SDFLBProtocol, ShardWorkerPool


def _records(n, seed=0, size=40):
    rng = np.random.default_rng(seed)
    return [bytes(rng.bytes(size)) for _ in range(n)]


@settings(max_examples=60, deadline=None)
@given(n=st.integers(1, 400), k=st.integers(1, 9), shards=st.integers(1, 9))
def test_plan_shard_bounds_covers_and_aligns(n, k, shards):
    """Property: bounds cover [0, n] contiguously, yield at most ``shards``
    ranges, and every shard but the last spans exactly 2^m chunk leaves (the
    alignment that makes the super-root equal the flat root)."""
    bounds = plan_shard_bounds(n, k, shards)
    assert bounds[0] == 0 and bounds[-1] == n
    assert all(a < b for a, b in zip(bounds, bounds[1:]))
    assert len(bounds) - 1 <= shards
    widths = [b - a for a, b in zip(bounds, bounds[1:])]
    if len(widths) > 1:
        g = widths[0]
        leaves = g // k
        assert g % k == 0 and leaves & (leaves - 1) == 0   # 2^m whole leaves
        assert all(w == g for w in widths[:-1]) and widths[-1] <= g


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 200), k=st.integers(1, 8), shards=st.integers(1, 8),
       seed=st.integers(0, 1000))
def test_super_root_and_proofs_match_flat_commit(n, k, shards, seed):
    """Property: for any (n, chunk_size, shard count), the sharded commit's
    super-root AND every record's two-level proof are byte-identical to the
    flat single-tree commit — shard count is not consensus-visible."""
    recs = _records(n, seed)
    flat = MerkleTree(recs, k)
    bounds = plan_shard_bounds(n, k, shards)
    commit = ShardedCommit([recs[a:b] for a, b in zip(bounds, bounds[1:])], k)
    assert commit.root == flat.root
    rng = np.random.default_rng(seed)
    for ri in set(int(rng.integers(0, n)) for _ in range(5)) | {0, n - 1}:
        assert commit.record_proof(ri) == flat.record_proof(ri)
        chunk, off = commit.record_chunk(ri)
        assert chunk[off] == recs[ri]
        assert MerkleTree.verify(b"".join(chunk), commit.record_proof(ri),
                                 commit.root)


def _settled_contract(S, rounds=4, W=50, chunk=3, seed=1):
    led = Ledger()
    c = TrustContract(led, requester_deposit=1e4, worker_stake=10.0,
                      penalty_pct=50.0, trust_threshold=0.5, top_k=5,
                      merkle_chunk_size=chunk, settlement_shards=S)
    c.join_batch(W)
    scores = np.random.default_rng(seed).random((rounds, W))
    for r in range(rounds):
        c.settle_round_batch(r, scores[r], timestamp=float(r + 1))
    return led, c


@pytest.mark.parametrize("S", [2, 7])
def test_sharded_chains_byte_identical_to_serial(S):
    """S ∈ {1, 2, 7} contracts seal byte-identical chains (block hashes,
    roots, payouts) on the same score stream — the sharded settlement is
    bit-equal to the unsharded PR-2 reference."""
    led1, c1 = _settled_contract(1)
    ledS, cS = _settled_contract(S)
    pay1, payS = c1.finalize(timestamp=9.0), cS.finalize(timestamp=9.0)
    assert [b.hash for b in led1.blocks] == [b.hash for b in ledS.blocks]
    assert pay1 == payS
    np.testing.assert_array_equal(c1.stake, cS.stake)
    assert c1.requester_balance == cS.requester_balance
    assert ledS.verify_chain(deep=True)
    # the sharded ledger really did commit through multiple subtrees
    assert ledS.num_shards(ledS.blocks[1].index) > 1
    assert led1.num_shards(led1.blocks[1].index) == 1


def test_two_level_proofs_roundtrip_and_tamper_detection():
    """Two-level settlement proofs verify for every worker; tampering is
    caught at both levels — a corrupted record (chunk level) and a forged
    shard sibling digest (shard level) both fail verification, and deep
    chain verification recurses into the bad subtree."""
    led, c = _settled_contract(4, rounds=2, W=60, chunk=4)
    blk_index = c._round_blocks[1]
    n_shards = led.num_shards(blk_index)
    assert n_shards > 1
    shard_path_len = (n_shards - 1).bit_length()     # levels above the shards
    for w in (0, 17, 31, 59):
        proof = c.settlement_proof(1, w)
        assert c.verify_settlement(proof)
        # the proof's tail is the cross-shard path to the super-root
        assert len(proof["proof"]) >= shard_path_len
        # chunk-level forgery: swap in a different (authentic-format) leaf
        assert not c.verify_settlement(dict(proof, leaf=b"\x01" * 40))
        # shard-level forgery: corrupt the shard-path sibling digest
        doctored = list(proof["proof"])
        side, digest = doctored[-1]
        doctored[-1] = (side, "00" * 32)
        assert not c.verify_settlement(dict(proof, proof=doctored))
        # malformed attacker-supplied proofs are rejected, never raised on:
        # non-hex sibling digests, non-bytes chunk entries, missing keys
        assert not c.verify_settlement(dict(proof, proof=[("L", "zz")]))
        garbled = list(proof["chunk"])
        garbled[(proof["offset"] + 1) % len(garbled)] = 12345
        assert not c.verify_settlement(dict(proof, chunk=garbled))
        assert not c.verify_settlement({})
        assert not c.verify_settlement({"chunk": 5, "leaf": b"x"})
        assert not c.verify_settlement(dict(proof, leaf=5, chunk=[5],
                                            offset=0))
    # tamper one stored record in a non-first shard: its proof and deep
    # verification break, the shallow hash chain stays intact
    bounds = c.shard_bounds(60)
    victim = bounds[1] + 1                           # lives in shard 1
    led.tamper_record(blk_index, victim, b"x" * 40)
    assert led.verify_chain() and not led.verify_chain(deep=True)
    assert not led.verify_record(blk_index, victim)
    # shard roots are individually exposed for cross-shard audit
    assert len(led.shard_roots(blk_index)) == n_shards


def test_append_block_drops_empty_shards_in_lockstep_with_trees():
    """Empty shards are filtered together with their precomputed trees (the
    shard↔tree pairing survives), and a shard/tree length mismatch is
    rejected up front."""
    led = Ledger()
    recs = _records(12)
    shards = [recs[:8], [], recs[8:]]
    trees = [MerkleTree(shards[0], 2), None, MerkleTree(shards[2], 2)]
    blk = led.append_block([{"t": 1}], timestamp=1.0, record_shards=shards,
                           shard_trees=trees, chunk_size=2)
    assert led.num_shards(blk.index) == 2
    assert led.verify_chain(deep=True)
    assert blk.records_root == ShardedCommit([recs[:8], recs[8:]], 2).root
    with pytest.raises(ValueError):
        led.append_block([{"t": 2}], record_shards=shards,
                         shard_trees=trees[:2], chunk_size=2)


def test_shard_worker_pool_maps_in_order_and_raises_deterministically():
    pool = ShardWorkerPool(3)
    try:
        assert pool.map([lambda i=i: i * i for i in range(10)]) == \
            [i * i for i in range(10)]
        assert pool.map([]) == []

        def boom(i):
            raise ValueError(f"shard {i} died")

        # every thunk runs; the lowest-index failure is the one raised
        with pytest.raises(ValueError, match="shard 2 died"):
            pool.map([lambda: 0, lambda: 1, lambda: boom(2),
                      lambda: boom(5)])
        # the pool survives a failed map and keeps serving
        assert pool.map([lambda: "ok"]) == ["ok"]
    finally:
        pool.stop()
    with pytest.raises(RuntimeError):
        pool.map([lambda: 1])
    pool.stop()                                      # idempotent


def test_pooled_settlement_bit_identical_to_inline():
    """The worker pool only changes which thread hashes a shard — penalties,
    state, and chains are bit-identical with and without it."""
    pool = ShardWorkerPool(2)
    try:
        outs = {}
        for use_pool in (False, True):
            led = Ledger()
            c = TrustContract(led, requester_deposit=1e3, worker_stake=10.0,
                              penalty_pct=50.0, trust_threshold=0.5, top_k=3,
                              merkle_chunk_size=2, settlement_shards=5)
            c.min_parallel_leaf_bytes = 1     # force fan-out at tiny leaves
            c.join_batch(40)
            scores = np.random.default_rng(2).random((3, 40))
            pens = [c.settle_round_batch(r, scores[r], timestamp=float(r + 1),
                                         pool=pool if use_pool else None)
                    for r in range(3)]
            outs[use_pool] = (pens, [b.hash for b in led.blocks],
                              c.stake.copy())
        for a, b in zip(outs[False][0], outs[True][0]):
            np.testing.assert_array_equal(a, b)
        assert outs[False][1] == outs[True][1]
        np.testing.assert_array_equal(outs[False][2], outs[True][2])
    finally:
        pool.stop()


def test_pool_spawn_gated_on_fanout_feasibility():
    """No dead threads: with auto pool sizing, shard workers spawn only
    when the contract's leaf-size gate could ever feed them; an explicit
    settler_pool_size forces the spawn (what the driver tests rely on)."""
    import dataclasses as dc

    from repro.configs.registry import get_config
    from repro.configs.base import FederationConfig, TrainConfig

    import os

    cfg = get_config("paper-net")
    tc = TrainConfig(remat=False)
    base = FederationConfig(num_clusters=1, workers_per_cluster=4,
                            settlement_shards=4, pipeline_depth=2)
    # default chunk (64 → 2.5 KiB leaves) < gate: auto sizing spawns nothing
    p1 = SDFLBProtocol(cfg, base, tc, use_blockchain=True, seed=0)
    assert p1._shard_pool is None
    assert not p1.contract.parallel_fanout_possible()
    # big leaves clear the gate: auto sizing spawns workers (auto size is
    # min(shards, cpus) — on a single-CPU host it stays 1 and nothing
    # spawns, so only assert the spawn where it can happen)
    p2 = SDFLBProtocol(cfg, dc.replace(base, merkle_chunk_size=1024), tc,
                       use_blockchain=True, seed=0)
    assert p2.contract.parallel_fanout_possible()
    if (os.cpu_count() or 1) > 1:
        assert p2._shard_pool is not None
    # retuned gate: the framed batched hasher amortizes the GIL handoff
    # from ~4 KiB leaves, so k=128 (5 KiB) clears a gate the old 32 KiB
    # crossover kept shut
    c128 = TrustContract(Ledger(), requester_deposit=1e3, worker_stake=10.0,
                         penalty_pct=50.0, trust_threshold=0.5, top_k=3,
                         merkle_chunk_size=128, settlement_shards=4)
    assert c128.parallel_fanout_possible()
    # explicit pool size forces the spawn even under the gate
    p3 = SDFLBProtocol(cfg, dc.replace(base, settler_pool_size=2), tc,
                       use_blockchain=True, seed=0)
    assert p3._shard_pool is not None
    for p in (p1, p2, p3):
        p.finalize()


# -- protocol-level: settler pool vs serial reference -------------------------


def _decision_trace(proto):
    return {
        "blocks": [b.hash for b in proto.ledger.blocks],
        "heads": [tuple(r.heads) for r in proto.history],
        "penalties": np.stack([r.penalties for r in proto.history]),
        "cids": [r.model_cid for r in proto.history],
    }


@pytest.mark.parametrize("shards", [2, 7])
def test_settler_pool_driver_matches_serial(shards):
    """Property: the sharded settler-pool driver (pipeline_depth > 0,
    settlement_shards ∈ {2, 7}, 2 shard workers) produces byte-identical
    chains, elections, penalties and payouts to the serial unsharded
    reference (depth 0, S = 1) on the same data."""
    from repro.configs.registry import get_config
    from repro.data.datasets import make_federated_mnist
    from repro.configs.base import FederationConfig, TrainConfig

    cfg = get_config("paper-net")
    tc = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd", remat=False)
    base = FederationConfig(num_clusters=2, workers_per_cluster=3,
                            trust_threshold=0.45, top_k_rewarded=3,
                            merkle_chunk_size=1)
    runs = {}
    for name, depth, S in (("serial", 0, 1), ("pooled", 3, shards)):
        ds = make_federated_mnist(6, samples=768, seed=5)
        fed = dataclasses.replace(base, pipeline_depth=depth,
                                  settlement_shards=S, settler_pool_size=2)
        proto = SDFLBProtocol(cfg, fed, tc, use_blockchain=True, seed=11)
        if name == "pooled":
            assert proto._shard_pool is not None     # workers really spawn
            # tiny leaves would normally inhibit fan-out (GIL economics);
            # force it so this test pins pool-thread determinism too
            proto.contract.min_parallel_leaf_bytes = 1
        for _ in range(6):
            proto.run_round(ds.round_batches(32))
        proto.flush()
        payouts = proto.finalize()
        assert proto.ledger.verify_chain(deep=True)
        runs[name] = (_decision_trace(proto), payouts)
    serial, pooled = runs["serial"], runs["pooled"]
    assert serial[0]["blocks"] == pooled[0]["blocks"]    # byte-identical
    assert serial[0]["heads"] == pooled[0]["heads"]
    assert serial[0]["cids"] == pooled[0]["cids"]
    np.testing.assert_array_equal(serial[0]["penalties"],
                                  pooled[0]["penalties"])
    assert serial[1] == pooled[1]                        # payouts


def test_shard_failure_is_sticky_and_never_half_commits():
    """One shard failing aborts its round with contract state and chain
    untouched (no half-settled super-root), poisons the settler for later
    rounds (sticky re-raise), and discards everything still queued."""
    from repro.configs.registry import get_config
    from repro.data.datasets import make_federated_mnist
    from repro.configs.base import FederationConfig, TrainConfig

    cfg = get_config("paper-net")
    tc = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd", remat=False)
    fed = FederationConfig(num_clusters=1, workers_per_cluster=6,
                           trust_threshold=0.2, merkle_chunk_size=1,
                           settlement_shards=3, settler_pool_size=2,
                           pipeline_depth=2)
    ds = make_federated_mnist(6, samples=256, seed=0)
    proto = SDFLBProtocol(cfg, fed, tc, use_blockchain=True, seed=0)
    assert len(proto.contract.shard_bounds(6)) - 1 > 1   # really sharded

    orig = proto.contract.settle_shard

    def failing_shard(round_index, ids, s, start, stop):
        if start > 0:                                    # shard 0 succeeds,
            raise RuntimeError("shard worker died")      # a later shard dies
        return orig(round_index, ids, s, start, stop)

    proto.contract.settle_shard = failing_shard
    stake_before = proto.contract.stake.copy()
    with pytest.raises(RuntimeError):
        for _ in range(4):
            proto.run_round(ds.round_batches(16))
    with pytest.raises(RuntimeError):
        proto.flush()
    with pytest.raises(RuntimeError):                    # sticky
        proto.flush()
    # nothing was applied or committed: genesis only, stakes untouched
    assert len(proto.ledger.blocks) == 1
    np.testing.assert_array_equal(proto.contract.stake, stake_before)
    assert proto.contract.requester_balance == 0.0
