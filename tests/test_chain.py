"""Blockchain substrate: ledger integrity, contract (Algorithm 1)
correctness + conservation properties, IPFS content addressing, and the
array-native batch settlement path (batch-vs-scalar equivalence, Merkle
commitments, 100k-worker scaling)."""
import time

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.chain.contract import (ContractError, TrustContract,
                                  decode_settlement_record)
from repro.chain.ipfs import IPFSStore
from repro.chain.ledger import Ledger, MerkleTree


def test_ledger_chain_verifies_and_detects_tampering():
    led = Ledger()
    led.append_block([{"type": "x", "v": 1}])
    led.append_block([{"type": "y", "v": 2}])
    assert led.verify_chain()
    led.blocks[1].transactions[0]["v"] = 999       # tamper
    assert not led.verify_chain()


def test_ledger_randomness_deterministic():
    a, b = Ledger(), Ledger()
    a.append_block([{"t": 1}], timestamp=1.0)
    b.append_block([{"t": 1}], timestamp=1.0)
    assert a.randomness(3) == b.randomness(3)
    assert a.randomness(3) != a.randomness(4)


def test_ipfs_roundtrip_and_tamper_detection():
    store = IPFSStore()
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": jnp.ones((5,), jnp.bfloat16)}
    cid = store.put_tree(tree)
    leaves = store.get_leaves(cid)
    np.testing.assert_allclose(leaves[1], tree["w"])   # dict order: b, w
    store.tamper(cid, b"garbage")
    with pytest.raises(ValueError):
        store.get_leaves(cid)


def test_contract_algorithm1_steps():
    led = Ledger()
    c = TrustContract(led, requester_deposit=100.0, worker_stake=10.0,
                      penalty_pct=50.0, trust_threshold=0.5, top_k=2)
    for w in ["w0", "w1", "w2"]:
        c.join(w)
    pens = c.settle_round(0, {"w0": 0.9, "w1": 0.4, "w2": 0.6}, "cid0")
    # Pen(w) = F·P/100 = 10·50/100 = 5 for the one bad worker
    assert pens == {"w1": 5.0}
    assert c.workers["w1"].stake == 5.0
    assert c.requester_balance == 5.0
    payouts = c.finalize()
    # refunds: w0 10, w1 5, w2 10 ; rewards: top-2 (w0, w2) split 100
    assert payouts["w0"] == 10.0 + 50.0
    assert payouts["w1"] == 5.0
    assert payouts["w2"] == 10.0 + 50.0
    assert led.verify_chain()


def test_contract_rejects_unknown_and_double_finalize():
    c = TrustContract(Ledger(), requester_deposit=10, worker_stake=1,
                      penalty_pct=10, trust_threshold=0.5, top_k=1)
    c.join("a")
    with pytest.raises(ContractError):
        c.settle_round(0, {"ghost": 1.0})
    c.finalize()
    with pytest.raises(ContractError):
        c.finalize()


@settings(max_examples=40, deadline=None)
@given(
    n_workers=st.integers(1, 12),
    deposit=st.floats(1.0, 1e4),
    stake=st.floats(0.1, 100.0),
    pct=st.floats(0.0, 100.0),
    threshold=st.floats(0.0, 1.0),
    k=st.integers(1, 12),
    rounds=st.integers(1, 5),
    seed=st.integers(0, 2**31),
)
def test_contract_value_conservation(n_workers, deposit, stake, pct,
                                     threshold, k, rounds, seed):
    """Property: total value (pool + requester + stakes + balances) is
    conserved through any score sequence; stakes never go negative."""
    rng = np.random.default_rng(seed)
    c = TrustContract(Ledger(), requester_deposit=deposit, worker_stake=stake,
                      penalty_pct=pct, trust_threshold=threshold, top_k=k)
    for w in range(n_workers):
        c.join(f"w{w}")
    total0 = c.total_value()
    for r in range(rounds):
        scores = {f"w{w}": float(rng.random()) for w in range(n_workers)}
        c.settle_round(r, scores)
        assert abs(c.total_value() - total0) < 1e-6 * max(total0, 1)
        assert all(a.stake >= -1e-9 for a in c.workers.values())
    c.finalize()
    assert abs(c.total_value() - total0) < 1e-6 * max(total0, 1)
    # after finalize all stakes are zero (everything refunded/penalized)
    assert all(a.stake == 0.0 for a in c.workers.values())


# -- array-native batch settlement -------------------------------------------

class ReferenceContract:
    """Seed-faithful scalar Algorithm 1 (per-worker dict loops) — the oracle
    the vectorized batch path must match exactly."""

    def __init__(self, deposit, stake, pct, threshold, k):
        self.F, self.P, self.T, self.k = stake, pct, threshold, k
        self.reward_pool = deposit
        self.requester_balance = 0.0
        self.accts = {}       # name -> [stake, balance, penalized, scores]

    def join(self, name):
        self.accts[name] = [self.F, 0.0, 0, []]

    def settle_round(self, scores):
        penalties = {}
        for wid, s in sorted(scores.items()):
            a = self.accts[wid]
            a[3].append(float(s))
            if s < self.T:
                pen = min(self.F * self.P / 100.0, a[0])
                a[0] -= pen
                a[2] += 1
                self.requester_balance += pen
                penalties[wid] = pen
        return penalties

    def finalize(self):
        payouts = {}
        for wid, a in sorted(self.accts.items()):
            payouts[wid] = a[0]
            a[1] += a[0]
            a[0] = 0.0
        ranked = sorted(self.accts,
                        key=lambda w: (sum(self.accts[w][3]) /
                                       max(len(self.accts[w][3]), 1)),
                        reverse=True)
        top = ranked[: self.k]
        if top:
            share = self.reward_pool / len(top)
            for wid in top:
                self.accts[wid][1] += share
                payouts[wid] += share
            self.reward_pool = 0.0
        return payouts

    def total_value(self):
        return (self.reward_pool + self.requester_balance +
                sum(a[0] + a[1] for a in self.accts.values()))


@settings(max_examples=30, deadline=None)
@given(
    n_workers=st.integers(1, 24),
    deposit=st.floats(1.0, 1e4),
    stake=st.floats(0.1, 100.0),
    pct=st.floats(0.0, 100.0),
    threshold=st.floats(0.0, 1.0),
    k=st.integers(1, 24),
    rounds=st.integers(1, 5),
    subset=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_batch_settlement_matches_scalar_reference(n_workers, deposit, stake,
                                                   pct, threshold, k, rounds,
                                                   subset, seed):
    """Property: the vectorized settle_round_batch + finalize produce
    penalties, payouts, stakes, penalized_rounds, and total_value identical
    to the seed's per-worker scalar loops on random score matrices (full
    rounds and random partial-participation rounds)."""
    rng = np.random.default_rng(seed)
    c = TrustContract(Ledger(), requester_deposit=deposit, worker_stake=stake,
                      penalty_pct=pct, trust_threshold=threshold, top_k=k)
    ref = ReferenceContract(deposit, stake, pct, threshold, k)
    ids = c.join_batch(n_workers)
    names = [c.worker_name(i) for i in ids]
    for n in names:
        ref.join(n)
    total0 = c.total_value()
    for r in range(rounds):
        if subset and n_workers > 1:
            m = int(rng.integers(1, n_workers + 1))
            sel = np.sort(rng.choice(n_workers, size=m, replace=False))
        else:
            sel = np.arange(n_workers)
        s = rng.random(len(sel))
        pen_vec = c.settle_round_batch(r, s, worker_ids=sel)
        ref_pen = ref.settle_round({names[w]: float(v)
                                    for w, v in zip(sel, s)})
        got_pen = {names[w]: float(p)
                   for w, p, v in zip(sel, pen_vec, s) if v < threshold}
        assert set(got_pen) == set(ref_pen)
        for n_ in ref_pen:
            assert got_pen[n_] == pytest.approx(ref_pen[n_], abs=1e-12)
        assert c.requester_balance == pytest.approx(ref.requester_balance)
        assert abs(c.total_value() - total0) < 1e-6 * max(total0, 1)
    for i, n_ in enumerate(names):
        assert c.workers[n_].stake == pytest.approx(ref.accts[n_][0])
        assert c.workers[n_].penalized_rounds == ref.accts[n_][2]
        assert c.workers[i].scores == ref.accts[n_][3]
    pay = c.finalize()
    ref_pay = ref.finalize()
    assert set(pay) == set(ref_pay)
    for n_ in pay:
        assert pay[n_] == pytest.approx(ref_pay[n_], abs=1e-9)
    assert c.total_value() == pytest.approx(ref.total_value())
    assert abs(c.total_value() - total0) < 1e-6 * max(total0, 1)


def test_merkle_tree_roots_and_proofs():
    for n in (1, 2, 3, 5, 8, 13):
        leaves = [f"leaf-{i}".encode() for i in range(n)]
        t = MerkleTree(leaves)
        for i, leaf in enumerate(leaves):
            assert MerkleTree.verify(leaf, t.proof(i), t.root)
            assert not MerkleTree.verify(b"forged", t.proof(i), t.root)
        if n > 1:   # a proof for one index never validates another's leaf
            assert not MerkleTree.verify(leaves[0], t.proof(1), t.root)
    with pytest.raises(ValueError):
        MerkleTree([])


def test_batched_block_merkle_audit_and_tamper_detection():
    led = Ledger()
    c = TrustContract(led, requester_deposit=100.0, worker_stake=10.0,
                      penalty_pct=50.0, trust_threshold=0.5, top_k=2)
    c.join_batch(6)
    scores = np.array([0.9, 0.4, 0.6, 0.2, 0.8, 0.55])
    pen = c.settle_round_batch(0, scores, model_cid="cid0")
    np.testing.assert_allclose(pen, [0, 5.0, 0, 5.0, 0, 0])
    assert led.verify_chain(deep=True)
    # every worker's settlement is individually auditable in O(log W)
    for w in range(6):
        proof = c.settlement_proof(0, w)
        assert c.verify_settlement(proof)
        assert len(proof["proof"]) <= 3           # ceil(log2(6))
        rec = proof["record"]
        assert rec["worker"] == w
        assert rec["score"] == pytest.approx(scores[w])
        assert rec["penalty"] == pytest.approx(pen[w])
    # proofs also accept string worker names (legacy id scheme)
    assert c.verify_settlement(c.settlement_proof(0, "worker-3"))
    # round-trip decode of the canonical leaf encoding
    blk = led.blocks[-1]
    rec0 = decode_settlement_record(led.record_batch(blk.index)[1])
    assert rec0 == {"round": 0, "worker": 1, "score": pytest.approx(0.4),
                    "penalty": pytest.approx(5.0),
                    "stake_after": pytest.approx(5.0), "staleness": 0}
    # tampering with an off-chain record breaks deep verification and the
    # record's proof, while the block hash chain itself stays intact
    led.tamper_record(blk.index, 1, b"x" * 40)
    assert led.verify_chain() and not led.verify_chain(deep=True)
    assert not led.verify_record(blk.index, 1)
    # tampering with the committed root breaks the shallow chain too
    blk.records_root = "0" * 64
    assert not led.verify_chain()


def test_settle_round_batch_validates_inputs():
    c = TrustContract(Ledger(), requester_deposit=10, worker_stake=1,
                      penalty_pct=10, trust_threshold=0.5, top_k=1)
    c.join_batch(4)
    with pytest.raises(ContractError):          # wrong length
        c.settle_round_batch(0, np.zeros(3))
    with pytest.raises(ContractError):          # unknown id
        c.settle_round_batch(0, np.zeros(1), worker_ids=np.array([9]))
    with pytest.raises(ContractError):          # duplicate ids
        c.settle_round_batch(0, np.zeros(2), worker_ids=np.array([1, 1]))
    c.finalize()
    with pytest.raises(ContractError):          # closed task
        c.settle_round_batch(1, np.zeros(4))


def test_settlement_scales_to_100k_workers_under_1s():
    """Acceptance: chain-only settlement at W=100,000 completes a full round
    (vectorized Algorithm 1 + Merkle commit + block seal) in < 1s on CPU."""
    W = 100_000
    led = Ledger()
    c = TrustContract(led, requester_deposit=1e6, worker_stake=10.0,
                      penalty_pct=50.0, trust_threshold=0.5, top_k=100)
    c.join_batch(W)
    scores = np.random.default_rng(0).random(W)
    t0 = time.monotonic()
    pen = c.settle_round_batch(0, scores)
    dt = time.monotonic() - t0
    assert dt < 1.0, f"100k-worker settlement took {dt:.2f}s"
    assert pen.shape == (W,)
    bad = int((scores < 0.5).sum())
    assert int((pen > 0).sum()) == bad
    assert c.requester_balance == pytest.approx(bad * 5.0)
    # spot-audit one worker without rehashing the round: the node path is
    # over chunk leaves (64 records each), so ceil(log2(ceil(100k/64)))
    proof = c.settlement_proof(0, 31_337)
    assert c.verify_settlement(proof)
    import math
    assert len(proof["proof"]) == math.ceil(math.log2(math.ceil(W / 64)))
    assert len(proof["chunk"]) == 64
    assert proof["chunk"][proof["offset"]] == proof["leaf"]


def test_chunked_root_with_chunk_size_one_matches_per_record_root():
    """chunk_size=1 must reproduce the per-record tree bit-for-bit (and
    both must match an independent reimplementation of the hash rule)."""
    import hashlib
    records = [f"rec-{i}".encode() for i in range(7)]
    per_record = MerkleTree(records)               # default: one record/leaf
    chunk1 = MerkleTree(records, chunk_size=1)
    assert chunk1.root == per_record.root
    # independent recomputation of the 7-leaf root (promote-unpaired rule)
    lvl = [hashlib.sha256(b"\x00" + r).digest() for r in records]
    while len(lvl) > 1:
        nxt = [hashlib.sha256(b"\x01" + lvl[i] + lvl[i + 1]).digest()
               for i in range(0, len(lvl) - 1, 2)]
        if len(lvl) % 2:
            nxt.append(lvl[-1])
        lvl = nxt
    assert per_record.root == lvl[0].hex()
    # chunking changes the root (different leaf bytes) but not the records
    assert MerkleTree(records, chunk_size=3).root != per_record.root


@pytest.mark.parametrize("chunk_size", [1, 3, 64, 10])
def test_chunked_proofs_verify_and_tampering_fails(chunk_size):
    """Across chunk sizes {1, 3, 64, W}: every worker's settlement proof
    verifies, tampered records fail both the proof and deep chain
    verification, and hash work shrinks to ~2·ceil(W/k) nodes."""
    W = 10                                         # chunk_size=10 == W
    led = Ledger()
    c = TrustContract(led, requester_deposit=100.0, worker_stake=10.0,
                      penalty_pct=50.0, trust_threshold=0.5, top_k=2,
                      merkle_chunk_size=chunk_size)
    c.join_batch(W)
    scores = np.linspace(0.05, 0.95, W)
    pen = c.settle_round_batch(0, scores)
    assert led.verify_chain(deep=True)
    n_leaves = -(-W // chunk_size)                 # ceil
    tree = led._record_trees[led.head.index]
    assert tree.num_leaves == n_leaves
    # ~2n−1 (+ promoted odd nodes, one per level at most)
    assert tree.hash_ops <= 2 * n_leaves + len(tree.levels)
    for w in range(W):
        proof = c.settlement_proof(0, w)
        assert c.verify_settlement(proof)
        assert len(proof["chunk"]) <= chunk_size
        rec = proof["record"]
        assert rec["worker"] == w
        assert rec["score"] == pytest.approx(scores[w])
        assert rec["penalty"] == pytest.approx(pen[w])
        assert led.verify_record(led.head.index, w)
        # a proof whose claimed record disagrees with its chunk is rejected
        forged = dict(proof, leaf=b"\x00" * len(proof["leaf"]))
        assert not c.verify_settlement(forged)
        # ... as is a doctored human-readable view over an authentic leaf,
        # and malformed offsets are rejected, not raised on
        assert not c.verify_settlement(
            dict(proof, record={**proof["record"], "score": 0.99}))
        assert not c.verify_settlement(dict(proof, offset=99))
        assert not c.verify_settlement(dict(proof, offset=-1))
    # tamper one stored record: its proof and deep verification both break,
    # the shallow hash chain stays intact
    victim = W // 2
    led.tamper_record(led.head.index, victim, b"x" * 40)
    assert led.verify_chain() and not led.verify_chain(deep=True)
    assert not led.verify_record(led.head.index, victim)


def test_chunked_commit_hashes_fewer_nodes_and_same_settlement():
    """Chunked vs per-record commits: identical Algorithm 1 outcome,
    ~k-fold fewer ledger work units for the commit."""
    W = 512
    scores = np.random.default_rng(3).random(W)
    outs = {}
    for k in (1, 64):
        led = Ledger()
        c = TrustContract(led, requester_deposit=1e4, worker_stake=10.0,
                          penalty_pct=50.0, trust_threshold=0.5, top_k=8,
                          merkle_chunk_size=k)
        c.join_batch(W)
        pen = c.settle_round_batch(0, scores)
        outs[k] = (pen, c.stake.copy(), led.work_units)
    np.testing.assert_allclose(outs[1][0], outs[64][0])
    np.testing.assert_allclose(outs[1][1], outs[64][1])
    assert outs[64][2] < outs[1][2] / 8            # far fewer hash ops


def test_finalize_with_zero_top_k_pays_refunds_only():
    c = TrustContract(Ledger(), requester_deposit=50.0, worker_stake=5.0,
                      penalty_pct=10.0, trust_threshold=0.5, top_k=0)
    c.join_batch(3)
    c.settle_round_batch(0, np.array([0.9, 0.8, 0.7]))
    pay = c.finalize()
    assert pay == {"worker-0": 5.0, "worker-1": 5.0, "worker-2": 5.0}
    assert c.reward_pool == 50.0               # undistributed, conserved
    assert c.total_value() == pytest.approx(50.0 + 3 * 5.0)


def test_finalize_topk_tie_break_is_join_order():
    """Exact mean-score ties straddling the k boundary must resolve by join
    order (the legacy stable sort), not argpartition's arbitrary pick."""
    c = TrustContract(Ledger(), requester_deposit=90.0, worker_stake=1.0,
                      penalty_pct=0.0, trust_threshold=0.0, top_k=3)
    c.join_batch(6)
    c.settle_round_batch(0, np.array([0.5, 0.9, 0.5, 0.5, 0.2, 0.5]))
    pay = c.finalize()
    # top-3: worker 1 (0.9) then the first two tied 0.5s by join order (0, 2)
    rewarded = {n for n, p in pay.items() if p > 1.0}
    assert rewarded == {"worker-1", "worker-0", "worker-2"}


def test_settlement_proofs_with_out_of_order_rounds():
    """Audit bookkeeping is keyed by round index, so rounds settled out of
    order (async arrivals) still yield correct per-worker proofs."""
    c = TrustContract(Ledger(), requester_deposit=10.0, worker_stake=2.0,
                      penalty_pct=50.0, trust_threshold=0.5, top_k=1)
    c.join_batch(4)
    c.settle_round_batch(5, np.array([0.9, 0.1]),
                         worker_ids=np.array([0, 1]))
    c.settle_round_batch(2, np.array([0.3, 0.8]),
                         worker_ids=np.array([2, 3]))
    for rnd, wid, score in ((5, 0, 0.9), (5, 1, 0.1), (2, 2, 0.3),
                            (2, 3, 0.8)):
        proof = c.settlement_proof(rnd, wid)
        assert c.verify_settlement(proof)
        assert proof["record"]["round"] == rnd
        assert proof["record"]["worker"] == wid
        assert proof["record"]["score"] == pytest.approx(score)
