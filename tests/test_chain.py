"""Blockchain substrate: ledger integrity, contract (Algorithm 1)
correctness + conservation properties, IPFS content addressing."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.chain.contract import ContractError, TrustContract
from repro.chain.ipfs import IPFSStore
from repro.chain.ledger import Ledger


def test_ledger_chain_verifies_and_detects_tampering():
    led = Ledger()
    led.append_block([{"type": "x", "v": 1}])
    led.append_block([{"type": "y", "v": 2}])
    assert led.verify_chain()
    led.blocks[1].transactions[0]["v"] = 999       # tamper
    assert not led.verify_chain()


def test_ledger_randomness_deterministic():
    a, b = Ledger(), Ledger()
    a.append_block([{"t": 1}], timestamp=1.0)
    b.append_block([{"t": 1}], timestamp=1.0)
    assert a.randomness(3) == b.randomness(3)
    assert a.randomness(3) != a.randomness(4)


def test_ipfs_roundtrip_and_tamper_detection():
    store = IPFSStore()
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": jnp.ones((5,), jnp.bfloat16)}
    cid = store.put_tree(tree)
    leaves = store.get_leaves(cid)
    np.testing.assert_allclose(leaves[1], tree["w"])   # dict order: b, w
    store.tamper(cid, b"garbage")
    with pytest.raises(ValueError):
        store.get_leaves(cid)


def test_contract_algorithm1_steps():
    led = Ledger()
    c = TrustContract(led, requester_deposit=100.0, worker_stake=10.0,
                      penalty_pct=50.0, trust_threshold=0.5, top_k=2)
    for w in ["w0", "w1", "w2"]:
        c.join(w)
    pens = c.settle_round(0, {"w0": 0.9, "w1": 0.4, "w2": 0.6}, "cid0")
    # Pen(w) = F·P/100 = 10·50/100 = 5 for the one bad worker
    assert pens == {"w1": 5.0}
    assert c.workers["w1"].stake == 5.0
    assert c.requester_balance == 5.0
    payouts = c.finalize()
    # refunds: w0 10, w1 5, w2 10 ; rewards: top-2 (w0, w2) split 100
    assert payouts["w0"] == 10.0 + 50.0
    assert payouts["w1"] == 5.0
    assert payouts["w2"] == 10.0 + 50.0
    assert led.verify_chain()


def test_contract_rejects_unknown_and_double_finalize():
    c = TrustContract(Ledger(), requester_deposit=10, worker_stake=1,
                      penalty_pct=10, trust_threshold=0.5, top_k=1)
    c.join("a")
    with pytest.raises(ContractError):
        c.settle_round(0, {"ghost": 1.0})
    c.finalize()
    with pytest.raises(ContractError):
        c.finalize()


@settings(max_examples=40, deadline=None)
@given(
    n_workers=st.integers(1, 12),
    deposit=st.floats(1.0, 1e4),
    stake=st.floats(0.1, 100.0),
    pct=st.floats(0.0, 100.0),
    threshold=st.floats(0.0, 1.0),
    k=st.integers(1, 12),
    rounds=st.integers(1, 5),
    seed=st.integers(0, 2**31),
)
def test_contract_value_conservation(n_workers, deposit, stake, pct,
                                     threshold, k, rounds, seed):
    """Property: total value (pool + requester + stakes + balances) is
    conserved through any score sequence; stakes never go negative."""
    rng = np.random.default_rng(seed)
    c = TrustContract(Ledger(), requester_deposit=deposit, worker_stake=stake,
                      penalty_pct=pct, trust_threshold=threshold, top_k=k)
    for w in range(n_workers):
        c.join(f"w{w}")
    total0 = c.total_value()
    for r in range(rounds):
        scores = {f"w{w}": float(rng.random()) for w in range(n_workers)}
        c.settle_round(r, scores)
        assert abs(c.total_value() - total0) < 1e-6 * max(total0, 1)
        assert all(a.stake >= -1e-9 for a in c.workers.values())
    c.finalize()
    assert abs(c.total_value() - total0) < 1e-6 * max(total0, 1)
    # after finalize all stakes are zero (everything refunded/penalized)
    assert all(a.stake == 0.0 for a in c.workers.values())
