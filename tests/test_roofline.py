"""Roofline-accounting correctness: analytic param counts vs eval_shape,
the scan-undercount fact that motivates the analytic calculator, and the
HLO collective parser's trip-count attribution."""
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, ".")   # benchmarks package lives at repo root
from benchmarks import analytic
from repro.compat.xla import normalize_cost_analysis
from repro.configs.registry import ARCH_IDS, INPUT_SHAPES, applicable, get_config
from repro.models import api


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_analytic_param_count_exact(arch):
    cfg = get_config(arch)
    sds = jax.eval_shape(lambda k: api.init(cfg, k, tp=16)[0],
                         jax.random.PRNGKey(0))
    actual = sum(x.size for x in jax.tree.leaves(sds))
    assert abs(actual - analytic.total_params(cfg)) / actual < 1e-4


def test_moe_active_params_less_than_total():
    for arch in ("qwen2-moe-a2.7b", "olmoe-1b-7b"):
        cfg = get_config(arch)
        assert analytic.total_params(cfg, active=True) < \
            analytic.total_params(cfg)


def test_cost_analysis_counts_scan_body_once():
    """The documented XLA behaviour that motivates analytic FLOPs."""
    def f_scan(ws, x):
        def body(x, w):
            return x @ w, None
        return jax.lax.scan(body, x, ws)[0]
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cost = normalize_cost_analysis(
        jax.jit(f_scan).lower(ws, x).compile().cost_analysis())
    flops = cost["flops"]
    assert abs(flops - 2 * 128 ** 3) / (2 * 128 ** 3) < 0.01   # body, once


def test_roofline_terms_all_pairs_finite():
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            if not applicable(arch, shape)[0]:
                continue
            t = analytic.roofline_terms(arch, shape)
            for k in ("compute_s", "memory_s", "collective_s"):
                assert np.isfinite(t[k]) and t[k] >= 0, (arch, shape, k)
            assert 0 < t["useful_ratio"] <= 1.5, (arch, shape)
            assert t["dominant"] in ("compute_s", "memory_s", "collective_s")


def test_decode_is_memory_bound_train_is_not():
    t_dec = analytic.roofline_terms("yi-6b", "decode_32k")
    t_train = analytic.roofline_terms("yi-6b", "train_4k")
    assert t_dec["dominant"] == "memory_s"
    assert t_train["dominant"] != "memory_s"


def test_collective_parser_trip_attribution():
    from repro.launch.dryrun import collective_bytes
    hlo = """
HloModule test

%cond.1 (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(30)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[1024] all-reduce(%big), to_apply=%add
  ROOT %t = (s32[], f32[8]) tuple(%i2, %x)
}

ENTRY %main.1 (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %ag = f32[2048] all-gather(%a2), dimensions={0}
  %w = (s32[], f32[8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""
    total, breakdown = collective_bytes(hlo)
    # all-gather once (2048*4B), all-reduce 30x (1024*4B*2 ring factor)
    assert breakdown["all-gather"]["count"] == 1
    assert breakdown["all-reduce"]["count"] == 30
    assert total == 2048 * 4 + 30 * 1024 * 4 * 2
