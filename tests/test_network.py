"""Property tests for the multi-node settlement network (repro.net).

The ISSUE-level guarantees, each asserted byte-for-byte:

- fault-free N-node cohorts converge to *byte-identical* chains for any
  seeded gossip order, with replica contract state bit-equal across
  nodes and to a from-scratch replay of the canonical chain;
- a partition produces divergent forks, and the rejoin converges every
  replica onto the fork-choice winner with contract state bit-equal to
  a single-node replay of the winning chain;
- an equivocating byzantine head is detected in every seeded run: its
  block never canonicalizes, equivocation evidence lands on-chain, and
  its head worker is slashed;
- a LightClient that synced the losing fork observes the reorg as a
  ``reset`` resync and ends bit-aligned with the winning chain.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.net import (LinkSpec, NetworkHarness, contract_fingerprint,
                       head_worker, replay_chain)
from repro.serve import ChainReadServer, LightClient


def _chains(harness, honest_only=True):
    nodes = harness.honest_nodes() if honest_only else harness.nodes
    return [[b.hash for b in n.ledger.blocks] for n in nodes]


# -- fault-free convergence --------------------------------------------------

@given(seed=st.integers(0, 10_000), num_nodes=st.sampled_from([2, 3, 5]))
@settings(max_examples=10, deadline=None)
def test_fault_free_convergence_any_seed(seed, num_nodes):
    """Any gossip schedule (per-link seeded latency/jitter) converges
    every replica to one byte-identical chain and bit-equal state."""
    h = NetworkHarness(num_nodes, seed=seed,
                       link=LinkSpec(latency=0.02, jitter=0.03))
    h.run(3)
    chains = _chains(h)
    assert all(c == chains[0] for c in chains[1:])
    assert len(chains[0]) == 2 + 3          # genesis + deploy + 3 rounds
    fps = [contract_fingerprint(n.contract) for n in h.nodes]
    assert all(fp == fps[0] for fp in fps[1:])
    # replay oracle: incremental replica state == from-scratch replay
    n0 = h.nodes[0]
    _, replayed = replay_chain(n0.ledger.blocks, n0.ledger._commits,
                               h.workers_per_node)
    assert contract_fingerprint(replayed) == fps[0]
    assert all(n.verify() for n in h.nodes)


def test_runs_are_byte_reproducible():
    """Same seed → identical chains; different net seed, same score
    seed → identical settled state may differ only in gossip schedule."""
    a = NetworkHarness(3, seed=42)
    b = NetworkHarness(3, seed=42)
    a.run(4)
    b.run(4)
    assert _chains(a) == _chains(b)
    assert a.net.delivered == b.net.delivered
    assert [contract_fingerprint(n.contract) for n in a.nodes] \
        == [contract_fingerprint(n.contract) for n in b.nodes]


@given(seed=st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_lossy_links_still_converge(seed):
    """iid message loss delays but never breaks convergence: lost
    proposals are healed by backup proposers and block relay."""
    h = NetworkHarness(3, seed=seed,
                       link=LinkSpec(latency=0.02, jitter=0.02, loss=0.15))
    h.run(6)
    h.sync()            # anti-entropy waves heal final-round losses
    chains = _chains(h)
    assert all(c == chains[0] for c in chains[1:])
    assert all(n.verify() for n in h.nodes)


# -- partition → forks → rejoin ---------------------------------------------

@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_partition_rejoin_converges_to_fork_choice_winner(seed):
    h = NetworkHarness(3, seed=seed,
                       partition_rounds=[(1, 3, ((0, 1), (2,)))])
    h.run(3)
    # during the split both sides kept settling: divergent forks
    assert h.nodes[0].ledger.head.hash == h.nodes[1].ledger.head.hash
    assert h.nodes[2].ledger.head.hash != h.nodes[0].ledger.head.hash
    h.run(2)
    chains = _chains(h)
    assert all(c == chains[0] for c in chains[1:])
    # the majority side won on the cumulative-trust tiebreak (it settled
    # the whole 3-cluster cohort; the minority settled only its own),
    # so the minority node is the one that reorged
    assert h.nodes[2].reorgs >= 1
    # contract state bit-equal to a single-node replay of the winner
    winner = h.nodes[2]
    _, replayed = replay_chain(winner.ledger.blocks, winner.ledger._commits,
                               h.workers_per_node)
    assert contract_fingerprint(replayed) \
        == contract_fingerprint(winner.contract)
    assert all(n.verify() for n in h.nodes)


def test_partition_forks_carry_both_sides_rounds():
    """The winning chain still settles every round — the partition costs
    the minority its fork, not the federation its rounds."""
    h = NetworkHarness(3, seed=9, partition_rounds=[(1, 3, ((0, 1), (2,)))])
    h.run(5)
    assert h.converged()
    settled = sorted(h.nodes[0].contract._round_blocks)
    assert settled == [0, 1, 2, 3, 4]


# -- byzantine equivocating head ---------------------------------------------

@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_equivocating_head_detected_and_penalized_every_seed(seed):
    byz = 1
    h = NetworkHarness(3, seed=seed, byzantine={byz: "equivocate"})
    h.run(4)
    honest = h.honest_nodes()
    chains = _chains(h)
    assert all(c == chains[0] for c in chains[1:])
    for n in honest:
        # detection: every honest replica saw the conflict
        assert n.evidence_found >= 1
        assert byz in n._equivocators
        txs = [tx for b in n.ledger.blocks for tx in b.transactions
               if isinstance(tx, dict)]
        # evidence landed on-chain…
        evidence = [tx for tx in txs if tx.get("type") == "equivocation"
                    and tx["proposer"] == byz]
        assert len(evidence) >= 1
        assert sorted(evidence[0]["blocks"]) == evidence[0]["blocks"]
        # …no equivocated seal canonicalized…
        assert all(tx["proposer"] != byz for tx in txs
                   if tx.get("type") == "seal")
        # …and the offender's head worker was slashed below full stake
        w = head_worker(evidence[0]["round"], byz, h.workers_per_node)
        assert n.contract.penalized_rounds[w] >= 1
    # every round still settled (honest backups healed the slots)
    assert sorted(honest[0].contract._round_blocks) == [0, 1, 2, 3]
    assert all(n.verify() for n in honest)


def test_tampered_super_root_rejected_and_penalized():
    """A head gossiping its block with forged settlement records is
    caught by semantic validation on receipt and slashed on-chain."""
    byz = 0
    h = NetworkHarness(3, seed=6, byzantine={byz: "tamper"})
    h.run(4)
    honest = h.honest_nodes()
    assert h.converged()
    for n in honest:
        assert n.rejected_blocks >= 1
        txs = [tx for b in n.ledger.blocks for tx in b.transactions
               if isinstance(tx, dict)]
        evidence = [tx for tx in txs if tx.get("type") == "tampered_block"
                    and tx["proposer"] == byz]
        assert len(evidence) >= 1
        assert "tampered" in evidence[0]["error"]
        assert all(tx["proposer"] != byz for tx in txs
                   if tx.get("type") == "seal")
    assert all(n.verify() for n in honest)


# -- serve integration: light clients across a reorg --------------------------

def test_light_client_resyncs_across_reorg():
    h = NetworkHarness(3, seed=3, partition_rounds=[(1, 3, ((0, 1), (2,)))])
    minority = h.nodes[2]
    server = ChainReadServer(ledger=minority.ledger,
                             contracts={None: minority.contract})
    client = LightClient(server)
    h.run(3)
    client.sync()                     # client tracks the minority fork
    fork_head = client.headers[-1].hash
    assert fork_head == minority.ledger.head.hash
    h.run(2)                          # rejoin: minority reorgs
    assert minority.reorgs >= 1
    gained = client.sync()
    assert client.reorg_resyncs == 1
    assert server.head_resets >= 1
    assert client.headers[-1].hash == minority.ledger.head.hash
    assert client.headers[-1].hash != fork_head
    assert len(client.headers) == len(minority.ledger.blocks)
    assert gained == len(client.headers) - (2 + 3)   # vs pre-reorg height
    # proofs resolve against the post-reorg chain
    r = server.latest_settled_round(None)
    batch = server.get_proofs(None, [0], round_index=r)
    assert client.verify_batch(batch)


# -- conservation -------------------------------------------------------------

@given(seed=st.integers(0, 10_000),
       scenario=st.sampled_from(["clean", "partition", "equivocate"]))
@settings(max_examples=9, deadline=None)
def test_total_value_conserved(seed, scenario):
    """Penalties move stake, never mint or burn it — on every replica,
    through partitions, reorgs, and evidence slashes."""
    kw = {}
    if scenario == "partition":
        kw["partition_rounds"] = [(1, 3, ((0, 1), (2,)))]
    elif scenario == "equivocate":
        kw["byzantine"] = {1: "equivocate"}
    h = NetworkHarness(3, seed=seed, **kw)
    initial = h.nodes[0].contract.total_value()
    h.run(4)
    for n in h.honest_nodes():
        assert n.contract.total_value() == pytest.approx(initial)


def test_converged_state_matches_across_scenarios():
    """The defended end-state is scenario-independent where it should
    be: honest replicas agree bit-for-bit in every scenario."""
    for kw in ({}, {"partition_rounds": [(1, 2, ((0,), (1, 2)))]},
               {"byzantine": {2: "tamper"}}):
        h = NetworkHarness(3, seed=5, **kw)
        h.run(4)
        fps = [contract_fingerprint(n.contract) for n in h.honest_nodes()]
        assert all(fp == fps[0] for fp in fps[1:]), kw


def test_chain_node_seal_listener_feeds_peer_replica():
    """The ChainNode network seam: a seal listener captures every block
    the live settler publishes (with its commit), and a peer node
    adopts the stream verbatim — replica chain byte-identical to the
    leader's and deep-verifiable, like a proof-serving follower."""
    from repro.configs.base import FederationConfig, TrainConfig
    from repro.configs.registry import get_config
    from repro.core.node import ChainNode
    from repro.data.datasets import make_federated_mnist

    fed = FederationConfig(num_clusters=1, workers_per_cluster=3,
                           trust_threshold=0.3, merkle_chunk_size=2)
    leader = ChainNode(pipeline_depth=2)
    sealed = []
    leader.add_seal_listener(lambda blk, commit: sealed.append((blk,
                                                                commit)))
    leader.create_task("t", get_config("paper-net"), fed,
                       TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd"),
                       seed=0)
    ds = make_federated_mnist(3, samples=192, seed=0)
    for _ in range(2):
        leader.run_tick({"t": ds.round_batches(32)})
    leader.flush()
    assert len(sealed) == len(leader.ledger.blocks) - 1   # all but genesis

    follower = ChainNode(pipeline_depth=0)
    n = follower.ingest_peer_blocks(
        [blk for blk, _ in sealed],
        commits={blk.index: c for blk, c in sealed if c is not None})
    assert n == len(sealed)
    assert [b.hash for b in follower.ledger.blocks] \
        == [b.hash for b in leader.ledger.blocks]
    assert follower.ledger.verify_chain(deep=True)
    # a forked/tampered block is refused by adopt-time verification
    bad, commit = sealed[-1]
    with pytest.raises(ValueError):
        follower.ingest_peer_blocks([bad], commits={bad.index: commit})
    leader.finalize()


def test_sim_counters_account_for_every_send():
    h = NetworkHarness(3, seed=8,
                       link=LinkSpec(latency=0.02, jitter=0.01, loss=0.2),
                       partition_rounds=[(1, 2, ((0, 1), (2,)))])
    h.run(3)
    net = h.net
    scheduled = net.sent - net.dropped_loss - net.dropped_partition
    assert net.dropped_loss > 0 and net.dropped_partition > 0
    assert net.delivered == scheduled        # harness drains every round
