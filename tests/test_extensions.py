"""Gossip exchange, reputation book, and client-selection strategies."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.chain.ipfs import IPFSStore
from repro.chain.ledger import Ledger
from repro.core.gossip import ClusterExchange
from repro.core.reputation import ReputationBook, reputation_cluster_weights
from repro.core import selection


def _tree(key, scale=1.0):
    return {"a": scale * jax.random.normal(key, (4, 8)),
            "b": scale * jax.random.normal(jax.random.fold_in(key, 1), (16,))}


# -- gossip -------------------------------------------------------------------

def test_gossip_publish_fetch_roundtrip():
    ex = ClusterExchange(IPFSStore(), Ledger(), num_clusters=3)
    agg = _tree(jax.random.PRNGKey(0))
    cid = ex.publish(0, 0, agg)
    out = ex.fetch(0, 0, agg)
    for k in agg:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(agg[k]),
                                   rtol=1e-6)
    txs = ex.round_transactions(0)
    assert txs == [{"type": "cluster_model", "round": 0, "cluster": 0,
                    "cid": cid}]


def test_gossip_merge_weighted_by_trust():
    ex = ClusterExchange(IPFSStore(), Ledger(), num_clusters=2)
    own = _tree(jax.random.PRNGKey(0))
    peer = _tree(jax.random.PRNGKey(1))
    ex.publish(0, 0, own)
    ex.publish(0, 1, peer)
    merged = ex.merge(0, own_cluster=0, own_aggregate=own,
                      peer_trust=[0.0, 1.0], self_weight=0.5)
    for k in own:
        expect = 0.5 * np.asarray(own[k]) + 0.5 * np.asarray(peer[k])
        np.testing.assert_allclose(np.asarray(merged[k], np.float32), expect,
                                   rtol=1e-4, atol=1e-5)
    # zero-trust peers are ignored entirely
    merged2 = ex.merge(0, 1, peer, peer_trust=[0.0, 1.0])
    for k in own:
        np.testing.assert_allclose(np.asarray(merged2[k]),
                                   np.asarray(peer[k]), rtol=1e-6)


def test_gossip_merge_without_peers_is_identity():
    ex = ClusterExchange(IPFSStore(), Ledger(), num_clusters=2)
    own = _tree(jax.random.PRNGKey(0))
    ex.publish(0, 0, own)
    out = ex.merge(0, 0, own, peer_trust=[1.0, 1.0])
    assert out is own


def test_gossip_register_shared_cid_single_put():
    """Heads sharing one identical tree pay a single IPFS put and per-
    cluster cid registrations — fetch works for every registrant."""
    store = IPFSStore()
    ex = ClusterExchange(store, Ledger(), num_clusters=3)
    agg = _tree(jax.random.PRNGKey(2))
    cid = ex.publish(0, 0, agg)
    ex.register(0, 1, cid)
    ex.register(0, 2, cid)
    assert store.puts == 1
    txs = ex.round_transactions(0)
    assert [t["cluster"] for t in txs] == [0, 1, 2]
    assert {t["cid"] for t in txs} == {cid}
    for c in range(3):
        out = ex.fetch(0, c, agg)
        for k in agg:
            np.testing.assert_array_equal(np.asarray(out[k]),
                                          np.asarray(agg[k]))


def test_gossip_tampered_cid_fetch_raises():
    """Content addressing makes the store tamper-evident: a corrupted
    blob no longer hashes to its cid and fetch refuses it."""
    store = IPFSStore()
    ex = ClusterExchange(store, Ledger(), num_clusters=2)
    agg = _tree(jax.random.PRNGKey(3))
    cid = ex.publish(0, 0, agg)
    store.tamper(cid, store.read_blob(cid) + b"!")
    with pytest.raises(ValueError, match="content hash mismatch"):
        ex.fetch(0, 0, agg)


def test_gossip_ingest_roundtrip_and_tamper():
    """Cross-node transfer: blob() on the publisher, ingest() on a peer
    with its own store round-trips the aggregate; a tampering relay is
    caught by the hash check before anything is stored."""
    a = ClusterExchange(IPFSStore(), Ledger(), num_clusters=2)
    b = ClusterExchange(IPFSStore(), Ledger(), num_clusters=2)
    agg = _tree(jax.random.PRNGKey(4))
    a.publish(0, 0, agg)
    cid, blob = a.blob(0, 0)
    b.ingest(0, 0, cid, blob)
    out = b.fetch(0, 0, agg)
    for k in agg:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(agg[k]))
    with pytest.raises(ValueError, match="content hash mismatch"):
        b.ingest(0, 1, cid, blob + b"\x00")
    assert 1 not in b._round_cids.get(0, {})   # nothing registered


# -- reputation ----------------------------------------------------------------

def test_reputation_ema_and_penalties():
    book = ReputationBook(4, ema=0.5, prior=0.5)
    book.update([1.0, 0.0, 0.5, 0.5], penalized=[1])
    assert book.scores[0] == pytest.approx(0.75)
    assert book.scores[1] == pytest.approx(0.25)
    w = book.leader_weights([0, 1, 2, 3])
    assert w[0] == max(w)           # best rep leads most often
    assert w[1] == min(w)           # penalized worker rarely
    np.testing.assert_allclose(w.sum(), 1.0)


def test_reputation_election_deterministic():
    book = ReputationBook(4)
    book.update([0.9, 0.1, 0.5, 0.5], penalized=[1])
    assert book.elect([0, 1, 2, 3], rng_seed=42) == \
        book.elect([0, 1, 2, 3], rng_seed=42)


@settings(max_examples=20, deadline=None)
@given(rounds=st.integers(1, 20), seed=st.integers(0, 100))
def test_reputation_weights_valid_distribution(rounds, seed):
    rng = np.random.default_rng(seed)
    book = ReputationBook(6)
    for r in range(rounds):
        book.update(rng.random(6), penalized=rng.choice(6, size=1))
    w = book.leader_weights(range(6))
    assert np.all(w > 0) and abs(w.sum() - 1.0) < 1e-9
    cw = reputation_cluster_weights(book, 2, 3)
    assert cw.shape == (2,) and abs(cw.sum() - 1.0) < 1e-9


# -- selection ------------------------------------------------------------------

def test_select_random_k_and_deterministic():
    m1 = selection.select_random(10, 4, seed=0, round_index=3)
    m2 = selection.select_random(10, 4, seed=0, round_index=3)
    assert (m1 == m2).all() and m1.sum() == 4
    m3 = selection.select_random(10, 4, seed=0, round_index=4)
    assert not (m1 == m3).all()


def test_select_by_reputation_prefers_good_workers():
    book = ReputationBook(8)
    book.update([0.9, 0.9, 0.9, 0.9, 0.1, 0.1, 0.1, 0.1])
    hits = np.zeros(8)
    for r in range(20):
        hits += selection.select_by_reputation(book, 4, seed=0,
                                               round_index=r)
    assert hits[:4].sum() > hits[4:].sum()
    assert hits[4:].sum() > 0          # exploration keeps everyone alive


def test_select_per_cluster_balanced():
    m = selection.select_per_cluster(12, num_clusters=3, k_per_cluster=2,
                                     seed=0, round_index=0)
    assert m.sum() == 6
    for c in range(3):
        assert m[c * 4:(c + 1) * 4].sum() == 2


# -- byzantine-head poisoning defense (examples/poisoning_defense.py) ----------

def test_byzantine_head_defense_accuracy_gap():
    """A rogue cluster head poisoning its whole cluster is contained by
    trust penalization: the defended run beats the undefended one on
    accuracy, and the rogue cluster's workers score lower and lose more
    stake than every honest worker. Deterministic (all seeds fixed)."""
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from examples.poisoning_defense import HEAD_CLUSTER_WORKERS, run

    on = run(True, head_level=True, rounds=25, samples=2048,
             eval_samples=1024)
    off = run(False, head_level=True, rounds=25, samples=2048,
              eval_samples=1024)
    assert on["acc"] - off["acc"] > 0.005     # defended accuracy gap
    att = set(HEAD_CLUSTER_WORKERS)
    honest = [w for w in range(8) if w not in att]
    scores = np.asarray(on["scores"])
    assert scores[list(att)].mean() < scores[honest].mean() - 0.02
    # every rogue-cluster worker lost more stake than any honest worker
    assert max(on["stakes"][w] for w in att) \
        < min(on["stakes"][w] for w in honest)
