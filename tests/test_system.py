"""End-to-end SDFL-B protocol behaviour (the paper's system claims)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import FederationConfig, TrainConfig
from repro.configs.registry import get_config, get_smoke_config
from repro.core import async_sim
from repro.core.protocol import SDFLBProtocol
from repro.data.datasets import (make_federated_mnist, partition_dirichlet,
                                 synthetic_mnist, synthetic_tokens)

FED3 = FederationConfig(num_clusters=1, workers_per_cluster=3,
                        trust_threshold=0.2)
TC = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd", remat=False)


def _run(proto, ds, rounds, batch=32, participation=None):
    for _ in range(rounds):
        proto.run_round(ds.round_batches(batch), participation=participation)
    return proto


def test_protocol_learns_and_chain_verifies():
    cfg = get_config("paper-net")
    ds = make_federated_mnist(3, samples=1024, seed=0)
    proto = SDFLBProtocol(cfg, FED3, TC, use_blockchain=True, seed=0)
    ev = ds.eval_batch(256)
    loss0 = proto.evaluate(ev)["loss"]
    _run(proto, ds, 25)
    loss1 = proto.evaluate(ev)["loss"]
    assert loss1 < loss0                       # convergence (Fig. 5/6 trend)
    assert proto.ledger.verify_chain()
    # pipelined driver: settlement trails training by one round
    assert len(proto.ledger.blocks) == 25      # genesis + 24 settled rounds
    payouts = proto.finalize()                 # flushes round 25, then final
    assert proto.ledger.verify_chain(deep=True)
    assert len(proto.ledger.blocks) == 27      # + round 25 + finalize block
    # one IPFS put per settled round: the identical global tree is stored
    # once, its cid registered per cluster head (§III.A exchange, deduped)
    assert proto.ipfs.puts == 25
    assert len(payouts) == 3
    assert abs(proto.contract.total_value()
               - (FED3.requester_deposit + 3 * FED3.worker_stake)) < 1e-6


def test_blockchain_off_same_learning_dynamics():
    """Paper Fig. 2: accuracy is blockchain-independent (identical rounds),
    chain adds wall-time overhead only."""
    cfg = get_config("paper-net")
    ds1 = make_federated_mnist(3, samples=512, seed=1)
    ds2 = make_federated_mnist(3, samples=512, seed=1)
    p_on = SDFLBProtocol(cfg, FED3, TC, use_blockchain=True, seed=7)
    p_off = SDFLBProtocol(cfg, FED3, TC, use_blockchain=False, seed=7)
    _run(p_on, ds1, 5)
    _run(p_off, ds2, 5)
    ev = make_federated_mnist(3, samples=512, seed=1).eval_batch(128)
    a_on = p_on.evaluate(ev)["accuracy"]
    a_off = p_off.evaluate(ev)["accuracy"]
    assert abs(a_on - a_off) < 1e-6            # identical learning updates
    # chain work is real but runs on the settler thread: compare the
    # settler-side settle_time (chain: IPFS + contract + Merkle; off: the
    # reputation update only) after draining the pipeline
    p_on.flush()
    p_off.flush()
    assert sum(r.settle_time for r in p_on.history) > \
        sum(r.settle_time for r in p_off.history)


def test_malicious_worker_penalized_on_chain():
    """A label-flipping worker must score below honest peers and lose stake."""
    cfg = get_config("paper-net")
    W = 4
    fed = dataclasses.replace(FED3, workers_per_cluster=W,
                              trust_threshold=0.45, penalty_pct=50.0)
    ds = make_federated_mnist(W, samples=1024, seed=0)

    def adversary(batch, round_index):
        labels = batch["labels"]
        flipped = (9 - labels[0])
        return {**batch, "labels": labels.at[0].set(flipped)}

    proto = SDFLBProtocol(cfg, fed, TC, use_blockchain=True, seed=0,
                          adversary=adversary)
    _run(proto, ds, 12)
    proto.flush()          # settle the trailing pipelined round
    scores = np.stack([r.scores for r in proto.history[2:]])
    assert scores[:, 0].mean() < scores[:, 1:].mean()
    acct = proto.contract.workers["worker-0"]
    honest = [proto.contract.workers[f"worker-{w}"] for w in range(1, W)]
    assert acct.penalized_rounds >= max(h.penalized_rounds for h in honest)
    assert acct.stake <= min(h.stake for h in honest)


def test_head_rotation_changes_heads():
    cfg = get_config("paper-net")
    fed = FederationConfig(num_clusters=2, workers_per_cluster=4,
                           trust_threshold=0.0)
    ds = make_federated_mnist(8, samples=512, seed=0)
    proto = SDFLBProtocol(cfg, fed, TC, seed=0)
    _run(proto, ds, 6)
    heads = [tuple(r.heads) for r in proto.history]
    assert len(set(heads)) > 1                 # rotation actually rotates


def test_async_mode_tolerates_stragglers():
    """Async rounds with partial participation still converge; staleness
    grows for absent workers and resets on arrival."""
    cfg = get_config("paper-net")
    W = 4
    fed = dataclasses.replace(FED3, workers_per_cluster=W, async_mode=True,
                              trust_threshold=0.0)
    ds = make_federated_mnist(W, samples=1024, seed=0)
    proto = SDFLBProtocol(cfg, fed, TC, seed=0)
    sched = async_sim.AsyncScheduler(
        async_sim.heterogeneous_profiles(W, straggler_frac=0.25, seed=0),
        seed=0, buffer_size=2)
    ev = ds.eval_batch(256)
    loss0 = proto.evaluate(ev)["loss"]
    for _ in range(20):
        _, mask, _ = sched.next_aggregation()
        proto.run_round(ds.round_batches(32), participation=mask)
    assert proto.evaluate(ev)["loss"] < loss0
    parts = np.stack([r.participation for r in proto.history])
    assert parts.sum() < 20 * W                # stragglers missed rounds


def test_async_scheduler_caps_buffer_at_worker_count():
    """buffer_size > W must terminate (only W distinct arrivals exist per
    tick) instead of spinning on the never-empty reschedule heap."""
    profiles = async_sim.heterogeneous_profiles(4, seed=0)
    sched = async_sim.AsyncScheduler(profiles, seed=0, buffer_size=8)
    t, mask, _ = sched.next_aggregation()
    assert mask.sum() == 4 and t > 0.0


def test_async_scheduler_deadline_advances_clock():
    """When max_wait elapses with no arrivals (all updates lost), the clock
    advances to the deadline instead of freezing."""
    profiles = [async_sim.WorkerProfile(speed=1.0, failure_prob=1.0)] * 3
    sched = async_sim.AsyncScheduler(profiles, seed=0, buffer_size=2,
                                     max_wait=5.0)
    times = [sched.next_aggregation()[0] for _ in range(3)]
    assert times == [5.0, 10.0, 15.0]


def test_async_scheduler_faster_than_sync():
    profiles = async_sim.heterogeneous_profiles(
        8, straggler_frac=0.25, straggler_slowdown=8.0, seed=0)
    sched = async_sim.AsyncScheduler(profiles, seed=0, buffer_size=4)
    t_prev, async_gaps = 0.0, []
    for _ in range(10):
        t, mask, _ = sched.next_aggregation()
        async_gaps.append(t - t_prev)
        t_prev = t
    sync_times = [sched.sync_round_time() for _ in range(10)]
    assert np.mean(async_gaps) < np.mean(sync_times)


def _decision_trace(proto):
    """Everything the threaded driver must reproduce byte-identically:
    block hashes (covering randomness sources, Merkle roots, transactions),
    per-round head elections, penalties, and reputation state."""
    return {
        "blocks": [b.hash for b in proto.ledger.blocks],
        "heads": [tuple(r.heads) for r in proto.history],
        "penalties": np.stack([r.penalties for r in proto.history]),
        "cids": [r.model_cid for r in proto.history],
        "reputation": (proto.reputation.scores.copy(),
                       proto.reputation.penalties.copy()),
    }


@pytest.mark.parametrize("reputation_leaders", [False, True])
def test_threaded_settler_matches_serial_driver(reputation_leaders):
    """Property: the background-settler pipeline produces identical blocks,
    on-chain randomness, head elections, penalties, reputation, and payouts
    as the serial (pipeline_depth=0) reference driver on the same data."""
    cfg = get_config("paper-net")
    fed = FederationConfig(num_clusters=2, workers_per_cluster=3,
                           trust_threshold=0.45, top_k_rewarded=3)
    runs = {}
    for depth in (0, 3):
        ds = make_federated_mnist(6, samples=768, seed=5)
        proto = SDFLBProtocol(cfg, dataclasses.replace(fed,
                                                       pipeline_depth=depth),
                              TC, use_blockchain=True, seed=11,
                              reputation_leaders=reputation_leaders)
        for _ in range(8):
            proto.run_round(ds.round_batches(32))
        proto.flush()
        payouts = proto.finalize()
        assert proto.ledger.verify_chain(deep=True)
        runs[depth] = (_decision_trace(proto), payouts)
    serial, threaded = runs[0], runs[3]
    assert serial[0]["blocks"] == threaded[0]["blocks"]   # byte-identical
    assert serial[0]["heads"] == threaded[0]["heads"]
    assert serial[0]["cids"] == threaded[0]["cids"]
    np.testing.assert_array_equal(serial[0]["penalties"],
                                  threaded[0]["penalties"])
    np.testing.assert_array_equal(serial[0]["reputation"][0],
                                  threaded[0]["reputation"][0])
    np.testing.assert_array_equal(serial[0]["reputation"][1],
                                  threaded[0]["reputation"][1])
    assert serial[1] == threaded[1]                       # payouts


def test_flush_is_idempotent_and_safe_mid_queue():
    """flush() drains in-flight rounds whenever called, repeated calls are
    no-ops, and training continues cleanly after a mid-queue flush."""
    cfg = get_config("paper-net")
    ds = make_federated_mnist(3, samples=512, seed=0)
    proto = SDFLBProtocol(cfg, FED3, TC, use_blockchain=True, seed=0)
    _run(proto, ds, 3)
    proto.flush()
    assert all(r.settled for r in proto.history)
    blocks_after_first = len(proto.ledger.blocks)
    assert blocks_after_first == 4             # genesis + 3 settled rounds
    proto.flush()                              # idempotent
    proto.flush()
    assert len(proto.ledger.blocks) == blocks_after_first
    _run(proto, ds, 2)                         # pipeline keeps working
    proto.flush()
    assert len(proto.ledger.blocks) == 6
    assert all(r.settled for r in proto.history)
    assert proto.ledger.verify_chain(deep=True)
    proto.finalize()
    assert len(proto.ledger.blocks) == 7       # + finalize block


def test_settler_failure_is_sticky_and_commits_nothing_after():
    """A settle failure surfaces on the training thread, keeps re-raising
    (sticky), and later queued rounds are discarded rather than committed
    on top of a half-settled chain."""
    cfg = get_config("paper-net")
    ds = make_federated_mnist(3, samples=256, seed=0)
    proto = SDFLBProtocol(cfg, FED3, TC, use_blockchain=True, seed=0)
    proto.run_round(ds.round_batches(16))
    proto.contract.closed = True               # force settlement to fail
    with pytest.raises(RuntimeError):
        proto.run_round(ds.round_batches(16))  # surfaces at wait/handoff
    with pytest.raises(RuntimeError):
        proto.flush()
    with pytest.raises(RuntimeError):          # sticky
        proto.flush()
    assert len(proto.ledger.blocks) == 1       # genesis only — no partial
                                               # chain from later rounds


def test_deep_pipeline_without_chain_keeps_rounds_in_flight():
    """With blockchain and reputation election off, nothing couples round
    r to round r−1's settlement — rounds queue up to pipeline_depth and a
    flush settles them all."""
    cfg = get_config("paper-net")
    fed = dataclasses.replace(FED3, pipeline_depth=4)
    ds = make_federated_mnist(3, samples=512, seed=0)
    proto = SDFLBProtocol(cfg, fed, TC, use_blockchain=False, seed=0)
    _run(proto, ds, 6)
    proto.flush()
    assert all(r.settled for r in proto.history)
    assert proto.reputation.rounds == 6


def test_dirichlet_partition_covers_all_samples():
    _, labels = synthetic_mnist(500, seed=0)
    parts = partition_dirichlet(labels, 5, alpha=0.5, seed=0)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == 500 and len(set(all_idx.tolist())) == 500


def test_llm_fl_round_runs():
    """The same protocol drives an LLM-family arch (generic codebase,
    paper §VI.D)."""
    cfg = get_smoke_config("smollm-135m")
    fed = FederationConfig(num_clusters=2, workers_per_cluster=2,
                           trust_threshold=0.0)
    tc = TrainConfig(optimizer="adamw", lr=1e-3, remat=False, grad_clip=1.0)
    proto = SDFLBProtocol(cfg, fed, tc, use_blockchain=True, seed=0)
    data = synthetic_tokens(4, 2, 64, cfg.vocab_size, seed=0)
    rec = proto.run_round(data)
    assert np.isfinite(rec.losses).all()
    assert proto.ledger.verify_chain()


def test_checkpoint_roundtrip_with_ledger():
    import tempfile, os
    from repro.checkpoint import store as ckpt
    from repro.chain.ledger import Ledger
    cfg = get_smoke_config("smollm-135m")
    from repro.models import api
    import jax
    params, _ = api.init(cfg, jax.random.PRNGKey(0), tp=1)
    led = Ledger()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.msgpack.zst")
        cid = ckpt.save(path, params, step=7, ledger=led)
        assert ckpt.verify(path, cid)
        restored, step = ckpt.restore(path, params)
        assert step == 7
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), rtol=2e-2,
                                       atol=1e-2)
    assert led.verify_chain() and len(led.blocks) == 2
