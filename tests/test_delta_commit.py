"""Sparse delta commits: incremental Merkle updates vs. full rebuilds.

The delta path's whole consensus claim is *bit-identity*: a chain of
``DeltaCommit``s must commit exactly the roots a dense rebuild over the
same records would — for any change sets, chunk sizes, and (for the dense
reference) shard counts — while hashing only the dirty paths. These
properties pin that, plus the audit surface the paper's reliability story
needs: idle workers stay proof-covered and tamper-evident in every delta
block.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.chain.contract import TrustContract, _RECORD_DTYPE
from repro.chain.ledger import (DeltaCommit, Ledger, MerkleTree, RecordBatch,
                                ShardedCommit, batch_leaf_digests,
                                gathered_leaf_digests, plan_shard_bounds)

REC = _RECORD_DTYPE.itemsize


def _batch(rng, n):
    buf = rng.integers(0, 256, n * REC, dtype=np.uint8)
    return buf, RecordBatch(memoryview(buf).cast("B"), REC)


def _mk_contract(sparse=True, chunk=8, shards=1, rebase=0, W=60, seed=3):
    led = Ledger()
    c = TrustContract(led, requester_deposit=1e3, worker_stake=10.0,
                      penalty_pct=50.0, trust_threshold=0.5, top_k=3,
                      merkle_chunk_size=chunk, settlement_shards=shards,
                      sparse_settlement=sparse, sparse_rebase_every=rebase)
    c.join_batch(W)
    return led, c


# -- batched leaf hashing: byte-identical digests ------------------------------


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 200), k=st.integers(1, 70), seed=st.integers(0, 99))
def test_batched_leaf_digests_match_per_leaf_hasher(n, k, seed):
    """The framed single-call hasher is a pure performance change: digests
    (and hence roots/proofs) are byte-identical to the incremental
    two-update ``_leaf_digest`` path and to a list-of-bytes tree."""
    from repro.chain.ledger import _leaf_digest
    rng = np.random.default_rng(seed)
    _, rb = _batch(rng, n)
    ref = [_leaf_digest(rb.chunk_bytes(i, min(i + k, n)))
           for i in range(0, n, k)]
    assert batch_leaf_digests(rb, k) == ref
    assert MerkleTree(rb, k).root == \
        MerkleTree([bytes(rb[i]) for i in range(n)], k).root
    sel = np.arange(len(ref))
    gathered = gathered_leaf_digests(rb, k, sel)
    assert [gathered[i] for i in range(len(ref))] == ref


# -- MerkleTree.update_leaves == rebuild ---------------------------------------


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 150), k=st.integers(1, 9),
       rounds=st.integers(1, 4), seed=st.integers(0, 99))
def test_update_leaves_bit_identical_to_rebuild(n, k, rounds, seed):
    rng = np.random.default_rng(seed)
    recs = [bytes(rng.integers(0, 256, REC, dtype=np.uint8))
            for _ in range(n)]
    t = MerkleTree(recs, k)
    for _ in range(rounds):
        nchg = int(rng.integers(1, n + 1))
        idx = [int(i) for i in rng.choice(n, size=nchg, replace=False)]
        for i in idx:
            recs[i] = bytes(rng.integers(0, 256, REC, dtype=np.uint8))
        t.update_leaves({li: b"".join(recs[li * k:min(li * k + k, n)])
                         for li in {i // k for i in idx}})
        assert t.root == MerkleTree(recs, k).root


# -- DeltaCommit roots == full rebuild (the tentpole property) -----------------


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 120), k=st.integers(1, 9),
       shards=st.sampled_from([1, 2, 3, 5]),
       rounds=st.integers(1, 5), seed=st.integers(0, 99))
def test_delta_roots_bit_identical_across_change_sets(n, k, shards, rounds,
                                                      seed):
    """Delta-commit roots equal full-rebuild roots across random change
    sets × shard counts × chunk sizes — and equal the subtree-aligned
    ``ShardedCommit`` super-root, so a delta block is indistinguishable
    (by root) from a dense commit over the same population."""
    rng = np.random.default_rng(seed)
    cur, rb = _batch(rng, n)
    cur = cur.reshape(n, REC)
    commit = DeltaCommit.full(rb, k)
    for _ in range(rounds):
        nchg = int(rng.integers(0, n + 1))
        idx = np.sort(rng.choice(n, size=nchg, replace=False)
                      ).astype(np.int64)
        rows = rng.integers(0, 256, nchg * REC, dtype=np.uint8)
        cur = cur.copy()
        if nchg:
            cur[idx] = rows.reshape(nchg, REC)
        commit = DeltaCommit.delta(
            commit, idx, RecordBatch(memoryview(rows).cast("B"), REC))
        dense = RecordBatch(memoryview(np.ascontiguousarray(cur)).cast("B"),
                            REC)
        flat_root = MerkleTree(dense, k).root
        assert commit.root == flat_root
        bounds = plan_shard_bounds(n, k, shards)
        sharded = ShardedCommit(
            [RecordBatch(dense.chunk_bytes(a, b), REC)
             for a, b in zip(bounds, bounds[1:])], k)
        assert sharded.root == flat_root
        assert commit.recompute_root() == flat_root
        # every record — changed or inherited — is proof-covered
        i = int(rng.integers(0, n))
        chunk, off = commit.record_chunk(i)
        assert chunk[off] == bytes(cur[i].tobytes())
        assert MerkleTree.verify(b"".join(chunk), commit.record_proof(i),
                                 commit.root)


def test_delta_rejects_malformed_change_sets():
    rng = np.random.default_rng(0)
    _, rb = _batch(rng, 16)
    base = DeltaCommit.full(rb, 4)
    rows = rng.integers(0, 256, 2 * REC, dtype=np.uint8)
    nr = RecordBatch(memoryview(rows).cast("B"), REC)
    with pytest.raises(ValueError):
        DeltaCommit.delta(base, np.array([3, 1]), nr)      # unsorted
    with pytest.raises(ValueError):
        DeltaCommit.delta(base, np.array([1, 1]), nr)      # duplicate
    with pytest.raises(IndexError):
        DeltaCommit.delta(base, np.array([1, 16]), nr)     # out of range
    with pytest.raises(ValueError):
        DeltaCommit.delta(base, np.array([1]), nr)         # length mismatch
    with pytest.raises(TypeError):
        DeltaCommit(rb, 4)          # must go through .full/.delta


def test_empty_change_set_keeps_root():
    rng = np.random.default_rng(1)
    _, rb = _batch(rng, 10)
    base = DeltaCommit.full(rb, 4)
    d = DeltaCommit.delta(base, np.zeros(0, np.int64),
                          RecordBatch(b"", REC))
    assert d.root == base.root and d.hash_ops == 0
    assert d.recompute_root() == base.root


# -- contract-level: sparse == dense Algorithm-1 state -------------------------


def test_sparse_contract_matches_dense_state_and_proofs():
    """Ten rounds of random partial participation: the sparse contract's
    Algorithm-1 state (stakes, penalties, requester transfer, conservation)
    is bit-identical to the dense contract fed the same subsets, the chain
    deep-verifies, and every round's block proves active AND idle
    workers."""
    rng = np.random.default_rng(7)
    W = 60
    led_d, cd = _mk_contract(sparse=False, W=W)
    led_s, cs = _mk_contract(sparse=True, rebase=4, W=W)
    for r in range(10):
        if r == 0:
            ids, s = None, rng.random(W)
        else:
            ids = rng.choice(W, size=int(rng.integers(1, 20)),
                             replace=False).astype(np.int64)
            s = rng.random(len(ids))
        pd = cd.settle_round_batch(r, s, worker_ids=ids, timestamp=float(r))
        ps = cs.settle_round_batch(r, s, worker_ids=ids, timestamp=float(r))
        np.testing.assert_array_equal(pd, ps)
    np.testing.assert_array_equal(cd.stake, cs.stake)
    np.testing.assert_array_equal(cd.penalized_rounds, cs.penalized_rounds)
    assert cd.requester_balance == cs.requester_balance
    assert abs(cd.total_value() - cs.total_value()) < 1e-9
    assert led_s.verify_chain(deep=True)
    for r in (1, 4, 9):
        active = set(cs._round_ids[r].tolist())
        idle = next(w for w in range(W) if w not in active)
        for w in (next(iter(active)), idle):
            proof = cs.settlement_proof(r, w)
            assert cs.verify_settlement(proof)
            assert proof["record"]["worker"] == w
        # idle records carry the last round that actually settled them
        assert cs.settlement_proof(r, idle)["record"]["round"] < r


def test_idle_worker_record_tamper_evident_in_delta_block():
    """The reliability half of the tentpole: an idle worker's (inherited,
    unhashed-this-round) record in a delta block still fails verification
    when tampered, per-record and chain-deep."""
    rng = np.random.default_rng(11)
    W = 50
    led, c = _mk_contract(sparse=True, W=W)
    c.settle_round_batch(0, rng.random(W), timestamp=0.0)
    ids = np.array([2, 30, 47], np.int64)
    c.settle_round_batch(1, rng.random(3), worker_ids=ids, timestamp=1.0)
    blk = c._round_blocks[1]
    idle = 13
    assert led.verify_record(blk, idle)
    proof = c.settlement_proof(1, idle)
    assert c.verify_settlement(proof)
    led.tamper_record(blk, idle, b"forged-idle-record")
    assert not led.verify_record(blk, idle)
    assert not led.verify_chain(deep=True)
    # a forged proof (mutated record claim) is rejected too
    bad = dict(proof)
    rec = dict(bad["record"])
    rec["penalty"] = 0.0 if rec["penalty"] else 1.0
    bad["record"] = rec
    assert not c.verify_settlement(bad)


def test_sparse_rebase_bounds_delta_depth():
    """``sparse_rebase_every=N`` re-anchors with a dense commit every N
    sparse rounds; enrollment growth and full participation force one
    immediately."""
    rng = np.random.default_rng(5)
    W = 40
    led, c = _mk_contract(sparse=True, rebase=3, W=W, chunk=4)
    depths = []
    for r in range(8):
        ids = rng.choice(W, size=5, replace=False).astype(np.int64)
        c.settle_round_batch(r, rng.random(5), worker_ids=ids,
                             timestamp=float(r))
        depths.append(c._last_commit.depth)
    # anchor at r=0 (first), r=3, r=6 — depth never reaches the cap
    assert depths[0] == 0 and max(depths) < 3
    assert depths[3] == 0 and depths[6] == 0
    # enrollment growth forces a fresh anchor covering the larger W
    c.join_batch(10)
    c.settle_round_batch(8, rng.random(5),
                         worker_ids=np.arange(5, dtype=np.int64),
                         timestamp=8.0)
    assert c._last_commit.depth == 0 and len(c._last_commit) == W + 10
    # full participation re-anchors too
    c.settle_round_batch(9, rng.random(W + 10), timestamp=9.0)
    assert c._last_commit.depth == 0
    assert led.verify_chain(deep=True)


def test_sparse_unsorted_ids_penalties_in_caller_order():
    rng = np.random.default_rng(9)
    led, c = _mk_contract(sparse=True, W=30)
    c.settle_round_batch(0, rng.random(30), timestamp=0.0)
    ids = np.array([20, 3, 11], np.int64)
    s = np.array([0.9, 0.1, 0.8])
    pen = c.settle_round_batch(1, s, worker_ids=ids, timestamp=1.0)
    assert pen[1] > 0 and pen[0] == 0 and pen[2] == 0
    assert led.verify_chain(deep=True)


# -- store quota (satellite) ---------------------------------------------------


def test_ipfs_owner_quota_enforced_atomically():
    from repro.chain.ipfs import IPFSStore, QuotaExceeded
    st_free = IPFSStore()                      # default: unlimited
    blob = {"w": np.arange(512, dtype=np.float32)}
    cid = st_free.put_tree(blob, owner="a")
    size = st_free.bytes_by_owner["a"]
    st_cap = IPFSStore(owner_quota_bytes=int(size * 2.5))
    assert st_cap.put_tree(blob, owner="a") == cid
    # dedup'd identical put still counts logical bytes against the owner
    st_cap.put_tree(blob, owner="a")
    assert st_cap.bytes_by_owner["a"] == 2 * size
    assert st_cap.dedup_hits == 1
    with pytest.raises(QuotaExceeded) as ei:
        st_cap.put_tree(blob, owner="a")
    # atomic rejection: nothing was counted, stored, or attributed
    assert st_cap.bytes_by_owner["a"] == 2 * size
    assert st_cap.puts == 2
    assert ei.value.owner == "a" and ei.value.quota == int(size * 2.5)
    # other owners (and anonymous puts) are unaffected
    st_cap.put_tree(blob, owner="b")
    st_cap.put_tree(blob)
    with pytest.raises(ValueError):
        IPFSStore(owner_quota_bytes=-1)
