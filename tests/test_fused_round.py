"""Fused device-resident trust round: flat-pack roundtrips, the async
Pallas kernel vs its jnp oracle, and property-tested equivalence of the
fused flat-pack path against the per-leaf reference — scores, penalization
weights, aggregates, and whole ``make_fl_round`` rounds (sync + async),
including the tamper case and the single-local-step loss-delta fix."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import FederationConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core import async_agg, fl_step, hierarchy, trust
from repro.kernels import fused_round, ops, pack, ref
from repro.models import api

jax.config.update("jax_enable_x64", False)


def _tree(key, W, dtype, sizes=((3, 70), (41,), (2, 5, 13))):
    ks = jax.random.split(key, len(sizes))
    return {f"l{i}": jax.random.normal(k, (W,) + s, jnp.float32).astype(dtype)
            for i, (k, s) in enumerate(zip(ks, sizes))}


def _template(tree):
    return jax.tree.map(lambda x: x[0], tree)


# ---------------------------------------------------------------------------
# pack: roundtrips + delta rule
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(w=st.integers(1, 17),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       data=st.data())
def test_pack_roundtrip(w, dtype, data):
    nleaf = data.draw(st.integers(1, 4))
    sizes = tuple(tuple(data.draw(st.integers(1, 9))
                        for _ in range(data.draw(st.integers(1, 3))))
                  for _ in range(nleaf))
    tree = _tree(jax.random.PRNGKey(w), w, jnp.dtype(dtype), sizes)
    spec = pack.pack_spec(_template(tree))
    mat = pack.pack_stack(tree, spec)
    assert mat.shape == (w, spec.total) and mat.dtype == spec.dtype
    back = pack.unpack_stack(mat, spec)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
    vec = pack.unpack_vector(mat[0], spec)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(vec[k]),
                                      np.asarray(tree[k][0]))


def test_pack_delta_matches_per_leaf_update_rule():
    """pack_delta must be bitwise the per-leaf rule:
    (new_f32 − global_f32).astype(param_dtype)."""
    for dtype in (jnp.float32, jnp.bfloat16):
        key = jax.random.PRNGKey(3)
        new_w = _tree(key, 5, dtype)
        g = _template(_tree(jax.random.fold_in(key, 1), 1, dtype))
        spec = pack.pack_spec(g)
        got = pack.pack_delta(new_w, g, spec)
        per_leaf = jax.tree.map(
            lambda a, b: (a.astype(jnp.float32)
                          - b.astype(jnp.float32)[None]).astype(a.dtype),
            new_w, g)
        expect = pack.pack_stack(per_leaf, spec)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_pack_spec_static_and_shape_only():
    g = _template(_tree(jax.random.PRNGKey(0), 1, jnp.float32))
    spec = pack.pack_spec(g)
    assert spec.total == sum(spec.sizes)
    assert spec.offsets == tuple(np.cumsum((0,) + spec.sizes[:-1]))
    # shape-only: building from eval_shape structs gives the same layout
    spec2 = pack.pack_spec(jax.eval_shape(lambda t: t, g))
    assert spec2.shapes == spec.shapes and spec2.total == spec.total \
        and spec2.dtype == spec.dtype


def test_packable_rules():
    assert pack.packable({"a": jnp.zeros((2,), jnp.float32),
                          "b": jnp.zeros((3,), jnp.float32)})
    assert not pack.packable({"a": jnp.zeros((2,), jnp.float32),
                              "b": jnp.zeros((3,), jnp.bfloat16)})
    assert not pack.packable({"a": jnp.zeros((2,), jnp.int32)})
    assert not pack.packable({})


# ---------------------------------------------------------------------------
# the async fused kernel vs its jnp oracle (interpret mode)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(w=st.integers(2, 40), d=st.integers(1, 3000),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_async_kernel_matches_ref(w, d, dtype):
    key = jax.random.PRNGKey(w * 7919 + d)
    u = jax.random.normal(key, (w, d), jnp.float32).astype(jnp.dtype(dtype))
    wp, dp = fused_round.pending_shape(w, d)
    pend = jnp.zeros((wp, dp), jnp.float32).at[:w, :d].set(
        jax.random.normal(jax.random.fold_in(key, 1), (w, d)))
    wt = jax.random.uniform(jax.random.fold_in(key, 2), (w,))
    keep = (jax.random.uniform(jax.random.fold_in(key, 3), (w,))
            > 0.5).astype(jnp.float32)
    agg, newp = fused_round.fused_async_agg_kernel(u, pend, wt, keep,
                                                   interpret=True)
    upad = jnp.pad(u, ((0, wp - w), (0, dp - d)))
    ragg, rnewp = ref.fused_async_agg_ref(
        upad, pend, jnp.pad(wt, (0, wp - w)), jnp.pad(keep, (0, wp - w)))
    tol = 2e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(agg), np.asarray(ragg[:d]),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(newp), np.asarray(rnewp),
                               rtol=tol, atol=tol)
    # padded rows (keep=0 there) stay flushed: re-entrant rounds never
    # resurrect phantom workers
    assert not np.asarray(newp[w:]).any()


# ---------------------------------------------------------------------------
# fused chain vs the per-leaf reference (steps 3–5 of the round)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(w=st.sampled_from([2, 4, 33]),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       masked=st.booleans())
def test_fused_matches_per_leaf_sync(w, dtype, masked):
    key = jax.random.PRNGKey(w * 131 + masked)
    upd = _tree(key, w, jnp.dtype(dtype))
    lb = jax.random.uniform(jax.random.fold_in(key, 1), (w,)) + 1.0
    la = lb - jax.random.uniform(jax.random.fold_in(key, 2), (w,))
    fed = FederationConfig(num_clusters=1, workers_per_cluster=w,
                           trust_threshold=0.3)
    mask = None
    if masked:
        mask = (jax.random.uniform(jax.random.fold_in(key, 3), (w,))
                > 0.4).astype(jnp.float32).at[0].set(1.0)

    stats_ref = trust.update_stats(upd, lb, la)
    scores_ref = trust.scores_from_stats(stats_ref, fed)
    weights_ref = trust.trust_weights(scores_ref, fed, participation=mask)
    agg_ref_t = hierarchy.aggregate_fused(upd, weights_ref)

    spec = pack.pack_spec(_template(upd))
    flat = pack.pack_stack(upd, spec)
    stats_f = trust.update_stats_flat(flat, lb, la)
    scores_f = trust.scores_from_stats(stats_f, fed)
    weights_f = trust.trust_weights(scores_f, fed, participation=mask)
    agg_f = pack.unpack_vector(ops.fused_agg(flat, weights_f), spec)

    tol = 1e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(scores_f), np.asarray(scores_ref),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(weights_f), np.asarray(weights_ref),
                               rtol=tol, atol=tol)
    for k in agg_f:
        np.testing.assert_allclose(
            np.asarray(agg_f[k], np.float32),
            np.asarray(agg_ref_t[k], np.float32), rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(w=st.sampled_from([2, 4, 33]),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_fused_matches_per_leaf_async(w, dtype):
    """Async cohort round with staleness > 0 and a nonzero pending buffer:
    weights, aggregate, and flushed pending agree across paths."""
    key = jax.random.PRNGKey(w * 17)
    upd = _tree(key, w, jnp.dtype(dtype))
    lb = jax.random.uniform(jax.random.fold_in(key, 1), (w,)) + 1.0
    la = lb - 0.1
    fed = FederationConfig(num_clusters=1, workers_per_cluster=w,
                           trust_threshold=0.0, async_mode=True)
    mask = (jax.random.uniform(jax.random.fold_in(key, 2), (w,))
            > 0.5).astype(jnp.float32).at[0].set(1.0)
    staleness = jax.random.randint(jax.random.fold_in(key, 3), (w,), 0, 5)
    pending_t = jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, 4),
                                    x.shape, jnp.float32), upd)

    scores = trust.scores_from_stats(trust.update_stats(upd, lb, la), fed)
    agg_t, new_state_t, w_t = async_agg.async_round(
        upd, scores, mask, async_agg.AsyncState(staleness, pending_t), fed)

    spec = pack.pack_spec(_template(upd))
    flat = pack.pack_stack(upd, spec)
    wp, dp = fused_round.pending_shape(w, spec.total)
    pend_flat = jnp.zeros((wp, dp), jnp.float32).at[:w, :spec.total].set(
        pack.pack_stack(pending_t, spec, dtype=jnp.float32))
    scores_f = trust.scores_from_stats(
        trust.update_stats_flat(flat, lb, la), fed)
    w_f = async_agg.effective_weights(scores_f, mask, staleness, fed)
    agg_f, newp = ops.fused_async_agg(flat, pend_flat, w_f,
                                      1.0 - mask.astype(jnp.float32))

    tol = 1e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_t),
                               rtol=tol, atol=tol)
    agg_f_t = pack.unpack_vector(agg_f, spec)
    for k in agg_f_t:
        np.testing.assert_allclose(
            np.asarray(agg_f_t[k], np.float32),
            np.asarray(agg_t[k], np.float32), rtol=tol, atol=tol)
    newp_t = pack.unpack_stack(newp[:w, :spec.total], spec)
    for k in newp_t:
        np.testing.assert_allclose(
            np.asarray(newp_t[k]), np.asarray(new_state_t.pending[k]),
            rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# whole-round equivalence on the paper CNN (knob on vs off)
# ---------------------------------------------------------------------------

def _cnn_round_inputs(W, B=4, seed=0):
    cfg = get_config("paper-net")
    key = jax.random.PRNGKey(seed)
    gp, _ = api.init(cfg, key, tp=1)
    batch = {"images": jax.random.normal(key, (W, 1, B, 28, 28, 1)),
             "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                          (W, 1, B), 0, 10)}
    return cfg, gp, batch


def _run_round(cfg, fed, gp, batch, *, rng, participation=None, rounds=1):
    tc = TrainConfig()
    W = batch["labels"].shape[0]
    opt = fl_step.init_worker_opt(gp, fed, tc)
    fn = jax.jit(fl_step.make_fl_round(cfg, fed, tc))
    outs = []
    if fed.async_mode:
        state = fl_step.init_async_state_for(cfg, fed, gp, W)
        for r in range(rounds):
            mask = participation[r]
            out, state = fn(gp, opt, batch, rng, mask, state)
            gp, opt = out.global_params, out.opt_state
            outs.append(out)
    else:
        for _ in range(rounds):
            out = fn(gp, opt, batch, rng, participation)
            gp, opt = out.global_params, out.opt_state
            outs.append(out)
    return outs


@pytest.mark.parametrize("W", [2, 4, 33])
@pytest.mark.parametrize("async_mode", [False, True])
def test_round_knob_equivalence(W, async_mode):
    cfg, gp, batch = _cnn_round_inputs(W)
    rng = jax.random.PRNGKey(7)
    if async_mode:
        k = jax.random.PRNGKey(W)
        part = [(jax.random.uniform(jax.random.fold_in(k, r), (W,))
                 > 0.4).astype(jnp.float32).at[0].set(1.0) for r in range(2)]
        rounds = 2   # round 2 consumes round 1's pending + staleness
    else:
        part, rounds = None, 1
    by_knob = {}
    for knob in ("off", "on"):
        fed = FederationConfig(num_clusters=1, workers_per_cluster=W,
                               trust_threshold=0.0, async_mode=async_mode,
                               fused_trust_path=knob)
        by_knob[knob] = _run_round(cfg, fed, gp, batch, rng=rng,
                                   participation=part, rounds=rounds)
    for a, b in zip(by_knob["off"], by_knob["on"]):
        np.testing.assert_allclose(np.asarray(a.scores),
                                   np.asarray(b.scores),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(a.weights),
                                   np.asarray(b.weights),
                                   rtol=1e-5, atol=1e-6)
        for la, lb in zip(jax.tree.leaves(a.global_params),
                          jax.tree.leaves(b.global_params)):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       rtol=1e-5, atol=1e-6)


def test_poisoned_worker_ranks_lowest_on_both_paths():
    """A −3× update flip must rank below every honest worker and be zeroed
    by the penalization filter — identically on both paths."""
    W, key = 8, jax.random.PRNGKey(11)
    base = _tree(key, 1, jnp.float32)
    honest = jax.tree.map(
        lambda b: b + 0.05 * jax.random.normal(jax.random.fold_in(key, 1),
                                               (W,) + b.shape[1:]), base)
    upd = jax.tree.map(lambda h, b: h.at[0].set(-3.0 * b[0]), honest, base)
    lb = jnp.full((W,), 2.0)
    la = jnp.full((W,), 1.5).at[0].set(2.2)     # attacker's loss got worse
    fed = FederationConfig(num_clusters=2, workers_per_cluster=4,
                           trust_threshold=0.5)

    s_ref = trust.scores_from_stats(trust.update_stats(upd, lb, la), fed)
    spec = pack.pack_spec(_template(upd))
    s_f = trust.scores_from_stats(
        trust.update_stats_flat(pack.pack_stack(upd, spec), lb, la), fed)
    for s in (s_ref, s_f):
        s = np.asarray(s)
        assert s[0] == s.min() and (s[1:] > s[0]).all()
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-6)
    for s in (s_ref, s_f):
        wts = np.asarray(trust.trust_weights(s, fed))
        assert wts[0] == 0.0 and (wts[1:] > 0).all()


# ---------------------------------------------------------------------------
# satellite: live loss delta at local_steps=1
# ---------------------------------------------------------------------------

def test_loss_delta_live_at_single_local_step():
    """Regression: with one local step the contribution-quality term used to
    see losses[:,0] == losses[:,-1] (a width-1 array) and contribute 0 for
    every worker. The post-step re-evaluation must yield a real delta."""
    W = 4
    cfg, gp, batch = _cnn_round_inputs(W, B=16)
    fed = FederationConfig(num_clusters=1, workers_per_cluster=W,
                           trust_threshold=0.0)
    assert fed.w_loss > 0 and TrainConfig().local_steps == 1
    out, = _run_round(cfg, fed, gp, batch, rng=jax.random.PRNGKey(3))
    assert float(out.metrics["mean_loss_delta"]) != 0.0
    # one SGD step on the same batch should improve its loss
    assert float(out.metrics["mean_loss_delta"]) > 0.0


def test_loss_delta_gated_off_when_unweighted():
    """w_loss=0 skips the extra forward: the delta metric is exactly 0."""
    W = 4
    cfg, gp, batch = _cnn_round_inputs(W)
    fed = FederationConfig(num_clusters=1, workers_per_cluster=W,
                           trust_threshold=0.0, w_loss=0.0)
    out, = _run_round(cfg, fed, gp, batch, rng=jax.random.PRNGKey(3))
    assert float(out.metrics["mean_loss_delta"]) == 0.0


# ---------------------------------------------------------------------------
# eligibility + state plumbing
# ---------------------------------------------------------------------------

def test_fused_eligibility():
    cnn = get_config("paper-net")
    key = jax.random.PRNGKey(0)
    params, _ = api.init(cnn, key, tp=1)
    fed = FederationConfig()
    assert fed.fused_trust_path == "auto"
    assert fl_step.fused_round_enabled(cnn, fed, params)
    # sharding constraints veto auto (flattening would all-gather)
    assert not fl_step.fused_round_enabled(cnn, fed, params, constrained=True)
    # auto stays off for non-CNN families even when packable
    dense = dataclasses.replace(cnn, family="dense")
    assert not fl_step.fused_round_enabled(dense, fed, params)
    # but "on" forces any packable tree, constrained or not
    fed_on = FederationConfig(fused_trust_path="on")
    assert fl_step.fused_round_enabled(dense, fed_on, params,
                                       constrained=True)
    assert not fl_step.fused_round_enabled(
        cnn, FederationConfig(fused_trust_path="off"), params)
    mixed = {"a": jnp.zeros((2,), jnp.float32),
             "b": jnp.zeros((2,), jnp.bfloat16)}
    with pytest.raises(ValueError, match="packable"):
        fl_step.fused_round_enabled(cnn, fed_on, mixed)
    assert not fl_step.fused_round_enabled(cnn, fed, mixed)  # auto: fallback
    with pytest.raises(ValueError, match="auto|on|off"):
        fl_step.fused_round_enabled(
            cnn, FederationConfig(fused_trust_path="yes"), params)


def test_init_async_state_for_layouts():
    cnn = get_config("paper-net")
    params, _ = api.init(cnn, jax.random.PRNGKey(0), tp=1)
    W = 6
    spec = pack.pack_spec(params)
    fused_state = fl_step.init_async_state_for(
        cnn, FederationConfig(async_mode=True), params, W)
    assert fused_state.pending.shape == \
        fused_round.pending_shape(W, spec.total)
    assert fused_state.staleness.shape == (W,)
    leaf_state = fl_step.init_async_state_for(
        cnn, FederationConfig(async_mode=True, fused_trust_path="off"),
        params, W)
    assert jax.tree.structure(leaf_state.pending) == \
        jax.tree.structure(params)
    for p, x in zip(jax.tree.leaves(leaf_state.pending),
                    jax.tree.leaves(params)):
        assert p.shape == (W,) + x.shape and p.dtype == jnp.float32


# ---------------------------------------------------------------------------
# geometry + HBM accounting
# ---------------------------------------------------------------------------

def test_block_d_for():
    for itemsize in (2, 4):
        prev = None
        for W in (16, 256, 1024, 4096, 10240):
            bd = fused_round.block_d_for(W, itemsize)
            assert bd % fused_round.LANE == 0 and 128 <= bd <= 2048
            if prev is not None:
                assert bd <= prev
            prev = bd
    # the 10k-cohort target keeps a full lane tile in budget at f32
    assert fused_round.block_d_for(10240, 4) >= fused_round.LANE


def test_pending_shape_alignment():
    for W in (1, 7, 8, 255, 256, 10000):
        for D in (1, 511, 512, 21840):
            wp, dp = fused_round.pending_shape(W, D)
            assert wp >= W and dp >= D
            assert wp % fused_round.SUBLANE == 0
            assert dp % fused_round.BLOCK_D_ASYNC == 0


def test_update_passes_gate():
    """The fused chain streams the update volume exactly twice (the
    information floor: weights depend on global stats of the matrix)."""
    for dtype in (jnp.float32, jnp.bfloat16):
        for async_mode in (False, True):
            p = fused_round.update_passes(10240, 21840, dtype,
                                          async_mode=async_mode)
            assert p <= 2.0
