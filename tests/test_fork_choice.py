"""Unit tests for fork tracking, reorg rollback/replay, and gossip
message hygiene (repro.net satellites).

Covers ``BlockTree`` scoring (height > cumulative trust > smaller
hash), reorg over *sparse* ``DeltaCommit`` overlay ledgers (idle-worker
proofs survive a rollback; ``verify_chain(deep=True)`` stays green
before and after adopting the competing branch), ``adopt_block``'s
rejection matrix, and malformed/stale gossip-message rejection on a
live ``SettlementNode``."""
import numpy as np
import pytest

from repro.chain.contract import TrustContract
from repro.chain.ledger import Block, Ledger
from repro.net import (AggregateGossip, BlockGossip, BlockTree, ChainRequest,
                       ChainResponse, HeadAnnounce, ScoreGossip,
                       SettlementNode, SimNet, apply_reorg, block_trust,
                       seal_info)


def _seal_block(parent: Block, round_index: int, proposer: int,
                trust: float, tag: str = "") -> Block:
    txs = [{"type": "seal", "round": round_index, "proposer": proposer,
            "trust": trust}]
    if tag:
        txs.append({"type": "tag", "tag": tag})
    blk = Block(parent.index + 1, parent.hash, txs,
                float(round_index + 1))
    blk.hash = blk.compute_hash()
    return blk


@pytest.fixture
def base():
    ledger = Ledger()
    ledger.append_block([{"type": "deploy", "deposit": 100.0}],
                        timestamp=0.0)
    return ledger


# -- BlockTree scoring --------------------------------------------------------

def test_seal_info_and_trust_extraction(base):
    blk = _seal_block(base.head, 3, 1, 2.5)
    assert seal_info(blk) == (3, 1)
    assert block_trust(blk) == 2.5
    assert seal_info(base.head) is None          # deploy block: no seal
    assert block_trust(base.head) == 0.0


def test_fork_choice_longest_chain_wins(base):
    tree = BlockTree(list(base.blocks))
    a1 = _seal_block(base.head, 0, 0, 1.0, "a")
    b1 = _seal_block(base.head, 0, 1, 9.0, "b")
    a2 = _seal_block(a1, 1, 0, 1.0, "a")
    for blk in (a1, b1, a2):
        assert tree.add(blk)
    # height beats trust: a-branch is longer though b1 carries more
    assert tree.best_head() == a2.hash


def test_fork_choice_trust_tiebreak_and_hash_tiebreak(base):
    tree = BlockTree(list(base.blocks))
    lo = _seal_block(base.head, 0, 0, 1.0, "lo")
    hi = _seal_block(base.head, 0, 1, 5.0, "hi")
    tree.add(lo)
    tree.add(hi)
    assert tree.best_head() == hi.hash           # equal height: trust wins
    eq = _seal_block(base.head, 0, 2, 5.0, "eq")
    tree.add(eq)
    assert tree.best_head() == min(hi.hash, eq.hash)   # equal: smaller hash


def test_invalidate_covers_descendants(base):
    tree = BlockTree(list(base.blocks))
    a1 = _seal_block(base.head, 0, 0, 1.0)
    a2 = _seal_block(a1, 1, 0, 1.0)
    b1 = _seal_block(base.head, 0, 1, 0.5, "b")
    for blk in (a1, a2, b1):
        tree.add(blk)
    assert tree.best_head() == a2.hash
    assert tree.invalidate(a1.hash) == 2         # a1 + a2
    assert not tree.is_valid(a2.hash)
    assert tree.best_head() == b1.hash
    # children added under an invalid parent inherit the invalidation
    a3 = _seal_block(a2, 2, 0, 9.9)
    assert tree.add(a3)
    assert not tree.is_valid(a3.hash)
    assert tree.best_head() == b1.hash


def test_orphan_add_returns_false(base):
    tree = BlockTree(list(base.blocks))
    a1 = _seal_block(base.head, 0, 0, 1.0)
    a2 = _seal_block(a1, 1, 0, 1.0)
    assert not tree.add(a2)                      # parent unknown
    assert a2.hash not in tree
    assert tree.add(a1) and tree.add(a2)


def test_ancestor_and_chain_to(base):
    tree = BlockTree(list(base.blocks))
    a1 = _seal_block(base.head, 0, 0, 1.0, "a")
    a2 = _seal_block(a1, 1, 0, 1.0, "a")
    b1 = _seal_block(base.head, 0, 1, 1.0, "b")
    for blk in (a1, a2, b1):
        tree.add(blk)
    assert tree.ancestor(a2.hash, b1.hash) == base.head.hash
    assert [b.index for b in tree.chain_to(a2.hash)] == [0, 1, 2, 3]
    with pytest.raises(KeyError):
        tree.chain_to("f" * 64)


# -- reorg over sparse DeltaCommit overlay chains ----------------------------

def _sparse_pair():
    """Two replicas of one sparse-settlement task, bit-identical through
    round 1 (partial participation, so round-1 blocks carry DeltaCommit
    overlays whose ancestors the reorg must preserve)."""
    out = []
    for _ in range(2):
        ledger = Ledger()
        c = TrustContract(ledger, requester_deposit=100.0, worker_stake=10.0,
                          penalty_pct=50.0, trust_threshold=0.4, top_k=3,
                          merkle_chunk_size=2, sparse_settlement=True)
        c.join_batch(6)
        c.settle_round_batch(0, np.full(6, 0.9), timestamp=1.0)
        # partial round: workers 4,5 idle — a delta overlay block
        c.settle_round_batch(1, np.asarray([0.8, 0.3, 0.7, 0.9]),
                             worker_ids=np.arange(4), timestamp=2.0)
        out.append((ledger, c))
    return out


def test_reorg_preserves_delta_overlays_and_idle_proofs():
    (ledger_a, con_a), (ledger_b, con_b) = _sparse_pair()
    assert [b.hash for b in ledger_a.blocks] \
        == [b.hash for b in ledger_b.blocks]
    # replicas diverge at round 2: different cohorts
    con_a.settle_round_batch(2, np.asarray([0.6, 0.5]),
                             worker_ids=np.asarray([0, 1]), timestamp=3.0)
    con_b.settle_round_batch(2, np.asarray([0.9, 0.2, 0.6]),
                             worker_ids=np.asarray([2, 3, 4]), timestamp=3.0)
    fork_a, fork_b = ledger_a.head, ledger_b.head
    assert fork_a.hash != fork_b.hash and fork_a.index == fork_b.index
    # A reorgs onto B's branch via the fork tree
    tree = BlockTree(ledger_a.blocks[:fork_a.index],
                     {i: ledger_a._commits.get(i)
                      for i in range(fork_a.index)})
    assert tree.add(fork_a, ledger_a.commit(fork_a.index))
    assert tree.add(fork_b, ledger_b.commit(fork_b.index))
    anc_index, adopted = apply_reorg(ledger_a, tree, fork_b.hash)
    assert anc_index == fork_a.index - 1
    assert [b.hash for b in adopted] == [fork_b.hash]
    assert ledger_a.head.hash == fork_b.hash
    # the whole chain — including the adopted delta overlay whose base
    # commit lives in the surviving prefix — deep-verifies
    assert ledger_a.verify_chain(deep=True)
    # idle-worker proof survives: worker 5 idled in round 1, its record
    # is still provable out of the surviving delta block…
    proof = con_a.proof(1, 5)
    assert proof.verify(ledger_a.blocks[proof.block_index])
    assert proof.record["worker"] == 5
    # …and in the *adopted* round-2 block (full-population overlay), via
    # the replica whose round map matches the winning branch
    proof_b = con_b.proof(2, 5)
    assert proof_b.verify(ledger_a.head)


def test_rollback_then_deep_verify_green():
    (ledger, con), _ = _sparse_pair()
    head_before = ledger.head.hash
    removed = ledger.rollback_to(1)
    assert [b.index for b in removed] == [2]
    assert ledger.head.index == 1 and ledger.head.hash != head_before
    assert ledger.verify_chain(deep=True)
    # proofs from the surviving prefix still verify
    proof = con.proof(0, 3)
    assert proof.verify(ledger.blocks[proof.block_index])
    with pytest.raises(ValueError):
        ledger.rollback_to(len(ledger.blocks))   # out of range
    with pytest.raises(ValueError):
        ledger.rollback_to(-1)


def test_adopt_block_rejection_matrix():
    (ledger_a, _), (ledger_b, con_b) = _sparse_pair()
    ledger_a.rollback_to(1)
    good = ledger_b.blocks[2]
    commit = ledger_b.commit(2)
    # wrong index
    with pytest.raises(ValueError, match="index"):
        ledger_a.adopt_block(ledger_b.blocks[1], ledger_b._commits.get(1))
    # wrong parent linkage
    orphan = Block(2, "a" * 64, good.transactions, good.timestamp,
                   records_root=good.records_root)
    orphan.hash = orphan.compute_hash()
    with pytest.raises(ValueError, match="link"):
        ledger_a.adopt_block(orphan, commit)
    # hash does not recompute
    forged = Block(good.index, good.prev_hash, good.transactions,
                   good.timestamp, records_root=good.records_root,
                   hash="b" * 64)
    with pytest.raises(ValueError, match="recompute"):
        ledger_a.adopt_block(forged, commit)
    # records committed but no commit shipped
    with pytest.raises(ValueError, match="no.*commit"):
        ledger_a.adopt_block(good, None)
    # tampered super-root: commit does not re-hash to records_root
    con_b.settle_round_batch(3, np.full(6, 0.9), timestamp=4.0)
    wrong_commit = ledger_b.commit(3)
    with pytest.raises(ValueError, match="tampered super-root"):
        ledger_a.adopt_block(good, wrong_commit)
    # the good pair still adopts after all those rejections
    ledger_a.adopt_block(good, commit)
    assert ledger_a.verify_chain(deep=True)


# -- malformed / stale gossip rejection ---------------------------------------

@pytest.fixture
def live_node():
    net = SimNet(seed=0)
    node = SettlementNode(0, net, num_nodes=2, workers_per_node=2)
    SettlementNode(1, net, num_nodes=2, workers_per_node=2)
    return net, node


def test_malformed_messages_counted_not_crashing(live_node):
    net, node = live_node
    node.on_message(1, "not a message")
    node.on_message(1, ScoreGossip(0, 5, (2, 3), (0.5, 0.5)))   # wrong src
    node.on_message(1, ScoreGossip(0, 1, (2, 2), (0.5, 0.5)))   # dup ids
    node.on_message(1, ScoreGossip(0, 1, (0, 1), (0.5, 0.5)))   # foreign ids
    node.on_message(1, ScoreGossip(0, 1, (2, 3), (1.5, 0.5)))   # score > 1
    node.on_message(1, ScoreGossip(-1, 1, (2, 3), (0.5, 0.5)))  # bad round
    node.on_message(1, HeadAnnounce(-1, "x"))
    node.on_message(1, ChainRequest(-3))
    node.on_message(1, ChainResponse((), (None,)))              # ragged
    assert node.malformed_messages == 9
    assert 0 not in node._scores.get(0, {})


def test_stale_score_gossip_counted(live_node):
    net, node = live_node
    node.begin_round(0)
    node.maybe_propose(0, node.candidate_rank(0))
    assert 0 in node.contract._round_blocks
    node.on_message(1, ScoreGossip(0, 1, (2, 3), (0.5, 0.5)))
    assert node.stale_messages == 1


def test_tampered_aggregate_gossip_rejected(live_node):
    net, node = live_node
    peer_net = SimNet(seed=1)
    peer = SettlementNode(0, peer_net, num_nodes=2, workers_per_node=2)
    peer.begin_round(0)
    cid, blob = peer.exchange.blob(0, 0)
    node.on_message(1, AggregateGossip(0, 1, cid, blob + b"!"))
    assert node.rejected_aggregates == 1
    assert not node.exchange.ipfs.has(cid)
    node.on_message(1, AggregateGossip(0, 1, cid, blob))        # honest copy
    assert node.exchange.ipfs.has(cid)


def test_bad_block_gossip_rejected(live_node):
    net, node = live_node
    head = node.ledger.head
    # hash does not recompute
    fake = Block(head.index + 1, head.hash,
                 [{"type": "seal", "round": 0, "proposer": 1,
                   "trust": 1.0}], 1.0, hash="c" * 64)
    node.on_message(1, BlockGossip(fake, None))
    # sealless block; unknown proposer
    for txs in ([{"type": "noise"}],
                [{"type": "seal", "round": 0, "proposer": 99,
                  "trust": 1.0}]):
        blk = Block(head.index + 1, head.hash, txs, 1.0)
        blk.hash = blk.compute_hash()
        node.on_message(1, BlockGossip(blk, None))
    assert node.rejected_blocks == 3
    assert node.ledger.head.hash == head.hash
    assert node.malformed_messages == 0
