import os
import pathlib
import sys

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 fake devices.

_SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis

    # "ci": fully deterministic property testing for the gate — fixed
    # example sequence (derandomize), no wall-clock deadline (shared
    # runners stall unpredictably), and print the falsifying example
    # verbosely. Selected via HYPOTHESIS_PROFILE=ci in the workflow; local
    # runs keep hypothesis defaults unless the env var says otherwise.
    hypothesis.settings.register_profile(
        "ci", deadline=None, derandomize=True, print_blob=True)
    _profile = os.environ.get("HYPOTHESIS_PROFILE")
    if _profile:
        hypothesis.settings.load_profile(_profile)
except ModuleNotFoundError:
    # hermetic containers may lack hypothesis; install the API-compatible
    # deterministic fallback so property tests still run (the fallback is
    # always derandomized — examples derive from the test's name)
    from repro.compat.hypothesis_fallback import install
    install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
