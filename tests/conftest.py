import pathlib
import sys

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 fake devices.

_SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # hermetic containers may lack hypothesis; install the API-compatible
    # deterministic fallback so property tests still run
    from repro.compat.hypothesis_fallback import install
    install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
