"""Analytic roofline FLOPs/bytes per (arch × shape), per device.

Why analytic: XLA's ``cost_analysis`` visits a ``lax.scan`` (while-loop)
body ONCE — with scan-over-layers the reported FLOPs/bytes are ~1/L of the
truth (verified in tests/test_roofline.py, which checks this calculator
against ``cost_analysis`` of small configs lowered with the scan fully
unrolled). The dry-run still supplies the memory analysis and the
collective schedule; this module supplies the compute/memory roofline
terms.

Conventions (documented in EXPERIMENTS.md):
  * matmul FLOPs = 2·M·N·K; a weight matrix contributes 2·params per token
    (forward). Backward = 2× forward matmul cost; remat adds one extra
    forward through the stack (train factor 3+1 = 4 forward-equivalents
    for rematerialized segments; heads/embeddings are not rematerialized:
    factor 3).
  * attention (causal, train/prefill): 4·S_eff·H·hd FLOPs/token with
    S_eff = S/2 (causal average) or min(S, window)·(…) for SWA; decode:
    4·S_ctx·H·hd per generated token.
  * HBM bytes: every parameter is read twice (fwd+bwd) and written once
    per step in training (+ optimizer state r/w); decode reads params once
    per token + the KV cache/state once per token; activations counted at
    checkpoint granularity.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig
from repro.configs.registry import get_config, get_shape
from repro.models.ssm import MAMBA_HEAD_DIM

BF16 = 2
F32 = 4


# ---------------------------------------------------------------------------
# parameter counting (exact, matches eval_shape — asserted in tests)
# ---------------------------------------------------------------------------

def _gqa_params(cfg, d=None):
    d = d or cfg.d_model
    hd = cfg.resolved_head_dim
    return d * hd * (2 * cfg.num_heads + 2 * cfg.num_kv_heads)


def _mla_params(cfg):
    m = cfg.mla
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return (cfg.d_model * m.q_lora_rank + m.q_lora_rank
            + m.q_lora_rank * cfg.num_heads * qk
            + cfg.d_model * (m.kv_lora_rank + m.qk_rope_head_dim)
            + m.kv_lora_rank
            + m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            + cfg.num_heads * m.v_head_dim * cfg.d_model)


def _swiglu_params(d, ff):
    return 3 * d * ff


def _mamba2_params(cfg):
    d = cfg.d_model
    di = cfg.ssm.expand * d
    H = di // MAMBA_HEAD_DIM
    N = cfg.ssm.state_dim
    cw = cfg.ssm.conv_width
    return (2 * d * di + d * 2 * N + d * H          # w_z, w_x, w_bc, w_dt
            + cw * di + cw * 2 * N                   # convs
            + 3 * H + di + di * d)                   # A_log/dt_bias/D, norm, out


def _mlstm_params(cfg):
    d = cfg.d_model
    di = cfg.ssm.expand * d
    H = max(cfg.ssm.num_ssm_heads, 1)
    dh = di // H
    return (d * 2 * di + cfg.ssm.conv_width * di
            + 3 * H * dh * dh + 2 * di * H + H + di + di * d)


def _slstm_params(cfg):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ffn = (int(d * 4 / 3) + 127) // 128 * 128
    return d * 4 * d + H * dh * 4 * dh + 4 * d + d + d * 2 * ffn + ffn * d


def layer_param_count(cfg: ModelConfig) -> Dict[str, float]:
    """Per-kind per-layer param counts + embedding/head."""
    out = {}
    if cfg.family in ("dense", "moe", "vlm"):
        attn = _mla_params(cfg) if cfg.attn_type == "mla" else _gqa_params(cfg)
        if cfg.moe.enabled:
            e = cfg.moe
            E_pad = -(-e.num_experts // 16) * 16
            routed = 3 * cfg.d_model * e.d_ff_expert
            shared = (3 * cfg.d_model * e.num_shared_experts * e.d_ff_shared
                      + cfg.d_model if e.num_shared_experts else 0)
            out["layer"] = attn + 2 * cfg.d_model + cfg.d_model * E_pad \
                + E_pad * routed + shared
            out["layer_active"] = attn + 2 * cfg.d_model \
                + cfg.d_model * E_pad + e.top_k * routed + shared
        else:
            out["layer"] = attn + _swiglu_params(cfg.d_model, cfg.d_ff) \
                + 2 * cfg.d_model
            out["layer_active"] = out["layer"]
        out["n_layers"] = cfg.num_layers
        head = 0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model
        out["embed_head"] = cfg.vocab_size * cfg.d_model + head
    elif cfg.family == "hybrid":
        out["layer"] = _mamba2_params(cfg) + cfg.d_model
        out["layer_active"] = out["layer"]
        out["n_layers"] = cfg.num_layers
        out["shared_block"] = (_gqa_params(cfg)
                               + _swiglu_params(cfg.d_model, cfg.d_ff)
                               + 2 * cfg.d_model)
        out["shared_uses"] = cfg.num_layers // cfg.shared_attn_every
        out["embed_head"] = 2 * cfg.vocab_size * cfg.d_model
    elif cfg.family == "ssm":
        n_s = cfg.num_layers // cfg.slstm_every
        n_m = cfg.num_layers - n_s
        out["layer"] = (_mlstm_params(cfg) + cfg.d_model)     # mLSTM block
        out["layer_active"] = out["layer"]
        out["n_layers"] = n_m
        out["slstm_layer"] = _slstm_params(cfg) + cfg.d_model
        out["n_slstm"] = n_s
        out["embed_head"] = 2 * cfg.vocab_size * cfg.d_model
    elif cfg.family == "audio":
        gelu = 2 * cfg.d_model * cfg.d_ff + cfg.d_ff + cfg.d_model
        enc_layer = _gqa_params(cfg) + gelu + 4 * cfg.d_model
        dec_layer = 2 * _gqa_params(cfg) + gelu + 6 * cfg.d_model
        out["layer"] = dec_layer
        out["layer_active"] = dec_layer
        out["n_layers"] = cfg.num_layers
        out["enc_layer"] = enc_layer
        out["n_enc"] = cfg.encoder_layers
        out["embed_head"] = (cfg.vocab_size * cfg.d_model
                             + cfg.encoder_seq * cfg.d_model)
    else:
        raise ValueError(cfg.family)
    return out


def total_params(cfg: ModelConfig, active: bool = False) -> float:
    p = layer_param_count(cfg)
    key = "layer_active" if active else "layer"
    n = p[key] * p["n_layers"] + p["embed_head"]
    n += p.get("shared_block", 0)                      # shared: ONE copy
    n += p.get("slstm_layer", 0) * p.get("n_slstm", 0)
    n += p.get("enc_layer", 0) * p.get("n_enc", 0)
    return n


def _weight_flops_per_token(cfg: ModelConfig) -> float:
    """2 × active params touched per token by matmuls (weights used per
    token — shared blocks count once per USE)."""
    p = layer_param_count(cfg)
    n = p["layer_active"] * p["n_layers"]
    n += p.get("shared_block", 0) * p.get("shared_uses", 0)
    n += p.get("slstm_layer", 0) * p.get("n_slstm", 0)
    n += p["embed_head"]
    return 2.0 * n


def _attn_flops_per_token(cfg: ModelConfig, s_ctx: float) -> float:
    """score + PV matmuls per token against s_ctx keys."""
    hd = cfg.resolved_head_dim
    if cfg.attn_type == "mla":
        hd = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
    per_use = 4.0 * s_ctx * cfg.num_heads * hd
    if cfg.family == "hybrid":
        return per_use * (cfg.num_layers // cfg.shared_attn_every)
    if cfg.family == "ssm":
        return 0.0
    n_attn = cfg.num_layers + (cfg.encoder_layers if cfg.family == "audio" else 0)
    if cfg.family == "audio":
        # decoder self (s_ctx) + cross (encoder_seq) + encoder self counted
        # separately by caller; simplify: self for num_layers, cross adds
        n_attn = cfg.num_layers
        return (per_use * n_attn
                + 4.0 * cfg.encoder_seq * cfg.num_heads * hd * cfg.num_layers)
    return per_use * n_attn


def _ssm_flops_per_token(cfg: ModelConfig) -> float:
    """Mamba2/mLSTM chunked-scan arithmetic per token (beyond projections):
    intra-chunk scores+gather ≈ 2·Q·(N+P) per head, state update 2·N·P."""
    if cfg.family == "hybrid":
        di = cfg.ssm.expand * cfg.d_model
        H = di // MAMBA_HEAD_DIM
        N, P, Q = cfg.ssm.state_dim, MAMBA_HEAD_DIM, cfg.ssm.chunk_size
        per_layer = H * (2.0 * Q * (N + P) + 4.0 * N * P)
        return per_layer * cfg.num_layers
    if cfg.family == "ssm":
        di = cfg.ssm.expand * cfg.d_model
        H = max(cfg.ssm.num_ssm_heads, 1)
        dh = di // H
        Q = cfg.ssm.chunk_size
        n_s = cfg.num_layers // cfg.slstm_every
        n_m = cfg.num_layers - n_s
        mlstm = H * (2.0 * Q * 2 * dh + 4.0 * dh * (dh + 1)) * n_m
        slstm = 2.0 * cfg.d_model * 4 * (cfg.d_model // cfg.num_heads) * n_s
        return mlstm + slstm
    return 0.0


def roofline_terms(arch: str, shape_name: str, *, n_devices: int = 256,
                   tp: int = 16, peak_flops: float = 197e12,
                   hbm_bw: float = 819e9, ici_bw: float = 50e9,
                   remat: bool = True) -> Dict[str, float]:
    cfg = get_config(arch)
    sh = get_shape(shape_name)
    N_active = total_params(cfg, active=True)
    N_total = total_params(cfg)

    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        s_eff = sh.seq_len / 2
        if cfg.attn_type == "swa":
            s_eff = min(sh.seq_len / 2, cfg.window)
        fwd = (_weight_flops_per_token(cfg)
               + _attn_flops_per_token(cfg, s_eff)
               + _ssm_flops_per_token(cfg)) * tokens
        factor = 4.0 if remat else 3.0          # fwd + bwd(2x) [+ remat fwd]
        flops = fwd * factor
        # bytes: params r/w + momentum r/w + grads + checkpoint stack r/w
        pbytes = N_total * BF16
        opt_bytes = N_total * (BF16 if N_total > 2e10 else F32)
        ckpt = (sh.global_batch * sh.seq_len * cfg.d_model * BF16
                * _n_checkpoint_layers(cfg))
        hbm = 4 * pbytes + 3 * opt_bytes + 2 * ckpt + 2 * pbytes  # heuristic: fwd2+bwd2 reads, grads+mom, stack
        # collectives: trust-weighted all-reduce of the update (2x ring) +
        # the cheaper of (a) per-layer TP psums of activations (2/layer,
        # both passes) or (b) FSDP-style batch-sharded activations: weight
        # all-gathers fwd+recompute+bwd plus the dW reduce — the launcher's
        # activation-sharding policy picks (b) when the per-worker batch
        # divides TP (see launch/specs.py)
        upd_ar = 2.0 * N_total * BF16
        act = sh.global_batch * sh.seq_len * cfg.d_model * BF16
        tp_coll = 2.0 * _n_tp_collectives(cfg) * act * 2    # fwd+bwd
        fsdp_coll = (3.0 + 2.0) * N_total * BF16            # 3 AG + dW RS(2x)
        coll = upd_ar + min(tp_coll, fsdp_coll)
    elif sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        s_eff = sh.seq_len / 2
        if cfg.attn_type == "swa":
            s_eff = min(sh.seq_len / 2, cfg.window)
        flops = (_weight_flops_per_token(cfg)
                 + _attn_flops_per_token(cfg, s_eff)
                 + _ssm_flops_per_token(cfg)) * tokens
        cache = _cache_bytes(cfg, sh.global_batch, sh.seq_len)
        hbm = N_total * BF16 + cache + tokens * cfg.d_model * BF16 * 2
        act = tokens * cfg.d_model * BF16
        coll = _n_tp_collectives(cfg) * act * 2
    else:                                        # decode: ONE token
        tokens = sh.global_batch
        s_ctx = sh.seq_len
        if cfg.attn_type == "swa":
            s_ctx = min(sh.seq_len, cfg.window)
        flops = (_weight_flops_per_token(cfg)
                 + _attn_flops_per_token(cfg, s_ctx)
                 + _ssm_decode_flops(cfg)) * tokens
        cache = _cache_bytes(cfg, sh.global_batch, sh.seq_len,
                             window=cfg.window if cfg.attn_type == "swa" else 0)
        hbm = N_total * BF16 + cache
        act = tokens * cfg.d_model * BF16
        coll = _n_tp_collectives(cfg) * act * 2

    compute_s = flops / (n_devices * peak_flops)
    memory_s = hbm / (n_devices * hbm_bw)
    collective_s = coll / (n_devices * ici_bw)
    terms = {"flops": flops, "hbm_bytes": hbm, "collective_bytes": coll,
             "compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s,
             "model_flops": (6.0 if sh.kind == "train" else 2.0)
             * N_active * (sh.global_batch
                           * (sh.seq_len if sh.kind != "decode" else 1)),
             "params_total": N_total, "params_active": N_active}
    terms["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                            key=lambda k: terms[k])
    terms["useful_ratio"] = terms["model_flops"] / max(flops, 1.0)
    return terms


def _n_checkpoint_layers(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.shared_attn_every
    if cfg.family == "ssm":
        return cfg.num_layers // cfg.slstm_every
    if cfg.family == "audio":
        return cfg.num_layers + cfg.encoder_layers
    return cfg.num_layers


def _n_tp_collectives(cfg: ModelConfig) -> int:
    """all-reduces of the residual per layer under TP (attn out + mlp out)."""
    if cfg.family == "ssm":
        return 2 * cfg.num_layers // cfg.slstm_every * (cfg.slstm_every - 1)
    if cfg.family == "hybrid":
        return cfg.num_layers + 2 * (cfg.num_layers // cfg.shared_attn_every)
    if cfg.family == "audio":
        return 2 * cfg.num_layers + 2 * cfg.encoder_layers + cfg.num_layers
    return 2 * cfg.num_layers


def _cache_bytes(cfg: ModelConfig, batch: int, seq: int, window: int = 0):
    s_eff = min(seq, window) if window else seq
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.attn_type == "mla":
            per = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
            return cfg.num_layers * batch * s_eff * per * BF16
        per = 2 * cfg.num_kv_heads * cfg.resolved_head_dim
        return cfg.num_layers * batch * s_eff * per * BF16
    if cfg.family == "hybrid":
        di = cfg.ssm.expand * cfg.d_model
        H = di // MAMBA_HEAD_DIM
        ssm = cfg.num_layers * batch * H * cfg.ssm.state_dim * MAMBA_HEAD_DIM * F32
        n_attn = cfg.num_layers // cfg.shared_attn_every
        kv = n_attn * batch * s_eff * 2 * cfg.num_kv_heads * cfg.resolved_head_dim * BF16
        return ssm + kv
    if cfg.family == "ssm":
        di = cfg.ssm.expand * cfg.d_model
        H = max(cfg.ssm.num_ssm_heads, 1)
        dh = di // H
        n_s = cfg.num_layers // cfg.slstm_every
        n_m = cfg.num_layers - n_s
        return (n_m * batch * H * dh * (dh + 1) * F32
                + n_s * batch * 3 * cfg.d_model * F32)
    if cfg.family == "audio":
        per = 2 * cfg.num_kv_heads * cfg.resolved_head_dim
        return cfg.num_layers * batch * (s_eff + cfg.encoder_seq) * per * BF16
    raise ValueError(cfg.family)


def _ssm_decode_flops(cfg: ModelConfig) -> float:
    if cfg.family == "hybrid":
        di = cfg.ssm.expand * cfg.d_model
        H = di // MAMBA_HEAD_DIM
        return cfg.num_layers * H * 4.0 * cfg.ssm.state_dim * MAMBA_HEAD_DIM
    if cfg.family == "ssm":
        di = cfg.ssm.expand * cfg.d_model
        H = max(cfg.ssm.num_ssm_heads, 1)
        dh = di // H
        n_s = cfg.num_layers // cfg.slstm_every
        n_m = cfg.num_layers - n_s
        return n_m * H * 4.0 * dh * (dh + 1) + n_s * 2.0 * cfg.d_model * 4 * (cfg.d_model // cfg.num_heads)
    return 0.0
