"""§Roofline table generator.

Merges (a) the analytic compute/memory/collective terms (benchmarks.analytic
— exact param counts, scan-aware FLOPs/bytes) with (b) the dry-run JSON
(results_dryrun_single.json: per-partition HLO cost numbers, peak memory,
collective schedule) produced by ``repro.launch.dryrun --all``.

Emits a markdown table (stdout + optionally EXPERIMENTS-ready)."""
from __future__ import annotations

import json
import os
from typing import Optional

from benchmarks import analytic
from repro.configs.registry import ARCH_IDS, INPUT_SHAPES, applicable


def build_table(dryrun_json: Optional[str] = "results_dryrun_single.json"):
    dry = {}
    if dryrun_json and os.path.exists(dryrun_json):
        for r in json.load(open(dryrun_json)):
            dry[(r["arch"], r["shape"])] = r
    rows = []
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            ok, reason = applicable(arch, shape)
            if not ok:
                rows.append({"arch": arch, "shape": shape, "skip": reason})
                continue
            t = analytic.roofline_terms(arch, shape)
            d = dry.get((arch, shape), {})
            rows.append({
                "arch": arch, "shape": shape,
                "compute_s": t["compute_s"], "memory_s": t["memory_s"],
                "collective_s": t["collective_s"], "dominant": t["dominant"],
                "model_flops": t["model_flops"],
                "useful_ratio": min(t["useful_ratio"], 1.0),
                "mem_gb": d.get("peak_memory_per_device_gb", float("nan")),
                "hlo_flops_dev": d.get("flops_per_device", float("nan")),
            })
    return rows


def markdown(rows) -> str:
    out = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | useful FLOPs | peak GiB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skip" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"SKIP: {r['skip'][:40]}… | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{r['useful_ratio'] * 100:.0f}% | {r['mem_gb']:.1f} |")
    return "\n".join(out)


def run(dryrun_json: str = "results_dryrun_single.json"):
    rows = build_table(dryrun_json)
    print(markdown(rows))
    n_dom = {}
    for r in rows:
        if "skip" not in r:
            n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
    print(f"\ndominant-term histogram: {n_dom}")
    return rows


if __name__ == "__main__":
    run()
