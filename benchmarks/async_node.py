"""Event-driven node settlement at scale — the async-first ChainNode
headline (``BENCH_async_node.json``, CI-gated).

Chain-only (no jitted learning): drives the arrival frontier + contract
layers exactly as ``ChainNode.run_events`` does, at worker counts where the
learning step would dwarf the signal.

Part A — simulated-time tail latency (deterministic, runner-noise-immune).
Heavy-tailed (Pareto) worker speeds with dropout. An update's settlement
latency is seal time − arrival time. The sync barrier (lockstep rounds)
makes every update wait for the slowest worker's (retried) arrival; the
event-driven path seals a cohort of ``buffer_size`` as soon as it fills.
Gate: async p95 (and p99) beat the sync barrier's.

Part B — wall-clock settlement cost. The sync path settles the full
population densely; the event path seals sparse cohort DeltaCommits with
staleness recorded per on-chain record. Gates: (1) sealing one cohort
event never costs more than ``event_seal_ratio`` of a dense
full-population round (so event-driven settlement can run many events per
round-time without blowing the chain budget); (2) the dense sync path —
byte-identical to the pre-async contract — stays under an absolute
per-record budget; (3) the sealed overlay chain deep-verifies with every
idle worker still proof-covered. The per-changed-record ratio is reported
(not gated): at small cohorts the fixed per-block seal dominates it.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_json, csv_row
from repro.chain.contract import TrustContract
from repro.chain.ledger import Ledger
from repro.core import async_sim
from repro.core.async_sim import AsyncScheduler


def _pcts(lat) -> dict:
    lat = np.asarray(lat, np.float64)
    return {f"p{p}": float(np.percentile(lat, p)) for p in (50, 95, 99)}


def _sync_barrier_latencies(profiles, rounds: int, seed: int) -> np.ndarray:
    """Lockstep sync baseline, vectorized: each round every worker starts at
    the barrier, trains, retries on a lost update (geometric attempts), and
    the round seals at the slowest worker's surviving arrival. Latency per
    update = barrier − its own arrival. (The event scheduler would model
    this too via buffer_size=W, but free-running fast workers re-arrive
    thousands of times under a Pareto tail — the lockstep form is the same
    distribution without the heap churn.)"""
    speed = np.array([p.speed for p in profiles])
    jitter = np.array([p.jitter for p in profiles])
    fail = np.array([p.failure_prob for p in profiles])
    rng = np.random.default_rng((seed, 1))
    lats = []
    for _ in range(rounds):
        attempts = rng.geometric(1.0 - fail)
        arrival = np.zeros(len(profiles))
        for a in range(int(attempts.max())):
            live = attempts > a
            arrival[live] += speed[live] * rng.lognormal(0.0, jitter[live])
        lats.append(arrival.max() - arrival)
    return np.concatenate(lats)


def _contract(W: int, *, sparse: bool, alpha: float = 0.5) -> TrustContract:
    c = TrustContract(Ledger(), requester_deposit=1e6, worker_stake=10.0,
                      penalty_pct=50.0, trust_threshold=0.5,
                      top_k=max(W // 100, 1), merkle_chunk_size=64,
                      sparse_settlement=sparse,
                      staleness_alpha=alpha if sparse else 0.0)
    c.join_batch(W)
    return c


def run(W: int = 100_000, sync_rounds: int = 4, async_events: int = 400,
        chain_events: int = 8, buffer_frac: int = 16, seed: int = 0,
        failure_prob: float = 0.05, event_seal_ratio: float = 1.5,
        per_record_budget_us: float = 5.0, wall_gates: bool = True,
        json_name: str = "async_node"):
    profiles = async_sim.heavy_tailed_profiles(
        W, shape=1.5, jitter=0.3, failure_prob=failure_prob, seed=seed)
    B = max(W // buffer_frac, 1)
    rng = np.random.default_rng(seed)

    # -- Part A: simulated-time settlement latency ---------------------------
    sync_lat = _sync_barrier_latencies(profiles, sync_rounds, seed)

    sched = AsyncScheduler(profiles, seed=seed, buffer_size=B)
    async_lat, cohort_sizes, max_staleness = [], [], 0
    for _ in range(async_events):
        t, mask, snap = sched.next_aggregation()
        cohort = mask > 0
        async_lat.append(t - sched.arrival_times()[cohort])
        cohort_sizes.append(int(cohort.sum()))
        max_staleness = max(max_staleness, int(snap.max()))
    async_lat = np.concatenate(async_lat)

    sp, ap = _pcts(sync_lat), _pcts(async_lat)
    csv_row(f"async_node_sync_latency_w{W}", sp["p95"] * 1e6,
            f"p50={sp['p50']:.2f}s p99={sp['p99']:.2f}s "
            f"updates={len(sync_lat)}")
    csv_row(f"async_node_event_latency_w{W}", ap["p95"] * 1e6,
            f"p50={ap['p50']:.2f}s p99={ap['p99']:.2f}s "
            f"buffer={B} mean_cohort={np.mean(cohort_sizes):.0f} "
            f"max_staleness={max_staleness}")
    assert ap["p95"] < sp["p95"] and ap["p99"] < sp["p99"], \
        "event-driven settlement tail latency must beat the sync barrier"

    # -- Part B: wall-clock settlement cost ----------------------------------
    # sync baseline: dense full-population settlement (byte-identical to the
    # pre-async contract — staleness_alpha=0, no staleness argument)
    dense = _contract(W, sparse=False)
    dense_times = []
    for r in range(max(sync_rounds, 3)):
        scores = rng.random(W)
        t0 = time.monotonic()
        dense.settle_round_batch(r, scores, timestamp=float(r + 1))
        dense_times.append(time.monotonic() - t0)
    dense_s = float(np.median(dense_times[1:]))
    per_record_us = dense_s / W * 1e6

    # event path: sparse cohort seals with on-chain staleness, driven by the
    # same arrival process as Part A
    sparse = _contract(W, sparse=True)
    sched = AsyncScheduler(profiles, seed=seed, buffer_size=B)
    sparse_times, changed = [], 0
    for r in range(chain_events):
        _, mask, snap = sched.next_aggregation()
        ids = np.nonzero(mask)[0].astype(np.int64)
        changed += len(ids)
        scores = rng.random(len(ids))
        t0 = time.monotonic()
        sparse.settle_round_batch(r, scores, worker_ids=ids,
                                  staleness=snap[ids],
                                  timestamp=float(r + 1))
        sparse_times.append(time.monotonic() - t0)
    sparse_s = float(np.median(sparse_times[1:]))
    per_changed_us = sparse_s / (changed / chain_events) * 1e6

    assert sparse.ledger.verify_chain(deep=True)
    # an idle worker (never in any cohort) is still proof-covered
    settled = set()
    for r in range(chain_events):
        settled.update(sparse._round_ids[r].tolist())
    idle = next(w for w in range(W) if w not in settled)
    proof = sparse.settlement_proof(chain_events - 1, idle)
    assert sparse.verify_settlement(proof) and proof["record"]["round"] == -1

    csv_row(f"async_node_dense_settle_w{W}", dense_s * 1e6,
            f"per_record_us={per_record_us:.3f}")
    csv_row(f"async_node_cohort_settle_w{W}", sparse_s * 1e6,
            f"per_changed_record_us={per_changed_us:.3f} "
            f"event/dense={sparse_s / dense_s:.2f}")
    if wall_gates:       # correctness-only smoke runs skip the wall gates
        assert sparse_s < event_seal_ratio * dense_s, \
            (f"cohort event seal {sparse_s * 1e3:.2f}ms exceeds "
             f"{event_seal_ratio}x a dense full-population round "
             f"{dense_s * 1e3:.2f}ms")
        assert per_record_us < per_record_budget_us, \
            (f"dense (sync-path) settlement regressed: {per_record_us:.3f}us "
             f"per record > {per_record_budget_us}us budget")

    payload = {
        "W": W, "buffer_size": B, "failure_prob": failure_prob,
        "profile": "pareto(shape=1.5) heavy-tailed + dropout",
        "sync": {"rounds": sync_rounds, "latency_sim_s": sp,
                 "settle_s": dense_s, "per_record_us": per_record_us},
        "async": {"events": async_events, "latency_sim_s": ap,
                  "mean_cohort": float(np.mean(cohort_sizes)),
                  "max_staleness": max_staleness,
                  "chain_events": chain_events, "settle_s": sparse_s,
                  "per_changed_record_us": per_changed_us},
        "gates": {
            "p95_latency_speedup": sp["p95"] / ap["p95"],
            "p99_latency_speedup": sp["p99"] / ap["p99"],
            "event_seal_vs_dense_round": sparse_s / dense_s,
            "event_seal_budget": event_seal_ratio,
            "per_record_us": per_record_us,
            "per_record_budget_us": per_record_budget_us,
            "per_changed_record_ratio": per_changed_us / per_record_us,
        },
    }
    bench_json(json_name, payload)
    return payload


if __name__ == "__main__":
    run(W=10_000, sync_rounds=3, async_events=120, chain_events=6)
