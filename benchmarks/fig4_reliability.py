"""Paper Fig. 4 — reliability: std-dev of per-worker accuracy vs epoch for
8/16/20 workers. Claim: similar, stable std-dev across worker counts.

``run_churn`` extends the table to the event-driven node: under stragglers
+ dropout (async_ablation's churn profile) workers miss aggregation events,
yet the per-worker accuracy spread stays bounded — the reliability claim
survives asynchronous functionality."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, paper_protocol
from repro.core import async_sim
from repro.data.datasets import make_federated_mnist


def run(rounds: int = 40, samples: int = 4096, seed: int = 0,
        worker_counts=(8, 16, 20), eval_every: int = 8):
    stds = {}
    for W in worker_counts:
        ds = make_federated_mnist(W, samples=samples, seed=seed)
        proto = paper_protocol(W, clusters=2 if W % 2 == 0 else 1, seed=seed)
        series = []
        for r in range(rounds):
            proto.run_round(ds.round_batches(32))
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                batch_w = {k: np.stack([ds.worker_batch(w, 128)[k]
                                        for w in range(W)])
                           for k in ("images", "labels")}
                m = proto.evaluate_per_worker(batch_w)
                series.append(float(np.std(m["accuracy"])))
        proto.finalize()
        stds[W] = series
        csv_row(f"fig4_final_std_w{W}", 0.0, f"std={series[-1]:.4f}")
    final_stds = [stds[W][-1] for W in worker_counts]
    csv_row("fig4_std_range", 0.0,
            f"range={max(final_stds) - min(final_stds):.4f}")
    assert max(final_stds) < 0.25, "per-worker accuracy spread stays bounded"
    return stds


def run_churn(rounds: int = 24, samples: int = 2048, seed: int = 0,
              worker_counts=(8, 16), failure_prob: float = 0.1,
              eval_every: int = 8):
    """Node-level churn row of the reliability table: event-driven cohorts
    (25% stragglers, ``failure_prob`` update loss) — per-worker accuracy
    spread stays bounded even when workers repeatedly miss events."""
    stds = {}
    for W in worker_counts:
        profiles = async_sim.heterogeneous_profiles(
            W, straggler_frac=0.25, straggler_slowdown=6.0,
            failure_prob=failure_prob, seed=seed)
        ds = make_federated_mnist(W, samples=samples, seed=seed)
        proto = paper_protocol(W, clusters=2, seed=seed, async_mode=True,
                               arrival_profiles=profiles,
                               buffer_size=max(W // 2, 1))
        series, done = [], 0
        while done < rounds:
            if not proto.run_events(lambda r: ds.round_batches(32),
                                    events=1):
                continue               # empty cohort: churn ate the window
            done += 1
            if done % eval_every == 0 or done == rounds:
                batch_w = {k: np.stack([ds.worker_batch(w, 128)[k]
                                        for w in range(W)])
                           for k in ("images", "labels")}
                m = proto.evaluate_per_worker(batch_w)
                series.append(float(np.std(m["accuracy"])))
        proto.finalize()
        stds[W] = series
        csv_row(f"fig4_churn_std_w{W}", 0.0, f"std={series[-1]:.4f}")
    final = [stds[W][-1] for W in worker_counts]
    csv_row("fig4_churn_std_range", 0.0,
            f"range={max(final) - min(final):.4f}")
    assert max(final) < 0.3, \
        "per-worker accuracy spread stays bounded under churn"
    return stds


if __name__ == "__main__":
    run(rounds=16, samples=2048)
    run_churn(rounds=12, samples=2048)
