"""Paper Figs. 5/6 — per-worker accuracy (5) and loss (6) convergence
curves. Claim: every worker improves accuracy / reduces loss as training
progresses, with slight per-worker variation."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, paper_protocol
from repro.data.datasets import make_federated_mnist


def run(rounds: int = 100, samples: int = 4096, W: int = 8, seed: int = 0,
        eval_every: int = 20):
    ds = make_federated_mnist(W, samples=samples, seed=seed)
    proto = paper_protocol(W, clusters=2, seed=seed)
    ev = ds.eval_batch(512)
    acc_curves, loss_curves, global_loss = [], [], []
    for r in range(rounds):
        proto.run_round(ds.round_batches(32))
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            batch_w = {k: np.stack([ds.worker_batch(w, 128)[k]
                                    for w in range(W)])
                       for k in ("images", "labels")}
            m = proto.evaluate_per_worker(batch_w)
            acc_curves.append(np.asarray(m["accuracy"]))
            loss_curves.append(np.asarray(m["loss"]))
            global_loss.append(proto.evaluate(ev)["loss"])
    proto.finalize()
    acc = np.stack(acc_curves)       # (evals, W)
    loss = np.stack(loss_curves)
    for w in range(W):
        csv_row(f"fig56_worker{w}", 0.0,
                f"acc {acc[0, w]:.3f}->{acc[-1, w]:.3f} "
                f"loss {loss[0, w]:.3f}->{loss[-1, w]:.3f}")
    improved = int(np.sum(acc[-1] >= acc[0]))
    csv_row("fig56_workers_improved", 0.0, f"{improved}/{W}")
    csv_row("fig56_global_loss", 0.0,
            f"{global_loss[0]:.3f}->{global_loss[-1]:.3f}")
    # Fig. 6 trend: the global objective falls; per-worker local-shard loss
    # is calibration-noisy under the synthetic data's label noise, so the
    # per-worker claim is asserted on accuracy (Fig. 5)
    assert global_loss[-1] < global_loss[0], "global loss must fall (Fig. 6)"
    assert improved >= W // 2, "most workers must improve (Fig. 5 trend)"
    return {"accuracy": acc, "loss": loss, "global_loss": global_loss}


if __name__ == "__main__":
    run(rounds=20, samples=2048)
