"""Benchmark harness — one entry per paper figure/table + framework-level
benches. Prints ``name,us_per_call,derived`` CSV rows per experiment.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig2,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced rounds/samples (CI-speed)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset (fig2,fig3,fig4,fig56,"
                         "trust,async,async_node,serve,network,cfl,chain,"
                         "kernels,fused_round,roofline)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    q = args.quick

    from benchmarks import (async_ablation, async_node, cfl_baseline,
                            fig2_blockchain, fig3_scalability,
                            fig4_reliability, fig56_convergence,
                            kernel_bench, network_reliability,
                            proof_serving, roofline, trust_ablation)

    suite = {
        "fig2": lambda: fig2_blockchain.run(
            rounds=20 if q else 60, samples=1024 if q else 2048),
        "fig3": lambda: fig3_scalability.run(
            rounds=20 if q else 60, samples=2048 if q else 4096),
        "fig4": lambda: (
            fig4_reliability.run(
                rounds=16 if q else 40, samples=2048 if q else 4096),
            fig4_reliability.run_churn(
                rounds=12 if q else 24, samples=2048)),
        "fig56": lambda: fig56_convergence.run(
            rounds=60 if q else 100, samples=2048 if q else 4096),
        "trust": lambda: trust_ablation.run(
            rounds=20 if q else 50, samples=2048 if q else 4096),
        "async": lambda: async_ablation.run(
            rounds=16 if q else 40, samples=2048 if q else 4096),
        # event-driven node headline: simulated-time settlement tail latency
        # under a heavy-tailed straggler profile + chain-only cohort seal
        # cost (writes the CI-gated BENCH_async_node.json)
        "async_node": lambda: async_node.run(
            W=10_000 if q else 100_000,
            sync_rounds=3 if q else 4,
            async_events=120 if q else 400,
            chain_events=6 if q else 8),
        # chain read path: batched multiproof speedup vs independent proofs
        # + light-client QPS under live settlement (writes the CI-gated
        # BENCH_proof_serving.json)
        "serve": lambda: proof_serving.run(
            W=10_000 if q else 100_000,
            rounds=3 if q else 4,
            duration_s=1.0 if q else 1.5),
        # multi-node settlement reliability: fault-free/partition/byzantine
        # seed sweep (writes the CI-gated BENCH_network_reliability.json:
        # rejoin within budget, byzantine containment == 1.0)
        "network": lambda: network_reliability.run(
            seeds=8 if q else 20),
        "cfl": lambda: cfl_baseline.run(
            rounds=25 if q else 50, samples=2048 if q else 4096),
        "kernels": kernel_bench.run,
        # fused flat-pack trust round vs per-leaf reference on paper-CNN
        # shapes up to the 10k cohort (writes the CI-gated
        # BENCH_fused_round.json: fused HBM passes <= 2, no wall regression
        # of the default path)
        "fused_round": lambda: kernel_bench.run_fused_round(
            worker_counts=(256, 1024, 4096) if q
            else (256, 1024, 4096, 10240),
            e2e=not q),
        "roofline": roofline.run,
        # chain-layer scaling: dense batch settlement vs the legacy scalar
        # path, then the sparse delta path (W=1M at full scale — the
        # million-worker headline gates on the cohort pattern)
        "chain": lambda: (
            fig3_scalability.run_chain_scaling(
                worker_counts=(1_000, 10_000) if q
                else (1_000, 10_000, 100_000),
                rounds=2 if q else 3),
            fig3_scalability.run_sparse_settlement(
                worker_count=100_000 if q else 1_000_000,
                rounds=3 if q else 6,
                headline_budget_s=None if q else 0.1)),
    }
    failures = []
    for name, fn in suite.items():
        if only and name not in only:
            continue
        print(f"\n===== {name} =====")
        t0 = time.monotonic()
        try:
            fn()
            print(f"[{name}] done in {time.monotonic() - t0:.1f}s")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED: {failures}")
        sys.exit(1)
    print("\nALL BENCHMARKS PASSED")


if __name__ == "__main__":
    main()
