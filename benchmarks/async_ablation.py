"""Asynchronous functionality ablation (paper §VI.C, quantified).

Heterogeneous worker speeds (25% stragglers, 4-8x slower). Compare:
  sync  : every round waits for the slowest worker
  async : aggregate as soon as `buffer_size` updates arrive, staleness-
          discounted (core.async_agg) — the paper's asynchronous mode.
Measures simulated wall-clock to reach a loss target + failure resilience."""
from __future__ import annotations


from benchmarks.common import csv_row, paper_protocol
from repro.core import async_sim
from repro.data.datasets import make_federated_mnist


def run(rounds: int = 40, samples: int = 4096, W: int = 8, seed: int = 0,
        slowdown: float = 6.0):
    profiles = async_sim.heterogeneous_profiles(
        W, straggler_frac=0.25, straggler_slowdown=slowdown, seed=seed)

    # --- sync: logical round time = slowest worker ---
    ds = make_federated_mnist(W, samples=samples, seed=seed)
    sync_proto = paper_protocol(W, clusters=2, seed=seed)
    sync_sched = async_sim.AsyncScheduler(profiles, seed=seed, buffer_size=W)
    sync_clock, sync_curve = 0.0, []
    ev = ds.eval_batch(512)
    for r in range(rounds):
        sync_clock += sync_sched.sync_round_time()
        sync_proto.run_round(ds.round_batches(32))
        if (r + 1) % 10 == 0 or r == rounds - 1:
            sync_curve.append((sync_clock, sync_proto.evaluate(ev)["loss"]))
    sync_proto.finalize()

    # --- async: buffer of W//2, staleness-weighted ---
    ds = make_federated_mnist(W, samples=samples, seed=seed)
    async_proto = paper_protocol(W, clusters=2, seed=seed, async_mode=True)
    sched = async_sim.AsyncScheduler(profiles, seed=seed, buffer_size=W // 2)
    async_curve = []
    for r in range(rounds):
        t, mask, _ = sched.next_aggregation()
        async_proto.run_round(ds.round_batches(32), participation=mask)
        if (r + 1) % 10 == 0 or r == rounds - 1:
            async_curve.append((t, async_proto.evaluate(ev)["loss"]))
    async_proto.finalize()

    t_sync, l_sync = sync_curve[-1]
    t_async, l_async = async_curve[-1]
    csv_row("async_sync_simclock", t_sync * 1e6, f"loss={l_sync:.3f}")
    csv_row("async_async_simclock", t_async * 1e6, f"loss={l_async:.3f}")
    csv_row("async_speedup", 0.0, f"{t_sync / t_async:.2f}x per round-budget")
    assert t_async < t_sync, "async rounds must beat slowest-worker barrier"
    return {"sync": sync_curve, "async": async_curve}


if __name__ == "__main__":
    run(rounds=20, samples=2048)
