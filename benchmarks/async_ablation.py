"""Asynchronous functionality ablation (paper §VI.C, quantified).

Heterogeneous worker speeds (25% stragglers, 4-8x slower) under churn
(``failure_prob`` of any finished update being lost). Compare:
  sync  : every round waits for the slowest worker — and under churn, for
          that worker's retry after a lost update
  async : event-driven node (``run_events``) — aggregate as soon as
          ``buffer_size`` updates arrive, staleness-discounted cohorts
          sealed per event (the paper's asynchronous mode).
Reports per-update settlement latency (simulated seal time − arrival time)
at p50/p95/p99 and simulated time-to-target-loss; the node-level churn rows
feed the fig4 reliability table (``fig4_reliability.run_churn`` reuses this
profile)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, paper_protocol
from repro.core import async_sim
from repro.data.datasets import make_federated_mnist


def _pcts(lat) -> dict:
    lat = np.asarray(lat, np.float64)
    return {f"p{p}": float(np.percentile(lat, p)) for p in (50, 95, 99)}


def run(rounds: int = 40, samples: int = 4096, W: int = 8, seed: int = 0,
        slowdown: float = 6.0, failure_prob: float = 0.1,
        target_loss: float = 2.15):
    profiles = async_sim.heterogeneous_profiles(
        W, straggler_frac=0.25, straggler_slowdown=slowdown,
        failure_prob=failure_prob, seed=seed)
    eval_every = 5

    # --- sync: each logical round barriers on the slowest worker (under
    # churn, on its retry after a lost update) ---
    ds = make_federated_mnist(W, samples=samples, seed=seed)
    ev = ds.eval_batch(512)
    sync_proto = paper_protocol(W, clusters=2, seed=seed)
    barrier = async_sim.AsyncScheduler(profiles, seed=seed, buffer_size=W)
    sync_lat, sync_curve, t_target_sync = [], [], None
    for r in range(rounds):
        t, mask, _ = barrier.next_aggregation()
        sync_lat.extend((t - barrier.arrival_times()[mask > 0]).tolist())
        sync_proto.run_round(ds.round_batches(32))
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            loss = sync_proto.evaluate(ev)["loss"]
            sync_curve.append((t, loss))
            if t_target_sync is None and loss <= target_loss:
                t_target_sync = t
    sync_proto.finalize()

    # --- async: event-driven node, buffer of W//2, staleness-weighted ---
    ds = make_federated_mnist(W, samples=samples, seed=seed)
    async_proto = paper_protocol(W, clusters=2, seed=seed, async_mode=True,
                                 arrival_profiles=profiles,
                                 buffer_size=W // 2)
    async_lat, async_curve, t_target_async = [], [], None
    done = 0
    while done < rounds:
        recs = async_proto.run_events(lambda r: ds.round_batches(32),
                                      events=1)
        if not recs:
            continue                       # empty cohort: churn ate the window
        rec = recs[0]
        done += 1
        cohort = rec.participation > 0
        async_lat.extend((rec.sim_time - rec.arrival_times[cohort]).tolist())
        if done % eval_every == 0 or done == rounds:
            loss = async_proto.evaluate(ev)["loss"]
            async_curve.append((rec.sim_time, loss))
            if t_target_async is None and loss <= target_loss:
                t_target_async = rec.sim_time
    async_proto.finalize()

    sp, ap = _pcts(sync_lat), _pcts(async_lat)
    t_sync, l_sync = sync_curve[-1]
    t_async, l_async = async_curve[-1]
    csv_row("async_sync_simclock", t_sync * 1e6, f"loss={l_sync:.3f}")
    csv_row("async_async_simclock", t_async * 1e6, f"loss={l_async:.3f}")
    for name, p in (("sync", sp), ("async", ap)):
        csv_row(f"async_{name}_latency_p95", p["p95"] * 1e6,
                f"p50={p['p50']:.2f}s p99={p['p99']:.2f}s")
    csv_row("async_speedup", 0.0, f"{t_sync / t_async:.2f}x per round-budget")
    csv_row("async_time_to_target", 0.0,
            f"target={target_loss} sync={t_target_sync} async={t_target_async}")
    assert t_async < t_sync, "async rounds must beat slowest-worker barrier"
    assert ap["p95"] < sp["p95"], \
        "event-driven p95 settlement latency must beat the sync barrier"
    if t_target_sync is not None:
        assert t_target_async is not None and t_target_async <= t_target_sync, \
            "async must reach the loss target no later (simulated time)"
    return {"sync": sync_curve, "async": async_curve,
            "latency": {"sync": sp, "async": ap},
            "time_to_target": {"sync": t_target_sync,
                               "async": t_target_async}}


if __name__ == "__main__":
    run(rounds=20, samples=2048)
