"""Multi-node settlement reliability sweep (``BENCH_network_reliability.json``,
CI-gated).

Chain-only (no jitted learning): drives ``repro.net`` 3-node cohorts over
seeded fault schedules and gates the ISSUE-level reliability claims:

- **fault-free**: every seeded gossip order converges all replicas to one
  byte-identical chain with bit-equal contract state — fraction must be
  1.0;
- **partition → rejoin**: a 2-round split forks the cohort; after the
  partition lifts, every replica must land on the fork-choice winner
  within ``rejoin_budget`` extra rounds (the CI gate), with the minority
  replaying to state bit-equal to a from-scratch replay of the winning
  chain;
- **byzantine head**: an equivocating head must be *contained* in every
  seeded run — detected by every honest replica, evidence sealed
  on-chain, none of its blocks canonicalized — fraction must be 1.0.

Derived CSV rows report messages delivered per settled round (the gossip
overhead of the settlement layer) alongside the reliability fractions.
"""
from __future__ import annotations

import time

from benchmarks.common import bench_json, csv_row
from repro.net import LinkSpec, NetworkHarness, contract_fingerprint, \
    replay_chain


def _fingerprints_equal(nodes) -> bool:
    fps = [contract_fingerprint(n.contract) for n in nodes]
    return all(fp == fps[0] for fp in fps[1:])


def run(seeds: int = 20, rounds: int = 4, rejoin_budget: int = 2,
        loss: float = 0.1, json_name: str = "network_reliability"):
    t_start = time.monotonic()

    # -- fault-free convergence under lossy links ----------------------------
    ff_converged = 0
    ff_msgs = ff_rounds = 0
    for seed in range(seeds):
        h = NetworkHarness(3, seed=seed,
                           link=LinkSpec(latency=0.02, jitter=0.02,
                                         loss=loss))
        h.run(rounds)
        h.sync()
        ok = h.converged() and _fingerprints_equal(h.nodes)
        ff_converged += ok
        ff_msgs += h.net.delivered
        ff_rounds += rounds
    ff_frac = ff_converged / seeds
    csv_row("net_fault_free_converged_frac", 0.0, f"{ff_frac:.2f}")
    csv_row("net_msgs_per_round", 0.0, f"{ff_msgs / ff_rounds:.0f}")

    # -- partition → forks → rejoin ------------------------------------------
    rejoin_rounds = []
    replay_ok = 0
    for seed in range(seeds):
        h = NetworkHarness(3, seed=seed,
                           partition_rounds=[(1, 3, ((0, 1), (2,)))])
        h.run(3)                     # rounds 1-2 run split: forks exist
        used = rejoin_budget + 1     # pessimistic: did not converge
        for extra in range(1, rejoin_budget + 1):
            h.run(1)
            if h.converged() and _fingerprints_equal(h.nodes):
                used = extra
                break
        rejoin_rounds.append(used)
        # minority state bit-equal to a from-scratch replay of the winner
        n = h.nodes[2]
        _, replayed = replay_chain(n.ledger.blocks, n.ledger._commits,
                                   h.workers_per_node)
        replay_ok += (contract_fingerprint(replayed)
                      == contract_fingerprint(n.contract))
    rejoin_max = max(rejoin_rounds)
    rejoin_mean = sum(rejoin_rounds) / seeds
    replay_frac = replay_ok / seeds
    csv_row("net_rejoin_rounds_max", 0.0, str(rejoin_max))
    csv_row("net_rejoin_rounds_mean", 0.0, f"{rejoin_mean:.2f}")
    csv_row("net_rejoin_replay_bitequal_frac", 0.0, f"{replay_frac:.2f}")

    # -- byzantine equivocating head -----------------------------------------
    contained = 0
    for seed in range(seeds):
        byz = 1
        h = NetworkHarness(3, seed=seed, byzantine={byz: "equivocate"})
        h.run(rounds)
        honest = h.honest_nodes()
        ok = h.converged() and _fingerprints_equal(honest)
        for n in honest:
            txs = [tx for b in n.ledger.blocks for tx in b.transactions
                   if isinstance(tx, dict)]
            ok &= n.evidence_found >= 1
            ok &= any(tx.get("type") == "equivocation"
                      and tx["proposer"] == byz for tx in txs)
            ok &= all(tx["proposer"] != byz for tx in txs
                      if tx.get("type") == "seal")
        contained += ok
    byz_frac = contained / seeds
    csv_row("net_byzantine_contained_frac", 0.0, f"{byz_frac:.2f}")

    wall_s = time.monotonic() - t_start
    payload = {
        "seeds": seeds,
        "rounds": rounds,
        "link_loss": loss,
        "fault_free_converged_frac": ff_frac,
        "msgs_per_round": ff_msgs / ff_rounds,
        "rejoin_budget_rounds": rejoin_budget,
        "rejoin_rounds_max": rejoin_max,
        "rejoin_rounds_mean": rejoin_mean,
        "rejoin_replay_bitequal_frac": replay_frac,
        "byzantine_contained_frac": byz_frac,
        "wall_s": round(wall_s, 2),
        "gates": {
            "fault_free_converged_frac": 1.0,
            "rejoin_rounds_max<=": rejoin_budget,
            "rejoin_replay_bitequal_frac": 1.0,
            "byzantine_contained_frac": 1.0,
        },
    }
    bench_json(json_name, payload)

    assert ff_frac == 1.0, f"fault-free convergence broke: {ff_frac}"
    assert rejoin_max <= rejoin_budget, \
        f"rejoin took {rejoin_max} rounds (budget {rejoin_budget})"
    assert replay_frac == 1.0, f"replay bit-equality broke: {replay_frac}"
    assert byz_frac == 1.0, f"byzantine head escaped: {byz_frac}"
    return payload


if __name__ == "__main__":
    run()
