"""Paper Fig. 2 — accuracy AND wall-time, 3 workers, with/without blockchain.

Paper claim: accuracy identical with/without blockchain; the blockchain
variant costs more time per round. Our reproduction runs the SAME seeds so
learning dynamics are bit-identical; the chain adds hashing/contract/IPFS
work measured separately.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, paper_protocol, run_rounds
from repro.data.datasets import make_federated_mnist


def run(rounds: int = 60, samples: int = 2048, seed: int = 0):
    results, settle = {}, {}
    for chain in (True, False):
        ds = make_federated_mnist(3, samples=samples, seed=seed)
        proto = paper_protocol(3, blockchain=chain, seed=seed)
        log = run_rounds(proto, ds, rounds, eval_every=max(rounds // 10, 1))
        proto.finalize()            # drains the settler: settle_time final
        key = "with" if chain else "without"
        results[key] = log
        settle[key] = float(np.mean([r.settle_time for r in proto.history]))
    on, off = results["with"], results["without"]
    acc_gap = max(abs(a["accuracy"] - b["accuracy"]) for a, b in zip(on, off))
    t_on = float(np.mean([r["round_time"] for r in on]))
    t_off = float(np.mean([r["round_time"] for r in off]))
    # training-thread chain cost is the settler queue handoff only; the real
    # per-round chain work (IPFS + contract + Merkle) is the settler-thread
    # settle_time
    handoff_on = float(np.mean([r["chain_time"] for r in on]))
    chain_on, chain_off = settle["with"], settle["without"]
    csv_row("fig2_round_time_with_chain", t_on * 1e6,
            f"acc={on[-1]['accuracy']:.3f} settle_us={chain_on * 1e6:.0f} "
            f"handoff_us={handoff_on * 1e6:.1f}")
    csv_row("fig2_round_time_without_chain", t_off * 1e6,
            f"acc={off[-1]['accuracy']:.3f}")
    csv_row("fig2_accuracy_gap", 0.0, f"max_gap={acc_gap:.6f}")
    csv_row("fig2_chain_overhead_pct", chain_on * 1e6,
            f"{chain_on / max(t_on, 1e-9) * 100:.2f}% of round, "
            f"off the training thread")
    assert acc_gap < 1e-6, "learning dynamics must be chain-independent"
    # the chain's extra work is measured directly on the settler thread
    # (hashing + contract + IPFS); comparing total wall-time is
    # noise-dominated on CPU at this model size, the paper's "with chain is
    # slower" trend is the positive per-round settle_time
    assert chain_on > 10 * chain_off   # chain work is real, off-path ~0
    return {"with": on, "without": off, "acc_gap": acc_gap,
            "settle_s": settle,
            "overhead_pct": chain_on / max(t_on, 1e-9) * 100}


def run_pipeline_depths(depths=(0, 1, 2, 4), rounds: int = 20,
                        samples: int = 1024, seed: int = 0):
    """Pipeline-depth sweep: identical chains at every depth (the settler
    preserves decision sequences), while the chain cost charged to the
    training thread collapses from the full settlement (depth 0, inline)
    to the queue handoff (depth > 0, background settler)."""
    from repro.configs.base import FederationConfig
    from repro.configs.registry import get_config
    from repro.core.protocol import SDFLBProtocol

    from benchmarks.common import PAPER_TC

    out = {}
    chains = {}
    for depth in depths:
        ds = make_federated_mnist(3, samples=samples, seed=seed)
        fed = FederationConfig(num_clusters=1, workers_per_cluster=3,
                               trust_threshold=0.2, pipeline_depth=depth)
        proto = SDFLBProtocol(get_config("paper-net"), fed, PAPER_TC,
                              use_blockchain=True, seed=seed)
        for _ in range(rounds):
            proto.run_round(ds.round_batches(32))
        proto.finalize()
        train_chain = float(np.mean([r.chain_time for r in proto.history]))
        settle_t = float(np.mean([r.settle_time for r in proto.history]))
        out[depth] = {"train_thread_chain_s": train_chain,
                      "settler_thread_s": settle_t}
        chains[depth] = [b.hash for b in proto.ledger.blocks]
        csv_row(f"fig2_pipeline_depth{depth}", train_chain * 1e6,
                f"settler_us={settle_t * 1e6:.0f} "
                f"{'inline' if depth == 0 else 'threaded'}")
    # decisions are depth-independent (byte-identical chains) ...
    assert all(c == chains[depths[0]] for c in chains.values())
    # ... and the threaded settler hides the chain work: the training
    # thread pays the queue handoff, a fraction of the inline settlement
    threaded = min(out[d]["train_thread_chain_s"] for d in depths if d > 0)
    assert threaded < 0.5 * out[0]["train_thread_chain_s"], \
        f"threaded handoff must beat inline settlement: {out}"
    return out


def run_sharded_pipeline(shard_counts=(1, 2, 4), rounds: int = 12,
                         samples: int = 768, seed: int = 0):
    """End-to-end settler-pool sweep on the paper protocol: every
    (pipeline_depth > 0, settlement_shards) combination seals the
    byte-identical chain as the serial unsharded driver — the shard pool
    changes who computes, never what is decided — while the training
    thread keeps paying only the queue handoff."""
    import dataclasses

    from repro.configs.base import FederationConfig
    from repro.configs.registry import get_config
    from repro.core.protocol import SDFLBProtocol

    from benchmarks.common import PAPER_TC

    base = FederationConfig(num_clusters=2, workers_per_cluster=3,
                            trust_threshold=0.2, merkle_chunk_size=1)
    chains, out = {}, {}
    configs = [("serial", 0, 1)] + [(f"s{S}", 2, S) for S in shard_counts]
    for name, depth, S in configs:
        ds = make_federated_mnist(6, samples=samples, seed=seed)
        fed = dataclasses.replace(base, pipeline_depth=depth,
                                  settlement_shards=S)
        proto = SDFLBProtocol(get_config("paper-net"), fed, PAPER_TC,
                              use_blockchain=True, seed=seed)
        for _ in range(rounds):
            proto.run_round(ds.round_batches(32))
        proto.finalize()
        chains[name] = [b.hash for b in proto.ledger.blocks]
        handoff = float(np.mean([r.chain_time for r in proto.history]))
        out[name] = handoff
        csv_row(f"fig2_sharded_pipeline_{name}", handoff * 1e6,
                f"depth={depth} shards={S}")
    assert all(c == chains["serial"] for c in chains.values()), \
        "settler-pool chains must be byte-identical to the serial driver"
    return out


def run_settlement_paths(W: int = 5_000, rounds: int = 5, seed: int = 0):
    """Batch vs legacy-scalar settlement cost on identical score streams:
    the scalar dict API (kept as a wrapper for Algorithm 1 equivalence)
    pays O(W) Python dict work per round; the array path pays O(1) Python
    + vectorized numpy. Reported as fig2 rows since this is exactly the
    chain-side wall-time the with-blockchain variant adds per round."""
    import time

    from repro.chain.contract import TrustContract
    from repro.chain.ledger import Ledger

    rng = np.random.default_rng(seed)
    score_mat = rng.random((rounds, W))

    def make():
        c = TrustContract(Ledger(), requester_deposit=1e5, worker_stake=10.0,
                          penalty_pct=50.0, trust_threshold=0.5, top_k=10)
        c.join_batch(W)
        return c

    c_scalar, c_batch = make(), make()
    t0 = time.monotonic()
    for r in range(rounds):
        c_scalar.settle_round(
            r, {f"worker-{w}": float(score_mat[r, w]) for w in range(W)})
    t_scalar = (time.monotonic() - t0) / rounds
    t0 = time.monotonic()
    for r in range(rounds):
        c_batch.settle_round_batch(r, score_mat[r])
    t_batch = (time.monotonic() - t0) / rounds
    # both paths settle identically (the equivalence property the tests pin)
    np.testing.assert_allclose(c_scalar.stake, c_batch.stake)
    assert abs(c_scalar.total_value() - c_batch.total_value()) < 1e-6
    csv_row("fig2_settle_scalar_path", t_scalar * 1e6, f"W={W}")
    csv_row("fig2_settle_batch_path", t_batch * 1e6,
            f"W={W} speedup={t_scalar / t_batch:.1f}x")
    assert t_batch < t_scalar, "array path must beat per-worker dict loops"
    return {"scalar_s": t_scalar, "batch_s": t_batch}


if __name__ == "__main__":
    import json
    run_settlement_paths()
    run_pipeline_depths()
    run_sharded_pipeline()
    print(json.dumps(run()["with"][-1], indent=1))
