"""Trust penalization ablation (paper §VI.A/B, quantified).

Label-flipping adversaries among the workers; compare final global accuracy
and on-chain penalties WITH the trust mechanism (threshold + soft weights)
vs WITHOUT (threshold 0, uniform weights). Claim to validate: penalization
filters malicious updates and protects model quality."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import csv_row, paper_protocol, run_rounds
from repro.data.datasets import make_federated_mnist


def _flip_adversary(bad_workers):
    def adversary(batch, round_index):
        labels = batch["labels"]
        for w in bad_workers:
            labels = labels.at[w].set(9 - labels[w])
        return {**batch, "labels": labels}
    return adversary


def run(rounds: int = 50, samples: int = 4096, W: int = 8, n_bad: int = 2,
        seed: int = 0):
    bad = list(range(n_bad))
    out = {}
    for trust_on in (True, False):
        ds = make_federated_mnist(W, samples=samples, seed=seed)
        proto = paper_protocol(
            W, clusters=2, seed=seed, adversary=_flip_adversary(bad),
            trust_threshold=0.45 if trust_on else -1.0)
        if not trust_on:
            proto.fed = dataclasses.replace(proto.fed,
                                            soft_trust_weighting=False)
        log = run_rounds(proto, ds, rounds, eval_every=rounds)
        proto.flush()   # pipelined driver: settle the trailing round first
        pen = {w: proto.contract.workers[f"worker-{w}"].penalized_rounds
               for w in range(W)}
        proto.finalize()
        out["on" if trust_on else "off"] = {
            "accuracy": log[-1]["accuracy"], "penalized": pen}
    acc_on, acc_off = out["on"]["accuracy"], out["off"]["accuracy"]
    pen_on = out["on"]["penalized"]
    bad_pen = np.mean([pen_on[w] for w in bad])
    good_pen = np.mean([pen_on[w] for w in range(n_bad, W)])
    csv_row("trust_ablation_acc_with_trust", 0.0, f"acc={acc_on:.3f}")
    csv_row("trust_ablation_acc_without", 0.0, f"acc={acc_off:.3f}")
    csv_row("trust_ablation_bad_vs_good_penalties", 0.0,
            f"bad={bad_pen:.1f} good={good_pen:.1f}")
    assert bad_pen > good_pen, "adversaries must be penalized more"
    assert acc_on >= acc_off - 0.02, "trust weighting must not hurt accuracy"
    return out


if __name__ == "__main__":
    run(rounds=25, samples=2048)
