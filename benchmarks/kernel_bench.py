"""Pallas kernel micro-benchmarks.

On this CPU container the kernels execute in interpret mode — timings are
NOT TPU-representative (documented); the derived column reports the
modeled TPU-v5e time from bytes/bandwidth, which is what §Roofline uses.
The jnp oracle is timed for a like-for-like CPU comparison."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, timeit
from repro.kernels import ops, ref

HBM_BW = 819e9


def run():
    key = jax.random.PRNGKey(0)
    W, D = 16, 1 << 20
    u = jax.random.normal(key, (W, D), jnp.bfloat16)
    wts = jax.random.uniform(jax.random.fold_in(key, 1), (W,))

    jd = jax.jit(ref.trust_agg_ref)
    us = timeit(jd, u, wts, iters=5)
    model_us = (W * D * 2) / HBM_BW * 1e6
    csv_row("trust_agg_jnp_cpu", us, f"modeled_v5e_us={model_us:.1f}")
    us = timeit(lambda a, b: ops.trust_weighted_aggregate(a, b), u, wts,
                iters=2, warmup=1)
    csv_row("trust_agg_pallas_interpret", us, "CPU interpret (not TPU perf)")

    js = jax.jit(ref.trust_score_ref)
    us = timeit(js, u, iters=5)
    csv_row("trust_score_jnp_cpu", us, f"modeled_v5e_us={model_us:.1f}")

    B, H, KV, hd, S, win = 4, 32, 8, 128, 32768, 4096
    q = jax.random.normal(key, (B, H, hd), jnp.bfloat16)
    kc = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd), jnp.bfloat16)
    vc = jax.random.normal(jax.random.fold_in(key, 3), (B, S, KV, hd), jnp.bfloat16)
    jr = jax.jit(lambda q, k, v: ref.swa_decode_ref(q, k, v, S - 1, win))
    us = timeit(jr, q, kc, vc, iters=3)
    win_bytes = B * win * KV * hd * 2 * 2
    full_bytes = B * S * KV * hd * 2 * 2
    csv_row("swa_decode_jnp_fullscan_cpu", us,
            f"modeled_v5e_us={full_bytes / HBM_BW * 1e6:.1f}")
    csv_row("swa_decode_kernel_window_model", 0.0,
            f"modeled_v5e_us={win_bytes / HBM_BW * 1e6:.1f} "
            f"({S / win:.0f}x less HBM than full scan)")
    return True


if __name__ == "__main__":
    run()
