"""Pallas kernel micro-benchmarks.

On this CPU container the kernels execute in interpret mode — timings are
NOT TPU-representative (documented); the derived column reports the
modeled TPU-v5e time from bytes/bandwidth, which is what §Roofline uses.
The jnp oracle is timed for a like-for-like CPU comparison."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_json, csv_row, timeit
from repro.kernels import ops, ref

HBM_BW = 819e9


def run():
    key = jax.random.PRNGKey(0)
    W, D = 16, 1 << 20
    u = jax.random.normal(key, (W, D), jnp.bfloat16)
    wts = jax.random.uniform(jax.random.fold_in(key, 1), (W,))

    jd = jax.jit(ref.trust_agg_ref)
    us = timeit(jd, u, wts, iters=5)
    model_us = (W * D * 2) / HBM_BW * 1e6
    csv_row("trust_agg_jnp_cpu", us, f"modeled_v5e_us={model_us:.1f}")
    us = timeit(lambda a, b: ops.trust_weighted_aggregate(a, b), u, wts,
                iters=2, warmup=1)
    csv_row("trust_agg_pallas_interpret", us, "CPU interpret (not TPU perf)")

    js = jax.jit(ref.trust_score_ref)
    us = timeit(js, u, iters=5)
    csv_row("trust_score_jnp_cpu", us, f"modeled_v5e_us={model_us:.1f}")

    B, H, KV, hd, S, win = 4, 32, 8, 128, 32768, 4096
    q = jax.random.normal(key, (B, H, hd), jnp.bfloat16)
    kc = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd), jnp.bfloat16)
    vc = jax.random.normal(jax.random.fold_in(key, 3), (B, S, KV, hd), jnp.bfloat16)
    jr = jax.jit(lambda q, k, v: ref.swa_decode_ref(q, k, v, S - 1, win))
    us = timeit(jr, q, kc, vc, iters=3)
    win_bytes = B * win * KV * hd * 2 * 2
    full_bytes = B * S * KV * hd * 2 * 2
    csv_row("swa_decode_jnp_fullscan_cpu", us,
            f"modeled_v5e_us={full_bytes / HBM_BW * 1e6:.1f}")
    csv_row("swa_decode_kernel_window_model", 0.0,
            f"modeled_v5e_us={win_bytes / HBM_BW * 1e6:.1f} "
            f"({S / win:.0f}x less HBM than full scan)")
    return True


def run_fused_round(worker_counts=(256, 1024, 4096, 10240), *, e2e=True,
                    wall_gate=True, json_name="fused_round"):
    """Fused flat-pack trust round vs the per-leaf reference on the paper
    CNN's shapes (D=21840 f32), swept over cohort sizes up to the
    10k-client target.

    Per W: CPU wall time of both step-3–5 pipelines (stats → scores →
    weights → aggregate), the unfused path's streamed passes over the W×D
    update volume as XLA's ``cost_analysis`` counts them (operand bytes
    per op — fusion dedup is invisible to it, so this is an upper-bound
    style count and is only used for the *unfused* side), the fused
    chain's passes from exact BlockSpec-geometry accounting
    (``fused_round.update_passes`` — the ≤2 gate), and modeled TPU-v5e
    time from bytes/bandwidth. Gates (CI): fused passes ≤ 2 and no
    CPU wall regression of the default path (fused ≤ 1.15× unfused at
    the largest W ≤ 4096 — interpret-mode Pallas is NOT on this path;
    on CPU the fused chain dispatches to the identical flat-jnp math).
    """
    from repro.compat.xla import normalize_cost_analysis
    from repro.configs.base import FederationConfig, TrainConfig
    from repro.configs.registry import get_config
    from repro.core import fl_step, hierarchy, trust
    from repro.kernels import fused_round, pack
    from repro.models import api

    cfg = get_config("paper-net")
    key = jax.random.PRNGKey(0)
    gp, _ = api.init(cfg, key, tp=1)
    spec = pack.pack_spec(gp)
    D = spec.total
    payload = {"D": D, "dtype": "float32", "sweep": [],
               "gates": {"fused_passes_max": 2.0,
                         "wall_ratio_max": 1.15 if wall_gate else None}}

    for W in worker_counts:
        fed = FederationConfig(num_clusters=1, workers_per_cluster=W,
                               trust_threshold=0.2)
        kw = jax.random.fold_in(key, W)
        flat = jax.random.normal(kw, (W, D), jnp.float32) * 0.01
        upd = pack.unpack_stack(flat, spec)
        lb = jax.random.uniform(jax.random.fold_in(kw, 1), (W,)) + 1.0
        la = lb - 0.1

        def per_leaf(upd, lb, la, fed=fed):
            s = trust.scores_from_stats(trust.update_stats(upd, lb, la), fed)
            w = trust.trust_weights(s, fed)
            return hierarchy.aggregate_fused(upd, w)

        def fused(flat, lb, la, fed=fed):
            s = trust.scores_from_stats(
                trust.update_stats_flat(flat, lb, la), fed)
            w = trust.trust_weights(s, fed)
            return ops.fused_agg(flat, w)

        iters = 2 if W >= 4096 else 5
        unfused_us = timeit(jax.jit(per_leaf), upd, lb, la,
                            iters=iters, warmup=1)
        fused_us = timeit(jax.jit(fused), flat, lb, la,
                          iters=iters, warmup=1)
        cost = normalize_cost_analysis(
            jax.jit(per_leaf).lower(upd, lb, la).compile().cost_analysis())
        vol = W * D * 4
        unfused_passes = cost.get("bytes accessed", 0.0) / vol
        fused_passes = fused_round.update_passes(W, D, jnp.float32)
        model_fused_us = fused_round.streamed_bytes(
            W, D, jnp.float32)["total"] / HBM_BW * 1e6
        model_unfused_us = unfused_passes * vol / HBM_BW * 1e6
        row = {"W": W, "unfused_us": unfused_us, "fused_us": fused_us,
               "unfused_passes_cost_analysis": unfused_passes,
               "fused_passes_analytic": fused_passes,
               "modeled_v5e_us_unfused": model_unfused_us,
               "modeled_v5e_us_fused": model_fused_us}
        payload["sweep"].append(row)
        csv_row(f"fused_round_W{W}_unfused_jnp_cpu", unfused_us,
                f"passes~{unfused_passes:.2f} (cost_analysis) "
                f"modeled_v5e_us={model_unfused_us:.1f}")
        csv_row(f"fused_round_W{W}_fused_flat_cpu", fused_us,
                f"passes={fused_passes:.2f} (BlockSpec-exact) "
                f"modeled_v5e_us={model_fused_us:.1f}")
        assert fused_passes <= 2.0, \
            f"fused chain streams the update volume {fused_passes}x > 2"

    # interpret-mode Pallas at the smallest W: kernel-correctness cost
    # only — Python-interpreted tiles, NOT representative of TPU perf
    Ws = worker_counts[0]
    flat_s = jax.random.normal(key, (Ws, D), jnp.float32)
    wt = jax.random.uniform(jax.random.fold_in(key, 1), (Ws,))
    us = timeit(lambda a, b: ops.trust_weighted_aggregate(a, b),
                flat_s, wt, iters=2, warmup=1)
    csv_row(f"fused_round_W{Ws}_pallas_interpret", us,
            "CPU interpret (not TPU perf)")

    if wall_gate:
        gate_rows = [r for r in payload["sweep"] if r["W"] <= 4096]
        r = gate_rows[-1]
        ratio = r["fused_us"] / r["unfused_us"]
        payload["gates"]["wall_ratio_measured"] = ratio
        assert ratio <= 1.15, \
            (f"fused path regressed the default round at W={r['W']}: "
             f"{r['fused_us']:.0f}us vs {r['unfused_us']:.0f}us")

    if e2e:
        # whole paper-CNN round, knob off vs on (auto==on for the CNN)
        W, B = 256, 32
        batch = {"images": jax.random.normal(key, (W, 1, B, 28, 28, 1)),
                 "labels": jax.random.randint(jax.random.fold_in(key, 2),
                                              (W, 1, B), 0, 10)}
        tc = TrainConfig()
        for knob in ("off", "on"):
            fed = FederationConfig(num_clusters=1, workers_per_cluster=W,
                                   trust_threshold=0.2,
                                   fused_trust_path=knob)
            opt = fl_step.init_worker_opt(gp, fed, tc)
            fn = jax.jit(fl_step.make_fl_round(cfg, fed, tc))
            us = timeit(fn, gp, opt, batch, jax.random.PRNGKey(1),
                        iters=3, warmup=1)
            payload[f"e2e_round_W{W}_{knob}_us"] = us
            csv_row(f"fused_round_e2e_W{W}_knob_{knob}", us, "full round")

    bench_json(json_name, payload)
    return payload


if __name__ == "__main__":
    run()
    run_fused_round()
