"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.configs.base import FederationConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core.protocol import SDFLBProtocol

PAPER_TC = TrainConfig(lr=0.01, momentum=0.5, optimizer="sgd", remat=False)


def paper_protocol(workers: int, *, clusters: int = 1, blockchain: bool = True,
                   seed: int = 0, trust_threshold: float = 0.2,
                   adversary=None, async_mode: bool = False,
                   penalty_pct: float = 50.0, arrival_profiles=None,
                   **fed_kw) -> SDFLBProtocol:
    """``fed_kw`` forwards extra FederationConfig knobs (buffer_size,
    max_wait, sparse_settlement, ...); ``arrival_profiles`` plus
    ``async_mode=True`` makes the protocol event-drivable (run_events)."""
    fed = FederationConfig(num_clusters=clusters,
                           workers_per_cluster=workers // clusters,
                           trust_threshold=trust_threshold,
                           penalty_pct=penalty_pct,
                           async_mode=async_mode, **fed_kw)
    return SDFLBProtocol(get_config("paper-net"), fed, PAPER_TC,
                         use_blockchain=blockchain, seed=seed,
                         adversary=adversary,
                         arrival_profiles=arrival_profiles)


def run_rounds(proto, ds, rounds: int, batch: int = 32, eval_every: int = 0,
               participation_fn=None) -> List[Dict]:
    """Returns per-eval records {round, accuracy, loss, round_time,...}."""
    ev = ds.eval_batch(512)
    log = []
    for r in range(rounds):
        part = participation_fn(r) if participation_fn else None
        t0 = time.monotonic()
        rec = proto.run_round(ds.round_batches(batch), participation=part)
        dt = time.monotonic() - t0
        if eval_every and ((r + 1) % eval_every == 0 or r == rounds - 1):
            m = proto.evaluate(ev)
            log.append({"round": r + 1, "accuracy": m["accuracy"],
                        "loss": m["loss"], "round_time": dt,
                        "chain_time": rec.chain_time,
                        "mean_score": float(np.mean(rec.scores))})
    return log


def timeit(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """us per call."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters * 1e6


def csv_row(name: str, us: float, derived: str = "") -> str:
    row = f"{name},{us:.1f},{derived}"
    print(row)
    return row


def bench_json(name: str, payload: Dict, directory: str = ".") -> str:
    """Write ``BENCH_<name>.json`` — the machine-readable benchmark artifact
    the CI benchmarks job uploads (and the repo commits) so the perf
    trajectory is diffable across PRs."""
    import json
    import pathlib

    path = pathlib.Path(directory) / f"BENCH_{name}.json"
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    print(f"wrote {path}")
    return str(path)
