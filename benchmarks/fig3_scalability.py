"""Paper Fig. 3 — scalability: average accuracy vs epoch for 8/16/20
workers. Claim: consistent accuracy trends across worker counts.

Extended with a chain-only settlement scaling sweep (``run_chain_scaling``)
to W ≥ 100k workers: the array-native contract settles a round in O(1)
Python ops + O(W) vectorized numpy/hashing, so per-worker settlement cost
*falls* with W (sub-linear total Python overhead) and a 100k-worker round
stays under 1s on CPU — the regime the ROADMAP's millions-of-users
north-star needs, far beyond the paper's W=20. ``run_merkle_chunk_sweep``
isolates the commit itself: chunked leaves (k records per leaf) hash
~2·W/k nodes instead of ~2·W, which removed the last O(W)·SHA-256 host
cost on the settlement path. ``run_sparse_settlement`` takes the last
step to W=1M: with ≤10% of workers active per tick, sparse delta commits
re-hash only the dirty chunk paths (O(C·log(W/k)) instead of O(W/k)), so
a million-worker round settles in delta time proportional to *activity*,
not population — reported per changed record alongside per worker."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_json, csv_row, paper_protocol, run_rounds
from repro.data.datasets import make_federated_mnist


def run(rounds: int = 60, samples: int = 4096, seed: int = 0,
        worker_counts=(8, 16, 20)):
    curves = {}
    for W in worker_counts:
        ds = make_federated_mnist(W, samples=samples, seed=seed)
        clusters = 2 if W % 2 == 0 else 1
        proto = paper_protocol(W, clusters=clusters, seed=seed)
        log = run_rounds(proto, ds, rounds, eval_every=max(rounds // 10, 1))
        proto.finalize()
        curves[W] = log
        csv_row(f"fig3_final_accuracy_w{W}", 0.0,
                f"acc={log[-1]['accuracy']:.3f}")
    finals = [curves[W][-1]["accuracy"] for W in worker_counts]
    spread = max(finals) - min(finals)
    csv_row("fig3_accuracy_spread_across_W", 0.0, f"spread={spread:.4f}")
    # scalability claim: all configs converge to a similar band
    assert spread < 0.15, f"accuracy should be consistent across W: {finals}"
    return curves


def run_merkle_chunk_sweep(worker_count: int = 100_000,
                           chunk_sizes=(1, 8, 64, 256), repeats: int = 3,
                           seed: int = 0):
    """Merkle-commit cost vs chunk size at fixed W: building the commit
    tree over one round's settlement records with k records per leaf. Pins
    the chunked-leaves claim — the k=64 default must cut commit time ≥5×
    versus the per-record (k=1, PR-1) commit at W=100k — and checks every
    chunking still proves and verifies an arbitrary record."""
    from repro.chain.contract import encode_settlement_records
    from repro.chain.ledger import MerkleTree

    rng = np.random.default_rng(seed)
    W = worker_count
    scores = rng.random(W)
    records = encode_settlement_records(0, np.arange(W), scores,
                                        np.zeros(W), np.full(W, 10.0))
    t_commit = {}
    for k in chunk_sizes:
        times, tree = [], None
        for _ in range(repeats):
            t0 = time.monotonic()
            tree = MerkleTree(records, chunk_size=k)
            times.append(time.monotonic() - t0)
        t_commit[k] = float(np.median(times))
        # an arbitrary record stays auditable: chunk + node path
        widx = W // 3
        start = (widx // k) * k
        chunk = records.chunk_bytes(start, min(start + k, W))
        assert MerkleTree.verify(chunk, tree.record_proof(widx), tree.root)
        csv_row(f"fig3_merkle_commit_w{W}_k{k}", t_commit[k] * 1e6,
                f"leaves={tree.num_leaves} hash_ops={tree.hash_ops}")
    bench_json("merkle_chunk_sweep",
               {"worker_count": W,
                "commit_s": {str(k): t for k, t in t_commit.items()}})
    if 1 in t_commit and 64 in t_commit:
        speedup = t_commit[1] / t_commit[64]
        csv_row(f"fig3_merkle_chunk_speedup_w{W}", 0.0,
                f"k64_vs_k1={speedup:.1f}x")
        assert speedup >= 5.0, \
            f"chunked commit must be >=5x faster than per-record: {t_commit}"
    return t_commit


def run_sharded_settlement(worker_count: int = 100_000,
                           shard_counts=(1, 4, 8), rounds: int = 7,
                           chunk_sizes=(64, 256, 4096), pool_size: int = 0,
                           seed: int = 0,
                           json_name: str = "sharded_settlement"):
    """Sharded settlement sweep at fixed W: a full Algorithm 1 round
    (slice settlement + per-shard subtree hashing + super-root block seal)
    per (chunk size k, shard count S), shards fanned out to a
    ``ShardWorkerPool``.

    Claims pinned: (1) every (k, S) seals the *byte-identical* chain per k
    — the subtree-aligned super-root makes shard count a node-local
    execution detail, not a consensus change; (2) at a parallel-friendly
    chunk size (leaves >= ``MIN_PARALLEL_LEAF_BYTES``, where each leaf
    hash's GIL-released window amortizes the acquire/release handoff)
    wall-time improves measurably at S >= 4 versus the serial S=1 settle;
    (3) at the small default leaves (k=64) the contract *refuses* to fan
    out — concurrent micro-hashing convoys on the GIL — so the pool never
    regresses the default path (pooled ≈ serial, asserted with slack).
    Writes ``BENCH_<json_name>.json`` for the perf trajectory."""
    import os

    from repro.chain.contract import MIN_PARALLEL_LEAF_BYTES, TrustContract
    from repro.chain.ledger import Ledger
    from repro.core.protocol import ShardWorkerPool

    W = worker_count
    rng = np.random.default_rng(seed)
    score_mat = rng.random((rounds, W))
    pool = ShardWorkerPool(pool_size or min(max(shard_counts),
                                            os.cpu_count() or 1))
    from repro.chain.contract import _RECORD_DTYPE
    record_size = _RECORD_DTYPE.itemsize  # tracks the on-chain record layout
    t_settle = {}
    try:
        for k in chunk_sizes:
            chains = {}
            for S in shard_counts:
                led = Ledger()
                c = TrustContract(led, requester_deposit=1e6,
                                  worker_stake=10.0, penalty_pct=50.0,
                                  trust_threshold=0.5,
                                  top_k=max(W // 100, 1),
                                  merkle_chunk_size=k, settlement_shards=S)
                c.join_batch(W)
                times = []
                for r in range(rounds):
                    t0 = time.monotonic()
                    c.settle_round_batch(r, score_mat[r],
                                         timestamp=float(r + 1),
                                         pool=pool if S > 1 else None)
                    times.append(time.monotonic() - t0)
                t_settle[(k, S)] = float(np.median(times[1:] or times))
                chains[S] = [b.hash for b in led.blocks]
                assert led.verify_chain(deep=True)
                fanout = led.num_shards(1) > 1 and \
                    k * record_size >= MIN_PARALLEL_LEAF_BYTES and S > 1
                csv_row(f"fig3_sharded_settle_w{W}_k{k}_s{S}",
                        t_settle[(k, S)] * 1e6,
                        f"shards={led.num_shards(1)} "
                        f"{'parallel' if fanout else 'inline'} "
                        f"per_worker_us={t_settle[(k, S)] / W * 1e6:.3f}")
            # consensus is shard-count independent: byte-identical chains
            first = shard_counts[0]
            assert all(chains[S] == chains[first] for S in shard_counts), \
                f"sharded chains must be byte-identical across S (k={k})"
    finally:
        pool.stop()
    payload = {"worker_count": W, "rounds": rounds,
               "record_size": record_size,
               "min_parallel_leaf_bytes": MIN_PARALLEL_LEAF_BYTES,
               "settle_s": {f"k{k}_s{S}": t for (k, S), t
                            in t_settle.items()},
               "cpu_count": os.cpu_count()}
    out = {"settle_s": t_settle, "chains_identical": True}
    parallel_ks = [k for k in chunk_sizes
                   if k * record_size >= MIN_PARALLEL_LEAF_BYTES]
    if 1 in shard_counts and parallel_ks:
        # strict-win gate only at the LARGEST parallel leaves: with the
        # retuned 4 KiB threshold, mid-size leaves (k=256 -> 10 KiB) are
        # *allowed* to fan out — each leaf hash clears hashlib's 2 KiB
        # GIL-release floor — but their win is runner-dependent, so they
        # only carry a no-regress bound below
        k = max(parallel_ks)
        serial = t_settle[(k, 1)]
        best = min(t_settle[(k, S)] for S in shard_counts if S >= 4)
        payload["parallel_speedup"] = {"chunk_size": k,
                                       "serial_s": serial, "best_s": best,
                                       "speedup": serial / best}
        csv_row(f"fig3_sharded_speedup_w{W}_k{k}", 0.0,
                f"best_S>=4_vs_serial={serial / best:.2f}x")
        # the win must be measurable (not asserting a large factor: CI
        # runners may expose as few as 2 often-throttled cores; a 1-core
        # box has no parallelism to win with, so only the no-regress
        # bounds apply there)
        if (os.cpu_count() or 1) >= 2:
            assert best < 0.95 * serial, \
                f"S>=4 settlement must beat serial at k={k}: {t_settle}"
        out["parallel_speedup"] = serial / best
        for k2 in parallel_ks:
            if k2 == k:
                continue
            worst = max(t_settle[(k2, S)] for S in shard_counts)
            assert worst < 1.5 * t_settle[(k2, 1)], \
                f"newly-parallel k={k2} must not regress serial: {t_settle}"
    small_ks = [k for k in chunk_sizes
                if k * record_size < MIN_PARALLEL_LEAF_BYTES]
    if 1 in shard_counts and small_ks:
        k = small_ks[0]
        worst = max(t_settle[(k, S)] for S in shard_counts)
        # below the leaf threshold the pool must not engage — sharded
        # settle stays within noise of serial instead of convoying
        assert worst < 1.5 * t_settle[(k, 1)], \
            f"gated fan-out must not regress small-leaf settles: {t_settle}"
    bench_json(json_name, payload)
    out["payload"] = payload
    return out


def run_sparse_settlement(worker_count: int = 1_000_000,
                          active_frac: float = 0.10, rounds: int = 6,
                          chunk_size: int = 64,
                          patterns=("cohort", "random"), seed: int = 0,
                          deep_verify: bool = True,
                          measure_dense_full: bool = True,
                          headline_budget_s=0.1, delta_gate_ratio=3.0,
                          json_name: str = "sparse_settlement"):
    """Million-worker sparse settlement sweep: W workers enrolled, only
    C = ``active_frac``·W settle per tick, each tick sealing a
    ``DeltaCommit`` block that still commits (and proves) the full
    population.

    Two activity patterns bound the delta cost:

    * ``cohort`` — contiguous disjoint cohorts rotate through the rounds
      (the paper's cluster-scheduled regime). Dirty chunk leaves = C/k, so
      delta hashing matches a dense commit over C records and the
      W=1M/10%-active tick lands under ``headline_budget_s`` (~100 ms on
      the 2-core CI class of box).
    * ``random`` — C uniform-random workers. At k=64 and 10% activity
      nearly *every* chunk is dirtied (E[dirty leaves] ≈ W/k), so the
      delta degenerates toward a full re-commit; reported honestly as the
      adversarial bound — the headline gates on ``cohort`` only.

    Costs are reported per *changed* record (delta_s/C — the number that
    must stay flat as W grows) alongside per enrolled worker (delta_s/W).
    The regression gate is *relative*: a cohort delta round touching C
    records must cost < ``delta_gate_ratio``× a dense round of a
    C-worker contract per record (pop-buffer scatter + overlay clone +
    O(C·log(W/k)) interior re-hash are the only extras). Extends
    ``BENCH_chain_scaling.json`` with the W row and writes
    ``BENCH_<json_name>.json``."""
    import os

    from repro.chain.contract import TrustContract
    from repro.chain.ledger import Ledger

    W = worker_count
    C = max(1, int(W * active_frac))
    k = chunk_size
    rng = np.random.default_rng(seed)

    def make(w, sparse):
        c = TrustContract(Ledger(), requester_deposit=1e6,
                          worker_stake=10.0, penalty_pct=50.0,
                          trust_threshold=0.5, top_k=max(w // 100, 1),
                          merkle_chunk_size=k, sparse_settlement=sparse)
        c.join_batch(w)
        return c

    # dense reference: a C-worker contract settling all C per round — the
    # per-record baseline the delta gate compares against
    dense_c = make(C, sparse=False)
    times = []
    for r in range(max(rounds, 2)):
        s = rng.random(C)
        t0 = time.monotonic()
        dense_c.settle_round_batch(r, s, timestamp=float(r + 1))
        times.append(time.monotonic() - t0)
    dense_at_active_s = float(np.median(times[1:]))
    csv_row(f"fig3_sparse_dense_ref_c{C}", dense_at_active_s * 1e6,
            f"per_record_us={dense_at_active_s / C * 1e6:.3f}")

    dense_at_full_s = None
    if measure_dense_full:
        # one dense full-population round at W — what every tick would
        # cost without the sparse path
        dense_w = make(W, sparse=False)
        times = []
        for r in range(2):
            s = rng.random(W)
            t0 = time.monotonic()
            dense_w.settle_round_batch(r, s, timestamp=float(r + 1))
            times.append(time.monotonic() - t0)
        dense_at_full_s = float(min(times))
        csv_row(f"fig3_sparse_dense_full_w{W}", dense_at_full_s * 1e6,
                f"per_worker_us={dense_at_full_s / W * 1e6:.3f}")

    anchor_s, delta_s, dirty = {}, {}, {}
    for pattern in patterns:
        c = make(W, sparse=True)
        times = []
        for r in range(rounds):
            if pattern == "cohort":
                start = (r % max(W // C, 1)) * C
                ids = np.arange(start, start + C, dtype=np.int64)
            else:
                ids = np.sort(rng.permutation(W)[:C]).astype(np.int64)
            s = rng.random(C)
            t0 = time.monotonic()
            c.settle_round_batch(r, s, worker_ids=ids, timestamp=float(r + 1))
            times.append(time.monotonic() - t0)
        # round 0 pays the dense anchor (the base commit over all W);
        # steady state is the delta rounds
        anchor_s[pattern] = times[0]
        delta_s[pattern] = float(np.median(times[1:] or times))
        dirty[pattern] = len(np.unique(ids // k))
        csv_row(f"fig3_sparse_settle_w{W}_{pattern}",
                delta_s[pattern] * 1e6,
                f"active={C} per_changed_us="
                f"{delta_s[pattern] / C * 1e6:.3f} per_worker_us="
                f"{delta_s[pattern] / W * 1e6:.4f} "
                f"dirty_leaves={dirty[pattern]}/{-(-W // k)} "
                f"anchor_s={anchor_s[pattern]:.3f}")
        # the full population stays proof-covered every delta round:
        # an active and an idle worker both verify against the last block
        last = rounds - 1
        active_w = int(ids[0])
        idle_w = int(np.setdiff1d(np.arange(C + 1, dtype=np.int64),
                                  ids[:C + 1])[0])
        for wid in (active_w, idle_w):
            assert c.verify_settlement(c.settlement_proof(last, wid)), \
                f"worker {wid} proof must verify ({pattern})"
        if deep_verify:
            assert c.ledger.verify_chain(deep=True), \
                f"sparse chain must deep-verify ({pattern})"

    if delta_gate_ratio and "cohort" in delta_s:
        per_changed = delta_s["cohort"] / C
        per_dense = dense_at_active_s / C
        csv_row(f"fig3_sparse_delta_gate_w{W}", 0.0,
                f"cohort_vs_dense_ref={per_changed / per_dense:.2f}x "
                f"(gate {delta_gate_ratio}x)")
        assert per_changed < delta_gate_ratio * per_dense, \
            f"cohort delta per-changed-record cost must stay within " \
            f"{delta_gate_ratio}x of a dense C-record round: " \
            f"{per_changed * 1e6:.3f}us vs {per_dense * 1e6:.3f}us"
    if headline_budget_s and "cohort" in delta_s:
        assert delta_s["cohort"] < headline_budget_s, \
            f"W={W} cohort delta tick must settle under " \
            f"{headline_budget_s}s: {delta_s['cohort']:.3f}s"

    payload = {"worker_count": W, "active": C, "active_frac": active_frac,
               "chunk_size": k, "rounds": rounds,
               "anchor_s": anchor_s, "delta_s": delta_s,
               "dirty_leaves": dirty,
               "per_changed_us": {p: t / C * 1e6
                                  for p, t in delta_s.items()},
               "per_worker_us": {p: t / W * 1e6
                                 for p, t in delta_s.items()},
               "dense_at_active_s": dense_at_active_s,
               "dense_at_full_s": dense_at_full_s,
               "cpu_count": os.cpu_count()}
    bench_json(json_name, payload)
    # extend the chain-scaling artifact with this W's sparse row (and the
    # dense full-round time when measured) — merge, don't overwrite: the
    # dense sweep owns the other rows
    import json
    import pathlib
    p = pathlib.Path("BENCH_chain_scaling.json")
    data = json.loads(p.read_text()) if p.exists() else {}
    if dense_at_full_s is not None:
        data.setdefault("batch_s", {})[str(W)] = dense_at_full_s
    data.setdefault("sparse_delta_s", {})[str(W)] = delta_s
    data["sparse_active_frac"] = active_frac
    p.write_text(json.dumps(data, indent=1, sort_keys=True) + "\n")
    print(f"merged sparse row into {p}")
    return payload


def run_multi_task_node(worker_count: int = 100_000,
                        task_counts=(1, 2, 4), shards: int = 4,
                        chunk_size: int = 4096, rounds: int = 7,
                        pool_size: int = 0, seed: int = 0,
                        perf_gate: bool = True,
                        json_name: str = "multi_task_node"):
    """Multi-tenant settlement sweep at fixed *total* W: N co-tenant tasks
    (worker_count // N workers each) settle every round into ONE
    multi-task block through the shared shard-worker pool, versus the
    N=1 single-task serial path on the same total record count.

    Claims pinned: (1) determinism — re-driving the same score stream
    seals byte-identical chains (the round-robin cross-task schedule is
    seed-reproducible); (2) per-task super-roots are co-tenancy
    independent — bit-identical to each task settling alone on its own
    ledger; (3) the perf gate (``perf_gate``, skip at smoke W where fixed
    per-task overheads dominate a sub-ms round) — shared-pool multi-task
    settlement throughput at N > 1 never regresses below the N=1 serial
    path: the node re-plans each task's shard fan-out against the pool
    budget, so cross-task parallelism replaces within-task parallelism as
    N grows. Writes ``BENCH_<json_name>.json`` for the perf trajectory."""
    import os

    from repro.chain.contract import TrustContract
    from repro.chain.ledger import Ledger
    from repro.core.node import (ShardWorkerPool, TaskRoundWork,
                                 settle_tasks_block)

    def make_contract(led, tid, Wt):
        c = TrustContract(led, requester_deposit=1e6, worker_stake=10.0,
                          penalty_pct=50.0, trust_threshold=0.5,
                          top_k=max(Wt // 100, 1),
                          merkle_chunk_size=chunk_size,
                          settlement_shards=shards, task_id=tid)
        c.join_batch(Wt)
        return c

    pool = ShardWorkerPool(pool_size or min(shards, os.cpu_count() or 1))
    t_settle, t_record, tput = {}, {}, {}
    try:
        for N in task_counts:
            Wt = worker_count // N           # N*Wt records actually settle
                                             # per tick (exact, not W, when
                                             # N does not divide W)
            tids = [f"task-{i:02d}" for i in range(N)]
            scores = np.random.default_rng(seed).random((rounds, N, Wt))

            def drive():
                led = Ledger()
                cs = {tid: make_contract(led, tid, Wt) for tid in tids}
                times, roots = [], []
                for r in range(rounds):
                    work = [TaskRoundWork(tid, cs[tid], r, scores[r, i])
                            for i, tid in enumerate(tids)]
                    t0 = time.monotonic()
                    blk, _, errors = settle_tasks_block(
                        led, work, timestamp=float(r + 1),
                        pool=pool if N > 1 else None)
                    times.append(time.monotonic() - t0)
                    assert not errors
                    roots.append(led.task_roots(blk.index))
                assert led.verify_chain(deep=True)
                return led, times, roots

            led, times, roots = drive()
            # determinism: the same stream seals byte-identical chains
            led2, times2, _ = drive()
            assert [b.hash for b in led.blocks] \
                == [b.hash for b in led2.blocks], \
                f"multi-task chains must be reproducible (N={N})"
            # steady-state capability: min over both drives' post-warmup
            # rounds — shared 2-vCPU runners show intermittent 3-5x
            # scheduling spikes that a 4-sample median does not absorb
            samples = (times[1:] or times) + (times2[1:] or times2)
            t_settle[N] = float(min(samples))
            t_record[N] = t_settle[N] / (N * Wt)
            tput[N] = 1.0 / t_record[N]
            # per-task commits are co-tenancy independent: spot-check two
            # tasks against standalone single-tenant runs
            for i, tid in enumerate(tids[:2]):
                solo_led = Ledger()
                solo = make_contract(solo_led, tid, Wt)
                for r in range(rounds):
                    solo.settle_round_batch(r, scores[r, i],
                                            timestamp=float(r + 1))
                assert [roots[r][tid] for r in range(rounds)] \
                    == [b.records_root for b in solo_led.blocks[1:]], \
                    f"task {tid} super-roots must be co-tenancy independent"
            csv_row(f"fig3_multi_task_node_w{worker_count}_n{N}",
                    t_settle[N] * 1e6,
                    f"tasks={N} shards={shards} k={chunk_size} "
                    f"records_per_s={tput[N] / 1e6:.2f}M "
                    f"{'shared-pool' if N > 1 else 'serial'}")
    finally:
        pool.stop()
    serial = t_record.get(1)
    if perf_gate and serial is not None:
        for N in task_counts:
            if N > 1:
                # the gate, per settled record (exact for any task_counts):
                # multi-tenancy through the shared pool must not regress
                # below the single-task serial path. The slack absorbs
                # shared-2-vCPU jitter (sporadic 30% drift between the N
                # segments even on min-of-rounds); the failure mode this
                # pins — N·S micro-thunks convoying on the GIL before
                # shard re-planning — measured 1.85-2x, well outside it
                assert t_record[N] < 1.5 * serial, \
                    f"N={N} shared-pool settle must not regress below " \
                    f"the N=1 serial path (per-record): {t_record}"
    bench_json(json_name,
               {"worker_count": worker_count, "rounds": rounds,
                "chunk_size": chunk_size, "shards": shards,
                "settle_s": {f"n{N}": t for N, t in t_settle.items()},
                "records_per_s": {f"n{N}": t for N, t in tput.items()},
                "cpu_count": os.cpu_count()})
    return {"settle_s": t_settle, "records_per_s": tput}


def run_chain_scaling(worker_counts=(1_000, 10_000, 100_000), rounds: int = 3,
                      seed: int = 0):
    """Chain-only settlement sweep: full Algorithm 1 round (vectorized
    BadWorkers/penalties/transfer + Merkle commit + block seal) per W,
    batch path vs the legacy per-worker scalar path.

    The claim pinned here: settlement wall-time grows sub-linearly in
    *Python overhead* — the batch path's interpreter work is O(1) per round
    (the O(W) remainder is vectorized numpy + C hashing), whereas the
    seed's per-worker loop (tx dicts, min(), list appends, W dicts
    canonically hashed into each block — emulated by ``_legacy_settle``)
    pays *rising* interpreter cost per worker. So the batch advantage must
    widen with W, batch per-worker cost must stay in a flat band, and a
    100k-worker round must settle in < 1s on CPU (the legacy path crosses
    1s right around W=100k)."""
    from repro.chain.contract import TrustContract
    from repro.chain.ledger import Ledger

    def _legacy_settle(ledger, r, names, scores, state, F, P, T):
        """Seed-faithful scalar settlement: per-worker score/penalty tx
        dicts appended into the round block."""
        pending = []
        for wid, s in sorted(zip(names, scores.tolist())):
            acct = state[wid]
            acct[2].append(s)
            pending.append({"type": "score", "round": r, "worker": wid,
                            "score": s})
            if s < T:
                pen = min(F * P / 100.0, acct[0])
                acct[0] -= pen
                acct[1] += 1
                pending.append({"type": "penalty", "round": r, "worker": wid,
                                "amount": pen})
        ledger.append_block(pending)

    rng = np.random.default_rng(seed)
    F, P, T = 10.0, 50.0, 0.5
    t_batch, t_legacy, speedup = {}, {}, {}
    for W in worker_counts:
        score_mat = rng.random((rounds, W))
        cb = TrustContract(Ledger(), requester_deposit=1e6, worker_stake=F,
                           penalty_pct=P, trust_threshold=T,
                           top_k=max(W // 100, 1))
        cb.join_batch(W)
        times = []
        for r in range(rounds):
            t0 = time.monotonic()
            cb.settle_round_batch(r, score_mat[r])
            times.append(time.monotonic() - t0)
        t_batch[W] = float(np.median(times))
        assert cb.ledger.verify_chain(deep=True)

        names = [cb.worker_name(i) for i in range(W)]
        state = {n: [F, 0, []] for n in names}
        legacy_ledger = Ledger()
        times = []
        for r in range(rounds):
            t0 = time.monotonic()
            _legacy_settle(legacy_ledger, r, names, score_mat[r], state, F, P,
                           T)
            times.append(time.monotonic() - t0)
        t_legacy[W] = float(np.median(times))
        speedup[W] = t_legacy[W] / t_batch[W]
        # identical Algorithm 1 outcome, loop or vectorized
        np.testing.assert_allclose(
            cb.stake, np.array([state[n][0] for n in names]))
        csv_row(f"fig3_chain_settle_w{W}", t_batch[W] * 1e6,
                f"per_worker_us={t_batch[W] / W * 1e6:.3f} "
                f"vs_legacy={speedup[W]:.1f}x")
    counts = sorted(t_batch)
    lo, hi = counts[0], counts[-1]
    # Python overhead is sub-linear: the gap to the Python-loop legacy path
    # widens with W, and per-worker batch cost stays in a flat band
    assert speedup[hi] > speedup[lo], \
        f"batch advantage must widen with W: {speedup}"
    assert t_batch[hi] / hi < 2.0 * t_batch[lo] / lo, \
        f"per-worker batch cost must stay flat: {t_batch}"
    if hi >= 100_000:
        assert t_batch[hi] < 1.0, \
            f"100k-worker settlement must stay under 1s: {t_batch[hi]:.2f}s"
    csv_row("fig3_chain_settle_scaling", 0.0,
            f"x{hi // lo} workers -> x{t_batch[hi] / t_batch[lo]:.1f} time, "
            f"legacy-path speedup {speedup[lo]:.1f}x -> {speedup[hi]:.1f}x")
    bench_json("chain_scaling",
               {"batch_s": {str(w): t for w, t in t_batch.items()},
                "legacy_s": {str(w): t for w, t in t_legacy.items()},
                "speedup": {str(w): s for w, s in speedup.items()}})
    return {"batch": t_batch, "legacy": t_legacy, "speedup": speedup}


if __name__ == "__main__":
    run_merkle_chunk_sweep()
    run_chain_scaling()
    run_sparse_settlement()
    run_sharded_settlement()
    run_multi_task_node()
    run(rounds=30, samples=2048)
