"""Paper Fig. 3 — scalability: average accuracy vs epoch for 8/16/20
workers. Claim: consistent accuracy trends across worker counts."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, paper_protocol, run_rounds
from repro.data.datasets import make_federated_mnist


def run(rounds: int = 60, samples: int = 4096, seed: int = 0,
        worker_counts=(8, 16, 20)):
    curves = {}
    for W in worker_counts:
        ds = make_federated_mnist(W, samples=samples, seed=seed)
        clusters = 2 if W % 2 == 0 else 1
        proto = paper_protocol(W, clusters=clusters, seed=seed)
        log = run_rounds(proto, ds, rounds, eval_every=max(rounds // 10, 1))
        proto.finalize()
        curves[W] = log
        csv_row(f"fig3_final_accuracy_w{W}", 0.0,
                f"acc={log[-1]['accuracy']:.3f}")
    finals = [curves[W][-1]["accuracy"] for W in worker_counts]
    spread = max(finals) - min(finals)
    csv_row("fig3_accuracy_spread_across_W", 0.0, f"spread={spread:.4f}")
    # scalability claim: all configs converge to a similar band
    assert spread < 0.15, f"accuracy should be consistent across W: {finals}"
    return curves


if __name__ == "__main__":
    run(rounds=30, samples=2048)
