"""CFL baseline (paper §II): centralized federated learning — one global
aggregator, no clusters, no trust weighting, no chain — vs SDFL-B.

The paper argues SDFL-B removes CFL's single point of failure and trust
dependency at comparable learning quality. Measured claims:
  (a) clean data: SDFL-B converges like CFL (no accuracy cost),
  (b) poisoned data: SDFL-B's trust penalization protects accuracy where
      plain CFL degrades.
"""
from __future__ import annotations

import dataclasses


from benchmarks.common import csv_row, paper_protocol, run_rounds
from repro.data.datasets import make_federated_mnist


def _flip(bad):
    def adv(batch, _):
        labels = batch["labels"]
        for w in bad:
            labels = labels.at[w].set(9 - labels[w])
        return {**batch, "labels": labels}
    return adv


def _cfl(W, seed, adversary=None):
    """Plain centralized FedAvg: 1 cluster, uniform weights, no filter."""
    p = paper_protocol(W, clusters=1, blockchain=False, seed=seed,
                       trust_threshold=-1.0, adversary=adversary)
    p.fed = dataclasses.replace(p.fed, soft_trust_weighting=False)
    return p


def run(rounds: int = 50, samples: int = 4096, W: int = 8, seed: int = 0):
    out = {}
    # (a) clean
    for name, mk in (("cfl", lambda a: _cfl(W, seed, a)),
                     ("sdflb", lambda a: paper_protocol(
                         W, clusters=2, seed=seed, trust_threshold=0.2,
                         adversary=a))):
        ds = make_federated_mnist(W, samples=samples, seed=seed)
        proto = mk(None)
        log = run_rounds(proto, ds, rounds, eval_every=rounds)
        proto.finalize()
        out[f"{name}_clean"] = log[-1]["accuracy"]
    # (b) 25% label-flipping adversaries
    bad = list(range(W // 4))
    for name, mk in (("cfl", lambda a: _cfl(W, seed, a)),
                     ("sdflb", lambda a: paper_protocol(
                         W, clusters=2, seed=seed, trust_threshold=0.45,
                         adversary=a))):
        ds = make_federated_mnist(W, samples=samples, seed=seed)
        proto = mk(_flip(bad))
        log = run_rounds(proto, ds, rounds, eval_every=rounds)
        proto.finalize()
        out[f"{name}_poisoned"] = log[-1]["accuracy"]

    csv_row("cfl_clean", 0.0, f"acc={out['cfl_clean']:.3f}")
    csv_row("sdflb_clean", 0.0, f"acc={out['sdflb_clean']:.3f}")
    csv_row("cfl_poisoned", 0.0, f"acc={out['cfl_poisoned']:.3f}")
    csv_row("sdflb_poisoned", 0.0, f"acc={out['sdflb_poisoned']:.3f}")
    # (a): no accuracy cost vs CFL on clean data
    assert out["sdflb_clean"] >= out["cfl_clean"] - 0.05
    # (b): trust penalization beats unprotected CFL under attack
    assert out["sdflb_poisoned"] >= out["cfl_poisoned"] - 0.02
    return out


if __name__ == "__main__":
    run(rounds=25, samples=2048)
