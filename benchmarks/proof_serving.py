"""Chain read path at scale — batched proof serving + light-client QPS
(``BENCH_proof_serving.json``, CI-gated).

Chain-only (no jitted learning): drives the ``repro.serve`` read API
against a live settlement contract at worker counts where the proof
arithmetic is the signal.

Part A — batched multiproof vs independent proofs. One
``get_proofs``/``verify_batch`` round trip for a 1k-worker batch against
1k independent ``settlement_proof``/``verify_settlement`` calls over the
same records. The batch ships each shared Merkle node once and the light
client recomputes each tree level in one framed sha256 pass, so it must
be ≥ ``speedup_floor`` (3×) faster end to end — and ships a small
fraction of the digests.

Part B — sustained reader QPS under live settlement. A writer thread
keeps sealing dense full-population rounds while reader threads (one
``LightClient`` each) loop head-sync → fetch a random batch for the
latest settled round → verify. Readers take no locks (the ledger's
publication-order contract), so the gates are two-sided: verified
proofs/sec ≥ ``qps_floor`` *and* the writer's dense per-record settle
cost stays under the same ``per_record_budget_us`` the async-node bench
gates — serving reads must not tax the write path.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import bench_json, csv_row
from repro.chain.contract import TrustContract
from repro.chain.ledger import Ledger
from repro.serve import (ChainReadServer, LightClient, RoundNotSettled,
                         StaleProofError)


def _contract(W: int) -> TrustContract:
    c = TrustContract(Ledger(), requester_deposit=1e6, worker_stake=10.0,
                      penalty_pct=50.0, trust_threshold=0.5,
                      top_k=max(W // 100, 1), merkle_chunk_size=64)
    c.join_batch(W)
    return c


def run(W: int = 100_000, rounds: int = 4, batch: int = 1_000,
        qps_batch: int = 256, readers: int = 4, duration_s: float = 1.5,
        repeats: int = 5, speedup_floor: float = 3.0,
        qps_floor: float = 2_000.0, per_record_budget_us: float = 5.0,
        seed: int = 0, wall_gates: bool = True,
        json_name: str = "proof_serving"):
    rng = np.random.default_rng(seed)

    # -- Part A: batched fetch+verify vs independent proofs ------------------
    contract = _contract(W)
    for r in range(rounds):
        contract.settle_round_batch(r, rng.random(W),
                                    timestamp=float(r + 1))
    server = ChainReadServer(contracts=contract)
    client = LightClient(server)
    client.sync()
    audit_round = rounds - 1
    wids = np.sort(rng.choice(W, size=batch, replace=False))

    batched_times = []
    for _ in range(repeats):
        t0 = time.monotonic()
        pb = client.fetch_proofs(None, wids, round_index=audit_round)
        assert client.verify_batch(pb)
        batched_times.append(time.monotonic() - t0)
    batched_s = float(np.median(batched_times))

    indep_times = []
    for _ in range(max(repeats // 2, 1)):
        t0 = time.monotonic()
        for w in wids:
            proof = contract.settlement_proof(audit_round, int(w))
            assert contract.verify_settlement(proof)
        indep_times.append(time.monotonic() - t0)
    indep_s = float(np.median(indep_times))

    indep_digests = sum(
        len(contract.settlement_proof(audit_round, int(w))["proof"])
        for w in wids[:64]) * batch // 64
    speedup = indep_s / batched_s
    dedup = indep_digests / max(pb.num_digests, 1)
    csv_row(f"proof_serving_batched_w{W}", batched_s * 1e6,
            f"batch={batch} digests={pb.num_digests} "
            f"per_proof_us={batched_s / batch * 1e6:.2f}")
    csv_row(f"proof_serving_indep_w{W}", indep_s * 1e6,
            f"digests~{indep_digests} speedup={speedup:.1f}x "
            f"digest_dedup={dedup:.0f}x")
    assert speedup >= speedup_floor, \
        (f"batched proof serving only {speedup:.2f}x faster than "
         f"{batch} independent proofs (floor {speedup_floor}x)")

    # -- Part B: reader QPS under concurrent settlement ----------------------
    live = _contract(W)
    live_server = ChainReadServer(contracts=live, max_batch=batch)
    live.settle_round_batch(0, rng.random(W), timestamp=1.0)

    stop = threading.Event()
    writer_times: list = []

    def writer() -> None:
        r = 1
        scores = rng.random(W)
        while not stop.is_set():
            t0 = time.monotonic()
            live.settle_round_batch(r, scores, timestamp=float(r + 1))
            writer_times.append(time.monotonic() - t0)
            r += 1

    verified = np.zeros(readers, np.int64)
    rejected = np.zeros(readers, np.int64)

    def reader(i: int) -> None:
        lc = LightClient(live_server)
        r = np.random.default_rng((seed, i))
        while not stop.is_set():
            lc.sync()
            ids = r.integers(0, W, size=qps_batch)
            try:
                pb = live_server.get_proofs(
                    None, ids, round_index=live_server
                    .latest_settled_round(None))
            except (RoundNotSettled, KeyError):
                continue
            try:
                ok = lc.verify_batch(pb)
            except StaleProofError:     # writer sealed mid-loop: re-anchor
                lc.sync()
                ok = lc.verify_batch(pb)
            if ok:
                verified[i] += qps_batch
            else:
                rejected[i] += qps_batch

    threads = [threading.Thread(target=writer)] + \
        [threading.Thread(target=reader, args=(i,)) for i in range(readers)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    time.sleep(duration_s)
    stop.set()
    for t in threads:
        t.join()
    elapsed = time.monotonic() - t0

    qps = float(verified.sum()) / elapsed
    rounds_sealed = len(writer_times)
    live_per_record_us = (float(np.median(writer_times)) / W * 1e6
                          if writer_times else float("nan"))
    csv_row(f"proof_serving_qps_w{W}", 1e6 / max(qps, 1e-9),
            f"qps={qps:.0f} readers={readers} qps_batch={qps_batch} "
            f"rounds_sealed={rounds_sealed} "
            f"writer_per_record_us={live_per_record_us:.3f}")
    assert rejected.sum() == 0, \
        f"{int(rejected.sum())} honest proofs failed verification"
    if wall_gates:
        assert qps >= qps_floor, \
            (f"reader throughput {qps:.0f} proofs/s under live settlement "
             f"below the {qps_floor:.0f} floor")
        assert rounds_sealed >= 1 and \
            live_per_record_us < per_record_budget_us, \
            (f"write path under reader load: {live_per_record_us:.3f}us "
             f"per record > {per_record_budget_us}us budget "
             f"({rounds_sealed} rounds sealed)")

    payload = {
        "W": W, "rounds": rounds, "batch": batch,
        "batched": {"s": batched_s, "digests": pb.num_digests,
                    "per_proof_us": batched_s / batch * 1e6},
        "independent": {"s": indep_s, "digests_est": indep_digests},
        "live": {"readers": readers, "qps_batch": qps_batch,
                 "duration_s": elapsed, "qps": qps,
                 "proofs_verified": int(verified.sum()),
                 "rounds_sealed_concurrently": rounds_sealed,
                 "writer_per_record_us": live_per_record_us},
        "gates": {
            "batched_speedup": speedup,
            "batched_speedup_floor": speedup_floor,
            "digest_dedup": dedup,
            "qps": qps, "qps_floor": qps_floor,
            "writer_per_record_us": live_per_record_us,
            "per_record_budget_us": per_record_budget_us,
        },
    }
    bench_json(json_name, payload)
    return payload


if __name__ == "__main__":
    run(W=10_000, rounds=3, duration_s=1.0)
